//! Quickstart: the OCP Data Cluster in ~80 lines.
//!
//! Boots an in-memory cluster, registers a dataset, ingests a synthetic
//! EM volume, reads cutouts, writes annotations with RAMON metadata, and
//! runs the spatial + metadata queries of paper §4.2.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ocpd::annotation::{Predicate, PredicateOp, RamonObject, SynapseType};
use ocpd::array::DenseVolume;
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project, WriteDiscipline};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::resolution::Propagator;

fn main() -> ocpd::Result<()> {
    // 1. A cluster: two database nodes, one SSD write node.
    let cluster = Cluster::in_memory(2, 1);

    // 2. A dataset: 512x512x64 voxels, 3 resolution levels (XY halve, Z
    //    fixed — paper §3.1).
    cluster.register_dataset(
        DatasetBuilder::new("demo", [512, 512, 64]).voxel_nm([4.0, 4.0, 40.0]).levels(3).build(),
    );

    // 3. An image project (sharded across database nodes) + synthetic EM.
    let img = cluster.create_image_project(Project::image("demo", "demo"))?;
    let sv = generate(&SynthSpec::small([512, 512, 64], 42));
    ingest_volume(&img, &sv.vol, [256, 256, 16])?;
    println!("ingested {} voxels ({} planted synapses)", sv.vol.len(), sv.synapses.len());

    // 4. Build the resolution hierarchy.
    let built = Propagator::new(&img).propagate_image()?;
    println!("hierarchy: {built} cuboids materialized across levels 1..2");

    // 5. Cutouts: the core service (Table 1 row 1).
    let cut = img.read::<u8>(0, 0, 0, Box3::new([100, 100, 10], [356, 356, 26]))?;
    println!("cutout 256x256x16 @ res 0: mean gray {:.1}", mean(&cut));
    let low = img.read::<u8>(2, 0, 0, Box3::new([0, 0, 0], [128, 128, 64]))?;
    println!("cutout whole volume @ res 2: mean gray {:.1}", mean(&low));

    // 6. An annotation project on the SSD node, with exceptions enabled.
    let anno = cluster.create_annotation_project(
        Project::annotation("demo_anno", "demo").with_exceptions(),
        true,
    )?;

    // 7. Write two overlapping objects with different disciplines.
    let bx = Box3::new([40, 40, 8], [72, 72, 16]);
    let mut vol = DenseVolume::<u32>::zeros(bx.extent());
    vol.fill_box(Box3::new([0, 0, 0], bx.extent()), 1);
    anno.write_volume(0, bx, &vol, WriteDiscipline::Overwrite)?;
    let bx2 = Box3::new([56, 56, 8], [88, 88, 16]);
    let mut vol2 = DenseVolume::<u32>::zeros(bx2.extent());
    vol2.fill_box(Box3::new([0, 0, 0], bx2.extent()), 2);
    let o = anno.write_volume(0, bx2, &vol2, WriteDiscipline::Exception)?;
    println!(
        "overlap write: {} voxels written, {} exceptions",
        o.voxels_written, o.exceptions_added
    );

    // 8. RAMON metadata + the paper's predicate query.
    anno.put_object(RamonObject::synapse(1, 0.97, SynapseType::Excitatory))?;
    anno.put_object(RamonObject::synapse(2, 0.42, SynapseType::Inhibitory))?;
    let hits = anno.query(&[
        Predicate::eq("type", "synapse"),
        Predicate::cmp("confidence", PredicateOp::Geq, 0.9),
    ])?;
    println!("objects/type/synapse/confidence/geq/0.9/ -> {hits:?}");

    // 9. Spatial queries: voxel list, bounding box, dense read.
    println!(
        "object 1: {} voxels, bbox {:?}",
        anno.voxel_list(0, 1)?.len(),
        anno.bounding_box(0, 1)?
    );
    let (dbx, dvol) = anno.dense_read(0, 2, None)?.expect("object 2");
    println!(
        "object 2 dense read: box {:?}..{:?}, {} labeled voxels",
        dbx.lo,
        dbx.hi,
        dvol.count_eq(2)
    );

    // 10. Migrate the annotation project off the SSD node (§4.1).
    let (_, moved) = cluster.migrate_annotation_project("demo_anno")?;
    println!("migrated demo_anno to a database node ({moved} values)");

    for (name, s) in cluster.node_stats() {
        println!("node {name}: {} reads / {} writes", s.reads, s.writes);
    }
    Ok(())
}

fn mean(v: &DenseVolume<u8>) -> f64 {
    v.as_slice().iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64
}
