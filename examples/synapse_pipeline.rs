//! End-to-end driver: the paper's headline workload (§2, Figure 7) on a
//! real (synthetic) volume — proves all three layers compose.
//!
//! 1. Boots a cluster (database nodes + SSD write node).
//! 2. Generates and ingests a synthetic EM volume with planted synapses.
//! 3. Runs the parallel synapse-finding pipeline: cutout (L3) → AOT
//!    detector graph via PJRT (L2/L1) → connected components → batched
//!    RAMON writes to the SSD node.
//! 4. Queries the results through the annotation services and reports
//!    precision/recall (ground truth!) plus the paper's §2 throughput
//!    framing (synapses/sec/instance vs. "19M synapses / 3 days / 20
//!    instances" ≈ 73/s/node with batching).
//! 5. Migrates the finished project to a database node and propagates
//!    annotations up the hierarchy (§3.2/§4.1).
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example synapse_pipeline
//! ```

use std::sync::Arc;

use ocpd::annotation::{Predicate, PredicateOp};
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::resolution::Propagator;
use ocpd::runtime::{artifact_dir, Runtime};
use ocpd::vision::{precision_recall, SynapsePipeline};

fn main() -> ocpd::Result<()> {
    let dims = [512u64, 512, 64];
    let seed = 2013;
    println!("=== ocpd synapse pipeline (E2E) ===");
    println!("volume {dims:?}, seed {seed}");

    // Layer-3 cluster: 2 database nodes (reads) + 1 SSD node (writes).
    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(
        DatasetBuilder::new("synth", dims).voxel_nm([4.0, 4.0, 40.0]).levels(3).build(),
    );
    let img = cluster.create_image_project(Project::image("synth", "synth"))?;
    let anno =
        cluster.create_annotation_project(Project::annotation("synapses_v0", "synth"), true)?;

    // Synthetic EM with ground truth.
    let t0 = std::time::Instant::now();
    let sv = generate(&SynthSpec::small(dims, seed));
    println!(
        "generated {} Mvox with {} planted synapses in {:.1}s",
        sv.vol.len() / 1_000_000,
        sv.synapses.len(),
        t0.elapsed().as_secs_f64()
    );
    let t0 = std::time::Instant::now();
    let bytes = ingest_volume(&img, &sv.vol, [256, 256, 16])?;
    println!(
        "ingested {:.1} MB in {:.1}s ({:.1} MB/s)",
        bytes as f64 / 1e6,
        t0.elapsed().as_secs_f64(),
        bytes as f64 / 1e6 / t0.elapsed().as_secs_f64()
    );

    // Layers 2+1: the AOT-compiled detector through PJRT.
    let runtime = Arc::new(Runtime::load_dir(artifact_dir())?);
    println!("loaded graphs: {:?}", runtime.graphs());

    let mut pipeline = SynapsePipeline::new(runtime, Arc::clone(&img), Arc::clone(&anno));
    pipeline.workers = 4; // the paper ran 20 parallel instances
    let report = pipeline.run(0, Box3::new([0, 0, 0], dims))?;

    println!("--- pipeline report ---");
    println!("blocks processed:   {}", report.blocks);
    println!("detections:         {}", report.detections.len());
    println!("voxels labeled:     {}", report.voxels_labeled);
    println!("wall:               {:.2}s", report.wall_secs);
    println!("cutout read rate:   {:.1} MB/s", report.read_mbps);
    println!(
        "synapse write rate: {:.1} obj/s across {} workers ({:.1} obj/s/worker; paper: 73/s/node)",
        report.objects_per_sec,
        pipeline.workers,
        report.objects_per_sec / pipeline.workers as f64
    );

    let (p, r, m) = precision_recall(&report.detections, &sv.synapses, 6.0);
    println!("--- accuracy vs ground truth (radius 6 vox) ---");
    println!("matches {m} / detections {} / truth {}", report.detections.len(), sv.synapses.len());
    println!("precision {p:.3}  recall {r:.3}");

    // Analysis through the annotation services (§4.2): high-confidence
    // detections, spatial distribution.
    let confident = anno.query(&[
        Predicate::eq("type", "synapse"),
        Predicate::cmp("confidence", PredicateOp::Geq, 0.9),
    ])?;
    println!("high-confidence (>=0.9) detections: {}", confident.len());
    if let Some(&id) = confident.first() {
        let bb = anno.bounding_box(0, id)?.unwrap();
        let voxels = anno.voxel_list(0, id)?;
        println!("example synapse {id}: {} voxels, bbox {:?}..{:?}", voxels.len(), bb.lo, bb.hi);
    }

    // Post-processing: migrate off the SSD node, then build the
    // annotation hierarchy (the order the paper uses, §4.1).
    let (anno, moved) = cluster.migrate_annotation_project("synapses_v0")?;
    println!("migrated project to database node: {moved} values");
    let built = Propagator::new(&anno.cutout).propagate_annotations()?;
    println!("annotation hierarchy: {built} cuboids materialized");
    let low = anno.objects_in_region(
        2,
        Box3::new([0, 0, 0], [dims[0] / 4, dims[1] / 4, dims[2]]),
        Default::default(),
    )?;
    println!("objects visible at res 2: {}", low.len());

    println!("--- node I/O ---");
    for (name, s) in cluster.node_stats() {
        println!(
            "  {name}: reads={} ({:.1} MB) writes={} ({:.1} MB)",
            s.reads,
            s.read_bytes as f64 / 1e6,
            s.writes,
            s.write_bytes as f64 / 1e6
        );
    }

    // E2E sanity: fail loudly if the detector did not actually work.
    assert!(r > 0.7, "recall {r} too low — detector regression");
    assert!(p > 0.7, "precision {p} too low — detector regression");
    println!("E2E OK");
    Ok(())
}
