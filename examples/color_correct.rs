//! Color correction (§3.4, Figure 6): remove per-section exposure
//! differences from a serial-section stack using the AOT-compiled
//! gradient-domain graph (Jacobi diffusion kernels at Layer 1).
//!
//! Generates a stack with strong alternating exposure, streams it through
//! `color_correct` into a "cleaned" project, and reports the per-section
//! mean variance before/after — the quantitative version of Figure 6.
//!
//! ```sh
//! make artifacts && cargo run --release --example color_correct
//! ```

use std::sync::Arc;

use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::runtime::{artifact_dir, Runtime};
use ocpd::vision::color_correct_volume;

fn main() -> ocpd::Result<()> {
    let dims = [512u64, 512, 32];
    let cluster = Cluster::in_memory(2, 0);
    cluster.register_dataset(DatasetBuilder::new("striped", dims).levels(1).build());
    let raw = cluster.create_image_project(Project::image("striped", "striped"))?;
    let clean = cluster.create_image_project(Project::image("striped_clean", "striped"))?;

    // A volume with severe exposure striping (±30 gray levels between
    // adjacent sections — the Figure 6 pathology).
    let sv = generate(&SynthSpec::small(dims, 99).with_exposure(60.0));
    ingest_volume(&raw, &sv.vol, [256, 256, 16])?;

    let runtime = Arc::new(Runtime::load_dir(artifact_dir())?);
    let t0 = std::time::Instant::now();
    let blocks = color_correct_volume(&runtime, &raw, &clean, 0)?;
    println!(
        "color-corrected {blocks} blocks of {}x{}x{} in {:.1}s",
        256, 256, 32,
        t0.elapsed().as_secs_f64()
    );

    // Quantify: variance of per-section means, before and after.
    let whole = Box3::new([0, 0, 0], dims);
    let before = raw.read::<u8>(0, 0, 0, whole)?;
    let after = clean.read::<u8>(0, 0, 0, whole)?;
    let section_means = |v: &ocpd::array::DenseVolume<u8>| -> Vec<f64> {
        (0..dims[2])
            .map(|z| {
                let mut s = 0u64;
                for y in 0..dims[1] {
                    for x in 0..dims[0] {
                        s += v.get([x, y, z]) as u64;
                    }
                }
                s as f64 / (dims[0] * dims[1]) as f64
            })
            .collect()
    };
    let var = |xs: &[f64]| {
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
    };
    let (vb, va) = (var(&section_means(&before)), var(&section_means(&after)));
    println!("per-section mean variance: before {vb:.1}, after {va:.1} ({:.1}x reduction)", vb / va);

    // In-section contrast must be preserved (high frequencies added back).
    let contrast = |v: &ocpd::array::DenseVolume<u8>, z: u64| {
        let mut s = 0.0;
        let mut s2 = 0.0;
        let n = (dims[0] * dims[1]) as f64;
        for y in 0..dims[1] {
            for x in 0..dims[0] {
                let g = v.get([x, y, z]) as f64;
                s += g;
                s2 += g * g;
            }
        }
        (s2 / n - (s / n) * (s / n)).sqrt()
    };
    println!(
        "in-section contrast (z=5): before {:.1}, after {:.1}",
        contrast(&before, 5),
        contrast(&after, 5)
    );

    assert!(va < vb * 0.5, "exposure variance must at least halve ({vb:.1} -> {va:.1})");
    println!("color correction OK");
    Ok(())
}
