//! Spatial analysis: the kasthuri11 use case (§2, §4.2).
//!
//! The paper's analysis: "(1) using metadata to get the identifiers of
//! all synapses that connect to the specified dendrite and then (2)
//! querying the spatial extent of the synapses and dendrite to compute
//! distances" — plus the dense-vs-voxel-list transfer tradeoff it
//! discusses for sparse neural objects (dendrite 13: 8M voxels in a 1.9T
//! voxel bounding box, <0.4% occupancy).
//!
//! We build a miniature kasthuri11-like annotation database: a dendrite
//! traced across the volume, synapses attached to its spines, RAMON links
//! between them, then run the paper's queries and report the
//! distance distribution and the transfer-size comparison.
//!
//! ```sh
//! cargo run --release --example spatial_analysis
//! ```

use ocpd::annotation::{Predicate, RamonObject, SynapseType};
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project, Vec3, WriteDiscipline};
use ocpd::util::Rng;
use ocpd::web::ocpk;

fn main() -> ocpd::Result<()> {
    let dims = [1024u64, 1024, 128];
    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(
        DatasetBuilder::new("kasthuri_mini", dims).voxel_nm([3.0, 3.0, 30.0]).levels(3).build(),
    );
    let anno = cluster.create_annotation_project(
        Project::annotation("kasthuri_ann", "kasthuri_mini"),
        false,
    )?;
    let mut rng = Rng::new(11);

    // --- Build the scene -------------------------------------------------
    // Dendrite 13: a long skinny object spanning the volume in X.
    const DENDRITE: u32 = 13;
    let mut dendrite_voxels: Vec<Vec3> = Vec::new();
    let mut y = 500.0f64;
    let mut z = 60.0f64;
    for x in 0..dims[0] {
        y += rng.normal() * 0.8;
        z += rng.normal() * 0.2;
        let (yc, zc) = (y.clamp(8.0, 1015.0) as u64, z.clamp(4.0, 123.0) as u64);
        // 3x3x1 shaft cross-section.
        for dy in 0..3 {
            for dz in 0..2 {
                dendrite_voxels.push([x, yc + dy, zc + dz]);
            }
        }
    }
    anno.write_voxels(0, DENDRITE, &dendrite_voxels, WriteDiscipline::Overwrite)?;
    let mut dend = RamonObject::segment(DENDRITE, 1);
    dend.author = "manual-tracer".into();
    anno.put_object(dend)?;

    // Synapses: attached near the dendrite (spine heads) + background
    // synapses elsewhere.
    let mut attached = Vec::new();
    for i in 0..60u32 {
        let t = rng.below(dendrite_voxels.len() as u64) as usize;
        let base = dendrite_voxels[t];
        // Spine: a few voxels off the shaft.
        let off = [
            base[0],
            base[1] + 3 + rng.below(8),
            (base[2] + rng.below(3)).min(dims[2] - 4),
        ];
        let id = 100 + i;
        write_blob(&anno, id, off, 2)?;
        let mut s = RamonObject::synapse(id, 0.9 + 0.1 * rng.f32(), SynapseType::Excitatory);
        s.segments = vec![(0, DENDRITE)]; // postsynaptic target: dendrite 13
        s.position = off;
        anno.put_object(s)?;
        attached.push(id);
    }
    for i in 0..40u32 {
        let id = 500 + i;
        let pos = [rng.below(dims[0] - 8), rng.below(dims[1] - 8), rng.below(dims[2] - 4)];
        write_blob(&anno, id, pos, 2)?;
        let mut s = RamonObject::synapse(id, 0.5 + 0.4 * rng.f32(), SynapseType::Inhibitory);
        s.position = pos;
        anno.put_object(s)?;
    }
    println!("scene: dendrite {DENDRITE} ({} voxels), 60 attached + 40 background synapses", dendrite_voxels.len());

    // --- Query 1: metadata — synapses connected to dendrite 13 ----------
    let synapse_ids = anno.query(&[Predicate::eq("type", "synapse")])?;
    let connected: Vec<u32> = synapse_ids
        .iter()
        .copied()
        .filter(|&id| {
            anno.get_object(id)
                .map(|o| o.segments.iter().any(|&(_, post)| post == DENDRITE))
                .unwrap_or(false)
        })
        .collect();
    println!("synapses connected to dendrite {DENDRITE}: {}", connected.len());
    assert_eq!(connected.len(), 60);

    // --- Query 2: spatial extent + distance distribution ----------------
    let dend_bb = anno.bounding_box(0, DENDRITE)?.unwrap();
    println!(
        "dendrite bbox {:?}..{:?} ({} voxels of {} = {:.3}% occupancy)",
        dend_bb.lo,
        dend_bb.hi,
        dendrite_voxels.len(),
        dend_bb.volume(),
        100.0 * dendrite_voxels.len() as f64 / dend_bb.volume() as f64
    );
    let mut distances: Vec<f64> = Vec::new();
    for &id in &connected {
        let syn_bb = anno.bounding_box(0, id)?.unwrap();
        distances.push(syn_bb.center_distance(&dend_bb_nearest(&anno, id, &dendrite_voxels)?));
        let _ = syn_bb;
    }
    distances.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| distances[((p / 100.0) * (distances.len() - 1) as f64) as usize];
    println!("spine length distribution (voxels): p10={:.1} p50={:.1} p90={:.1} max={:.1}",
        pct(10.0), pct(50.0), pct(90.0), distances.last().unwrap());

    // --- Dense vs voxel-list transfer (§4.2) -----------------------------
    let voxels = anno.voxel_list(0, DENDRITE)?;
    let sparse_frame = ocpk::encode_voxels(&voxels);
    let (bx, dense) = anno.dense_read(0, DENDRITE, None)?.unwrap();
    let dense_frame = ocpk::encode_volume(ocpd::core::Dtype::U32, bx.lo, &dense)?;
    println!("--- transfer comparison for the dendrite (long + sparse) ---");
    println!("voxel-list frame: {:>10} bytes", sparse_frame.len());
    println!("dense cutout frame: {:>8} bytes (gzip'd labels)", dense_frame.len());
    // And for a compact synapse the dense frame wins or ties.
    let (sbx, sdense) = anno.dense_read(0, connected[0], None)?.unwrap();
    let s_sparse = ocpk::encode_voxels(&anno.voxel_list(0, connected[0])?);
    let s_dense = ocpk::encode_volume(ocpd::core::Dtype::U32, sbx.lo, &sdense)?;
    println!("--- transfer comparison for a synapse (compact + dense) ---");
    println!("voxel-list frame: {:>10} bytes", s_sparse.len());
    println!("dense cutout frame: {:>8} bytes", s_dense.len());

    // --- Region query: what objects share space with the dendrite? ------
    let mid = Box3::new([480, 400, 40], [544, 640, 90]);
    let in_region = anno.objects_in_region(0, mid, Default::default())?;
    println!("objects intersecting region {:?}..{:?}: {:?}", mid.lo, mid.hi, in_region.len());

    println!("spatial analysis OK");
    Ok(())
}

/// Nearest dendrite voxel as a degenerate box (distance anchor).
fn dend_bb_nearest(
    anno: &ocpd::annotation::AnnotationDb,
    syn_id: u32,
    dendrite: &[Vec3],
) -> ocpd::Result<Box3> {
    let sb = anno.bounding_box(0, syn_id)?.unwrap();
    let c = [(sb.lo[0] + sb.hi[0]) / 2, (sb.lo[1] + sb.hi[1]) / 2, (sb.lo[2] + sb.hi[2]) / 2];
    let nearest = dendrite
        .iter()
        .min_by_key(|v| {
            let dx = v[0].abs_diff(c[0]);
            let dy = v[1].abs_diff(c[1]);
            let dz = v[2].abs_diff(c[2]) * 10; // anisotropy
            dx * dx + dy * dy + dz * dz
        })
        .unwrap();
    Ok(Box3::new(*nearest, [nearest[0] + 1, nearest[1] + 1, nearest[2] + 1]))
}

/// Paint a small cubic blob annotation.
fn write_blob(
    anno: &ocpd::annotation::AnnotationDb,
    id: u32,
    at: Vec3,
    r: u64,
) -> ocpd::Result<()> {
    let mut voxels = Vec::new();
    for z in 0..r {
        for y in 0..2 * r {
            for x in 0..2 * r {
                voxels.push([at[0] + x, at[1] + y, at[2] + z]);
            }
        }
    }
    anno.write_voxels(0, id, &voxels, WriteDiscipline::Overwrite)?;
    Ok(())
}
