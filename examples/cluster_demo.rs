//! Cluster demo: the full Figure 7 topology over real HTTP.
//!
//! Boots a sharded cluster, serves the Table 1 REST interface, then
//! exercises it as remote clients would: cutouts over the wire, CATMAID
//! tile fetches (stored layout + prefetch cache), annotation uploads with
//! write disciplines, predicate queries, and batch metadata reads.
//!
//! ```sh
//! cargo run --release --example cluster_demo
//! ```

use ocpd::annotation::{RamonObject, SynapseType};
use ocpd::array::DenseVolume;
use ocpd::client::{cluster_info, OcpClient};
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project, WriteDiscipline};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};

fn main() -> ocpd::Result<()> {
    // --- Server side -----------------------------------------------------
    let dims = [512u64, 512, 32];
    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(DatasetBuilder::new("bock_mini", dims).levels(2).build());
    let img = cluster.create_image_project(Project::image("bock_mini", "bock_mini"))?;
    cluster.create_annotation_project(
        Project::annotation("bock_ann", "bock_mini").with_exceptions(),
        true,
    )?;
    let sv = generate(&SynthSpec::small(dims, 7));
    ingest_volume(&img, &sv.vol, [256, 256, 16])?;

    let server = ocpd::web::serve(std::sync::Arc::clone(&cluster), None, "127.0.0.1:0", 8)?;
    println!("serving at {}", server.url());
    println!("{}", cluster_info(&server.url())?);

    // --- Remote clients ---------------------------------------------------
    let image_client = OcpClient::new(&server.url(), "bock_mini");
    let anno_client = OcpClient::new(&server.url(), "bock_ann");

    // Cutout over the wire (Table 1 row 1) and verify against the source.
    let bx = Box3::new([64, 64, 4], [192, 192, 20]);
    let cut = image_client.cutout_u8(0, bx)?;
    assert_eq!(cut, sv.vol.extract_box(bx));
    println!("HTTP cutout {:?}: verified {} voxels", bx.extent(), cut.len());

    // CATMAID tile fetches — stored layout r/z/y_x (§3.3).
    let t0 = std::time::Instant::now();
    let tile = image_client.tile(0, 8, 0, 0)?;
    let cold = t0.elapsed();
    let t0 = std::time::Instant::now();
    let tile2 = image_client.tile(0, 8, 0, 1)?; // prefetched neighbour
    let warm = t0.elapsed();
    println!(
        "tiles: {} bytes each; cold fetch {:?}, neighbour (prefetched) {:?}",
        tile.len(),
        cold,
        warm
    );
    assert_eq!(tile.len(), 256 * 256);
    assert_eq!(tile2.len(), 256 * 256);

    // Annotation upload with disciplines (Table 1 "Write an annotation").
    let abx = Box3::new([100, 100, 8], [164, 164, 16]);
    let mut labels = DenseVolume::<u32>::zeros(abx.extent());
    labels.fill_box(Box3::new([0, 0, 0], [32, 64, 8]), 1);
    labels.fill_box(Box3::new([32, 0, 0], [64, 64, 8]), 2);
    let resp = anno_client.write_annotation(0, abx.lo, &labels, WriteDiscipline::Overwrite)?;
    println!("annotation write: {resp}");

    // Overlapping exception write.
    let mut overlay = DenseVolume::<u32>::zeros(abx.extent());
    overlay.fill_box(Box3::new([16, 0, 0], [48, 64, 8]), 3);
    let resp = anno_client.write_annotation(0, abx.lo, &overlay, WriteDiscipline::Exception)?;
    println!("exception write: {resp}");

    // RAMON metadata batch write + predicate query (Table 1 last row).
    let objs: Vec<RamonObject> = (1..=3u32)
        .map(|id| RamonObject::synapse(id, 0.5 + 0.15 * id as f32, SynapseType::Excitatory))
        .collect();
    let ids = anno_client.put_objects(&objs)?;
    println!("wrote RAMON objects {ids:?}");
    let hits = anno_client.query(&["type", "synapse", "confidence", "geq", "0.9"])?;
    println!("objects/type/synapse/confidence/geq/0.9/ -> {hits:?}");
    assert_eq!(hits, vec![3]);

    // Batch metadata read + spatial reads over the wire.
    let got = anno_client.get_objects(&[1, 2, 3])?;
    println!("batch read {} objects", got.len());
    let bb = anno_client.bounding_box(1)?;
    println!("object 1 bbox: {:?}..{:?}", bb.lo, bb.hi);
    let voxels = anno_client.voxels(3)?;
    println!("object 3 (exception-labeled): {} voxels via voxel-list", voxels.len());
    assert!(!voxels.is_empty(), "exception voxels must be readable");
    let (obx, ovol) = anno_client.object_cutout(2, None)?;
    println!("object 2 dense read: {:?} box, {} labeled", obx.extent(), ovol.count_eq(2));

    // Annotation cutout (u32) over the wire.
    let acut = anno_client.cutout_u32(0, abx)?;
    assert_eq!(acut.count_eq(1), 32 * 64 * 8);
    println!("annotation cutout verified");

    println!("requests served: {}", server.requests.get());
    println!(
        "server latency: mean {:.1}ms p90 {:.1}ms",
        server.latency.mean_us() / 1000.0,
        server.latency.percentile_us(90.0) as f64 / 1000.0
    );
    println!("cluster demo OK");
    Ok(())
}
