"""Layer-2 JAX compute graphs for the OCP vision workloads.

Three graphs, each AOT-lowered to HLO text by aot.py and executed from the
Rust coordinator via PJRT (Python never runs on the request path):

* ``synapse_detector`` — the parallel synapse-finding workload of §2/§4:
  Gaussian smoothing, difference-of-Gaussians band-pass (synapses are
  compact bright blobs of a characteristic scale), logistic squashing to a
  probability map. Rust thresholds + connected-components the output and
  writes RAMON synapses.
* ``color_correct`` — §3.4's gradient-domain exposure correction: separate
  the stack into low/high frequencies, diffuse the low frequencies across
  sections (where exposure differences live), add the high frequencies
  back to preserve edges.
* ``downsample2x`` — one XY-halving step of the resolution hierarchy
  (§3.1), used by the hierarchy builder.

AXIS CONVENTION: arrays are ``[Z, Y, X]`` — row-major with X fastest,
which is exactly the memory order of the Rust ``DenseVolume`` (x-fastest),
so buffers cross the PJRT bridge with zero copies.

Block geometry (static AOT shapes, [Z, Y, X]):
  synapse_detector : f32[20,144,144] -> f32[16,128,128]
      (one flat cuboid of core plus a 2/8/8 halo; the halo absorbs both
       filter support and the kernels' circular-shift edge effects)
  color_correct    : f32[32,256,256] -> f32[32,256,256]
  downsample2x     : f32[16,128,128] -> f32[16,64,64]
"""

import jax
import jax.numpy as jnp

from compile import kernels

# Detector geometry [Z, Y, X]: core block (one flat cuboid) + halo. The
# halo must exceed the composed filter radius (XY: 3 passes x radius 2 =
# 6 < 8; Z: 3 passes x radius 1 = 3 < 4) so circular-shift wraparound
# never reaches the core.
CORE = (16, 128, 128)
HALO = (4, 8, 8)
DET_IN = tuple(c + 2 * h for c, h in zip(CORE, HALO))

# Binomial (Gaussian-approximating) taps. sigma ~ sqrt(n)/2.
GAUSS_XY = (1 / 16, 4 / 16, 6 / 16, 4 / 16, 1 / 16)
GAUSS_Z = (1 / 4, 2 / 4, 1 / 4)

# Logistic squash parameters, tuned on the synthetic EM generator
# (rust/src/ingest): a planted synapse (amp ~0.43 of full scale, sigma
# ~(1,2,2) vox) produces a DoG peak ~0.13; dendrite/vessel edges and
# sensor noise stay below ~0.04. The bias sits between; the gain makes
# the logistic crisp. The Rust pipeline applies its own decision
# threshold on top.
DOG_GAIN = 120.0
DOG_BIAS = 0.07

CC_SHAPE = (32, 256, 256)
CC_XY_STEPS = 6  # in-section smoothing to isolate low frequencies
CC_Z_STEPS = 12  # cross-section diffusion of exposure
DS_IN = (16, 128, 128)


def synapse_detector(x):
    """f32[20,144,144] haloed block -> f32[16,128,128] synapse probability.

    DoG = G_narrow(x) - G_wide(x): positive on bright blobs at the synapse
    scale, ~0 on flat background and on structures much larger than the
    filter (dendrite shafts, vessels).
    """
    assert x.shape == DET_IN, x.shape
    narrow = kernels.sepconv3d(x, GAUSS_XY, GAUSS_Z)
    # Wider Gaussian by composing the same taps twice more (binomial
    # composition: variance adds).
    wide = kernels.sepconv3d(narrow, GAUSS_XY, GAUSS_Z)
    wide = kernels.sepconv3d(wide, GAUSS_XY, GAUSS_Z)
    dog = narrow - wide
    core = dog[
        HALO[0] : HALO[0] + CORE[0],
        HALO[1] : HALO[1] + CORE[1],
        HALO[2] : HALO[2] + CORE[2],
    ]
    return (jax.nn.sigmoid(DOG_GAIN * (core - DOG_BIAS)),)


def color_correct(x):
    """f32[32,256,256] stack -> exposure-corrected stack (§3.4).

    low  = in-section diffusion of x          (low-frequency content)
    high = x - low                            (edges and texture)
    lowz = cross-section diffusion of low     (smooths exposure steps)
    out  = clip(lowz + high)
    """
    assert x.shape == CC_SHAPE, x.shape
    low = x
    for _ in range(CC_XY_STEPS):
        low = kernels.diffuse_xy(low, alpha=0.9)
    high = x - low
    lowz = low
    for _ in range(CC_Z_STEPS):
        lowz = kernels.diffuse_z(lowz, alpha=0.9)
    return (jnp.clip(lowz + high, 0.0, 1.0),)


def downsample2x(x):
    """f32[16,128,128] -> f32[16,64,64]: one hierarchy level step."""
    assert x.shape == DS_IN, x.shape
    return (kernels.downsample2x_xy(x),)


# ---------------------------------------------------------------------
# Pure-jnp reference models (oracles for python/tests/test_models.py and
# documentation of intent — independent of the Pallas layer).
# ---------------------------------------------------------------------

from compile.kernels import ref as _ref  # noqa: E402


def synapse_detector_ref(x):
    narrow = _ref.sepconv3d_ref(x, GAUSS_XY, GAUSS_Z)
    wide = _ref.sepconv3d_ref(
        _ref.sepconv3d_ref(narrow, GAUSS_XY, GAUSS_Z), GAUSS_XY, GAUSS_Z
    )
    dog = narrow - wide
    core = dog[
        HALO[0] : HALO[0] + CORE[0],
        HALO[1] : HALO[1] + CORE[1],
        HALO[2] : HALO[2] + CORE[2],
    ]
    return jax.nn.sigmoid(DOG_GAIN * (core - DOG_BIAS))


def color_correct_ref(x):
    low = x
    for _ in range(CC_XY_STEPS):
        low = _ref.diffuse_xy_ref(low, alpha=0.9)
    high = x - low
    lowz = low
    for _ in range(CC_Z_STEPS):
        lowz = _ref.diffuse_z_ref(lowz, alpha=0.9)
    return jnp.clip(lowz + high, 0.0, 1.0)
