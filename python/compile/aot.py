"""AOT lowering: JAX/Pallas compute graphs -> HLO text artifacts.

The interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out ../artifacts

Produces one ``<name>.hlo.txt`` per graph plus ``manifest.txt`` recording
the input/output shapes the Rust runtime expects. ``make artifacts`` runs
this exactly once; the Rust binary is self-contained afterwards.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """Lower a jitted computation to XLA HLO text (return_tuple=True; the
    Rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


GRAPHS = {
    "synapse_detector": (model.synapse_detector, [model.DET_IN], [model.CORE]),
    "color_correct": (model.color_correct, [model.CC_SHAPE], [model.CC_SHAPE]),
    "downsample2x": (
        model.downsample2x,
        [model.DS_IN],
        [(model.DS_IN[0], model.DS_IN[1] // 2, model.DS_IN[2] // 2)],
    ),
}


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    written = {}
    for name, (fn, in_shapes, out_shapes) in GRAPHS.items():
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in in_shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            f"{name} in={';'.join(map(str, in_shapes))} "
            f"out={';'.join(map(str, out_shapes))} dtype=f32"
        )
        written[name] = path
        print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    return written


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    print(f"AOT-lowering {len(GRAPHS)} graphs to {args.out}")
    lower_all(args.out)
    print("done")


if __name__ == "__main__":
    main()
