"""Build-time Python package: Layer-2 JAX models, Layer-1 Pallas kernels,
and the AOT lowering entry point. Never imported at runtime — `make
artifacts` runs once and the Rust coordinator loads the HLO text."""
