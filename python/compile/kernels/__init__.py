"""Layer-1 Pallas kernels for the OCP vision compute graphs.

Every kernel is authored with ``interpret=True`` — the CPU PJRT plugin
cannot run Mosaic custom-calls, so interpret mode is both the correctness
path and what gets AOT-lowered into the artifacts (see aot_recipe gotchas).
On a real TPU the same ``pallas_call`` bodies lower to Mosaic; the tiling
choices (cuboid-shaped blocks) are discussed in DESIGN.md §2.
"""

from compile.kernels.conv3d import sepconv3d
from compile.kernels.downsample import downsample2x_xy
from compile.kernels.jacobi import diffuse_xy, diffuse_z

__all__ = ["sepconv3d", "downsample2x_xy", "diffuse_xy", "diffuse_z"]
