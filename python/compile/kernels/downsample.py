"""2x2 XY mean-pool as a Pallas kernel — the resolution-hierarchy builder.

The paper's hierarchy halves X and Y but never Z (§3.1); this kernel is
the compute for one level step. Arrays are ``[Z, Y, X]`` (see conv3d.py).
The grid iterates over Z sections with cuboid-plane-shaped blocks: input
blocks ``(1, Y, X)``, output blocks ``(1, Y/2, X/2)`` — an example of
asymmetric in/out BlockSpecs.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _down_kernel(x_ref, o_ref):
    v = x_ref[...]  # (1, Y, X)
    o_ref[...] = 0.25 * (
        v[:, 0::2, 0::2] + v[:, 1::2, 0::2] + v[:, 0::2, 1::2] + v[:, 1::2, 1::2]
    )


def downsample2x_xy(x):
    """Mean-pool 2x2 in XY, preserving Z. f32[Z,Y,X] -> f32[Z,Y/2,X/2]."""
    Z, Y, X = x.shape
    assert X % 2 == 0 and Y % 2 == 0, f"even XY required, got {x.shape}"
    return pl.pallas_call(
        _down_kernel,
        grid=(Z,),
        in_specs=[pl.BlockSpec((1, Y, X), lambda z: (z, 0, 0))],
        out_specs=pl.BlockSpec((1, Y // 2, X // 2), lambda z: (z, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Z, Y // 2, X // 2), x.dtype),
        interpret=True,
    )(x)
