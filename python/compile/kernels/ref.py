"""Pure-jnp oracles for every Pallas kernel — the build-time correctness
signal. pytest asserts kernel == ref to float tolerance across
hypothesis-generated shapes (python/tests/test_kernels.py).

Arrays are [Z, Y, X] throughout (see conv3d.py)."""

import jax.numpy as jnp


def conv_axis_ref(v, taps, axis):
    """Circular correlation with `taps` along `axis` (matches the kernel's
    roll-based edge semantics)."""
    r = len(taps) // 2
    acc = jnp.zeros_like(v)
    for i, t in enumerate(taps):
        acc = acc + float(t) * jnp.roll(v, r - i, axis=axis)
    return acc


def sepconv3d_ref(x, taps_xy, taps_z):
    v = conv_axis_ref(x, taps_xy, axis=1)  # Y
    v = conv_axis_ref(v, taps_xy, axis=2)  # X
    return conv_axis_ref(v, taps_z, axis=0)  # Z


def downsample2x_xy_ref(x):
    return 0.25 * (
        x[:, 0::2, 0::2] + x[:, 1::2, 0::2] + x[:, 0::2, 1::2] + x[:, 1::2, 1::2]
    )


def diffuse_xy_ref(x, alpha=0.8):
    n = (
        jnp.roll(x, 1, axis=1)
        + jnp.roll(x, -1, axis=1)
        + jnp.roll(x, 1, axis=2)
        + jnp.roll(x, -1, axis=2)
    ) * 0.25
    return (1.0 - alpha) * x + alpha * n


def diffuse_z_ref(x, alpha=0.8):
    n = (jnp.roll(x, 1, axis=0) + jnp.roll(x, -1, axis=0)) * 0.5
    return (1.0 - alpha) * x + alpha * n
