"""Separable 3-d convolution as a Pallas kernel.

The synapse detector's hot loop: Gaussian / difference-of-Gaussian
filtering of an EM cutout block. The convolution is separable — one pass
of taps per axis — and the whole block lives in one kernel invocation so
the three passes fuse without materializing intermediates in HBM.

AXIS CONVENTION: arrays are ``[Z, Y, X]`` (row-major, X fastest) — the
exact memory order of the Rust coordinator's ``DenseVolume`` (x-fastest),
so the PJRT bridge is a zero-copy relabeling. ``taps_xy`` filters the Y
and X axes (1, 2); ``taps_z`` filters the section axis (0).

Tiling: one grid step processes one Z-slab of shape ``(zb, Y, X)``.
The X/Y taps only need data within the slab; the Z pass runs in a second
``pallas_call`` over the full depth. Block shapes match the cuboid
geometry (flat ``16x128x128`` blocks at high resolution) so one grid step
consumes one cuboid — DESIGN.md §2.

Edge semantics: circular shifts (``jnp.roll``); callers pad the input
with a halo of at least ``len(taps)//2`` voxels per axis and discard the
halo afterwards, so wraparound never reaches valid output.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_axis(v, taps, axis):
    """Correlate `v` with `taps` along `axis` using circular shifts."""
    r = len(taps) // 2
    acc = jnp.zeros_like(v)
    for i, t in enumerate(taps):
        acc = acc + t * jnp.roll(v, r - i, axis=axis)
    return acc


def _sepconv_xy_kernel(x_ref, o_ref, *, taps_xy):
    v = x_ref[...]
    v = _conv_axis(v, taps_xy, axis=1)  # Y
    v = _conv_axis(v, taps_xy, axis=2)  # X
    o_ref[...] = v


def _conv_z_kernel(x_ref, o_ref, *, taps_z):
    o_ref[...] = _conv_axis(x_ref[...], taps_z, axis=0)  # Z


def sepconv3d(x, taps_xy, taps_z, *, z_block=None):
    """Separable 3-d convolution: `taps_xy` along Y and X, `taps_z` along Z.

    Args:
      x: f32[Z, Y, X] input block (caller pads a halo; roll wraparound only
        touches the halo).
      taps_xy / taps_z: odd-length tuples of python floats (compile-time
        constants, baked into the kernel).
      z_block: Z-slab thickness per grid step (default: whole depth).

    Returns f32[Z, Y, X].
    """
    Z, Y, X = x.shape
    taps_xy = tuple(float(t) for t in taps_xy)
    taps_z = tuple(float(t) for t in taps_z)
    assert len(taps_xy) % 2 == 1 and len(taps_z) % 2 == 1, "taps must be odd-length"
    zb = Z if z_block is None else z_block
    assert Z % zb == 0, f"z_block {zb} must divide Z {Z}"

    # Pass 1+2: Y and X taps, tiled over Z-slabs (cuboid-shaped blocks).
    xy = pl.pallas_call(
        functools.partial(_sepconv_xy_kernel, taps_xy=taps_xy),
        grid=(Z // zb,),
        in_specs=[pl.BlockSpec((zb, Y, X), lambda z: (z, 0, 0))],
        out_specs=pl.BlockSpec((zb, Y, X), lambda z: (z, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Z, Y, X), x.dtype),
        interpret=True,
    )(x)

    # Pass 3: Z taps over the full depth (single block: depth is small for
    # flat cuboids).
    return pl.pallas_call(
        functools.partial(_conv_z_kernel, taps_z=taps_z),
        out_shape=jax.ShapeDtypeStruct((Z, Y, X), x.dtype),
        interpret=True,
    )(xy)
