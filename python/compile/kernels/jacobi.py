"""Jacobi relaxation steps as Pallas kernels — the gradient-domain color
correction of §3.4 (after Kazhdan & Hoppe [18]).

The paper color-corrects EM stacks by solving a global Poisson equation
that smooths low-frequency exposure differences between serial sections
while high frequencies are added back. The relaxation primitive here is a
damped Jacobi step of the heat equation:

    u' = (1 - a) * u + a * mean(neighbours)

Arrays are ``[Z, Y, X]`` (see conv3d.py). ``diffuse_xy`` relaxes within
each section (5-point stencil over Y/X, one grid step per section);
``diffuse_z`` relaxes across sections (3-point stencil along axis 0),
which is where inter-slice exposure differences actually live. L2
composes K steps of each around high-frequency add-back
(model.color_correct).

Edge semantics: circular shifts; callers either pad or accept periodic
boundaries on the block border (acceptable for the low-frequency field).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xy_kernel(x_ref, o_ref, *, alpha):
    v = x_ref[...]  # (1, Y, X)
    n = (
        jnp.roll(v, 1, axis=1)
        + jnp.roll(v, -1, axis=1)
        + jnp.roll(v, 1, axis=2)
        + jnp.roll(v, -1, axis=2)
    ) * 0.25
    o_ref[...] = (1.0 - alpha) * v + alpha * n


def _z_kernel(x_ref, o_ref, *, alpha):
    v = x_ref[...]
    n = (jnp.roll(v, 1, axis=0) + jnp.roll(v, -1, axis=0)) * 0.5
    o_ref[...] = (1.0 - alpha) * v + alpha * n


def diffuse_xy(x, alpha=0.8):
    """One damped-Jacobi diffusion step within each section (Y/X axes)."""
    Z, Y, X = x.shape
    return pl.pallas_call(
        functools.partial(_xy_kernel, alpha=float(alpha)),
        grid=(Z,),
        in_specs=[pl.BlockSpec((1, Y, X), lambda z: (z, 0, 0))],
        out_specs=pl.BlockSpec((1, Y, X), lambda z: (z, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def diffuse_z(x, alpha=0.8):
    """One damped-Jacobi diffusion step along Z (across sections)."""
    return pl.pallas_call(
        functools.partial(_z_kernel, alpha=float(alpha)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)
