"""Pallas kernels vs pure-jnp oracles — the core build-time correctness
signal. hypothesis sweeps shapes and tap sets; assert_allclose against
ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random(shape, dtype=np.float32))


# Small even dims keep interpret-mode runtime reasonable.
dims_xy = st.sampled_from([4, 8, 12, 16])
dims_z = st.sampled_from([2, 4, 6, 8])
taps3 = st.sampled_from([(0.25, 0.5, 0.25), (1.0, 2.0, 1.0), (0.0, 1.0, 0.0)])
taps5 = st.sampled_from([(1 / 16, 4 / 16, 6 / 16, 4 / 16, 1 / 16), (0.1, 0.2, 0.4, 0.2, 0.1)])


class TestSepconv3d:
    @settings(max_examples=10, deadline=None)
    @given(x=dims_xy, y=dims_xy, z=dims_z, txy=taps3, tz=taps3, seed=st.integers(0, 100))
    def test_matches_ref_taps3(self, x, y, z, txy, tz, seed):
        v = rand((x, y, z), seed)
        got = kernels.sepconv3d(v, txy, tz)
        want = ref.sepconv3d_ref(v, txy, tz)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @settings(max_examples=6, deadline=None)
    @given(txy=taps5, tz=taps3, seed=st.integers(0, 100))
    def test_matches_ref_taps5(self, txy, tz, seed):
        v = rand((16, 16, 4), seed)
        got = kernels.sepconv3d(v, txy, tz)
        want = ref.sepconv3d_ref(v, txy, tz)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_z_blocking_invariant(self):
        # Tiling over Z slabs must not change the XY passes.
        v = rand((8, 8, 8), 7)
        t = (0.25, 0.5, 0.25)
        full = kernels.sepconv3d(v, t, t, z_block=None)
        tiled = kernels.sepconv3d(v, t, t, z_block=2)
        np.testing.assert_allclose(full, tiled, rtol=1e-6)

    def test_identity_taps(self):
        v = rand((8, 8, 4), 1)
        got = kernels.sepconv3d(v, (0.0, 1.0, 0.0), (0.0, 1.0, 0.0))
        np.testing.assert_allclose(got, v, rtol=1e-6)

    def test_dc_preserved_by_normalized_taps(self):
        # Normalized taps preserve a constant field exactly.
        v = jnp.full((8, 8, 4), 0.37, dtype=jnp.float32)
        got = kernels.sepconv3d(v, (0.25, 0.5, 0.25), (0.25, 0.5, 0.25))
        np.testing.assert_allclose(got, v, rtol=1e-6)

    def test_even_taps_rejected(self):
        with pytest.raises(AssertionError):
            kernels.sepconv3d(rand((4, 4, 2), 0), (0.5, 0.5), (1.0,))


class TestDownsample:
    @settings(max_examples=10, deadline=None)
    @given(x=dims_xy, y=dims_xy, z=dims_z, seed=st.integers(0, 100))
    def test_matches_ref(self, x, y, z, seed):
        v = rand((z, y, x), seed)
        got = kernels.downsample2x_xy(v)
        want = ref.downsample2x_xy_ref(v)
        assert got.shape == (z, y // 2, x // 2)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_mean_of_window(self):
        # [Z=1, Y=4, X=4]; window (y=0..2, x=0..2) = elements 0, 1, 4, 5.
        v = jnp.arange(4 * 4, dtype=jnp.float32).reshape(1, 4, 4)
        got = kernels.downsample2x_xy(v)
        np.testing.assert_allclose(got[0, 0, 0], (0 + 1 + 4 + 5) / 4)

    def test_odd_dims_rejected(self):
        with pytest.raises(AssertionError):
            kernels.downsample2x_xy(rand((2, 4, 5), 0))


class TestJacobi:
    @settings(max_examples=10, deadline=None)
    @given(
        x=dims_xy,
        y=dims_xy,
        z=dims_z,
        alpha=st.sampled_from([0.2, 0.5, 0.9]),
        seed=st.integers(0, 100),
    )
    def test_xy_matches_ref(self, x, y, z, alpha, seed):
        v = rand((x, y, z), seed)
        np.testing.assert_allclose(
            kernels.diffuse_xy(v, alpha), ref.diffuse_xy_ref(v, alpha), rtol=1e-5, atol=1e-6
        )

    @settings(max_examples=10, deadline=None)
    @given(
        x=dims_xy,
        y=dims_xy,
        z=dims_z,
        alpha=st.sampled_from([0.2, 0.5, 0.9]),
        seed=st.integers(0, 100),
    )
    def test_z_matches_ref(self, x, y, z, alpha, seed):
        v = rand((x, y, z), seed)
        np.testing.assert_allclose(
            kernels.diffuse_z(v, alpha), ref.diffuse_z_ref(v, alpha), rtol=1e-5, atol=1e-6
        )

    def test_fixed_point_constant(self):
        # A constant field is a fixed point of diffusion.
        v = jnp.full((8, 8, 4), 0.5, dtype=jnp.float32)
        np.testing.assert_allclose(kernels.diffuse_xy(v, 0.9), v, rtol=1e-6)
        np.testing.assert_allclose(kernels.diffuse_z(v, 0.9), v, rtol=1e-6)

    def test_diffusion_contracts_variance(self):
        v = rand((16, 16, 8), 3)
        out = kernels.diffuse_xy(v, 0.9)
        assert float(jnp.var(out)) < float(jnp.var(v))
        outz = kernels.diffuse_z(v, 0.9)
        assert float(jnp.var(outz)) < float(jnp.var(v))

    def test_mean_preserved(self):
        # Diffusion with periodic boundaries conserves mass.
        v = rand((8, 8, 8), 11)
        np.testing.assert_allclose(
            float(jnp.mean(kernels.diffuse_xy(v, 0.7))), float(jnp.mean(v)), rtol=1e-5
        )
        np.testing.assert_allclose(
            float(jnp.mean(kernels.diffuse_z(v, 0.7))), float(jnp.mean(v)), rtol=1e-5
        )
