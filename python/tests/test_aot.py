"""AOT lowering: every graph lowers to parseable HLO text with the shapes
the Rust runtime expects, and the manifest is written."""

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    return aot.lower_all(str(out)), str(out)


def test_all_graphs_lowered(artifacts):
    written, out = artifacts
    assert set(written) == {"synapse_detector", "color_correct", "downsample2x"}
    for path in written.values():
        assert os.path.getsize(path) > 0


def test_hlo_text_headers(artifacts):
    written, _ = artifacts
    for name, path in written.items():
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} missing HloModule header"
        assert "ENTRY" in text, f"{name} missing entry computation"


def test_shapes_in_hlo(artifacts):
    written, _ = artifacts
    det = open(written["synapse_detector"]).read()
    ins = ",".join(map(str, model.DET_IN))
    outs = ",".join(map(str, model.CORE))
    assert f"f32[{ins}]" in det, "detector input shape missing"
    assert f"f32[{outs}]" in det, "detector output shape missing"
    ds = open(written["downsample2x"]).read()
    assert "f32[16,64,64]" in ds


def test_outputs_are_tuples(artifacts):
    # return_tuple=True: the Rust side unwraps with to_tuple1.
    written, _ = artifacts
    for name, path in written.items():
        text = open(path).read()
        assert "(f32[" in text, f"{name} entry not tuple-shaped"


def test_manifest_written(artifacts):
    _, out = artifacts
    manifest = open(os.path.join(out, "manifest.txt")).read()
    for name in ("synapse_detector", "color_correct", "downsample2x"):
        assert name in manifest


def test_no_custom_calls(artifacts):
    # interpret=True must not leave Mosaic custom-calls behind — the CPU
    # PJRT client cannot execute those.
    written, _ = artifacts
    for name, path in written.items():
        text = open(path).read()
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower(), (
            f"{name} contains a Mosaic custom-call"
        )
