"""Layer-2 model behaviour: shapes, kernel-vs-ref agreement at model
scope, and semantic sanity (the detector fires on synapse-scale blobs;
color correction removes exposure steps).

Arrays are [Z, Y, X] (see compile/model.py)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")


def blob(shape, center, sigma, amp):
    """Gaussian blob on a [Z, Y, X] grid; sigma is (sz, sy, sx)."""
    zs = jnp.arange(shape[0])[:, None, None]
    ys = jnp.arange(shape[1])[None, :, None]
    xs = jnp.arange(shape[2])[None, None, :]
    d2 = (
        (zs - center[0]) ** 2 / sigma[0] ** 2
        + (ys - center[1]) ** 2 / sigma[1] ** 2
        + (xs - center[2]) ** 2 / sigma[2] ** 2
    )
    return amp * jnp.exp(-0.5 * d2).astype(jnp.float32)


class TestSynapseDetector:
    def test_shapes(self):
        x = jnp.zeros(model.DET_IN, dtype=jnp.float32)
        (out,) = model.synapse_detector(x)
        assert out.shape == model.CORE
        assert out.dtype == jnp.float32

    def test_matches_ref_model(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.random(model.DET_IN, dtype=np.float32))
        (got,) = model.synapse_detector(x)
        want = model.synapse_detector_ref(x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_fires_on_synapse_scale_blob(self):
        # Background 0.43 + a bright compact blob at the block center
        # (matching the synthetic generator: BG 110/255, amp 110/255,
        # sigma (1, 2, 2) in zyx).
        bg = jnp.full(model.DET_IN, 0.43, dtype=jnp.float32)
        center = tuple(s // 2 for s in model.DET_IN)
        x = bg + blob(model.DET_IN, center, (1.0, 2.0, 2.0), 0.43)
        (out,) = model.synapse_detector(x)
        cc = tuple(c - h for c, h in zip(center, model.HALO))
        at_blob = float(out[cc])
        far = float(out[2, 5, 5])
        assert at_blob > 0.9, f"blob response {at_blob}"
        assert far < 0.1, f"background response {far}"

    def test_flat_background_quiet(self):
        x = jnp.full(model.DET_IN, 0.5, dtype=jnp.float32)
        (out,) = model.synapse_detector(x)
        # DoG of a constant is 0 -> sigmoid(-GAIN*BIAS) ~ 0; must be
        # uniform and near zero.
        assert float(out.max()) - float(out.min()) < 1e-4
        assert float(out.max()) < 0.01

    def test_noise_stays_quiet(self):
        # Sensor noise at the generator's sigma (6/255) must not fire.
        rng = np.random.default_rng(3)
        x = jnp.asarray(
            0.43 + rng.normal(0, 6.0 / 255.0, model.DET_IN).astype(np.float32)
        )
        (out,) = model.synapse_detector(x)
        assert float(out.max()) < 0.5, f"noise fired: {float(out.max())}"

    def test_large_structure_suppressed(self):
        # A structure much larger than the DoG scale (a "vessel") responds
        # weakly compared to a synapse-scale blob.
        bg = jnp.full(model.DET_IN, 0.43, dtype=jnp.float32)
        center = tuple(s // 2 for s in model.DET_IN)
        big = bg + blob(model.DET_IN, center, (6.0, 20.0, 20.0), 0.43)
        small = bg + blob(model.DET_IN, center, (1.0, 2.0, 2.0), 0.43)
        cc = tuple(c - h for c, h in zip(center, model.HALO))
        (out_big,) = model.synapse_detector(big)
        (out_small,) = model.synapse_detector(small)
        assert float(out_small[cc]) > float(out_big[cc]) + 0.3


class TestColorCorrect:
    def striped_stack(self):
        """Uniform texture with a per-section exposure step (the Figure 6
        pathology). Sections are axis 0."""
        rng = np.random.default_rng(1)
        base = rng.random(model.CC_SHAPE, dtype=np.float32) * 0.2 + 0.4
        exposure = np.where(np.arange(model.CC_SHAPE[0]) % 2 == 0, 0.15, -0.15)
        return jnp.asarray(base + exposure[:, None, None])

    def test_shapes_and_range(self):
        x = self.striped_stack()
        (out,) = model.color_correct(x)
        assert out.shape == model.CC_SHAPE
        assert float(out.min()) >= 0.0 and float(out.max()) <= 1.0

    def test_matches_ref_model(self):
        x = self.striped_stack()
        (got,) = model.color_correct(x)
        want = model.color_correct_ref(x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_reduces_intersection_exposure_steps(self):
        x = self.striped_stack()
        (out,) = model.color_correct(x)
        means_in = jnp.mean(x, axis=(1, 2))
        means_out = jnp.mean(out, axis=(1, 2))
        # Variance of per-section means (the exposure signature) must
        # shrink substantially.
        assert float(jnp.var(means_out)) < 0.35 * float(jnp.var(means_in))

    def test_preserves_high_frequencies(self):
        x = self.striped_stack()
        (out,) = model.color_correct(x)
        # In-section contrast (std within each section) is preserved.
        s_in = jnp.std(x, axis=(1, 2)).mean()
        s_out = jnp.std(out, axis=(1, 2)).mean()
        assert float(s_out) > 0.8 * float(s_in)


class TestDownsampleModel:
    def test_shape(self):
        x = jnp.zeros(model.DS_IN, dtype=jnp.float32)
        (out,) = model.downsample2x(x)
        assert out.shape == (model.DS_IN[0], model.DS_IN[1] // 2, model.DS_IN[2] // 2)

    def test_constant_preserved(self):
        x = jnp.full(model.DS_IN, 0.25, dtype=jnp.float32)
        (out,) = model.downsample2x(x)
        np.testing.assert_allclose(out, 0.25, rtol=1e-6)
