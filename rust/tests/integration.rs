//! Cross-module integration: cluster + cutout + annotation + index +
//! hierarchy + sharding working together (no AOT artifacts required).

use std::sync::Arc;

use ocpd::annotation::{Predicate, PredicateOp, RamonObject, RegionQuery, SynapseType};
use ocpd::array::DenseVolume;
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project, WriteDiscipline};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::resolution::Propagator;
use ocpd::util::prop::property;
use ocpd::util::Rng;

fn cluster(dims: [u64; 3], levels: u32) -> Arc<Cluster> {
    let c = Cluster::in_memory(2, 1);
    c.register_dataset(DatasetBuilder::new("ds", dims).levels(levels).build());
    c
}

#[test]
fn ingest_hierarchy_cutout_roundtrip() {
    let c = cluster([512, 512, 32], 3);
    let img = c.create_image_project(Project::image("img", "ds")).unwrap();
    let sv = generate(&SynthSpec::small([512, 512, 32], 1));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    // Full-volume read matches the source exactly.
    let whole = Box3::new([0, 0, 0], [512, 512, 32]);
    assert_eq!(img.read::<u8>(0, 0, 0, whole).unwrap(), sv.vol);
    // Hierarchy: level dims halve in XY; content is locally averaged.
    Propagator::new(&img).propagate_image().unwrap();
    let l1 = img.read::<u8>(1, 0, 0, Box3::new([0, 0, 0], [256, 256, 32])).unwrap();
    let mean0 = sv.vol.as_slice().iter().map(|&v| v as f64).sum::<f64>() / sv.vol.len() as f64;
    let mean1 = l1.as_slice().iter().map(|&v| v as f64).sum::<f64>() / l1.len() as f64;
    assert!((mean0 - mean1).abs() < 2.0, "level means {mean0:.1} vs {mean1:.1}");
}

#[test]
fn annotation_full_lifecycle_through_cluster() {
    let c = cluster([256, 256, 32], 2);
    let anno = c
        .create_annotation_project(Project::annotation("ann", "ds").with_exceptions(), true)
        .unwrap();

    // Write 30 labeled blobs at disjoint sites + metadata.
    let mut objs = Vec::new();
    for id in 1..=30u32 {
        let i = (id - 1) as u64;
        let lo = [(i % 6) * 40, (i / 6) * 40, (i % 4) * 6];
        let bx = Box3::at(lo, [8, 8, 4]);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), id);
        anno.write_volume(0, bx, &v, WriteDiscipline::Preserve).unwrap();
        objs.push(RamonObject::synapse(id, id as f32 / 30.0, SynapseType::Excitatory));
    }
    anno.put_objects(objs).unwrap();

    // Predicate query matches the confidence partition (>= 0.5 -> ids 15..30).
    let hi = anno
        .query(&[
            Predicate::eq("type", "synapse"),
            Predicate::cmp("confidence", PredicateOp::Geq, 0.5),
        ])
        .unwrap();
    assert_eq!(hi.len(), 16, "{hi:?}");

    // Every object readable: voxels + bbox agree.
    for id in 1..=30u32 {
        let voxels = anno.voxel_list(0, id).unwrap();
        assert_eq!(voxels.len(), 8 * 8 * 4, "object {id}");
        let bb = anno.bounding_box(0, id).unwrap().unwrap();
        for v in &voxels {
            assert!(bb.contains(*v), "voxel {v:?} outside bbox {bb:?} for {id}");
        }
    }

    // Propagate annotations and check they exist at level 1.
    Propagator::new(&anno.cutout).propagate_annotations().unwrap();
    let ids_l1 = anno
        .objects_in_region(1, Box3::new([0, 0, 0], [128, 128, 32]), RegionQuery::default())
        .unwrap();
    assert!(!ids_l1.is_empty());

    // Migration preserves everything.
    let before = anno.voxel_list(0, 7).unwrap();
    let (anno2, moved) = c.migrate_annotation_project("ann").unwrap();
    assert!(moved > 0);
    assert_eq!(anno2.voxel_list(0, 7).unwrap(), before);
    assert_eq!(anno2.get_object(7).unwrap().rtype, ocpd::annotation::RamonType::Synapse);
}

#[test]
fn sharded_image_cutouts_match_prop() {
    // Cutouts from a 2-node sharded store must equal the source volume,
    // for arbitrary boxes straddling shard boundaries.
    let c = cluster([256, 256, 32], 1);
    let img = c.create_image_project(Project::image("img", "ds")).unwrap();
    let sv = generate(&SynthSpec::small([256, 256, 32], 3));
    ingest_volume(&img, &sv.vol, [128, 128, 16]).unwrap();
    property("sharded_cutouts", 60, |g| {
        let (lo, hi) = g.boxed([256, 256, 32], 128);
        let bx = Box3::new(lo, hi);
        assert_eq!(img.read::<u8>(0, 0, 0, bx).unwrap(), sv.vol.extract_box(bx));
    });
}

#[test]
fn concurrent_cutouts_and_annotation_writes() {
    // The paper's concurrent-workload placement: vision reads cutouts
    // while writing annotations. Run both in parallel and verify nothing
    // interferes.
    let c = cluster([256, 256, 32], 1);
    let img = c.create_image_project(Project::image("img", "ds")).unwrap();
    let anno = c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
    let sv = generate(&SynthSpec::small([256, 256, 32], 5));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();

    std::thread::scope(|s| {
        for t in 0..4u64 {
            let img = Arc::clone(&img);
            let truth = sv.vol.clone();
            s.spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..20 {
                    let lo = [rng.below(192), rng.below(192), rng.below(16)];
                    let bx = Box3::at(lo, [64, 64, 16]);
                    let got = img.read::<u8>(0, 0, 0, bx).unwrap();
                    assert_eq!(got, truth.extract_box(bx));
                }
            });
        }
        for w in 0..4u32 {
            let anno = Arc::clone(&anno);
            s.spawn(move || {
                for i in 0..16u32 {
                    let id = w * 16 + i + 1;
                    // Disjoint sites per id so overwrites never collide.
                    let k = (id - 1) as u64;
                    let lo = [(k % 8) * 30, ((k / 8) % 8) * 30, (k % 4) * 7];
                    let bx = Box3::at(lo, [6, 6, 3]);
                    let mut v = DenseVolume::<u32>::zeros(bx.extent());
                    v.fill_box(Box3::new([0, 0, 0], bx.extent()), id);
                    anno.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
                }
            });
        }
    });

    // All 64 writer objects present.
    for id in 1..=64u32 {
        assert!(!anno.voxel_list(0, id).unwrap().is_empty(), "object {id}");
    }
}

#[test]
fn spatial_index_consistent_with_volume_prop() {
    // For random annotation writes, the index's cuboid list must cover
    // every cuboid where the object's voxels live.
    let c = cluster([256, 256, 32], 1);
    let anno = c.create_annotation_project(Project::annotation("ann", "ds"), false).unwrap();
    let cshape = anno.cutout.store().cuboid_shape(0).unwrap();
    property("index_covers_voxels", 30, |g| {
        let id = 1 + g.u32_below(1000);
        let (lo, hi) = g.boxed([256, 256, 32], 40);
        let bx = Box3::new(lo, hi);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), id);
        anno.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
        let codes = anno.index.cuboids_of(0, id).unwrap();
        let cover = bx.cuboid_cover(cshape);
        for cz in cover.lo[2]..cover.hi[2] {
            for cy in cover.lo[1]..cover.hi[1] {
                for cx in cover.lo[0]..cover.hi[0] {
                    let e = ocpd::morton::encode3(cx, cy, cz);
                    assert!(codes.binary_search(&e).is_ok(), "missing cuboid {e}");
                }
            }
        }
    });
}

#[test]
fn io_separation_reads_db_writes_ssd() {
    // Reads hit database nodes; annotation writes hit the SSD node.
    let c = cluster([256, 256, 32], 1);
    let img = c.create_image_project(Project::image("img", "ds")).unwrap();
    let anno = c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
    let sv = generate(&SynthSpec::small([256, 256, 32], 8));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    let base = c.node_stats();

    for _ in 0..8 {
        img.read::<u8>(0, 0, 0, Box3::new([0, 0, 0], [128, 128, 16])).unwrap();
    }
    let bx = Box3::new([0, 0, 0], [8, 8, 4]);
    let mut v = DenseVolume::<u32>::zeros(bx.extent());
    v.fill_box(Box3::new([0, 0, 0], bx.extent()), 1);
    anno.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();

    let now = c.node_stats();
    let delta = |i: usize| {
        (
            now[i].1.read_bytes - base[i].1.read_bytes,
            now[i].1.write_bytes - base[i].1.write_bytes,
        )
    };
    let (db0_r, db0_w) = delta(0);
    let (_db1_r, db1_w) = delta(1);
    let (_ssd_r, ssd_w) = delta(2);
    assert!(db0_r > 0, "db reads expected");
    assert_eq!(db0_w + db1_w, 0, "image reads must not write db nodes");
    assert!(ssd_w > 0, "annotation write must hit ssd node");
}

#[test]
fn simulated_cluster_end_to_end() {
    // The device-model cluster serves the same workload, just slower.
    let c = Cluster::simulated(1, 1, 0.001);
    c.register_dataset(DatasetBuilder::new("ds", [128, 128, 16]).levels(1).build());
    let img = c.create_image_project(Project::image("img", "ds")).unwrap();
    let anno = c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
    let sv = generate(&SynthSpec::small([128, 128, 16], 9));
    ingest_volume(&img, &sv.vol, [128, 128, 16]).unwrap();
    let whole = Box3::new([0, 0, 0], [128, 128, 16]);
    assert_eq!(img.read::<u8>(0, 0, 0, whole).unwrap(), sv.vol);
    let bx = Box3::new([4, 4, 2], [12, 12, 6]);
    let mut v = DenseVolume::<u32>::zeros(bx.extent());
    v.fill_box(Box3::new([0, 0, 0], bx.extent()), 3);
    anno.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
    assert_eq!(anno.voxel_list(0, 3).unwrap().len() as u64, bx.volume());
}

#[test]
fn timeseries_dataset_through_cluster() {
    let c = Cluster::in_memory(1, 0);
    c.register_dataset(
        DatasetBuilder::new("ts", [64, 64, 8]).levels(1).timesteps(6).build(),
    );
    let img = c.create_image_project(Project::image("tsimg", "ts")).unwrap();
    let bx = Box3::new([0, 0, 0], [64, 64, 8]);
    for t in 0..6u64 {
        let mut v = DenseVolume::<u8>::zeros(bx.extent());
        v.fill_box(bx, 10 + t as u8);
        img.write(0, 0, t, bx, &v).unwrap();
    }
    let series = img.read_timeseries::<u8>(0, 0, 0, 6, Box3::new([8, 8, 2], [16, 16, 4])).unwrap();
    for (t, v) in series.iter().enumerate() {
        assert_eq!(v.get([0, 0, 0]), 10 + t as u8);
    }
}
