//! Web API conformance: the Table 1 URL grammar over real HTTP.

use std::sync::Arc;

use ocpd::array::DenseVolume;
use ocpd::client::OcpClient;
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project, WriteDiscipline};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::web::http::request;
use ocpd::web::Server;

struct Fixture {
    server: Server,
    truth: DenseVolume<u8>,
}

fn fixture() -> Fixture {
    let dims = [256u64, 256, 32];
    let cluster = Cluster::in_memory(1, 1);
    cluster.register_dataset(DatasetBuilder::new("img", dims).levels(2).build());
    let img = cluster.create_image_project(Project::image("img", "img")).unwrap();
    cluster
        .create_annotation_project(Project::annotation("ann", "img").with_exceptions(), true)
        .unwrap();
    let sv = generate(&SynthSpec::small(dims, 1));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    let server = ocpd::web::serve(cluster, None, "127.0.0.1:0", 8).unwrap();
    Fixture { server, truth: sv.vol }
}

#[test]
fn cutout_url_table1() {
    let f = fixture();
    // Table 1: http://.../token/ocpk/resolution/x-range/y-range/z-range/
    let url = format!("{}/img/ocpk/0/64,128/32,96/4,12/", f.server.url());
    let (code, body) = request("GET", &url, &[]).unwrap();
    assert_eq!(code, 200);
    let (_dt, bx, vol) = ocpd::web::ocpk::decode_volume::<u8>(&body).unwrap();
    assert_eq!(bx, Box3::new([64, 32, 4], [128, 96, 12]));
    assert_eq!(vol, f.truth.extract_box(bx));
}

#[test]
fn cutout_errors_are_http_statuses() {
    let f = fixture();
    // Out of bounds -> 400.
    let (code, _) =
        request("GET", &format!("{}/img/ocpk/0/0,9999/0,8/0,8/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 400);
    // Unknown token -> 404.
    let (code, _) =
        request("GET", &format!("{}/nope/ocpk/0/0,8/0,8/0,8/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 404);
    // Bad range -> 400.
    let (code, _) =
        request("GET", &format!("{}/img/ocpk/0/8,0/0,8/0,8/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 400);
    // Bad method -> 405.
    let (code, _) = request("DELETE", &format!("{}/img/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 405);
}

#[test]
fn annotation_write_then_object_reads() {
    let f = fixture();
    let client = OcpClient::new(&f.server.url(), "ann");

    // Write two objects.
    let bx = Box3::new([10, 10, 2], [42, 42, 10]);
    let mut labels = DenseVolume::<u32>::zeros(bx.extent());
    labels.fill_box(Box3::new([0, 0, 0], [16, 32, 8]), 7);
    labels.fill_box(Box3::new([16, 0, 0], [32, 32, 8]), 9);
    client.write_annotation(0, bx.lo, &labels, WriteDiscipline::Overwrite).unwrap();

    // Table 1: voxel list.
    let voxels = client.voxels(7).unwrap();
    assert_eq!(voxels.len() as u64, 16 * 32 * 8);
    assert!(voxels.contains(&[10, 10, 2]));

    // Table 1: bounding box (cuboid-granular; must contain the object).
    let bb = client.bounding_box(9).unwrap();
    assert!(bb.contains([26, 10, 2]));

    // Table 1: cutout restricted to a region.
    let region = Box3::new([10, 10, 2], [26, 20, 6]);
    let (obx, ovol) = client.object_cutout(7, Some((0, region))).unwrap();
    assert_eq!(obx, region);
    assert_eq!(ovol.count_eq(7), 16 * 10 * 4);

    // Annotation cutout of the region shows both labels.
    let acut = client.cutout_u32(0, bx).unwrap();
    assert_eq!(acut.count_eq(7), 16 * 32 * 8);
    assert_eq!(acut.count_eq(9), 16 * 32 * 8);
}

#[test]
fn ramon_batch_and_predicate_query() {
    let f = fixture();
    let client = OcpClient::new(&f.server.url(), "ann");
    use ocpd::annotation::{RamonObject, SynapseType};
    let objs = vec![
        RamonObject::synapse(0, 0.99, SynapseType::Excitatory),
        RamonObject::synapse(0, 0.45, SynapseType::Inhibitory),
        RamonObject::segment(0, 12),
    ];
    let ids = client.put_objects(&objs).unwrap();
    assert_eq!(ids.len(), 3);
    // Server-assigned unique ids (§4.2).
    assert!(ids[0] != ids[1] && ids[1] != ids[2]);

    // Paper's example: /objects/type/synapse/confidence/geq/0.99/
    let hits = client.query(&["type", "synapse", "confidence", "geq", "0.99"]).unwrap();
    assert_eq!(hits, vec![ids[0]]);

    // Batch metadata read: /{id1},{id2}/
    let got = client.get_objects(&[ids[0], ids[2]]).unwrap();
    assert_eq!(got.len(), 2);
    assert_eq!(got[0].confidence, 0.99);
    assert_eq!(got[1].neuron, 12);
}

#[test]
fn exception_discipline_over_http() {
    let f = fixture();
    let client = OcpClient::new(&f.server.url(), "ann");
    let bx = Box3::new([0, 0, 0], [16, 16, 4]);
    let mut a = DenseVolume::<u32>::zeros(bx.extent());
    a.fill_box(Box3::new([0, 0, 0], bx.extent()), 1);
    client.write_annotation(0, bx.lo, &a, WriteDiscipline::Overwrite).unwrap();
    let mut b = DenseVolume::<u32>::zeros(bx.extent());
    b.fill_box(Box3::new([0, 0, 0], [8, 16, 4]), 2);
    let resp = client.write_annotation(0, bx.lo, &b, WriteDiscipline::Exception).unwrap();
    assert!(resp.contains("exceptions=512"), "{resp}");
    // Both readable.
    assert_eq!(client.voxels(1).unwrap().len() as u64, bx.volume());
    assert_eq!(client.voxels(2).unwrap().len(), 8 * 16 * 4);
}

#[test]
fn plane_and_tile_routes() {
    let f = fixture();
    // Plane projection.
    let url = format!("{}/img/xy/0/5/0,64/0,64/", f.server.url());
    let (code, body) = request("GET", &url, &[]).unwrap();
    assert_eq!(code, 200);
    let (_dt, bx, plane) = ocpd::web::ocpk::decode_volume::<u8>(&body).unwrap();
    assert_eq!(bx.extent(), [64, 64, 1]);
    assert_eq!(plane.get([3, 4, 0]), f.truth.get([3, 4, 5]));

    // Tile (256x256 grayscale, stored layout r/z/y_x).
    let url = format!("{}/img/tile/0/7/0_0.gray", f.server.url());
    let (code, tile) = request("GET", &url, &[]).unwrap();
    assert_eq!(code, 200);
    assert_eq!(tile.len(), 256 * 256);
    assert_eq!(tile[5 + 9 * 256], f.truth.get([5, 9, 7]));
}

#[test]
fn region_query_route() {
    let f = fixture();
    let client = OcpClient::new(&f.server.url(), "ann");
    let bx = Box3::new([100, 100, 20], [110, 110, 24]);
    let mut v = DenseVolume::<u32>::zeros(bx.extent());
    v.fill_box(Box3::new([0, 0, 0], bx.extent()), 77);
    client.write_annotation(0, bx.lo, &v, WriteDiscipline::Overwrite).unwrap();
    let (code, body) = request(
        "GET",
        &format!("{}/ann/region/0/96,128/96,128/16,28/", f.server.url()),
        &[],
    )
    .unwrap();
    assert_eq!(code, 200);
    assert_eq!(String::from_utf8_lossy(&body), "77");
}

#[test]
fn info_route_lists_projects_and_nodes() {
    let f = fixture();
    let info = ocpd::client::cluster_info(&f.server.url()).unwrap();
    assert!(info.contains("img"));
    assert!(info.contains("ann"));
    assert!(info.contains("db0"));
    assert!(info.contains("ssd0"));
}

#[test]
fn wal_status_and_flush_routes() {
    let f = fixture();
    let client = OcpClient::new(&f.server.url(), "ann");
    let bx = Box3::new([0, 0, 0], [16, 16, 4]);
    let mut v = DenseVolume::<u32>::zeros(bx.extent());
    v.fill_box(Box3::new([0, 0, 0], bx.extent()), 5);
    client.write_annotation(0, bx.lo, &v, WriteDiscipline::Overwrite).unwrap();

    // Status lists the hot project's log.
    let status = ocpd::client::wal_status(&f.server.url()).unwrap();
    assert!(status.contains("ann:"), "{status}");

    // GET on flush is rejected; PUT drains everything.
    let (code, _) = request("GET", &format!("{}/wal/flush/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 405);
    let resp = ocpd::client::wal_flush(&f.server.url(), None).unwrap();
    assert!(resp.starts_with("flushed="), "{resp}");
    let status = ocpd::client::wal_status(&f.server.url()).unwrap();
    assert!(status.contains("depth=0"), "{status}");
    // Reads answer identically from the database node.
    assert_eq!(client.voxels(5).unwrap().len() as u64, bx.volume());

    // Per-token flush; unknown tokens are 404.
    let resp = ocpd::client::wal_flush(&f.server.url(), Some("ann")).unwrap();
    assert!(resp.starts_with("flushed="), "{resp}");
    let (code, _) =
        request("PUT", &format!("{}/wal/flush/nope/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 404);
}

#[test]
fn cache_status_route_reports_per_project_caches() {
    let f = fixture();
    // A repeated cutout warms the image project's cuboid cache.
    let client = OcpClient::new(&f.server.url(), "img");
    let bx = Box3::new([0, 0, 0], [128, 128, 16]);
    let _ = client.cutout_u8(0, bx).unwrap();
    let _ = client.cutout_u8(0, bx).unwrap();
    let status = ocpd::client::cache_status(&f.server.url()).unwrap();
    assert!(status.contains("img:"), "{status}");
    assert!(status.contains("ann:"), "{status}");
    assert!(status.contains("hit_rate="), "{status}");
    // The warm second read registered hits.
    let img_line = status.lines().find(|l| l.trim_start().starts_with("img:")).unwrap();
    assert!(!img_line.contains("hits=0 "), "{img_line}");
    // Unknown cache sub-routes are 400; the name is reserved, so it can
    // never be shadowed by a project token.
    let (code, _) =
        request("GET", &format!("{}/cache/nope/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 400);
}

#[test]
fn write_engine_routes_report_and_retune() {
    let f = fixture();
    // The fixture's cuboid-aligned ingest went through the write
    // engine: aligned blocks elide every existing-cuboid read.
    let status = ocpd::client::write_status(&f.server.url()).unwrap();
    assert!(status.contains("img:"), "{status}");
    assert!(status.contains("ann:"), "{status}");
    assert!(status.contains("elided_reads="), "{status}");
    let img_line = status.lines().find(|l| l.trim_start().starts_with("img:")).unwrap();
    assert!(img_line.contains("rmw_reads=0"), "{img_line}");

    // Retune the fan-out width cluster-wide over HTTP.
    let resp = ocpd::client::set_write_workers(&f.server.url(), 2).unwrap();
    assert_eq!(resp, "workers=2 projects=2");
    let status = ocpd::client::write_status(&f.server.url()).unwrap();
    for line in status.lines().filter(|l| l.contains(": workers=")) {
        assert!(line.contains("workers=2"), "{line}");
    }

    // Wrong methods 405; unknown sub-routes 400; garbled counts 400.
    let (code, _) =
        request("DELETE", &format!("{}/write/status/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 405);
    let (code, _) =
        request("GET", &format!("{}/write/workers/4/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 405);
    let (code, _) =
        request("PUT", &format!("{}/write/status/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 405);
    let (code, _) =
        request("GET", &format!("{}/write/nope/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 400);
    let (code, _) =
        request("PUT", &format!("{}/write/workers/banana/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 400);
}

#[test]
fn reserved_tokens_reject_wrong_methods_with_405() {
    let f = fixture();
    // Previously these fell through to the project PUT handler and came
    // back as a confusing 400 ("unknown write discipline 'status'").
    let (code, _) =
        request("PUT", &format!("{}/cache/status/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 405);
    let (code, _) =
        request("DELETE", &format!("{}/wal/status/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 405);
    let (code, _) =
        request("DELETE", &format!("{}/jobs/status/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 405);
    // Wrong method on a sub-route of a reserved token, not just the root.
    let (code, body) =
        request("GET", &format!("{}/jobs/cancel/1/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 405);
    assert!(
        String::from_utf8_lossy(&body).contains("allow:"),
        "405 bodies must name the allowed methods"
    );
    let (code, _) =
        request("POST", &format!("{}/jobs/status/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 405);
}

#[test]
fn job_routes_submit_status_cancel() {
    let f = fixture();
    let client = OcpClient::new(&f.server.url(), "ann");

    // Seed the annotation project with an object to propagate.
    let bx = Box3::new([32, 32, 4], [96, 96, 12]);
    let mut v = DenseVolume::<u32>::zeros(bx.extent());
    v.fill_box(Box3::new([0, 0, 0], bx.extent()), 42);
    client.write_annotation(0, bx.lo, &v, WriteDiscipline::Overwrite).unwrap();

    // Submit a propagate job over HTTP and parse its id.
    let resp = ocpd::client::submit_job(&f.server.url(), "propagate/ann", "workers=2").unwrap();
    assert!(resp.starts_with("id="), "{resp}");
    let id: u64 = resp
        .split_whitespace()
        .next()
        .unwrap()
        .trim_start_matches("id=")
        .parse()
        .unwrap();

    // Poll status until terminal.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    loop {
        let status = ocpd::client::job_status(&f.server.url(), Some(id)).unwrap();
        if status.contains("state=completed") {
            break;
        }
        assert!(
            !status.contains("state=failed"),
            "job failed: {status}"
        );
        assert!(std::time::Instant::now() < deadline, "job stuck: {status}");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    // The full listing mentions it too.
    let all = ocpd::client::job_status(&f.server.url(), None).unwrap();
    assert!(all.contains("propagate/ann"), "{all}");

    // And the propagated level answers over the normal cutout route.
    let out = client.cutout_u32(1, Box3::new([16, 16, 4], [48, 48, 12])).unwrap();
    assert_eq!(out.count_eq(42), 32 * 32 * 8);

    // Cancelling a finished job is fine; unknown ids are 404s.
    assert!(ocpd::client::cancel_job(&f.server.url(), id).is_ok());
    assert!(ocpd::client::cancel_job(&f.server.url(), 9999).is_err());
    assert!(ocpd::client::job_status(&f.server.url(), Some(9999)).is_err());
    // Unknown tokens and bad shapes are client errors.
    let (code, _) = request(
        "POST",
        &format!("{}/jobs/propagate/nope/", f.server.url()),
        &[],
    )
    .unwrap();
    assert_eq!(code, 404);
    let (code, _) =
        request("POST", &format!("{}/jobs/frobnicate/x/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 400);
    // Ingest without dims is a 400.
    let (code, _) =
        request("POST", &format!("{}/jobs/ingest/img/", f.server.url()), &[]).unwrap();
    assert_eq!(code, 400);
    // Synapse submit without a loaded runtime is a 400, not a crash.
    let (code, _) = request(
        "POST",
        &format!("{}/jobs/synapse/img/ann/", f.server.url()),
        &[],
    )
    .unwrap();
    assert_eq!(code, 400);
}

#[test]
fn ingest_job_over_http_fills_a_project() {
    // A fresh cluster with an empty image project; the ingest job
    // generates and uploads the synthetic volume server-side.
    let dims = [128u64, 128, 16];
    let cluster = Cluster::in_memory(1, 1);
    cluster.register_dataset(DatasetBuilder::new("ds", dims).levels(1).build());
    cluster.create_image_project(Project::image("fresh", "ds")).unwrap();
    let server = ocpd::web::serve(cluster, None, "127.0.0.1:0", 4).unwrap();

    let resp = ocpd::client::submit_job(
        &server.url(),
        "ingest/fresh",
        "dims=128,128,16 seed=4 workers=2",
    )
    .unwrap();
    let id: u64 = resp
        .split_whitespace()
        .next()
        .unwrap()
        .trim_start_matches("id=")
        .parse()
        .unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    loop {
        let status = ocpd::client::job_status(&server.url(), Some(id)).unwrap();
        if status.contains("state=completed") {
            break;
        }
        assert!(!status.contains("state=failed"), "{status}");
        assert!(std::time::Instant::now() < deadline, "job stuck: {status}");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let client = OcpClient::new(&server.url(), "fresh");
    let truth = generate(&SynthSpec::small(dims, 4));
    let got = client.cutout_u8(0, Box3::new([0, 0, 0], dims)).unwrap();
    assert_eq!(got, truth.vol);
}

#[test]
fn parallel_http_cutouts_consistent() {
    let f = Arc::new(fixture());
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let f = Arc::clone(&f);
            std::thread::spawn(move || {
                let client = OcpClient::new(&f.server.url(), "img");
                let x0 = (i % 4) * 32;
                let bx = Box3::new([x0, 0, 0], [x0 + 64, 64, 8]);
                for _ in 0..5 {
                    let got = client.cutout_u8(0, bx).unwrap();
                    assert_eq!(got, f.truth.extract_box(bx));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert!(f.server.requests.get() >= 40);
}
