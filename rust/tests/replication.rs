//! Shard replication + deterministic failover, end to end.
//!
//! The headline harness: a replicated cluster is built over seeded
//! fault-injecting stores (`ClusterConfig::fault_seed`), the leader of
//! shard 0 is wobbled (seeded per-op error rate) and then hard-crashed
//! **mid write burst**, the control plane promotes the most-caught-up
//! follower, and the test proves the replication contract:
//!
//! * every **acked** write survives the promotion (read-your-writes on
//!   the new leader, routed transparently through the epoch fence);
//! * every **rejected** write is fully applied or absent — never torn —
//!   and succeeds when retried against the new leadership;
//! * the whole run — acks, rejections, fault logs, promotion reports —
//!   is **bit-for-bit reproducible** from `OCPD_FAULT_SEED` (CI sweeps
//!   a seed matrix; any seed must satisfy the same invariants).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use ocpd::cluster::{Cluster, ClusterConfig, ReplicaSet, ReplicationConfig};
use ocpd::core::{DatasetBuilder, Project};
use ocpd::storage::{Blob, Engine, MemStore, StorageEngine};
use ocpd::util::prop::property;
use ocpd::Error;

/// Flatten a storage read to owned bytes for comparisons.
fn bytes(v: Option<Blob>) -> Option<Vec<u8>> {
    v.map(|b| b.to_vec())
}

/// The deterministic seed every fault draw derives from. CI runs the
/// suite under several seeds; the default reproduces local failures.
fn fault_seed() -> u64 {
    std::env::var("OCPD_FAULT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42)
}

/// A 3-database-node cluster, every image shard 2-way replicated, every
/// node's engine behind a seeded fault injector.
fn drill_cluster(seed: u64, lease: Duration, monitor: bool) -> Arc<Cluster> {
    Cluster::with_config(ClusterConfig {
        n_database: 3,
        n_ssd: 0,
        replicas: 2,
        lease,
        monitor,
        monitor_interval: Duration::from_millis(10),
        fault_seed: Some(seed),
        ..ClusterConfig::default()
    })
}

/// Register a dataset, create the replicated image project, and hand
/// back its sharded engine (the routed write/read surface).
fn drill_engine(c: &Cluster) -> Engine {
    c.register_dataset(DatasetBuilder::new("synth", [256, 256, 32]).levels(1).build());
    let img = c.create_image_project(Project::image("img", "synth")).unwrap();
    Arc::clone(img.store().engine())
}

/// Deterministic per-round payload for `key`.
fn payload(key: u64, round: u8) -> Vec<u8> {
    vec![(key % 251) as u8, round, (key >> 8) as u8, 0xA5]
}

/// Keys interleaved shard-major so any contiguous half of the list hits
/// every shard — crashing a leader "mid write" then always leaves both
/// acked and rejected writes on its shard.
fn drill_keys(eng: &Engine) -> Vec<u64> {
    let map = eng.shard_map().expect("image project must be sharded").clone();
    let mut keys = Vec::new();
    for j in 0..8u64 {
        for s in 0..map.num_shards() {
            let (lo, hi) = map.shard_range(s);
            keys.push(lo + j % (hi - lo));
        }
    }
    keys
}

/// Everything observable about one drill run; two runs from the same
/// seed must produce identical outcomes.
#[derive(Debug, PartialEq, Eq)]
struct DrillOutcome {
    victim: usize,
    acked: Vec<(u64, Vec<u8>)>,
    rejected: Vec<u64>,
    promotions: Vec<(usize, usize, usize, u64, u64)>,
    fault_logs: Vec<Vec<u64>>,
}

/// The kill-the-leader-mid-write drill (see module docs).
fn failover_drill(seed: u64) -> DrillOutcome {
    // Lease ZERO: a dead leader is promotable on the very next tick.
    let c = drill_cluster(seed, Duration::ZERO, false);
    let eng = drill_engine(&c);
    let table = "img/drill";
    let keys = drill_keys(&eng);

    let sets = c.control().sets_for("img");
    assert!(!sets.is_empty(), "replicated project must register its sets");
    assert!(sets.iter().all(|s| s.num_members() == 2));
    let victim = sets[0].leader_node();

    let mut acked: Vec<(u64, Vec<u8>)> = Vec::new();
    let mut rejected: Vec<u64> = Vec::new();
    let write = |round: u8, ks: &[u64], acked: &mut Vec<(u64, Vec<u8>)>, rej: &mut Vec<u64>| {
        for &k in ks {
            match eng.put(table, k, &payload(k, round)) {
                Ok(()) => acked.push((k, payload(k, round))),
                Err(_) => rej.push(k),
            }
        }
    };

    // Round 0: healthy — every write must ack.
    write(0, &keys, &mut acked, &mut rejected);
    assert_eq!(acked.len(), keys.len(), "healthy writes must all ack");

    // Round 1: the victim wobbles with a seeded per-op error rate.
    c.fault(victim).unwrap().set_error_rate(0.4);
    write(1, &keys, &mut acked, &mut rejected);
    c.fault(victim).unwrap().set_error_rate(0.0);

    // Round 2: hard crash mid burst — half the keys land before the
    // crash, half after (post-crash writes to the victim's shard bounce
    // with `NodeDown` until the control plane promotes).
    let (before, after) = keys.split_at(keys.len() / 2);
    write(2, before, &mut acked, &mut rejected);
    c.fault(victim).unwrap().crash();
    write(2, after, &mut acked, &mut rejected);
    assert!(!rejected.is_empty(), "the crashed leader must reject its mid-burst writes");

    // Failover: the next control-plane tick promotes past the corpse.
    let reports = c.control().tick();
    assert!(
        reports.iter().any(|r| r.from == victim),
        "tick must promote the dead leader's shard, got {reports:?}"
    );
    let sets = c.control().sets_for("img");
    assert_ne!(sets[0].leader_node(), victim, "shard 0 must have a new leader");
    assert!(sets[0].epoch() >= 1, "promotion must bump the epoch");

    // Read-your-writes across the failover: the last ACKED payload per
    // key is exactly what the new leadership serves. A rejected write
    // never surfaces (fully applied or absent — here absent, since the
    // leader rejected it before framing a round).
    let expected: BTreeMap<u64, Vec<u8>> = acked.iter().cloned().collect();
    for (k, v) in &expected {
        let got = bytes(eng.get(table, *k).unwrap());
        assert_eq!(got.as_ref(), Some(v), "acked write to key {k} lost in failover");
    }

    // Retrying every rejected write against the new leadership acks.
    let bounced: Vec<u64> = rejected.clone();
    write(3, &bounced, &mut acked, &mut rejected);
    assert_eq!(rejected, bounced, "retries against the new leader must all ack");
    let expected: BTreeMap<u64, Vec<u8>> = acked.iter().cloned().collect();
    for (k, v) in &expected {
        assert_eq!(bytes(eng.get(table, *k).unwrap()).as_ref(), Some(v));
    }

    // The operator surface reflects the drill.
    let status = c.cluster_status();
    assert!(status.contains("project img"), "status must list the project:\n{status}");
    assert!(c.failover("nope", 0).is_err(), "failover on an unknown token must fail");

    DrillOutcome {
        victim,
        acked,
        rejected,
        promotions: reports.iter().map(|r| (r.shard, r.from, r.to, r.epoch, r.lost_lsns)).collect(),
        fault_logs: (0..3).map(|n| c.fault(n).unwrap().fired()).collect(),
    }
}

/// The headline test: kill the leader mid-write, prove the contract,
/// and prove the whole run replays bit-for-bit from the fault seed.
#[test]
fn kill_leader_mid_write_is_survivable_and_deterministic() {
    let seed = fault_seed();
    let first = failover_drill(seed);
    let second = failover_drill(seed);
    assert_eq!(first, second, "drill must be reproducible from OCPD_FAULT_SEED={seed}");
}

/// Property: across randomized interleavings of put/delete/mixed
/// rounds, batch sizes, replica counts, and mid-sequence promotions,
/// every follower's engine ends byte-identical to the leader's.
#[test]
fn followers_stay_byte_identical_to_leader() {
    property("replication_follower_identical", 25, |g| {
        let n = 2 + g.usize_below(2); // 2..=3 replicas
        let engines: Vec<Engine> =
            (0..n).map(|_| Arc::new(MemStore::new()) as Engine).collect();
        let members = engines.iter().cloned().enumerate().collect();
        let set =
            ReplicaSet::new("t", 0, (0, u64::MAX), members, ReplicationConfig::default()).unwrap();
        let mut epoch = set.epoch();
        let tables = ["t/a", "t/b"];

        for _ in 0..(4 + g.usize_below(12)) {
            let table = tables[g.usize_below(tables.len())];
            let batch = 1 + g.usize_below(16);
            match g.usize_below(3) {
                0 => {
                    let items: Vec<(u64, Vec<u8>)> = (0..batch)
                        .map(|_| {
                            let k = g.u64_below(512);
                            (k, payload(k, 1))
                        })
                        .collect();
                    set.put_batch(epoch, table, &items).unwrap();
                }
                1 => {
                    let ks = g.vec_u64(batch, 512);
                    set.delete_batch(epoch, table, &ks).unwrap();
                }
                _ => {
                    let muts: Vec<(u64, Option<Vec<u8>>)> = (0..batch)
                        .map(|_| {
                            let k = g.u64_below(512);
                            (k, g.chance(0.7).then(|| payload(k, 2)))
                        })
                        .collect();
                    set.apply(epoch, table, &muts).unwrap();
                }
            }
            if g.chance(0.15) {
                set.promote().unwrap();
                epoch = set.epoch();
            }
        }

        // Heal anything a promotion demoted, then compare bytes.
        set.catch_up();
        set.sync().unwrap();
        for t in tables {
            let keys = engines[0].keys(t).unwrap();
            for (i, e) in engines.iter().enumerate().skip(1) {
                assert_eq!(e.keys(t).unwrap(), keys, "replica {i} key set diverged on {t}");
                for &k in &keys {
                    assert_eq!(
                        e.get(t, k).unwrap().as_deref(),
                        engines[0].get(t, k).unwrap().as_deref(),
                        "replica {i} diverged on {t}/{k}"
                    );
                }
            }
        }
    });
}

/// Regression: after a failover, every pre-failover epoch snapshot is
/// fenced — direct replica-set reads and writes, and cuboid-cache
/// installs alike. No stale data can surface from a cache entry or a
/// demoted leader.
#[test]
fn stale_epoch_readers_are_fenced_after_failover() {
    // Direct replica-set fence: reads AND writes holding the old epoch.
    let engines: Vec<Engine> = (0..2).map(|_| Arc::new(MemStore::new()) as Engine).collect();
    let members = engines.iter().cloned().enumerate().collect();
    let set =
        ReplicaSet::new("t", 0, (0, u64::MAX), members, ReplicationConfig::default()).unwrap();
    let old = set.epoch();
    set.put_batch(old, "t/c", &[(1, vec![7])]).unwrap();
    set.promote().unwrap();
    match set.get(old, "t/c", 1) {
        Err(Error::Fenced { held, current }) => {
            assert_eq!(held, old);
            assert_eq!(current, old + 1);
        }
        other => panic!("stale read must fence, got {other:?}"),
    }
    assert!(
        matches!(set.put_batch(old, "t/c", &[(2, vec![8])]), Err(Error::Fenced { .. })),
        "stale write must fence"
    );
    let fresh = set.epoch();
    assert_eq!(bytes(set.get(fresh, "t/c", 1).unwrap()), Some(vec![7]));

    // Cluster-level: a promotion clears the project's cuboid cache, so
    // an insert racing the failover (snapshotted epoch, then promoted)
    // is refused and the routed read serves the replicated truth.
    let c = drill_cluster(fault_seed(), Duration::ZERO, false);
    let eng = drill_engine(&c);
    let k0 = drill_keys(&eng)[0];
    eng.put("img/drill", k0, &[1, 2, 3]).unwrap();
    let cache = c.cache("img").unwrap();
    let snap = cache.epoch("img/cuboids", k0);
    let report = c.failover("img", 0).unwrap();
    assert!(report.epoch >= 1);
    assert!(
        !cache.insert_if("img/cuboids", k0, Some(Arc::new(vec![9])), snap),
        "pre-failover cache snapshot must be fenced"
    );
    let snap = cache.epoch("img/cuboids", k0);
    assert!(cache.insert_if("img/cuboids", k0, Some(Arc::new(vec![9])), snap));

    // The routed surface retries through the fence transparently: the
    // pre-failover write reads back, a post-failover write lands on the
    // new leader and reads back too.
    assert_eq!(bytes(eng.get("img/drill", k0).unwrap()), Some(vec![1, 2, 3]));
    eng.put("img/drill", k0, &[4, 5, 6]).unwrap();
    assert_eq!(bytes(eng.get("img/drill", k0).unwrap()), Some(vec![4, 5, 6]));
}

/// The background monitor (no manual ticks) detects a crashed leader
/// and promotes within its lease, keeping every acked write readable.
#[test]
fn monitor_promotes_dead_leader_within_lease() {
    let c = drill_cluster(fault_seed(), Duration::from_millis(50), true);
    let eng = drill_engine(&c);
    let keys = drill_keys(&eng);
    for &k in &keys {
        eng.put("img/drill", k, &payload(k, 0)).unwrap();
    }
    let victim = c.control().sets_for("img")[0].leader_node();
    c.fault(victim).unwrap().crash();

    let deadline = Instant::now() + Duration::from_secs(5);
    while c.control().sets_for("img")[0].leader_node() == victim {
        assert!(Instant::now() < deadline, "monitor failed to promote within 5s");
        std::thread::sleep(Duration::from_millis(10));
    }
    for &k in &keys {
        assert_eq!(bytes(eng.get("img/drill", k).unwrap()), Some(payload(k, 0)));
    }
}

/// The replication surface over real HTTP: `/cluster/status/` lists the
/// sets, `/cluster/failover/` promotes, and both client helpers parse.
#[test]
fn cluster_routes_over_http() {
    let c = drill_cluster(fault_seed(), Duration::ZERO, false);
    let _eng = drill_engine(&c);
    let server = ocpd::web::serve(c, None, "127.0.0.1:0", 4).unwrap();
    let url = server.url();

    let status = ocpd::client::cluster_status(&url).unwrap();
    assert!(status.contains("project img"), "status must list the project:\n{status}");
    assert!(status.contains("nodes:"), "status must list node health:\n{status}");

    let out = ocpd::client::cluster_failover(&url, "img", 0).unwrap();
    assert!(out.contains("promoted"), "failover must report the promotion: {out}");
    assert!(out.contains("epoch=1"), "failover must report the bumped epoch: {out}");

    let status = ocpd::client::cluster_status(&url).unwrap();
    assert!(status.contains("failovers=1"), "status must count the failover:\n{status}");

    // Unknown token -> client error, not a hang or a 200.
    assert!(ocpd::client::cluster_failover(&url, "nope", 0).is_err());
}
