//! Batch compute engine integration: crash/resume fidelity and parity
//! with the one-shot pipelines.
//!
//! * A `Propagate` job killed mid-run (budget-stopped, as a crash
//!   stand-in — resume relies only on the checkpoint journal) resumes
//!   and produces volumes byte-identical to an uninterrupted run.
//! * `Propagate` job output is byte-identical to the one-shot
//!   [`Propagator`] (the satellite parity contract for the
//!   reuse-previous-level optimization).
//! * A `SynapseDetect` job at 4 workers matches the sequential
//!   `SynapsePipeline` detection set (requires `make artifacts`;
//!   skipped gracefully without them).

use std::sync::Arc;

use ocpd::annotation::{AnnotationDb, Predicate};
use ocpd::array::DenseVolume;
use ocpd::chunkstore::CuboidStore;
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project, Vec3, WriteDiscipline};
use ocpd::cutout::CutoutService;
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::jobs::{JobConfig, JobManager, JobState, PropagateJob, SynapseDetectJob};
use ocpd::resolution::Propagator;
use ocpd::storage::{Engine, MemStore};
use ocpd::util::Rng;

fn image_service(dims: Vec3, levels: u32) -> Arc<CutoutService> {
    let ds = Arc::new(DatasetBuilder::new("t", dims).levels(levels).build());
    let pr = Arc::new(Project::image("img", "t"));
    Arc::new(CutoutService::new(Arc::new(CuboidStore::new(
        ds,
        pr,
        Arc::new(MemStore::new()),
    ))))
}

/// An annotation database over small (32x32x8) cuboids so propagation
/// plans several blocks even at test-sized volumes.
fn anno_db(dims: Vec3, levels: u32) -> Arc<AnnotationDb> {
    let ds = Arc::new(
        DatasetBuilder::new("t", dims)
            .levels(levels)
            .cuboids([32, 32, 8], [16, 16, 16])
            .build(),
    );
    let pr = Arc::new(Project::annotation("ann", "t"));
    let engine: Engine = Arc::new(MemStore::new());
    let store = Arc::new(CuboidStore::new(ds, pr, Arc::clone(&engine)));
    Arc::new(AnnotationDb::new(store, engine).unwrap())
}

/// Random sparse labels: deterministic for a seed, ~25% zero.
fn random_labels(dims: Vec3, seed: u64) -> DenseVolume<u32> {
    let mut rng = Rng::new(seed);
    let n = (dims[0] * dims[1] * dims[2]) as usize;
    DenseVolume::from_vec(
        dims,
        (0..n)
            .map(|_| {
                let v = rng.next_u32() % 64;
                if v < 16 {
                    0
                } else {
                    v
                }
            })
            .collect(),
    )
    .unwrap()
}

fn manager() -> JobManager {
    JobManager::new(Arc::new(MemStore::new()))
}

/// Read every level above base fully and concatenate the bytes.
fn hierarchy_bytes_u32(svc: &CutoutService) -> Vec<u8> {
    let mut out = Vec::new();
    let levels = svc.store().dataset.num_levels();
    for res in 1..levels {
        let dims = svc.store().dataset.level(res).unwrap().dims;
        let vol = svc.read::<u32>(res, 0, 0, Box3::new([0, 0, 0], dims)).unwrap();
        out.extend_from_slice(vol.as_bytes());
    }
    out
}

#[test]
fn propagate_job_matches_one_shot_propagator_image() {
    // Power-of-two and ragged (odd-truncating) volume shapes.
    for dims in [[256u64, 256, 32], [200, 120, 24]] {
        let a = image_service(dims, 3);
        let b = image_service(dims, 3);
        let whole = Box3::new([0, 0, 0], dims);
        let mut rng = Rng::new(17);
        let n = whole.volume() as usize;
        let vol = DenseVolume::<u8>::from_vec(
            dims,
            (0..n).map(|_| rng.next_u32() as u8).collect(),
        )
        .unwrap();
        a.write(0, 0, 0, whole, &vol).unwrap();
        b.write(0, 0, 0, whole, &vol).unwrap();

        // One-shot path on A; the batch job (4 workers) on B.
        Propagator::new(&a).propagate_image().unwrap();
        let h = manager()
            .submit(Arc::new(PropagateJob::image(Arc::clone(&b))), JobConfig::with_workers(4))
            .unwrap();
        assert_eq!(h.wait(), JobState::Completed);

        for res in 1..3u32 {
            let d = a.store().dataset.level(res).unwrap().dims;
            let box_ = Box3::new([0, 0, 0], d);
            let va = a.read::<u8>(res, 0, 0, box_).unwrap();
            let vb = b.read::<u8>(res, 0, 0, box_).unwrap();
            assert_eq!(va.as_bytes(), vb.as_bytes(), "dims {dims:?} level {res}");
        }
    }
}

#[test]
fn propagate_job_deep_hierarchy_banded_parity() {
    // Five levels span two bands (phases): the second band reads the
    // level the first band built, across the engine's phase barrier —
    // and the result still matches the one-shot Propagator byte for
    // byte.
    let dims = [256u64, 256, 16];
    let mk = || {
        let ds = Arc::new(
            DatasetBuilder::new("t", dims)
                .levels(5)
                .cuboids([16, 16, 8], [16, 16, 8])
                .build(),
        );
        let pr = Arc::new(Project::image("img", "t"));
        Arc::new(CutoutService::new(Arc::new(CuboidStore::new(
            ds,
            pr,
            Arc::new(MemStore::new()),
        ))))
    };
    let a = mk();
    let b = mk();
    let whole = Box3::new([0, 0, 0], dims);
    let mut rng = Rng::new(23);
    let n = whole.volume() as usize;
    let vol =
        DenseVolume::<u8>::from_vec(dims, (0..n).map(|_| rng.next_u32() as u8).collect())
            .unwrap();
    a.write(0, 0, 0, whole, &vol).unwrap();
    b.write(0, 0, 0, whole, &vol).unwrap();
    Propagator::new(&a).propagate_image().unwrap();
    let h = manager()
        .submit(Arc::new(PropagateJob::image(Arc::clone(&b))), JobConfig::with_workers(4))
        .unwrap();
    assert_eq!(h.wait(), JobState::Completed);
    let st = h.status();
    assert!(st.total_blocks >= 10, "want multi-band plan, got {}", st.total_blocks);
    for res in 1..5u32 {
        let d = a.store().dataset.level(res).unwrap().dims;
        let box_ = Box3::new([0, 0, 0], d);
        assert_eq!(
            a.read::<u8>(res, 0, 0, box_).unwrap().as_bytes(),
            b.read::<u8>(res, 0, 0, box_).unwrap().as_bytes(),
            "level {res}"
        );
    }
}

#[test]
fn propagate_job_matches_one_shot_propagator_labels() {
    let dims = [160u64, 96, 24];
    let a = anno_db(dims, 3);
    let b = anno_db(dims, 3);
    let whole = Box3::new([0, 0, 0], dims);
    let labels = random_labels(dims, 5);
    a.write_volume(0, whole, &labels, WriteDiscipline::Overwrite).unwrap();
    b.write_volume(0, whole, &labels, WriteDiscipline::Overwrite).unwrap();

    Propagator::new(&a.cutout).propagate_annotations().unwrap();
    let h = manager()
        .submit(
            Arc::new(PropagateJob::annotation(Arc::clone(&b))),
            JobConfig::with_workers(4),
        )
        .unwrap();
    assert_eq!(h.wait(), JobState::Completed);
    assert_eq!(hierarchy_bytes_u32(&a.cutout), hierarchy_bytes_u32(&b.cutout));
}

#[test]
fn propagate_job_killed_midway_resumes_byte_identical() {
    let dims = [256u64, 128, 24]; // 6 blocks with 32x32x8 cuboids
    let whole = Box3::new([0, 0, 0], dims);
    let labels = random_labels(dims, 9);

    // Reference: an uninterrupted run.
    let a = anno_db(dims, 3);
    a.write_volume(0, whole, &labels, WriteDiscipline::Overwrite).unwrap();
    let h = manager()
        .submit(Arc::new(PropagateJob::annotation(Arc::clone(&a))), JobConfig::with_workers(2))
        .unwrap();
    assert_eq!(h.wait(), JobState::Completed);
    let total = h.status().total_blocks;
    assert!(total >= 6, "want several blocks, got {total}");

    // Interrupted run: stop after 2 block completions — the engine
    // behaves exactly as after a kill, because resume consults nothing
    // but the checkpoint journal.
    let b = anno_db(dims, 3);
    b.write_volume(0, whole, &labels, WriteDiscipline::Overwrite).unwrap();
    let m = manager();
    let cfg = JobConfig { workers: 2, max_blocks: Some(2), ..JobConfig::default() };
    let h1 = m
        .submit(Arc::new(PropagateJob::annotation(Arc::clone(&b))), cfg)
        .unwrap();
    assert_eq!(h1.wait(), JobState::Cancelled);
    let partial = h1.status().completed_blocks;
    assert!(partial >= 2 && partial < total, "partial={partial} total={total}");

    // Resume under the same id with a freshly-built spec (what a
    // restarted process would construct).
    let h2 = m
        .submit_with_id(
            h1.id,
            Arc::new(PropagateJob::annotation(Arc::clone(&b))),
            JobConfig::with_workers(2),
        )
        .unwrap();
    assert_eq!(h2.wait(), JobState::Completed);
    let st = h2.status();
    assert_eq!(st.resumed_blocks, partial, "resume must start from the journal");
    assert_eq!(st.completed_blocks, total);

    // The contract: byte-identical hierarchy vs. the uninterrupted run.
    assert_eq!(hierarchy_bytes_u32(&a.cutout), hierarchy_bytes_u32(&b.cutout));
}

#[test]
fn propagate_job_resume_when_already_complete_is_a_noop() {
    let dims = [128u64, 64, 8];
    let db = anno_db(dims, 2);
    let whole = Box3::new([0, 0, 0], dims);
    db.write_volume(0, whole, &random_labels(dims, 3), WriteDiscipline::Overwrite).unwrap();
    let m = manager();
    let h = m
        .submit(Arc::new(PropagateJob::annotation(Arc::clone(&db))), JobConfig::default())
        .unwrap();
    assert_eq!(h.wait(), JobState::Completed);
    let before = hierarchy_bytes_u32(&db.cutout);
    // Resubmit: every block is already journaled.
    let h2 = m
        .submit_with_id(h.id, Arc::new(PropagateJob::annotation(Arc::clone(&db))), JobConfig::default())
        .unwrap();
    assert_eq!(h2.wait(), JobState::Completed);
    let st = h2.status();
    assert_eq!(st.resumed_blocks, st.total_blocks);
    assert_eq!(st.completed_blocks, st.total_blocks);
    assert_eq!(hierarchy_bytes_u32(&db.cutout), before);
}

// ----------------------------------------------------------------------
// Synapse detection (requires `make artifacts`; skipped without them)
// ----------------------------------------------------------------------

fn runtime() -> Option<Arc<ocpd::runtime::Runtime>> {
    ocpd::runtime::Runtime::load_dir(ocpd::runtime::artifact_dir()).ok().map(Arc::new)
}

fn boot_pair(
    dims: Vec3,
    seed: u64,
) -> (Arc<Cluster>, Arc<CutoutService>, Arc<AnnotationDb>) {
    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(DatasetBuilder::new("synth", dims).levels(1).build());
    let img = cluster.create_image_project(Project::image("synth", "synth")).unwrap();
    let anno = cluster
        .create_annotation_project(Project::annotation("syn", "synth"), true)
        .unwrap();
    let sv = generate(&SynthSpec::small(dims, seed));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    (cluster, img, anno)
}

#[test]
fn synapse_detect_job_at_4_workers_matches_sequential_pipeline() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let dims = [256u64, 256, 16];
    let region = Box3::new([0, 0, 0], dims);

    // Sequential reference: the one-shot pipeline, one worker.
    let (_ca, img_a, ann_a) = boot_pair(dims, 3);
    let mut seq = ocpd::vision::SynapsePipeline::new(Arc::clone(&rt), img_a, ann_a);
    seq.workers = 1;
    let report = seq.run(0, region).unwrap();

    // The batch job at 4 workers over an identical cluster.
    let (cb, img_b, ann_b) = boot_pair(dims, 3);
    let pipeline =
        Arc::new(ocpd::vision::SynapsePipeline::new(rt, img_b, Arc::clone(&ann_b)));
    let h = cb
        .jobs()
        .submit(
            Arc::new(SynapseDetectJob::new(pipeline, 0, region)),
            JobConfig::with_workers(4),
        )
        .unwrap();
    assert_eq!(h.wait(), JobState::Completed);
    let st = h.status();
    assert_eq!(st.items as usize, report.detections.len(), "detection counts differ");

    // Same detection set: compare centroid multisets through the RAMON
    // metadata the job wrote (ids differ by assignment order).
    let ids = ann_b.query(&[Predicate::eq("type", "synapse")]).unwrap();
    assert_eq!(ids.len(), report.detections.len());
    let mut got: Vec<Vec3> = ids
        .iter()
        .map(|&id| ann_b.get_object(id).unwrap().position)
        .collect();
    let mut want: Vec<Vec3> = report.detections.iter().map(|d| d.centroid).collect();
    got.sort_unstable();
    want.sort_unstable();
    assert_eq!(got, want, "4-worker job must detect the sequential set");
}
