//! Transport-tier conformance: keep-alive, pipelining, streaming
//! cutouts, admission control, and parser robustness under hostile or
//! fragmented input.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use ocpd::array::DenseVolume;
use ocpd::client::OcpClient;
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::util::Rng;
use ocpd::web::http::{request, request_info, request_once};
use ocpd::web::{serve_with, ServeOptions, Server};

fn fixture(dims: [u64; 3], stream_threshold: usize) -> (Server, DenseVolume<u8>) {
    let cluster = Cluster::in_memory(1, 1);
    cluster.register_dataset(DatasetBuilder::new("img", dims).levels(1).build());
    let img = cluster.create_image_project(Project::image("img", "img")).unwrap();
    let sv = generate(&SynthSpec::small(dims, 11));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    let server = serve_with(
        cluster,
        None,
        "127.0.0.1:0",
        ServeOptions { stream_threshold, ..ServeOptions::default() },
    )
    .unwrap();
    (server, sv.vol)
}

/// The request counter increments after the response is written, so
/// wait for it to catch up before asserting exact counts.
fn await_requests(server: &Server, n: u64) {
    let t0 = std::time::Instant::now();
    while server.metrics.requests.get() < n && t0.elapsed() < Duration::from_secs(2) {
        std::thread::yield_now();
    }
}

/// Read one full HTTP response (status, headers, Content-Length body)
/// from a buffered raw socket.
fn read_response(reader: &mut BufReader<TcpStream>) -> (u16, Vec<u8>) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 =
        status_line.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap();
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).unwrap();
    (status, body)
}

#[test]
fn every_route_shape_works_over_one_reused_connection() {
    // Keep-alive parity: the grammar's GET routes answered back-to-back
    // on a single pooled socket. The pooled client reuses the same
    // connection for sequential requests, so connections stays at 1.
    let (server, truth) = fixture([128, 128, 16], usize::MAX);
    let url = server.url();
    let client = OcpClient::new(&url, "img");
    let bx = Box3::new([0, 0, 0], [64, 64, 8]);
    for _ in 0..3 {
        assert_eq!(client.cutout_u8(0, bx).unwrap(), truth.extract_box(bx));
        let (code, _) = request("GET", &format!("{url}/info/"), &[]).unwrap();
        assert_eq!(code, 200);
        let (code, _) = request("GET", &format!("{url}/img/tile/0/3/0_0.gray"), &[]).unwrap();
        assert_eq!(code, 200);
    }
    await_requests(&server, 9);
    assert_eq!(server.metrics.requests.get(), 9);
    assert_eq!(
        server.metrics.connections.get(),
        1,
        "sequential pooled requests must share one connection"
    );
    assert!(server.metrics.reuse_ratio() >= 9.0);
}

#[test]
fn pipelined_requests_answer_in_order() {
    let (server, _) = fixture([64, 64, 8], usize::MAX);
    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // Four requests in one write, no reads in between.
    let mut batch = String::new();
    for i in 0..4 {
        batch.push_str(&format!("GET /q{i}/ HTTP/1.1\r\nHost: t\r\n\r\n"));
    }
    s.write_all(batch.as_bytes()).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    for i in 0..4 {
        let (status, body) = read_response(&mut reader);
        // Unknown single-segment paths are 400s, but the body echoes
        // the path — proving responses come back in request order.
        assert_eq!(status, 400);
        assert!(
            String::from_utf8_lossy(&body).contains(&format!("/q{i}")),
            "response {i} out of order: {}",
            String::from_utf8_lossy(&body)
        );
    }
}

#[test]
fn pipelined_requests_with_bodies_keep_framing() {
    let (server, _) = fixture([64, 64, 8], usize::MAX);
    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // A PUT whose body must be fully consumed before the next request
    // line, then a GET. If body framing slips, the GET line is eaten.
    let mut batch = Vec::new();
    batch.extend_from_slice(b"PUT /jobs/cancel/999/ HTTP/1.1\r\nContent-Length: 9\r\n\r\nworkers=1");
    batch.extend_from_slice(b"GET /info/ HTTP/1.1\r\n\r\n");
    s.write_all(&batch).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let (status, _) = read_response(&mut reader);
    assert_eq!(status, 404); // job 999 does not exist
    let (status, body) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert!(String::from_utf8_lossy(&body).contains("projects:"));
}

#[test]
fn request_head_split_across_many_tcp_writes() {
    // Property-style: a valid request head delivered in randomized
    // fragments (flushed separately) must parse identically to a
    // single-write delivery, across many seeds.
    let (server, _) = fixture([64, 64, 8], usize::MAX);
    let raw = b"GET /info/ HTTP/1.1\r\nHost: split\r\nX-Pad: abcdef\r\n\r\n";
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut at = 0usize;
        while at < raw.len() {
            let take = 1 + rng.below((raw.len() - at) as u64) as usize;
            s.write_all(&raw[at..at + take]).unwrap();
            s.flush().unwrap();
            at += take;
            if rng.chance(0.3) {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        let (status, body) = read_response(&mut BufReader::new(s));
        assert_eq!(status, 200, "seed {seed}");
        assert!(String::from_utf8_lossy(&body).contains("routes:"), "seed {seed}");
    }
}

#[test]
fn put_body_split_across_tcp_writes_roundtrips() {
    let (server, _) = fixture([64, 64, 8], usize::MAX);
    // A body delivered byte-dribble must keep its Content-Length
    // framing: the request parses cleanly (the 404 proves routing ran,
    // i.e. the head and body were consumed exactly) at every split.
    let payload = b"workers=3 dims=1,2,3";
    for seed in [3u64, 17, 99] {
        let mut rng = Rng::new(seed);
        let head =
            format!("PUT /jobs/cancel/1234/ HTTP/1.1\r\nContent-Length: {}\r\n\r\n", payload.len());
        let mut raw = head.into_bytes();
        raw.extend_from_slice(payload);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut at = 0usize;
        while at < raw.len() {
            let take = 1 + rng.below(7.min((raw.len() - at) as u64)) as usize;
            s.write_all(&raw[at..at + take]).unwrap();
            s.flush().unwrap();
            at += take;
        }
        let (status, _) = read_response(&mut BufReader::new(s));
        assert_eq!(status, 404, "seed {seed}"); // parsed fine; job doesn't exist
    }
}

#[test]
fn oversized_and_conflicting_heads_rejected_without_hanging() {
    let (server, _) = fixture([64, 64, 8], usize::MAX);
    let cases: &[&[u8]] = &[
        // Conflicting Content-Length values.
        b"PUT /x/ HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 7\r\n\r\nabcd",
        // Chunked request body (unsupported for requests).
        b"PUT /x/ HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n",
        // Garbage request line.
        b"\x7f\x45\x4c\x46 what HTTP/9.9\r\n\r\n",
    ];
    for (i, payload) in cases.iter().enumerate() {
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let _ = s.write_all(payload);
        let _ = s.shutdown(std::net::Shutdown::Write);
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line).unwrap();
        let status: u16 =
            line.split_whitespace().nth(1).and_then(|v| v.parse().ok()).unwrap_or(0);
        assert_eq!(status, 400, "case {i}: {line}");
    }
    // An oversized single header line: cut off at the head cap.
    let mut huge = b"GET /info/ HTTP/1.1\r\nX-Junk: ".to_vec();
    huge.extend(std::iter::repeat(b'z').take(100 << 10));
    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let _ = s.write_all(&huge);
    let _ = s.shutdown(std::net::Shutdown::Write);
    let mut line = String::new();
    BufReader::new(s).read_line(&mut line).unwrap();
    assert!(line.contains("400"), "{line}");
}

#[test]
fn absent_content_length_means_empty_body() {
    let (server, _) = fixture([64, 64, 8], usize::MAX);
    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // A PUT with no Content-Length parses as a zero-length body (here:
    // flush-all with an empty params body).
    s.write_all(b"PUT /wal/flush/ HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let (status, body) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert!(String::from_utf8_lossy(&body).starts_with("flushed="));
    // And the connection is still usable (framing did not slip).
    s.write_all(b"GET /info/ HTTP/1.1\r\n\r\n").unwrap();
    let (status, _) = read_response(&mut reader);
    assert_eq!(status, 200);
}

#[test]
fn large_cutout_streams_chunked_and_matches_buffered() {
    // Same request served buffered (high threshold) and streamed (low
    // threshold) must be byte-identical after decode; the streamed one
    // must actually arrive chunked with bounded chunks.
    let dims = [256u64, 256, 64];
    let bx = Box3::new([0, 0, 0], dims);

    let (buffered_server, truth) = fixture(dims, usize::MAX);
    let info =
        request_info("GET", &format!("{}/img/ocpk/0/0,256/0,256/0,64/", buffered_server.url()), &[])
            .unwrap();
    assert_eq!(info.status, 200);
    assert!(!info.chunked, "threshold=MAX must buffer");
    let (_, _, buffered_vol) = ocpd::web::ocpk::decode_volume::<u8>(&info.body).unwrap();
    drop(buffered_server);

    let (streaming_server, _) = fixture(dims, 1 << 20);
    let info = request_info(
        "GET",
        &format!("{}/img/ocpk/0/0,256/0,256/0,64/", streaming_server.url()),
        &[],
    )
    .unwrap();
    assert_eq!(info.status, 200);
    assert!(info.chunked, "a 4 MiB raw cutout above a 1 MiB threshold must stream");
    // Chunk high-water mark stays at the slab size — well below the
    // whole 4 MiB payload (the peak-memory win).
    let raw_total = (dims[0] * dims[1] * dims[2]) as usize;
    assert!(info.max_chunk > 0 && info.max_chunk <= raw_total / 2, "{}", info.max_chunk);
    let (_, obx, streamed_vol) = ocpd::web::ocpk::decode_volume::<u8>(&info.body).unwrap();
    assert_eq!(obx, bx);
    assert_eq!(streamed_vol, buffered_vol);
    assert_eq!(streamed_vol, truth.extract_box(bx));
    assert!(streaming_server.metrics.streamed_responses.get() >= 1);
    assert!(streaming_server.metrics.stream_peak_chunk.get() > 0);

    // An unaligned streamed box decodes correctly too.
    let ub = Box3::new([3, 5, 1], [250, 251, 63]);
    let info = request_info(
        "GET",
        &format!("{}/img/ocpk/0/3,250/5,251/1,63/", streaming_server.url()),
        &[],
    )
    .unwrap();
    assert_eq!(info.status, 200);
    assert!(info.chunked);
    let (_, obx, vol) = ocpd::web::ocpk::decode_volume::<u8>(&info.body).unwrap();
    assert_eq!(obx, ub);
    assert_eq!(vol, truth.extract_box(ub));
}

#[test]
fn http_status_route_reports_transport_metrics() {
    let (server, _) = fixture([64, 64, 8], usize::MAX);
    let url = server.url();
    for _ in 0..4 {
        let (code, _) = request("GET", &format!("{url}/info/"), &[]).unwrap();
        assert_eq!(code, 200);
    }
    let status = ocpd::client::http_status(&url).unwrap();
    assert!(status.starts_with("http:"), "{status}");
    assert!(status.contains("requests="), "{status}");
    assert!(status.contains("reuse="), "{status}");
    assert!(status.contains("latency:"), "{status}");
    // Per-route histograms name the routes that served.
    assert!(status.contains("info:"), "{status}");
    // The legacy dead-metric gap: Server::requests now surfaces here.
    let served: u64 = status
        .lines()
        .find(|l| l.trim_start().starts_with("requests="))
        .and_then(|l| {
            l.trim_start()
                .split_whitespace()
                .next()
                .and_then(|kv| kv.strip_prefix("requests=")?.parse().ok())
        })
        .unwrap();
    // The /http/status request itself is still in flight when the
    // handler snapshots the counter, so it reports the 4 completed.
    assert!(served >= 4);
    // Wrong method and unknown subroutes behave like other reserved
    // names.
    let (code, _) = request("PUT", &format!("{url}/http/status/"), &[]).unwrap();
    assert_eq!(code, 405);
    let (code, _) = request("GET", &format!("{url}/http/nope/"), &[]).unwrap();
    assert_eq!(code, 400);
}

#[test]
fn info_lists_routes_from_the_table() {
    let (server, _) = fixture([64, 64, 8], usize::MAX);
    let info = ocpd::client::cluster_info(&server.url()).unwrap();
    assert!(info.contains("routes:"), "{info}");
    for needle in ["/{token}/ocpk/", "/wal/flush/", "/http/status/", "/jobs/propagate/"] {
        assert!(info.contains(needle), "missing {needle} in:\n{info}");
    }
}

#[test]
fn close_per_request_and_keepalive_coexist() {
    let (server, _) = fixture([64, 64, 8], usize::MAX);
    let url = server.url();
    let (code, _) = request_once("GET", &format!("{url}/info/"), &[]).unwrap();
    assert_eq!(code, 200);
    let (code, _) = request("GET", &format!("{url}/info/"), &[]).unwrap();
    assert_eq!(code, 200);
    let (code, _) = request("GET", &format!("{url}/info/"), &[]).unwrap();
    assert_eq!(code, 200);
    // 3 requests over 2 connections (one closed, one reused).
    await_requests(&server, 3);
    assert_eq!(server.metrics.requests.get(), 3);
    assert_eq!(server.metrics.connections.get(), 2);
}

#[test]
fn concurrent_keepalive_clients_hammering() {
    let (server, truth) = fixture([128, 128, 16], usize::MAX);
    let server = Arc::new(server);
    let handles: Vec<_> = (0..8)
        .map(|i| {
            let server = Arc::clone(&server);
            let truth = truth.clone();
            std::thread::spawn(move || {
                let client = OcpClient::new(&server.url(), "img");
                let x0 = (i % 4) * 16;
                let bx = Box3::new([x0, 0, 0], [x0 + 32, 32, 8]);
                for _ in 0..10 {
                    assert_eq!(client.cutout_u8(0, bx).unwrap(), truth.extract_box(bx));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    await_requests(&server, 80);
    assert!(server.metrics.requests.get() >= 80);
    // 8 workers × 10 sequential requests each should reuse far fewer
    // than 80 connections.
    assert!(
        server.metrics.connections.get() <= 16,
        "connections={} — keep-alive not reusing",
        server.metrics.connections.get()
    );
}
