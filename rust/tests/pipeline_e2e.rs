//! End-to-end integration over runtime + vision: requires `make
//! artifacts` (skipped gracefully when artifacts are absent).

use std::sync::Arc;

use ocpd::array::DenseVolume;
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::runtime::{artifact_dir, Runtime};
use ocpd::vision::{color_correct_volume, precision_recall, SynapsePipeline};

fn runtime() -> Option<Arc<Runtime>> {
    Runtime::load_dir(artifact_dir()).ok().map(Arc::new)
}

fn boot(dims: [u64; 3], seed: u64) -> (Arc<Cluster>, Arc<ocpd::cutout::CutoutService>, Arc<ocpd::annotation::AnnotationDb>) {
    let cluster = Cluster::in_memory(1, 1);
    cluster.register_dataset(DatasetBuilder::new("t", dims).levels(1).build());
    let img = cluster.create_image_project(Project::image("t", "t")).unwrap();
    let anno = cluster
        .create_annotation_project(Project::annotation("a", "t"), true)
        .unwrap();
    let _ = seed;
    (cluster, img, anno)
}

#[test]
fn detector_finds_single_planted_synapse() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let dims = [128u64, 128, 16];
    let (_c, img, anno) = boot(dims, 1);
    // One synapse, no distractors, no noise.
    let spec = SynthSpec {
        dims,
        seed: 5,
        n_synapses: 1,
        n_dendrites: 0,
        n_vessels: 0,
        noise_sigma: 0.0,
        exposure_amp: 0.0,
    };
    let sv = generate(&spec);
    ingest_volume(&img, &sv.vol, [128, 128, 16]).unwrap();

    let pipeline = SynapsePipeline::new(rt, img, anno);
    let report = pipeline.run(0, Box3::new([0, 0, 0], dims)).unwrap();
    assert_eq!(report.blocks, 1);
    assert_eq!(
        report.detections.len(),
        1,
        "expected exactly one detection, got {:?}",
        report
            .detections
            .iter()
            .map(|d| (d.centroid, d.voxels, d.confidence))
            .collect::<Vec<_>>()
    );
    let d = &report.detections[0];
    let t = sv.synapses[0];
    let dist = ((d.centroid[0] as f64 - t[0] as f64).powi(2)
        + (d.centroid[1] as f64 - t[1] as f64).powi(2)
        + (d.centroid[2] as f64 - t[2] as f64).powi(2))
    .sqrt();
    assert!(
        dist <= 3.0,
        "detection at {:?} too far from truth {:?} (dist {dist:.1})",
        d.centroid,
        t
    );
}

#[test]
fn detector_precision_recall_with_distractors() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dims = [256u64, 256, 32];
    let (_c, img, anno) = boot(dims, 2);
    let sv = generate(&SynthSpec::small(dims, 17));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    let mut pipeline = SynapsePipeline::new(rt, img, anno);
    pipeline.workers = 2;
    let report = pipeline.run(0, Box3::new([0, 0, 0], dims)).unwrap();
    let (p, r, _m) = precision_recall(&report.detections, &sv.synapses, 6.0);
    assert!(r > 0.7, "recall {r:.3} (detections {})", report.detections.len());
    assert!(p > 0.7, "precision {p:.3} (detections {})", report.detections.len());
}

#[test]
fn detections_written_as_annotations() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dims = [128u64, 128, 16];
    let (_c, img, anno) = boot(dims, 3);
    let spec = SynthSpec {
        dims,
        seed: 9,
        n_synapses: 3,
        n_dendrites: 0,
        n_vessels: 0,
        noise_sigma: 2.0,
        exposure_amp: 0.0,
    };
    let sv = generate(&spec);
    ingest_volume(&img, &sv.vol, [128, 128, 16]).unwrap();
    let pipeline = SynapsePipeline::new(rt, Arc::clone(&img), Arc::clone(&anno));
    let report = pipeline.run(0, Box3::new([0, 0, 0], dims)).unwrap();
    // Every detection must be readable back: metadata + voxels + index.
    for d in &report.detections {
        let obj = anno.get_object(d.id).unwrap();
        assert_eq!(obj.rtype, ocpd::annotation::RamonType::Synapse);
        assert!((obj.confidence - d.confidence).abs() < 1e-5);
        let voxels = anno.voxel_list(0, d.id).unwrap();
        assert_eq!(voxels.len(), d.voxels);
        assert!(anno.bounding_box(0, d.id).unwrap().is_some());
    }
}

#[test]
fn color_correct_reduces_exposure_variance() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dims = [256u64, 256, 32];
    let cluster = Cluster::in_memory(1, 0);
    cluster.register_dataset(DatasetBuilder::new("s", dims).levels(1).build());
    let raw = cluster.create_image_project(Project::image("s", "s")).unwrap();
    let clean = cluster.create_image_project(Project::image("s_clean", "s")).unwrap();
    let spec = SynthSpec {
        dims,
        seed: 4,
        n_synapses: 10,
        n_dendrites: 2,
        n_vessels: 0,
        noise_sigma: 4.0,
        exposure_amp: 50.0,
    };
    let sv = generate(&spec);
    ingest_volume(&raw, &sv.vol, [256, 256, 16]).unwrap();
    color_correct_volume(&rt, &raw, &clean, 0).unwrap();

    let whole = Box3::new([0, 0, 0], dims);
    let before = raw.read::<u8>(0, 0, 0, whole).unwrap();
    let after = clean.read::<u8>(0, 0, 0, whole).unwrap();
    let section_var = |v: &DenseVolume<u8>| {
        let means: Vec<f64> = (0..dims[2])
            .map(|z| {
                let mut s = 0u64;
                for y in 0..dims[1] {
                    for x in 0..dims[0] {
                        s += v.get([x, y, z]) as u64;
                    }
                }
                s as f64 / (dims[0] * dims[1]) as f64
            })
            .collect();
        let m = means.iter().sum::<f64>() / means.len() as f64;
        means.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / means.len() as f64
    };
    let (vb, va) = (section_var(&before), section_var(&after));
    assert!(va < vb * 0.5, "exposure variance {vb:.1} -> {va:.1}");
}

#[test]
fn downsample_graph_matches_rust_hierarchy() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    // The AOT downsampler and the Rust-side mean downsampler must agree.
    let mut input = DenseVolume::<f32>::zeros([128, 128, 16]);
    for z in 0..16u64 {
        for y in 0..128u64 {
            for x in 0..128u64 {
                input.set([x, y, z], ((x * 31 + y * 7 + z * 3) % 255) as f32 / 255.0);
            }
        }
    }
    let out = rt.run3d("downsample2x", &input).unwrap();
    assert_eq!(out.dims(), [64, 64, 16]);
    for &(x, y, z) in &[(0u64, 0u64, 0u64), (13, 40, 7), (63, 63, 15)] {
        let mean = (input.get([2 * x, 2 * y, z])
            + input.get([2 * x + 1, 2 * y, z])
            + input.get([2 * x, 2 * y + 1, z])
            + input.get([2 * x + 1, 2 * y + 1, z]))
            / 4.0;
        assert!((out.get([x, y, z]) - mean).abs() < 1e-5);
    }
}
