//! End-to-end request tracing (DESIGN.md §9): the `X-Request-Id`
//! correlation header round-trips through the pooled keep-alive client,
//! and a cutout over a sharded cluster leaves a retained span tree with
//! tagged children from every layer it crossed.
//!
//! These tests mutate the process-wide tracer configuration, so they
//! live in their own integration binary; both tests install the same
//! retain-everything config to stay order-independent.

use ocpd::cluster::Cluster;
use ocpd::core::{DatasetBuilder, Project};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::obs::trace::{self, TraceConfig, TraceMode};
use ocpd::web::http::request_info;
use ocpd::web::Server;

/// Retain every trace in the slow ring (threshold 0) so assertions
/// never depend on sampling luck or wall-clock speed.
fn retain_everything() {
    trace::tracer().configure(TraceConfig {
        mode: TraceMode::Always,
        sample_every: 1,
        slow_threshold_us: 0,
        capacity: 256,
    });
}

/// Two database nodes so cutout reads fan out across shards.
fn sharded_fixture() -> Server {
    let dims = [256u64, 256, 32];
    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(DatasetBuilder::new("img", dims).levels(1).build());
    let img = cluster.create_image_project(Project::image("img", "img")).unwrap();
    let sv = generate(&SynthSpec::small(dims, 7));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    ocpd::web::serve(cluster, None, "127.0.0.1:0", 8).unwrap()
}

#[test]
fn request_id_echoes_end_to_end() {
    retain_everything();
    let server = sharded_fixture();
    let url = format!("{}/img/ocpk/0/0,128/0,128/0,16/", server.url());

    // With no ambient trace the client sends no X-Request-Id; the
    // server mints one and names it in the response.
    let info = request_info("GET", &url, &[]).unwrap();
    assert_eq!(info.status, 200);
    let minted = info.request_id.expect("server must always name the trace");
    assert!(minted.starts_with("req-"), "{minted}");

    // With an ambient trace the pooled client stamps its request id
    // outbound, and the server echoes that exact id back.
    let root = trace::start_trace("test", "client-side", "cli-trace-001");
    let info = request_info("GET", &url, &[]).unwrap();
    drop(root);
    assert_eq!(info.status, 200);
    assert_eq!(info.request_id.as_deref(), Some("cli-trace-001"));
}

#[test]
fn sharded_cutout_leaves_layered_span_tree() {
    retain_everything();
    let server = sharded_fixture();

    // Issue the cutout under a client-chosen request id so the exact
    // trace is findable in the retention ring afterwards.
    let req_id = "trace-e2e-cutout-42";
    let url = format!("{}/img/ocpk/0/0,256/0,256/0,32/", server.url());
    let root = trace::start_trace("test", "cutout", req_id);
    let info = request_info("GET", &url, &[]).unwrap();
    drop(root);
    assert_eq!(info.status, 200);
    assert_eq!(info.request_id.as_deref(), Some(req_id));

    // The server finished (and retained) the trace before it wrote the
    // response, so the slow ring already names it.
    let slow = ocpd::client::trace_slow(&server.url()).unwrap();
    let trace_block: String = {
        // Isolate this request's tree: from its header line to the next
        // trace header (traces render newest-first).
        let start = slow
            .find(&format!("trace req={req_id}"))
            .unwrap_or_else(|| panic!("trace {req_id} not retained:\n{slow}"));
        let rest = &slow[start..];
        let end = rest[6..].find("\ntrace req=").map(|i| i + 7).unwrap_or(rest.len());
        rest[..end].to_string()
    };

    // Root span from the HTTP layer, tagged with route + status...
    assert!(trace_block.contains("[http] GET /img/ocpk/"), "{trace_block}");
    assert!(trace_block.contains("status=200"), "{trace_block}");
    // ...a cutout child tagged with the cuboid count...
    assert!(trace_block.contains("[cutout] read"), "{trace_block}");
    assert!(trace_block.contains("cuboids="), "{trace_block}");
    // ...a cache-lookup child reporting hits/misses...
    assert!(trace_block.contains("[cache] lookup"), "{trace_block}");
    assert!(trace_block.contains("misses="), "{trace_block}");
    // ...and shard fan-out batches tagged with their node.
    assert!(trace_block.contains("[shard] get_batch"), "{trace_block}");
    assert!(trace_block.contains("node="), "{trace_block}");

    // The tracer status page reflects retention.
    let status = ocpd::client::trace_status(&server.url()).unwrap();
    assert!(status.contains("mode=always"), "{status}");
    assert!(!status.contains("finished=0 "), "{status}");
}

#[test]
fn pooled_connections_reuse_and_still_correlate() {
    retain_everything();
    let server = sharded_fixture();
    let url = format!("{}/img/ocpk/0/0,64/0,64/0,8/", server.url());
    let mut saw_reuse = false;
    for i in 0..4 {
        let rid = format!("pool-{i}");
        let root = trace::start_trace("test", "pooled", &rid);
        let info = request_info("GET", &url, &[]).unwrap();
        drop(root);
        assert_eq!(info.status, 200);
        assert_eq!(info.request_id.as_deref(), Some(rid.as_str()));
        saw_reuse |= info.reused;
    }
    assert!(saw_reuse, "keep-alive pool never reused a connection");
}
