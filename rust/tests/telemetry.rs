//! Workload telemetry (DESIGN.md §11): the skewed-workload heat-map
//! ranking, per-tenant accounting ledgers, SLO attainment, and the
//! collector lifecycle — a dropped project must vanish from
//! `/metrics/`.

use std::sync::Arc;
use std::time::Duration;

use ocpd::array::DenseVolume;
use ocpd::client::OcpClient;
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project, WriteDiscipline};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::loadgen::{self, LoadgenConfig, ScenarioMix};
use ocpd::web::Server;

const DIMS: [u64; 3] = [256, 256, 32];

/// Boot a two-node sharded cluster with an ingested image project and
/// a hot annotation project, served over HTTP.
fn fixture() -> (Arc<Cluster>, Server) {
    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(DatasetBuilder::new("img", DIMS).levels(2).build());
    let img = cluster.create_image_project(Project::image("img", "img")).unwrap();
    cluster.create_annotation_project(Project::annotation("ann", "img"), true).unwrap();
    let sv = generate(&SynthSpec::small(DIMS, 3));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    let server = ocpd::web::serve(Arc::clone(&cluster), None, "127.0.0.1:0", 8).unwrap();
    (cluster, server)
}

#[test]
fn skewed_workload_tops_the_hot_shard_in_the_heat_ranking() {
    let (cluster, server) = fixture();

    // Open-loop, cutout-only, every request pinned to the origin
    // corner: all traffic lands on the low end of the Morton
    // key-space, which shard 0 owns.
    let mut cfg = LoadgenConfig::new(&server.url(), "img");
    cfg.rate = 400.0;
    cfg.duration = Duration::from_millis(500);
    cfg.concurrency = 4;
    cfg.hotspot = 1.0;
    cfg.mix = ScenarioMix { cutout: 1, tile: 0, write: 0, poll: 0 };
    let report = loadgen::run(&cfg).unwrap();

    // The loadgen itself: every scheduled arrival issued and answered,
    // and the latency histogram is non-empty.
    let overall = report.overall();
    assert_eq!(overall.requests, 200, "{}", report.render_text());
    assert_eq!(overall.transport_errors, 0, "{}", report.render_text());
    assert_eq!(overall.ok, overall.requests, "{}", report.render_text());
    assert!(overall.p50_us > 0);
    assert_eq!(report.rows[1].scenario, "cutout_read");
    assert_eq!(report.rows[1].requests, overall.requests);

    // In-process view: shard 0 ranks first and strictly dominates.
    let heat = cluster.heat("img").expect("image project has a heat tracker");
    let snap = heat.snapshot();
    assert!(snap.total_score > 0.0);
    let hottest = &snap.shards[0];
    assert_eq!(hottest.shard, 0, "origin-corner reads must heat shard 0");
    assert!(hottest.read_ops > 0.0);
    assert!(hottest.read_bytes > 0.0, "cutout responses carry bytes");
    assert!(hottest.score > snap.shards[1].score);

    // The split key a dynamic splitter would use lies strictly inside
    // the hot shard's key range.
    let split = heat.hot_split_key(hottest.shard).expect("hot shard has a split key");
    assert!(split > hottest.lo && split < hottest.hi, "split {split} outside shard");

    // HTTP view agrees: in the img section, the first (hottest-first)
    // shard line is shard 0.
    let body = ocpd::client::heat_status(&server.url()).unwrap();
    let img_section = &body[body.find("  img:").unwrap_or_else(|| panic!("{body}"))..];
    let shard_line = img_section
        .lines()
        .find(|l| l.trim_start().starts_with("shard "))
        .unwrap_or_else(|| panic!("{body}"));
    assert!(shard_line.trim_start().starts_with("shard 0 "), "{body}");
    assert!(img_section.contains("hot ["), "hot bucket ranges listed: {body}");

    // The same traffic showed up in the SLO report (interactive class
    // covers cutout reads) and the per-tenant ledger.
    let slo = ocpd::client::slo_status(&server.url()).unwrap();
    assert!(slo.contains("interactive: threshold="), "{slo}");
    let account = ocpd::client::account_status(&server.url()).unwrap();
    assert!(account.contains("  img: requests="), "{account}");
}

#[test]
fn ledgers_meter_requests_bytes_and_worker_time_per_tenant() {
    let (cluster, server) = fixture();

    let client = OcpClient::new(&server.url(), "img");
    let bx = Box3::new([0, 0, 0], [128, 128, 16]);
    for _ in 0..8 {
        let _ = client.cutout_u8(0, bx).unwrap();
    }
    let ann = OcpClient::new(&server.url(), "ann");
    let wbx = Box3::new([32, 32, 4], [96, 96, 12]);
    let mut v = DenseVolume::<u32>::zeros(wbx.extent());
    v.fill_box(Box3::new([0, 0, 0], wbx.extent()), 7);
    ann.write_annotation(0, wbx.lo, &v, WriteDiscipline::Overwrite).unwrap();

    let accounts = cluster.account_status();
    let (_, img_ledger) =
        accounts.iter().find(|(t, _)| t == "img").expect("img ledger exists");
    assert!(img_ledger.requests >= 8, "{img_ledger:?}");
    assert!(img_ledger.bytes_out > 0, "cutout responses metered: {img_ledger:?}");
    assert!(img_ledger.read_worker_us > 0, "read-pool busy time metered: {img_ledger:?}");

    let (_, ann_ledger) =
        accounts.iter().find(|(t, _)| t == "ann").expect("ann ledger exists");
    assert!(ann_ledger.requests >= 1, "{ann_ledger:?}");
    assert!(ann_ledger.bytes_in > 0, "write bodies metered: {ann_ledger:?}");

    // Unknown tokens 404 at admission and must not mint a ledger.
    let ghost = OcpClient::new(&server.url(), "ghost");
    assert!(ghost.cutout_u8(0, bx).is_err());
    assert!(
        !cluster.account_status().iter().any(|(t, _)| t == "ghost"),
        "unknown token minted a ledger"
    );
}

#[test]
fn dropped_project_disappears_from_the_metrics_scrape() {
    let (cluster, server) = fixture();

    // Exercise the project so every per-project collector has samples.
    let client = OcpClient::new(&server.url(), "img");
    let _ = client.cutout_u8(0, Box3::new([0, 0, 0], [64, 64, 8])).unwrap();

    let before = ocpd::client::metrics(&server.url()).unwrap();
    for needle in [
        "project=\"img\"",
        "ocpd_heat_shard_score",
        "ocpd_heat_total_score",
        "ocpd_account_requests_total",
    ] {
        assert!(before.contains(needle), "missing {needle}:\n{before}");
    }

    cluster.drop_project("img").unwrap();

    let after = ocpd::client::metrics(&server.url()).unwrap();
    assert!(
        !after.contains("project=\"img\""),
        "dropped project still in the scrape:\n{after}"
    );
    // The surviving project's collectors are untouched.
    assert!(after.contains("project=\"ann\""), "{after}");
    // The heat/account status views forget the token too.
    assert!(!ocpd::client::heat_status(&server.url()).unwrap().contains("  img:"));
    assert!(!ocpd::client::account_status(&server.url()).unwrap().contains("  img:"));
    // Dropping again is a clean NotFound, not a panic.
    assert!(cluster.drop_project("img").is_err());
}
