//! Integration coverage for the parallel cutout read engine and the
//! sharded cuboid cache, through the full cluster stack: Morton-sharded
//! image projects, WAL'd annotation projects, and the invalidation
//! protocol (write → fresh read; WAL flush → no stale hits).

use std::sync::Arc;

use ocpd::array::DenseVolume;
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project, WriteDiscipline};
use ocpd::cutout::ReadConfig;
use ocpd::util::Rng;

fn hash_vol(bx: Box3) -> DenseVolume<u8> {
    let mut v = DenseVolume::zeros(bx.extent());
    for z in 0..v.dims()[2] {
        for y in 0..v.dims()[1] {
            for x in 0..v.dims()[0] {
                let (gx, gy, gz) = (bx.lo[0] + x, bx.lo[1] + y, bx.lo[2] + z);
                v.set([x, y, z], (gx * 7 + gy * 131 + gz * 31 + 1) as u8);
            }
        }
    }
    v
}

#[test]
fn sharded_cluster_parallel_reads_match_sequential() {
    // Three database nodes: the image project shards across all of
    // them, so fan-out batches split at shard boundaries and the
    // ShardedEngine reads nodes concurrently.
    let c = Cluster::in_memory(3, 0);
    let dims = [512u64, 512, 32];
    c.register_dataset(DatasetBuilder::new("ds", dims).levels(1).build());
    let svc = c.create_image_project(Project::image("img", "ds")).unwrap();
    let whole = Box3::new([0, 0, 0], dims);
    let vol = hash_vol(whole);
    svc.write(0, 0, 0, whole, &vol).unwrap();

    let mut rng = Rng::new(42);
    for _ in 0..12 {
        let lo = [rng.below(400), rng.below(400), rng.below(24)];
        let hi = [
            lo[0] + 1 + rng.below(dims[0] - lo[0]),
            lo[1] + 1 + rng.below(dims[1] - lo[1]),
            lo[2] + 1 + rng.below(dims[2] - lo[2]),
        ];
        let bx = Box3::new(lo, hi);
        let seq = svc.read_with_workers::<u8>(0, 0, 0, bx, 1).unwrap();
        let par = svc.read_with_workers::<u8>(0, 0, 0, bx, 8).unwrap();
        assert_eq!(seq, par, "box {bx:?}");
        assert_eq!(par, vol.extract_box(bx), "box {bx:?} vs truth");
    }
    // Wide reads actually fanned out.
    assert!(svc.metrics.parallel_reads.get() > 0);
}

#[test]
fn cache_serves_warm_reads_and_writes_invalidate() {
    let c = Cluster::in_memory(1, 0);
    c.register_dataset(DatasetBuilder::new("ds", [256, 256, 32]).levels(1).build());
    let svc = c.create_image_project(Project::image("img", "ds")).unwrap();
    let bx = Box3::new([0, 0, 0], [256, 256, 32]);
    let v1 = hash_vol(bx);
    svc.write(0, 0, 0, bx, &v1).unwrap();

    // Cold then warm: the second read must be served from the cache.
    assert_eq!(svc.read::<u8>(0, 0, 0, bx).unwrap(), v1);
    let cache = c.cache("img").unwrap();
    let cold = cache.status();
    assert_eq!(svc.read::<u8>(0, 0, 0, bx).unwrap(), v1);
    let warm = cache.status();
    assert!(warm.hits > cold.hits, "warm read produced no cache hits");
    assert_eq!(warm.inserts, cold.inserts, "warm read should insert nothing");

    // Write → invalidation → the very next read sees the new data.
    let mut v2 = v1.clone();
    v2.map_in_place(|x| x ^ 0xff);
    svc.write(0, 0, 0, bx, &v2).unwrap();
    assert!(cache.status().invalidations > warm.invalidations);
    assert_eq!(svc.read::<u8>(0, 0, 0, bx).unwrap(), v2, "stale cache hit after write");
}

#[test]
fn wal_flush_leaves_no_stale_cache_hits() {
    let c = Cluster::in_memory(1, 1);
    c.register_dataset(DatasetBuilder::new("ds", [160, 160, 16]).levels(1).build());
    let db = c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
    let bx = Box3::new([0, 0, 0], [160, 160, 16]);
    let mut v = DenseVolume::<u32>::zeros(bx.extent());
    v.fill_box(bx, 9);
    db.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();

    // Reads through the overlay populate the cache.
    assert_eq!(db.cutout.read::<u32>(0, 0, 0, bx).unwrap(), v);
    let cache = c.cache("ann").unwrap();
    assert!(cache.status().entries > 0);

    // Drain the log into the database node: the flush hook invalidates
    // each applied key, and the next read refetches fresh data.
    let moved = c.flush_wal("ann").unwrap();
    assert!(moved > 0);
    assert_eq!(db.cutout.read::<u32>(0, 0, 0, bx).unwrap(), v, "stale hit after flush");

    // A second write-read-flush-read cycle with different data proves
    // the sequence is stable, not a one-off.
    let mut v2 = DenseVolume::<u32>::zeros(bx.extent());
    v2.fill_box(bx, 77);
    db.write_volume(0, bx, &v2, WriteDiscipline::Overwrite).unwrap();
    assert_eq!(db.cutout.read::<u32>(0, 0, 0, bx).unwrap(), v2);
    c.flush_wal("ann").unwrap();
    assert_eq!(db.cutout.read::<u32>(0, 0, 0, bx).unwrap(), v2);
}

#[test]
fn sharded_cluster_parallel_writes_match_sequential() {
    // The write engine over the full cluster stack: three database
    // nodes, shard-aligned scatter commits, parity with the sequential
    // path for unaligned RMW patches.
    let dims = [512u64, 512, 32];
    let mk = || {
        let c = Cluster::in_memory(3, 0);
        c.register_dataset(DatasetBuilder::new("ds", dims).levels(1).build());
        c.create_image_project(Project::image("img", "ds")).unwrap()
    };
    let (seq, par) = (mk(), mk());
    let whole = Box3::new([0, 0, 0], dims);
    let base = hash_vol(whole);
    seq.write_with_workers(0, 0, 0, whole, &base, 1).unwrap();
    par.write_with_workers(0, 0, 0, whole, &base, 8).unwrap();
    assert!(par.write_metrics.parallel_writes.get() > 0, "wide write must fan out");

    let mut rng = Rng::new(7);
    for _ in 0..6 {
        let lo = [rng.below(400), rng.below(400), rng.below(24)];
        let hi = [
            lo[0] + 1 + rng.below(dims[0] - lo[0]),
            lo[1] + 1 + rng.below(dims[1] - lo[1]),
            lo[2] + 1 + rng.below(dims[2] - lo[2]),
        ];
        let bx = Box3::new(lo, hi);
        let mut patch = hash_vol(bx);
        patch.map_in_place(|v| v ^ 0xa5);
        seq.write_with_workers(0, 0, 0, bx, &patch, 1).unwrap();
        par.write_with_workers(0, 0, 0, bx, &patch, 8).unwrap();
        let a = seq.read_with_workers::<u8>(0, 0, 0, whole, 1).unwrap();
        let b = par.read_with_workers::<u8>(0, 0, 0, whole, 1).unwrap();
        assert_eq!(a.as_bytes(), b.as_bytes(), "box {bx:?}");
    }
}

#[test]
fn parallel_writes_through_wal_keep_read_your_writes() {
    // A hot annotation project's cutout service writes through the
    // WalEngine: a fanned-out write group-commits per batch, reads merge
    // the overlay, and the answer survives the flush.
    let c = Cluster::in_memory(1, 1);
    c.register_dataset(DatasetBuilder::new("ds", [256, 256, 32]).levels(1).build());
    let db = c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
    let bx = Box3::new([3, 5, 1], [250, 251, 31]);
    let mut v = DenseVolume::<u32>::zeros(bx.extent());
    v.fill_box(Box3::new([0, 0, 0], bx.extent()), 11);
    db.cutout.write_with_workers(0, 0, 0, bx, &v, 4).unwrap();
    assert!(c.wal("ann").unwrap().depth() > 0, "writes must land in the log");
    assert_eq!(db.cutout.read::<u32>(0, 0, 0, bx).unwrap(), v);
    c.flush_wal("ann").unwrap();
    assert_eq!(db.cutout.read::<u32>(0, 0, 0, bx).unwrap(), v, "post-flush mismatch");
}

#[test]
fn read_config_knobs_are_honored() {
    let c = Cluster::in_memory(2, 0);
    c.register_dataset(DatasetBuilder::new("ds", [256, 256, 32]).levels(1).build());
    let svc = c.create_image_project(Project::image("img", "ds")).unwrap();
    let bx = Box3::new([0, 0, 0], [256, 256, 32]);
    let vol = hash_vol(bx);
    svc.write(0, 0, 0, bx, &vol).unwrap();
    // Defaults produce a sane config; explicit configs round-trip.
    let cfg = svc.read_config();
    assert!(cfg.workers >= 1 && cfg.parallel_threshold >= 1);
    assert_eq!(ReadConfig::sequential().workers, 1);
    assert_eq!(ReadConfig::with_workers(6).workers, 6);
    assert_eq!(ReadConfig::with_workers(0).workers, 1, "clamped to 1");
}
