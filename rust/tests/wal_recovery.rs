//! WAL crash recovery through the full stack: a persistent cluster is
//! killed mid-segment (no flush, no graceful shutdown), reopened, and
//! must answer reads identically from the replayed overlay — then flush
//! correctly afterwards.

use std::sync::Arc;

use ocpd::annotation::{RamonObject, SynapseType};
use ocpd::array::DenseVolume;
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project, WriteDiscipline};
use ocpd::storage::{FileStore, StorageEngine};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ocpd-walrec-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn dataset() -> ocpd::Dataset {
    DatasetBuilder::new("ds", [256, 256, 32]).levels(1).build()
}

/// The label volume both halves of the crash test agree on.
fn labels(bx: Box3) -> DenseVolume<u32> {
    let mut v = DenseVolume::<u32>::zeros(bx.extent());
    v.fill_box(Box3::new([0, 0, 0], bx.extent()), 7);
    v
}

#[test]
fn crash_mid_segment_recovers_overlay() {
    let dir = tmpdir("crash");
    let bx = Box3::new([5, 9, 2], [70, 60, 20]);
    let whole = Box3::new([0, 0, 0], [256, 256, 32]);
    let mut expected = DenseVolume::<u32>::zeros(whole.extent());
    expected.copy_box_from(&labels(bx), Box3::new([0, 0, 0], bx.extent()), bx.lo);

    {
        let c = Cluster::persistent(&dir, 1, 1).unwrap();
        c.register_dataset(dataset());
        let anno =
            c.create_annotation_project(Project::annotation("hot", "ds"), true).unwrap();
        anno.write_volume(0, bx, &labels(bx), WriteDiscipline::Overwrite).unwrap();
        anno.put_object(RamonObject::synapse(7, 0.8, SynapseType::Excitatory)).unwrap();
        // Everything sits in the (unsealed) log: nothing flushed yet.
        let wal = c.wal("hot").unwrap();
        assert!(wal.depth() > 0, "writes must be absorbed by the log");
        assert_eq!(wal.metrics.flushed_records.get(), 0);
        assert_eq!(anno.cutout.read::<u32>(0, 0, 0, whole).unwrap(), expected);
        // Dropped here with the segment still open — the crash.
    }
    {
        let c = Cluster::persistent(&dir, 1, 1).unwrap();
        c.register_dataset(dataset());
        let anno =
            c.create_annotation_project(Project::annotation("hot", "ds"), true).unwrap();
        let wal = c.wal("hot").unwrap();
        assert!(wal.depth() > 0, "recovery must replay the unsealed segment");
        // Overlay answers exactly the pre-crash reads.
        assert_eq!(anno.cutout.read::<u32>(0, 0, 0, whole).unwrap(), expected);
        assert_eq!(anno.voxel_list(0, 7).unwrap().len() as u64, bx.volume());
        assert_eq!(anno.get_object(7).unwrap().confidence, 0.8);
        // And the replayed records still flush to the database node.
        let moved = c.flush_wal("hot").unwrap();
        assert!(moved >= 2, "expected cuboids + index + metadata, got {moved}");
        assert_eq!(wal.depth(), 0);
        assert_eq!(anno.cutout.read::<u32>(0, 0, 0, whole).unwrap(), expected);
    }
    {
        // Third incarnation: the log is empty, data lives on the db node.
        let c = Cluster::persistent(&dir, 1, 1).unwrap();
        c.register_dataset(dataset());
        let anno =
            c.create_annotation_project(Project::annotation("hot", "ds"), true).unwrap();
        assert_eq!(c.wal("hot").unwrap().depth(), 0);
        assert_eq!(anno.cutout.read::<u32>(0, 0, 0, whole).unwrap(), expected);
        assert_eq!(anno.get_object(7).unwrap().confidence, 0.8);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_chunk_on_disk_is_truncated_not_fatal() {
    let dir = tmpdir("torn");
    let bx = Box3::new([0, 0, 0], [16, 16, 4]);
    {
        let c = Cluster::persistent(&dir, 1, 1).unwrap();
        c.register_dataset(dataset());
        let anno =
            c.create_annotation_project(Project::annotation("hot", "ds"), true).unwrap();
        anno.write_volume(0, bx, &labels(bx), WriteDiscipline::Overwrite).unwrap();
        // A later, separately-committed chunk that the tear will destroy.
        anno.put_object(RamonObject::new(99, ocpd::annotation::RamonType::Seed)).unwrap();
    }
    // Tear the tail of the last WAL chunk directly on the SSD node's
    // store — the on-disk damage a power cut can leave.
    {
        let ssd = FileStore::open(dir.join("ssd0")).unwrap();
        let keys = ssd.keys("hot/wal/log").unwrap();
        let last = *keys.last().unwrap();
        let blob = ssd.get("hot/wal/log", last).unwrap().unwrap();
        let mut torn = (*blob).clone();
        let n = torn.len();
        torn.truncate(n.saturating_sub(4));
        ssd.put("hot/wal/log", last, &torn).unwrap();
        ssd.sync().unwrap();
    }
    {
        let c = Cluster::persistent(&dir, 1, 1).unwrap();
        c.register_dataset(dataset());
        let anno =
            c.create_annotation_project(Project::annotation("hot", "ds"), true).unwrap();
        let wal = c.wal("hot").unwrap();
        assert!(wal.metrics.truncated_chunks.get() >= 1, "tear must be detected");
        // The earlier chunk (spatial write) survived intact.
        assert_eq!(anno.voxel_list(0, 7).unwrap().len() as u64, bx.volume());
        // The torn record is gone — consistently, not as a panic.
        assert!(anno.get_object(99).is_err());
        // The log keeps absorbing and flushing after the repair.
        anno.put_object(RamonObject::new(100, ocpd::annotation::RamonType::Seed)).unwrap();
        assert!(c.flush_wal("hot").unwrap() >= 1);
        assert_eq!(anno.get_object(100).unwrap().id, 100);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_writers_then_crash_lose_nothing_committed() {
    // Group commit under concurrency, then a crash: every write whose
    // call returned must be readable after recovery.
    let dir = tmpdir("group");
    {
        let c = Cluster::persistent(&dir, 1, 1).unwrap();
        c.register_dataset(dataset());
        let anno =
            c.create_annotation_project(Project::annotation("hot", "ds"), true).unwrap();
        std::thread::scope(|s| {
            for w in 0..4u32 {
                let anno = Arc::clone(&anno);
                s.spawn(move || {
                    for i in 0..8u32 {
                        let id = w * 8 + i + 1;
                        let k = (id - 1) as u64;
                        let lo = [(k % 8) * 30, ((k / 8) % 4) * 30, (k % 4) * 7];
                        let bx = Box3::at(lo, [6, 6, 3]);
                        let mut v = DenseVolume::<u32>::zeros(bx.extent());
                        v.fill_box(Box3::new([0, 0, 0], bx.extent()), id);
                        anno.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
                    }
                });
            }
        });
        // Crash without flushing.
    }
    {
        let c = Cluster::persistent(&dir, 1, 1).unwrap();
        c.register_dataset(dataset());
        let anno =
            c.create_annotation_project(Project::annotation("hot", "ds"), true).unwrap();
        for id in 1..=32u32 {
            assert_eq!(
                anno.voxel_list(0, id).unwrap().len(),
                6 * 6 * 3,
                "object {id} lost by the crash"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
