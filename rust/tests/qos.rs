//! Multi-tenant QoS enforcement over live HTTP (DESIGN.md §12):
//! per-tenant token-bucket admission with honest `Retry-After`,
//! client-side throttle retries, request deadlines abandoning engine
//! work as 504, and batch jobs yielding to in-flight interactive
//! requests at block boundaries.

use std::sync::Arc;
use std::time::{Duration, Instant};

use ocpd::array::DenseVolume;
use ocpd::client::{self, OcpClient};
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Dtype, Project};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::obs::slo::RouteClass;
use ocpd::web::http::{request_info, RetryPolicy};
use ocpd::web::{ocpk, Server};
use ocpd::Error;

const DIMS: [u64; 3] = [256, 256, 32];

/// Boot a two-node sharded cluster with an ingested image project and
/// a hot annotation project, served over HTTP. Enforcement starts off
/// (the default) — each test opts in.
fn fixture() -> (Arc<Cluster>, Server) {
    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(DatasetBuilder::new("img", DIMS).levels(2).build());
    let img = cluster.create_image_project(Project::image("img", "img")).unwrap();
    cluster.create_annotation_project(Project::annotation("ann", "img"), true).unwrap();
    let sv = generate(&SynthSpec::small(DIMS, 3));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    let server = ocpd::web::serve(Arc::clone(&cluster), None, "127.0.0.1:0", 8).unwrap();
    (cluster, server)
}

/// Pull the integer after `key` out of a `/qos/status/` body.
fn counter(status: &str, key: &str) -> u64 {
    let pos = status.find(key).unwrap_or_else(|| panic!("{key} missing in:\n{status}"));
    status[pos + key.len()..]
        .split_whitespace()
        .next()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("unparsable {key} in:\n{status}"))
}

#[test]
fn quota_throttles_with_retry_after_and_retrying_clients_recover() {
    let (cluster, server) = fixture();
    let url = server.url();

    client::qos_set_quota(&url, "img", "req_per_s=3").unwrap();
    let on = client::qos_enforce(&url, "on", None).unwrap();
    assert!(on.contains("on"), "{on}");

    // Hammer the quota'd tenant with raw requests: the token bucket
    // drains and the server answers 429 with an honest Retry-After.
    let cutout = format!("{url}/img/ocpk/0/0,64/0,64/0,16/");
    let mut ok = 0u32;
    let mut throttle = None;
    for _ in 0..40 {
        let info = request_info("GET", &cutout, &[]).unwrap();
        match info.status {
            200 => ok += 1,
            429 => {
                throttle = Some(info);
                break;
            }
            s => panic!("unexpected status {s}"),
        }
    }
    assert!(ok >= 1, "the bucket starts full: the first request must pass");
    let throttle = throttle.expect("40 back-to-back requests must overrun 3 req/s");
    assert!(
        throttle.retry_after >= Some(1),
        "Retry-After floors at one second: {:?}",
        throttle.retry_after
    );

    // An unquota'd tenant is untouched while its neighbor is throttled.
    let ann = OcpClient::new(&url, "ann");
    for _ in 0..10 {
        ann.cutout_u32(0, Box3::new([0, 0, 0], [64, 64, 16])).unwrap();
    }

    // A client that opts into throttle retries rides it out: every call
    // lands, sleeping out the server's Retry-After in between.
    let img = OcpClient::new(&url, "img").with_retry(RetryPolicy {
        max_retries: 5,
        base: Duration::from_millis(50),
        cap: Duration::from_secs(2),
    });
    for _ in 0..4 {
        let vol = img.cutout_u8(0, Box3::new([0, 0, 0], [64, 64, 16])).unwrap();
        assert_eq!(vol.dims(), [64, 64, 16]);
    }

    let status = client::qos_status(&url).unwrap();
    assert!(status.contains("enforcement: on"), "{status}");
    assert!(status.contains("tenant img:"), "{status}");
    assert!(cluster.qos().throttled_total() > 0);
}

#[test]
fn enforcement_shields_interactive_reads_from_a_bulk_storm() {
    let (cluster, server) = fixture();
    let url = server.url();

    let vol = DenseVolume::<u32>::zeros([64, 64, 8]);
    let body = ocpk::encode_volume(Dtype::U32, [0, 0, 0], &vol).unwrap();
    let write_url = format!("{url}/ann/overwrite/0/");

    // Enforcement off (the default): the storm is admitted wholesale.
    for _ in 0..8 {
        let info = request_info("PUT", &write_url, &body).unwrap();
        assert_eq!(info.status, 200, "enforcement off never throttles");
    }
    assert_eq!(cluster.qos().throttled_total(), 0);

    // Quota the bulk tenant and switch enforcement on: the storm gets
    // paced while an interactive reader on another project, interleaved
    // with it, sails through untouched.
    client::qos_set_quota(&url, "ann", "req_per_s=4 bytes_per_s=400000").unwrap();
    client::qos_enforce(&url, "on", None).unwrap();

    let img = OcpClient::new(&url, "img");
    let (mut ok, mut throttled) = (0u32, 0u32);
    for i in 0..24 {
        let info = request_info("PUT", &write_url, &body).unwrap();
        match info.status {
            200 => ok += 1,
            429 => {
                throttled += 1;
                assert!(
                    info.retry_after >= Some(1),
                    "429 carries Retry-After: {:?}",
                    info.retry_after
                );
            }
            s => panic!("unexpected status {s}"),
        }
        if i % 3 == 0 {
            let v = img.cutout_u8(0, Box3::new([0, 0, 0], [128, 128, 16])).unwrap();
            assert_eq!(v.dims(), [128, 128, 16]);
        }
    }
    assert!(ok >= 1, "the bucket starts full: some of the storm lands");
    assert!(throttled > 0, "24 back-to-back 128 KiB writes must overrun the quota");

    let status = client::qos_status(&url).unwrap();
    assert!(status.contains("tenant ann:"), "{status}");
    assert!(counter(&status, "throttled:") >= u64::from(throttled), "{status}");

    // The qos families surface on the unified exposition.
    let metrics = request_info("GET", &format!("{url}/metrics/"), &[]).unwrap();
    assert_eq!(metrics.status, 200);
    let text = String::from_utf8_lossy(&metrics.body).to_string();
    for family in
        ["ocpd_qos_enforcement_enabled", "ocpd_qos_throttled_total", "ocpd_qos_inflight_bytes"]
    {
        assert!(text.contains(family), "missing {family}");
    }
}

#[test]
fn expired_deadlines_abandon_reads_and_answer_504() {
    // The parallel read path checks the deadline at batch boundaries; on
    // a single hardware thread the engine degenerates to the one-shot
    // sequential pass, which has no mid-read boundary to observe the
    // expiry deterministically.
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) < 2 {
        return;
    }
    let cluster = Cluster::simulated(2, 1, 1e-4);
    // Small cuboids (32x32x8) turn the full-volume read into 256
    // cuboids, so the planner always forms more batches than workers: a
    // second scheduling wave is guaranteed to hit a batch boundary after
    // the injected device latency has burned the budget.
    cluster.register_dataset(
        DatasetBuilder::new("img", DIMS).cuboids([32, 32, 8], [32, 32, 32]).build(),
    );
    let img = cluster.create_image_project(Project::image("img", "img")).unwrap();
    let sv = generate(&SynthSpec::small(DIMS, 11));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    let server = ocpd::web::serve(Arc::clone(&cluster), None, "127.0.0.1:0", 8).unwrap();
    let url = server.url();

    // 20-25 ms per device op against a 5 ms budget: the first wave of
    // batches alone overruns the deadline.
    for node in 0..2 {
        cluster
            .fault(node)
            .unwrap()
            .set_delay_range(Duration::from_millis(20), Duration::from_millis(25));
    }
    let slow = OcpClient::new(&url, "img").with_deadline_ms(5);
    let err = slow.cutout_u8(0, Box3::new([0, 0, 0], DIMS)).unwrap_err();
    assert!(matches!(err, Error::DeadlineExceeded(_)), "got {err:?}");
    let status = client::qos_status(&url).unwrap();
    assert!(counter(&status, "deadline_expired:") >= 1, "{status}");

    // Disarm the latency and drop the budget: the same read completes.
    for node in 0..2 {
        cluster.fault(node).unwrap().set_delay_range(Duration::ZERO, Duration::ZERO);
    }
    let v = OcpClient::new(&url, "img").cutout_u8(0, Box3::new([0, 0, 0], DIMS)).unwrap();
    assert_eq!(v.dims(), DIMS);
}

#[test]
fn job_blocks_yield_while_interactive_requests_are_in_flight() {
    let (cluster, server) = fixture();
    let url = server.url();
    client::qos_enforce(&url, "on", None).unwrap();

    // Pin an interactive request "in flight" exactly the way admission
    // does, then submit a batch ingest: every block boundary must
    // observe the live interactive work and yield before scheduling the
    // next block.
    let qos = Arc::clone(cluster.qos());
    let base = qos.preemptions();
    let guard = qos.admit(Some("img"), RouteClass::Interactive, 0).unwrap();

    let reply = client::submit_job(
        &url,
        "ingest/img",
        "dims=128,128,32 block=64,64,16 workers=1 seed=9",
    )
    .unwrap();
    let id: u64 = reply
        .split_whitespace()
        .find_map(|kv| kv.strip_prefix("id="))
        .unwrap_or_else(|| panic!("submit echoes id=: {reply}"))
        .parse()
        .unwrap();

    let t0 = Instant::now();
    while qos.preemptions() == base && t0.elapsed() < Duration::from_secs(20) {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(qos.preemptions() > base, "job blocks must yield to live interactive work");
    drop(guard);

    // With the interactive load gone the job runs unimpeded to the end.
    let t0 = Instant::now();
    loop {
        let s = client::job_status(&url, Some(id)).unwrap();
        if s.contains("state=completed") {
            break;
        }
        assert!(!s.contains("state=failed"), "{s}");
        assert!(t0.elapsed() < Duration::from_secs(60), "job stuck: {s}");
        std::thread::sleep(Duration::from_millis(10));
    }
    let status = client::qos_status(&url).unwrap();
    assert!(counter(&status, "preemptions:") >= 1, "{status}");
}
