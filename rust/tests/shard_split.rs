//! Dynamic shard splitting, end to end (DESIGN.md §13).
//!
//! Two property drills over the live-move machinery:
//!
//! * **Round trip**: split a shard at a random block-aligned cut, move
//!   the upper half to a fresh node, then merge it back home — every
//!   byte reads back identically before, during, and after both moves,
//!   and routing agrees with the map at every step.
//! * **Concurrent writes**: writers keep mutating both halves while the
//!   copier drains the window and the map commits; a reader thread
//!   observes every key as present and well-formed mid-copy, and the
//!   last write per key wins after the move — no loss, no tears.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use ocpd::cluster::{ReplicaSet, ShardMove, ShardedEngine};
use ocpd::obs::heat::snap_split_key;
use ocpd::shard::ShardMap;
use ocpd::storage::{Engine, MemStore, StorageEngine};
use ocpd::util::prop::property;

const TABLE: &str = "t/data";
/// A table outside the move's scope: must never be copied or purged.
const OTHER: &str = "other/data";

/// Deterministic payload for `key` at write round `round`; the first
/// two bytes self-identify the key so a torn or misrouted read is
/// detectable from the value alone.
fn payload(key: u64, round: u8) -> Vec<u8> {
    vec![(key % 251) as u8, (key >> 8) as u8, round, 0xC3]
}

fn well_formed(key: u64, v: &[u8]) -> bool {
    v.len() == 4 && v[0] == (key % 251) as u8 && v[1] == (key >> 8) as u8 && v[3] == 0xC3
}

/// A 2-shard engine over dedicated per-node stores.
fn two_shard(total: u64) -> (Arc<ShardedEngine>, Vec<Arc<MemStore>>) {
    let mems: Vec<Arc<MemStore>> = (0..2).map(|_| Arc::new(MemStore::new())).collect();
    let engines: Vec<Engine> = mems.iter().map(|m| Arc::clone(m) as Engine).collect();
    let map = ShardMap::even(total, vec![0, 1]).unwrap();
    (Arc::new(ShardedEngine::new(map, engines)), mems)
}

/// Split `shard` at `cut`, rehoming the upper half onto a brand-new
/// store (returned). Mirrors what the cluster's balancer executes.
fn split_move(s: &ShardedEngine, shard: usize, cut: u64, chunk: usize) -> Arc<MemStore> {
    let target = Arc::new(MemStore::new());
    let map = s.map();
    let new_node = map.nodes().iter().copied().max().unwrap_or(0) + 1;
    let new_map = Arc::new(map.split(shard, cut).unwrap().assign(shard + 1, new_node).unwrap());
    let from = Arc::clone(&s.sets()[shard]);
    let to = ReplicaSet::solo(shard + 1, new_node, Arc::clone(&target) as Engine);
    to.set_range(new_map.shard_range(shard + 1));
    let mut sets = s.sets();
    sets.insert(shard + 1, Arc::clone(&to));
    s.begin_move(ShardMove {
        range: new_map.shard_range(shard + 1),
        from,
        to,
        scope: "t".into(),
        map: new_map,
        sets,
    })
    .unwrap();
    s.copy_moving(chunk).unwrap();
    s.commit_move().unwrap();
    target
}

/// Merge shard `hi` back into shard `lo` (adjacent), moving its keys
/// home and retiring its set.
fn merge_move(s: &ShardedEngine, lo: usize, hi: usize, chunk: usize) {
    let map = s.map();
    let range = map.shard_range(hi);
    let sets = s.sets();
    let from = Arc::clone(&sets[hi]);
    let to = Arc::clone(&sets[lo]);
    let merged = Arc::new(map.merge(lo, hi).unwrap());
    to.set_range(merged.shard_range(lo));
    let mut new_sets = sets;
    new_sets.remove(hi);
    s.begin_move(ShardMove { range, from, to, scope: "t".into(), map: merged, sets: new_sets })
        .unwrap();
    s.copy_moving(chunk).unwrap();
    s.commit_move().unwrap();
}

#[test]
fn split_route_merge_round_trip_preserves_every_byte() {
    property("split_route_merge_round_trip", 16, |g| {
        let total = 1u64 << (7 + g.u64_below(4)); // 128..=1024 keys
        let (s, mems) = two_shard(total);
        let original_map = s.map();
        let keys: Vec<u64> = (0..total).step_by(3).collect();
        for &k in &keys {
            s.put(TABLE, k, &payload(k, 0)).unwrap();
            s.put(OTHER, k, b"keep").unwrap();
        }
        // Split a random shard at a random block-snapped interior cut.
        let shard = g.usize_below(2);
        let (lo, hi) = original_map.shard_range(shard);
        let span = hi.min(total) - lo;
        let cut = match snap_split_key(lo + 1 + g.u64_below(span.saturating_sub(2).max(1)), lo, hi)
        {
            Some(c) => c,
            None => return, // degenerate draw: shard too small to split
        };
        let chunk = 1 + g.usize_below(64);
        let target = split_move(&s, shard, cut, chunk);
        let split_map = s.map();
        assert_eq!(split_map.num_shards(), 3);
        assert_eq!(split_map.version(), original_map.version() + 1);
        // Every byte identical, and routing agrees with the new map.
        for &k in &keys {
            let v = s.get(TABLE, k).unwrap().unwrap_or_else(|| panic!("key {k} lost by split"));
            assert_eq!(**v, *payload(k, 0), "key {k} corrupted by split");
        }
        // The rehomed half lives on the target — scoped tables only.
        let upper: Vec<u64> = keys.iter().copied().filter(|&k| k >= cut).collect();
        assert_eq!(target.keys(TABLE).unwrap(), upper);
        assert!(target.keys(OTHER).unwrap().is_empty(), "out-of-scope table copied");
        // The old owner purged the moved range but kept its own half
        // and every out-of-scope key.
        let donor = &mems[split_map.nodes()[shard]];
        let lower: Vec<u64> = keys.iter().copied().filter(|&k| k >= lo && k < cut).collect();
        let shard_keys: Vec<u64> =
            keys.iter().copied().filter(|&k| k >= lo && k < hi).collect();
        assert_eq!(donor.keys(TABLE).unwrap(), lower, "donor kept wrong half");
        assert_eq!(donor.keys(OTHER).unwrap(), shard_keys, "out-of-scope table purged");
        // Merge the new shard back home and prove the round trip.
        merge_move(&s, shard, shard + 1, chunk);
        let merged_map = s.map();
        assert_eq!(merged_map.num_shards(), 2);
        for &k in &keys {
            let v = s.get(TABLE, k).unwrap().unwrap_or_else(|| panic!("key {k} lost by merge"));
            assert_eq!(**v, *payload(k, 0), "key {k} corrupted by merge");
            assert_eq!(
                merged_map.shard_for(k),
                original_map.shard_for(k),
                "routing diverged after round trip"
            );
        }
        assert!(target.keys(TABLE).unwrap().is_empty(), "merge left keys on the split node");
        // Writes still land after two topology swaps.
        let probe = keys[keys.len() / 2];
        s.put(TABLE, probe, &payload(probe, 9)).unwrap();
        assert_eq!(**s.get(TABLE, probe).unwrap().unwrap(), *payload(probe, 9));
    });
}

#[test]
fn concurrent_writes_survive_a_live_split() {
    property("concurrent_writes_survive_split", 8, |g| {
        let total = 256u64;
        let (s, _mems) = two_shard(total);
        let keys: Vec<u64> = (0..total).collect();
        for &k in &keys {
            s.put(TABLE, k, &payload(k, 0)).unwrap();
        }
        // Open the window by hand so writers and readers overlap the
        // copy: shard 1 = [128, MAX), cut mid-shard.
        let cut = snap_split_key(128 + 8 + g.u64_below(96), 128, u64::MAX).unwrap();
        let target = Arc::new(MemStore::new());
        let map = s.map();
        let new_map = Arc::new(map.split(1, cut).unwrap().assign(2, 2).unwrap());
        let from = Arc::clone(&s.sets()[1]);
        let to = ReplicaSet::solo(2, 2, Arc::clone(&target) as Engine);
        to.set_range(new_map.shard_range(2));
        let mut sets = s.sets();
        sets.insert(2, Arc::clone(&to));
        s.begin_move(ShardMove {
            range: new_map.shard_range(2),
            from,
            to,
            scope: "t".into(),
            map: new_map,
            sets,
        })
        .unwrap();

        let rounds: u8 = 3 + g.u64_below(3) as u8;
        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| {
            // Two writers own disjoint stripes (even/odd keys) and
            // rewrite them round by round across copy AND commit.
            let mut writers = Vec::new();
            for stripe in 0..2u64 {
                let s = &s;
                let keys = &keys;
                writers.push(scope.spawn(move || {
                    for round in 1..=rounds {
                        for &k in keys.iter().filter(|&&k| k % 2 == stripe) {
                            s.put(TABLE, k, &payload(k, round)).unwrap();
                        }
                    }
                }));
            }
            // A reader hammers random keys mid-copy: every value must
            // be present and self-consistent at all times.
            let reader = {
                let s = &s;
                let keys = &keys;
                let stop = &stop;
                let mut seed = 0x5EED ^ rounds as u64;
                scope.spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                        let k = keys[(seed >> 33) as usize % keys.len()];
                        let v = s.get(TABLE, k).unwrap().expect("key vanished mid-move");
                        assert!(well_formed(k, &v), "torn read of key {k}: {:?}", &**v);
                    }
                })
            };
            // Drain the window in small chunks while the writers run,
            // then commit with them still going.
            s.copy_moving(1 + g.usize_below(16)).unwrap();
            s.commit_move().unwrap();
            for w in writers {
                w.join().unwrap();
            }
            stop.store(true, Ordering::Release);
            reader.join().unwrap();
        });

        // Last write wins for every key, read through the new topology
        // and present on the correct owner's store.
        assert_eq!(s.map().num_shards(), 3);
        for &k in &keys {
            let v = s.get(TABLE, k).unwrap().unwrap_or_else(|| panic!("key {k} lost"));
            assert_eq!(**v, *payload(k, rounds), "key {k} lost the last write");
        }
        let moved = target.keys(TABLE).unwrap();
        assert!(moved.iter().all(|&k| k >= cut), "target holds out-of-range keys");
        assert_eq!(moved.len() as u64, total - cut, "target missing moved keys");
    });
}
