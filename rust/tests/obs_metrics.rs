//! Unified metrics exposition (DESIGN.md §9): one `GET /metrics/`
//! scrape carries every subsystem's counters, gauges, and histograms in
//! well-formed Prometheus text format.

use ocpd::array::DenseVolume;
use ocpd::client::OcpClient;
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project, WriteDiscipline};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::web::Server;

/// Boot a sharded cluster with an image project and a hot annotation
/// project, then drive every subsystem once: cutout reads (cold + warm
/// for cache hits), an annotation write (write engine + WAL), a WAL
/// flush, and a propagate job.
fn exercised_fixture() -> Server {
    let dims = [256u64, 256, 32];
    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(DatasetBuilder::new("img", dims).levels(2).build());
    let img = cluster.create_image_project(Project::image("img", "img")).unwrap();
    cluster.create_annotation_project(Project::annotation("ann", "img"), true).unwrap();
    let sv = generate(&SynthSpec::small(dims, 3));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    let server = ocpd::web::serve(cluster, None, "127.0.0.1:0", 8).unwrap();

    let client = OcpClient::new(&server.url(), "img");
    let bx = Box3::new([0, 0, 0], [128, 128, 16]);
    let _ = client.cutout_u8(0, bx).unwrap();
    let _ = client.cutout_u8(0, bx).unwrap();

    let ann = OcpClient::new(&server.url(), "ann");
    let wbx = Box3::new([32, 32, 4], [96, 96, 12]);
    let mut v = DenseVolume::<u32>::zeros(wbx.extent());
    v.fill_box(Box3::new([0, 0, 0], wbx.extent()), 42);
    ann.write_annotation(0, wbx.lo, &v, WriteDiscipline::Overwrite).unwrap();
    ocpd::client::wal_flush(&server.url(), None).unwrap();

    let resp = ocpd::client::submit_job(&server.url(), "propagate/ann", "workers=2").unwrap();
    let id: u64 =
        resp.split_whitespace().next().unwrap().trim_start_matches("id=").parse().unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    loop {
        let status = ocpd::client::job_status(&server.url(), Some(id)).unwrap();
        if status.contains("state=completed") {
            break;
        }
        assert!(!status.contains("state=failed"), "{status}");
        assert!(std::time::Instant::now() < deadline, "job stuck: {status}");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    server
}

/// Strip the `{labels}` part of a sample line, returning (name, value).
fn split_sample(line: &str) -> (&str, &str) {
    let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line: {line}"));
    let name = series.split('{').next().unwrap();
    (name, value)
}

#[test]
fn one_scrape_carries_every_subsystem() {
    let server = exercised_fixture();
    let text = ocpd::client::metrics(&server.url()).unwrap();

    // Well-formed exposition: every line is HELP, TYPE, or a sample;
    // each family announces exactly one TYPE before its samples; all
    // values parse as finite numbers.
    let mut typed = std::collections::HashMap::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (family, kind) = rest.split_once(' ').unwrap();
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "unknown kind: {line}"
            );
            let prev = typed.insert(family.to_string(), kind.to_string());
            assert!(prev.is_none(), "duplicate TYPE for {family}");
        } else if let Some(rest) = line.strip_prefix("# HELP ") {
            assert!(rest.contains(' '), "HELP without text: {line}");
        } else {
            let (name, value) = split_sample(line);
            let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value: {line}"));
            assert!(v.is_finite(), "non-finite value: {line}");
            // A sample's family is its name minus histogram suffixes.
            let family = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum"))
                .or_else(|| name.strip_suffix("_count"))
                .unwrap_or(name);
            assert!(
                typed.contains_key(family) || typed.contains_key(name),
                "sample before TYPE: {line}"
            );
        }
    }

    // Every subsystem surfaced in the one scrape, labeled by project
    // where per-project (read/write/cache/wal).
    for family in [
        "ocpd_read_sequential_total",
        "ocpd_read_parallel_total",
        "ocpd_write_parallel_total",
        "ocpd_write_elided_reads_total",
        "ocpd_write_merge_latency_us",
        "ocpd_cache_hits_total",
        "ocpd_cache_misses_total",
        "ocpd_wal_appended_records_total",
        "ocpd_wal_depth_records",
        "ocpd_job_retries_total",
        "ocpd_job_block_latency_us",
        "ocpd_http_requests_total",
        "ocpd_http_request_latency_us",
        "ocpd_http_route_latency_us",
        "ocpd_http_in_flight",
        "ocpd_heat_shard_score",
        "ocpd_heat_shard_read_bytes",
        "ocpd_heat_shard_write_bytes",
        "ocpd_heat_shard_ops",
        "ocpd_heat_total_score",
        "ocpd_account_requests_total",
        "ocpd_account_bytes_in_total",
        "ocpd_account_bytes_out_total",
        "ocpd_account_read_worker_us_total",
        "ocpd_account_write_worker_us_total",
        "ocpd_account_job_worker_us_total",
        "ocpd_account_cache_bytes",
        "ocpd_slo_requests_total",
        "ocpd_slo_within_total",
        "ocpd_slo_threshold_us",
        "ocpd_slo_attainment_milli",
        "ocpd_slo_burn_milli",
    ] {
        assert!(typed.contains_key(family), "missing family {family}:\n{text}");
    }
    assert!(text.contains("project=\"img\""), "{text}");
    assert!(text.contains("project=\"ann\""), "{text}");

    // The warmed cache registered hits; the transport counted requests;
    // the histogram families carry cumulative buckets.
    let hit_line = text
        .lines()
        .find(|l| l.starts_with("ocpd_cache_hits_total") && l.contains("project=\"img\""))
        .unwrap();
    assert_ne!(split_sample(hit_line).1, "0", "{hit_line}");
    let req_line =
        text.lines().find(|l| l.starts_with("ocpd_http_requests_total")).unwrap();
    assert!(split_sample(req_line).1.parse::<u64>().unwrap() > 0, "{req_line}");
    assert!(text.contains("ocpd_http_request_latency_us_bucket{le=\"+Inf\"}"), "{text}");
    assert!(text.contains("ocpd_http_request_latency_us_count"), "{text}");

    // The telemetry layer carries the driven traffic: the image
    // project is warm in the heat map and metered in its ledger.
    let heat_line = text
        .lines()
        .find(|l| l.starts_with("ocpd_heat_total_score") && l.contains("project=\"img\""))
        .unwrap();
    assert_ne!(split_sample(heat_line).1, "0", "{heat_line}");
    let acct_line = text
        .lines()
        .find(|l| l.starts_with("ocpd_account_requests_total") && l.contains("project=\"img\""))
        .unwrap();
    assert!(split_sample(acct_line).1.parse::<u64>().unwrap() > 0, "{acct_line}");
}

#[test]
fn scrape_is_idempotent_and_stable() {
    let server = exercised_fixture();
    let a = ocpd::client::metrics(&server.url()).unwrap();
    let b = ocpd::client::metrics(&server.url()).unwrap();
    // Family sets are identical between scrapes (values may advance —
    // the scrape itself is an HTTP request).
    let families = |t: &str| {
        t.lines()
            .filter_map(|l| l.strip_prefix("# TYPE ").map(str::to_string))
            .collect::<Vec<_>>()
    };
    assert_eq!(families(&a), families(&b));
    // Content type is the Prometheus text version.
    let info = ocpd::web::http::request_info(
        "GET",
        &format!("{}/metrics/", server.url()),
        &[],
    )
    .unwrap();
    assert_eq!(info.status, 200);
}
