//! Write-engine benches: the mirror of `bench_cutout` for the write
//! path.
//!
//! One volume-sized write served by the parallel write engine at
//! 1/2/4/8 writers, on the paper's simulated device models, in four
//! configurations:
//!
//! * `direct` / `ingest-aligned` — cuboid-aligned overwrite straight at
//!   the RAID-6 database-node profile. Every cuboid is fully covered,
//!   so the engine **elides** all existing-cuboid reads (the
//!   acceptance row: `existing_reads` must be 0).
//! * `direct` / `rmw-unaligned` — an off-grid box over pre-seeded data:
//!   every cuboid pays a batched read-modify-write pre-read.
//! * `wal` / … — the same two workloads through the SSD write-absorber
//!   ([`WalEngine`]): commits group-commit into the SSD log while
//!   pre-reads stream from the (flushed) HDD destination.
//!
//! Prints the table and rewrites `../BENCH_write.json` (override with
//! `OCPD_BENCH_OUT`). `OCPD_BENCH_SMOKE=1` shrinks the volume and the
//! device time scale so CI can run the binary in seconds (keeps the
//! elision assertion, skips the timing assertion).

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::*;
use ocpd::chunkstore::CuboidStore;
use ocpd::core::{Box3, DatasetBuilder, Project, Vec3};
use ocpd::cutout::{CutoutService, WriteConfig};
use ocpd::storage::{DeviceProfile, Engine, MemStore, SimulatedStore};
use ocpd::wal::{Wal, WalConfig, WalEngine};

const WRITERS: [usize; 4] = [1, 2, 4, 8];

fn smoke() -> bool {
    std::env::var("OCPD_BENCH_SMOKE").is_ok()
}

fn dims() -> Vec3 {
    if smoke() {
        [256, 256, 32] // 8 cuboids
    } else {
        [512, 512, 64] // 64 cuboids, ~16.8 MB
    }
}

fn time_scale() -> f64 {
    if smoke() {
        0.02
    } else {
        1.0
    }
}

fn reps() -> usize {
    if smoke() {
        1
    } else {
        3
    }
}

/// A fresh service over the chosen engine stack. `wal` routes every
/// mutation through the SSD write-absorber with the HDD array as the
/// flush destination (`background_flush` off so timing is deterministic).
fn fixture(wal: bool) -> (Arc<CutoutService>, Option<Arc<Wal>>) {
    let ds = Arc::new(
        DatasetBuilder::new("kasthuri_like", dims())
            .voxel_nm([3.0, 3.0, 30.0])
            .levels(1)
            .build(),
    );
    // gzip off: EM data is incompressible and these rows are about I/O
    // + merge, not codec speed.
    let pr = Arc::new(Project::image("img", "kasthuri_like").with_gzip(0));
    let hdd: Engine = Arc::new(SimulatedStore::new(
        Arc::new(MemStore::new()),
        DeviceProfile::hdd_array(),
        time_scale(),
    ));
    let (engine, handle): (Engine, Option<Arc<Wal>>) = if wal {
        let log: Engine = Arc::new(SimulatedStore::new(
            Arc::new(MemStore::new()),
            DeviceProfile::ssd_raid0(),
            time_scale(),
        ));
        let cfg = WalConfig { background_flush: false, ..WalConfig::default() };
        let w = Wal::open("img", log, hdd, cfg).unwrap();
        (Arc::new(WalEngine::new(Arc::clone(&w))) as Engine, Some(w))
    } else {
        (hdd, None)
    };
    let svc = Arc::new(
        CutoutService::new(Arc::new(CuboidStore::new(ds, pr, engine))).with_write_config(
            WriteConfig { parallel_threshold: 1, ..WriteConfig::default() },
        ),
    );
    (svc, handle)
}

struct Row {
    config: &'static str,
    workload: &'static str,
    workers: usize,
    seconds: f64,
    mbps: f64,
    speedup: f64,
    /// Existing-cuboid pre-reads per timed write (the elision counter:
    /// 0 on the aligned ingest workload).
    existing_reads: u64,
}

/// Median seconds plus per-run pre-read count for one workload at one
/// fan-out width, on a fresh fixture.
fn timed_write(config: &'static str, workload: &'static str, workers: usize) -> (f64, u64) {
    let (svc, wal) = fixture(config == "wal");
    let d = dims();
    let whole = Box3::new([0, 0, 0], d);
    let vol = em_like_volume(d, 7);
    let (bx, sub) = if workload == "rmw-unaligned" {
        // Seed (untimed) so the RMW path reads real data, then drain the
        // log: pre-reads must stream from the destination device.
        svc.write_with_workers(0, 0, 0, whole, &vol, 1).unwrap();
        if let Some(w) = &wal {
            w.flush_now().unwrap();
        }
        let bx = Box3::new([1, 1, 1], [d[0] - 1, d[1] - 1, d[2] - 1]);
        let sub = vol.extract_box(bx);
        (bx, sub)
    } else {
        (whole, vol.extract_box(whole))
    };
    let before = svc.write_metrics.rmw_reads.get();
    let n = reps();
    let mut ts: Vec<f64> = Vec::with_capacity(n);
    for _ in 0..n {
        // Drain the log between reps (untimed): a rep's pre-reads must
        // stream from the destination device, not resolve against the
        // previous rep's in-memory overlay.
        if let Some(w) = &wal {
            w.flush_now().unwrap();
        }
        ts.push(time(|| {
            if workload == "rmw-unaligned" {
                // Preserve-style discipline: the merge depends on the
                // existing voxels, so no cuboid can elide its pre-read.
                svc.write_rmw_with_workers(
                    0,
                    0,
                    0,
                    bx,
                    &sub,
                    |old, new| if old != 0 { old } else { new },
                    workers,
                )
                .unwrap();
            } else {
                svc.write_with_workers(0, 0, 0, bx, &sub, workers).unwrap();
            }
        }));
    }
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let secs = ts[ts.len() / 2];
    let per_run = (svc.write_metrics.rmw_reads.get() - before) / n as u64;
    (secs, per_run)
}

fn main() {
    let d = dims();
    println!(
        "Parallel write engine: one {:?} write on the simulated devices (time_scale {})",
        d,
        time_scale()
    );
    let mut rows: Vec<Row> = Vec::new();

    for config in ["direct", "wal"] {
        for workload in ["ingest-aligned", "rmw-unaligned"] {
            header(
                &format!("{config} / {workload}"),
                &["writers", "seconds", "MB/s", "speedup", "pre-reads"],
            );
            let mut seq_secs = 0.0;
            for &w in &WRITERS {
                let (secs, existing_reads) = timed_write(config, workload, w);
                if w == 1 {
                    seq_secs = secs;
                }
                let bytes = if workload == "rmw-unaligned" {
                    (d[0] - 2) * (d[1] - 2) * (d[2] - 2)
                } else {
                    d[0] * d[1] * d[2]
                };
                let r = Row {
                    config,
                    workload,
                    workers: w,
                    seconds: secs,
                    mbps: bytes as f64 / 1e6 / secs,
                    speedup: seq_secs / secs,
                    existing_reads,
                };
                row(&[
                    w.to_string(),
                    format!("{:.4}", r.seconds),
                    format!("{:.1}", r.mbps),
                    format!("{:.2}x", r.speedup),
                    r.existing_reads.to_string(),
                ]);
                rows.push(r);
            }
        }
    }

    // Acceptance 1: the fully-aligned ingest workload performs ZERO
    // existing-cuboid reads — RMW elision covers every cuboid.
    for r in rows.iter().filter(|r| r.workload == "ingest-aligned") {
        assert_eq!(
            r.existing_reads, 0,
            "{}/{} at {} writers read existing cuboids",
            r.config, r.workload, r.workers
        );
    }
    // Acceptance 2: >= 2x aggregate throughput at 4 writers on the
    // unaligned RMW workload (timing-based; skipped in CI smoke mode).
    let rmw4 = rows
        .iter()
        .find(|r| r.config == "direct" && r.workload == "rmw-unaligned" && r.workers == 4)
        .unwrap();
    println!(
        "\ndirect rmw-unaligned at 4 writers: {:.2}x vs sequential",
        rmw4.speedup
    );
    if !smoke() {
        assert!(
            rmw4.speedup >= 2.0,
            "unaligned RMW must scale >= 2x at 4 writers, got {:.2}x",
            rmw4.speedup
        );
    }

    // Machine-readable results.
    let mut json = String::from("{\n  \"bench\": \"bench_write\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"dims\": [{}, {}, {}], \"voxel_bytes\": 1, \"device\": \
         \"raid6-sata (+ ssd-vertex4 log on wal rows)\", \"time_scale\": {}}},\n",
        d[0],
        d[1],
        d[2],
        time_scale()
    ));
    json.push_str(
        "  \"provenance\": \"measured by cargo bench --bench bench_write; speedup is vs \
         the 1-writer row of the same config/workload; existing_reads counts RMW \
         pre-read cuboids per write (0 = fully elided)\",\n",
    );
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"config\": \"{}\", \"workload\": \"{}\", \"workers\": {}, \
             \"seconds\": {:.4}, \"mbps\": {:.1}, \"speedup\": {:.2}, \
             \"existing_reads\": {}}}{}\n",
            r.config,
            r.workload,
            r.workers,
            r.seconds,
            r.mbps,
            r.speedup,
            r.existing_reads,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let out = std::env::var("OCPD_BENCH_OUT").unwrap_or_else(|_| "../BENCH_write.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
