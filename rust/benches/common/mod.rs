//! Shared helpers for the bench binaries (no criterion in the offline
//! vendor set — each bench is a `harness = false` binary that prints the
//! rows of the paper table/figure it regenerates).

// Each bench compiles this module into its own crate and uses a subset
// of the helpers; the unused remainder is not dead code.
#![allow(dead_code)]

use std::time::Instant;

use ocpd::array::DenseVolume;
use ocpd::util::Rng;

/// Print a fixed-width table row.
pub fn row(cells: &[String]) {
    let line: Vec<String> = cells.iter().map(|c| format!("{c:>14}")).collect();
    println!("{}", line.join(" "));
}

pub fn header(title: &str, cols: &[&str]) {
    println!("\n=== {title} ===");
    row(&cols.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    println!("{}", "-".repeat(15 * cols.len()));
}

/// Human size label.
pub fn size_label(bytes: u64) -> String {
    if bytes >= 1 << 20 {
        format!("{}M", bytes >> 20)
    } else if bytes >= 1 << 10 {
        format!("{}K", bytes >> 10)
    } else {
        format!("{bytes}B")
    }
}

/// Time a closure, returning seconds.
pub fn time<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// Median of `n` timed runs.
pub fn median_time<F: FnMut()>(n: usize, mut f: F) -> f64 {
    let mut ts: Vec<f64> = (0..n).map(|_| time(&mut f)).collect();
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts[ts.len() / 2]
}

/// A high-entropy (incompressible, EM-like) u8 volume.
pub fn em_like_volume(dims: [u64; 3], seed: u64) -> DenseVolume<u8> {
    let n = (dims[0] * dims[1] * dims[2]) as usize;
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(n);
    // Word-at-a-time fill: bench setup time matters.
    for _ in 0..n.div_ceil(8) {
        data.extend_from_slice(&rng.next_u64().to_le_bytes());
    }
    data.truncate(n);
    DenseVolume::from_vec(dims, data).unwrap()
}

/// A dense (>90% labeled) annotation volume with one label per `block`
/// sub-block — matching the paper's Figure 12 upload payload ("dense
/// manual annotations ... more than 90% of voxels are labeled").
pub fn dense_labels(dims: [u64; 3], block: u64, seed: u64) -> DenseVolume<u32> {
    let mut rng = Rng::new(seed);
    let mut v = DenseVolume::<u32>::zeros(dims);
    let mut next_id = 1u32;
    let mut z = 0;
    while z < dims[2] {
        let mut y = 0;
        while y < dims[1] {
            let mut x = 0;
            while x < dims[0] {
                let id = if rng.chance(0.93) { next_id } else { 0 };
                next_id += 1;
                let bx = ocpd::core::Box3::new(
                    [x, y, z],
                    [(x + block).min(dims[0]), (y + block).min(dims[1]), (z + block).min(dims[2])],
                );
                if id != 0 {
                    v.fill_box(bx, id);
                }
                x += block;
            }
            y += block;
        }
        z += block;
    }
    v
}
