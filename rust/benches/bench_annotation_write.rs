//! Figure 12: annotation write throughput as a function of the uploaded
//! region size — and why it collapses.
//!
//! The paper uploads dense manual annotations (>90% labeled, compressing
//! to ~6%) with 16 parallel writers and finds: writes scale to ~2 MB
//! regions, peak far below read throughput (19 vs 121 MB/s), and collapse
//! beyond 2 MB because every upload is a read-modify-write *plus* a
//! spatial-index update — and parallel index updates contend ("transaction
//! retries and timeouts in MySQL"; here, the index transaction lock).
//!
//! We reproduce the sweep over the RAID-6 device model and also print the
//! read throughput of the same regions for the read≫write comparison.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::*;
use ocpd::annotation::AnnotationDb;
use ocpd::chunkstore::CuboidStore;
use ocpd::core::{Box3, DatasetBuilder, Project, Vec3, WriteDiscipline};
use ocpd::storage::{DeviceProfile, Engine, MemStore, SimulatedStore};
use ocpd::util::pool::scoped_map;
use ocpd::util::Rng;

const DIMS: [u64; 3] = [1024, 1024, 64];
const PARALLEL: usize = 16;

fn db() -> Arc<AnnotationDb> {
    let ds = Arc::new(DatasetBuilder::new("ds", DIMS).levels(1).build());
    let pr = Arc::new(Project::annotation("ann", "ds"));
    let engine: Engine = Arc::new(SimulatedStore::new(
        Arc::new(MemStore::new()),
        DeviceProfile::hdd_array(),
        1.0,
    ));
    Arc::new(
        AnnotationDb::new(
            Arc::new(CuboidStore::new(ds, pr, Arc::clone(&engine))),
            engine,
        )
        .unwrap(),
    )
}

/// Region shape holding `voxels` voxels.
fn shape_for(voxels: u64) -> Vec3 {
    let mut s = [16u64, 16, 1];
    let mut cur = 256;
    let mut axis = 0;
    while cur < voxels {
        s[axis % 3] *= 2;
        cur *= 2;
        axis += 1;
    }
    [s[0].min(DIMS[0]), s[1].min(DIMS[1]), s[2].min(DIMS[2])]
}

fn main() {
    println!("Figure 12: dense annotation upload throughput, {PARALLEL} parallel writers");
    header(
        "Fig 12: throughput (MB/s of region) vs region size",
        &["size", "write", "read", "ids/region"],
    );
    // Region sizes in voxels (4B each): 16K .. 2M voxels = 64KB .. 8MB.
    for exp in 0..8u32 {
        let voxels = 16 * 1024u64 << exp;
        let shape = shape_for(voxels);
        let db = db();
        let mut rng = Rng::new(exp as u64);
        // Pre-generate distinct regions + payloads; one label per 32^3
        // sub-block, like fused segmentation output — bigger regions
        // carry more distinct ids, so the index-update fan-out grows.
        let payload = dense_labels(shape, 32, exp as u64 + 9);
        let ids = payload.unique_nonzero().len();
        let boxes: Vec<Box3> = (0..PARALLEL)
            .map(|_| {
                Box3::at(
                    [
                        rng.below(DIMS[0] - shape[0] + 1),
                        rng.below(DIMS[1] - shape[1] + 1),
                        rng.below(DIMS[2] - shape[2] + 1),
                    ],
                    shape,
                )
            })
            .collect();
        let bytes = voxels * 4 * PARALLEL as u64;
        let wsecs = time(|| {
            scoped_map(PARALLEL, PARALLEL, |i| {
                db.write_volume(0, boxes[i], &payload, WriteDiscipline::Overwrite).unwrap()
            });
        });
        let rsecs = time(|| {
            scoped_map(PARALLEL, PARALLEL, |i| {
                db.cutout.read::<u32>(0, 0, 0, boxes[i]).unwrap().len()
            });
        });
        row(&[
            size_label(voxels * 4),
            format!("{:.1}", bytes as f64 / 1e6 / wsecs),
            format!("{:.1}", bytes as f64 / 1e6 / rsecs),
            ids.to_string(),
        ]);
    }
    println!(
        "\npaper shape: write ≪ read at equal size; write peaks near ~2MB then\n\
         collapses as per-region id count multiplies index-update contention\n\
         (§5, Fig 12: 19 MB/s write vs 121 MB/s read)."
    );
}
