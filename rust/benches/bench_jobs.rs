//! Batch compute engine throughput: propagation and synapse-detection
//! blocks/sec at 1/2/4/8 workers — the job-engine analogue of §2's "20
//! parallel instances" scaling claim.
//!
//! Synapse-detect rows need the AOT artifacts (`make artifacts`); when
//! the runtime cannot load, those rows are skipped and noted in the
//! output. Prints the table and rewrites `../BENCH_jobs.json` (override
//! with `OCPD_BENCH_OUT`).

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::Instant;

use common::*;
use ocpd::annotation::AnnotationDb;
use ocpd::chunkstore::CuboidStore;
use ocpd::core::{Box3, DatasetBuilder, Project, WriteDiscipline};
use ocpd::cutout::CutoutService;
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::jobs::{JobConfig, JobManager, JobSpec, JobState, PropagateJob, SynapseDetectJob};
use ocpd::runtime::{artifact_dir, Runtime};
use ocpd::storage::{Engine, MemStore};
use ocpd::vision::SynapsePipeline;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];
const PROP_DIMS: [u64; 3] = [512, 256, 32];
const SYN_DIMS: [u64; 3] = [512, 512, 16];

struct Row {
    job: &'static str,
    workers: usize,
    blocks: u64,
    seconds: f64,
}

impl Row {
    fn blocks_per_sec(&self) -> f64 {
        self.blocks as f64 / self.seconds.max(1e-9)
    }
}

/// Annotation database over small cuboids so propagation has plenty of
/// blocks to schedule.
fn labeled_db(dims: [u64; 3]) -> Arc<AnnotationDb> {
    let ds = Arc::new(
        DatasetBuilder::new("b", dims)
            .levels(3)
            .cuboids([32, 32, 8], [16, 16, 16])
            .build(),
    );
    let pr = Arc::new(Project::annotation("ann", "b"));
    let engine: Engine = Arc::new(MemStore::new());
    let store = Arc::new(CuboidStore::new(ds, pr, Arc::clone(&engine)));
    let db = Arc::new(AnnotationDb::new(store, engine).unwrap());
    let labels = dense_labels(dims, 16, 7);
    db.write_volume(0, Box3::new([0, 0, 0], dims), &labels, WriteDiscipline::Overwrite)
        .unwrap();
    db
}

/// Run one job to completion and return (blocks, seconds).
fn run(spec: Arc<dyn JobSpec>, workers: usize) -> (u64, f64) {
    let m = JobManager::new(Arc::new(MemStore::new()));
    let t0 = Instant::now();
    let h = m.submit(spec, JobConfig::with_workers(workers)).unwrap();
    assert_eq!(h.wait(), JobState::Completed, "{:?}", h.status().error);
    (h.status().completed_blocks, t0.elapsed().as_secs_f64())
}

fn main() {
    let mut rows: Vec<Row> = Vec::new();
    header(
        "Batch compute engine: blocks/sec vs. workers",
        &["job", "workers", "blocks", "seconds", "blocks/s"],
    );

    // Propagation: fresh labeled volume per worker count (each run
    // builds the full hierarchy from scratch).
    for &workers in &WORKER_COUNTS {
        let db = labeled_db(PROP_DIMS);
        let (blocks, seconds) =
            run(Arc::new(PropagateJob::annotation(db)), workers);
        let r = Row { job: "propagate", workers, blocks, seconds };
        row(&[
            r.job.to_string(),
            r.workers.to_string(),
            r.blocks.to_string(),
            format!("{:.3}", r.seconds),
            format!("{:.1}", r.blocks_per_sec()),
        ]);
        rows.push(r);
    }

    // Synapse detection: needs the AOT artifacts.
    match Runtime::load_dir(artifact_dir()) {
        Ok(rt) => {
            let rt = Arc::new(rt);
            let ds = Arc::new(DatasetBuilder::new("s", SYN_DIMS).levels(1).build());
            let pr = Arc::new(Project::image("img", "s"));
            let img = Arc::new(CutoutService::new(Arc::new(CuboidStore::new(
                Arc::clone(&ds),
                pr,
                Arc::new(MemStore::new()),
            ))));
            let sv = generate(&SynthSpec::small(SYN_DIMS, 7));
            ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
            let region = Box3::new([0, 0, 0], SYN_DIMS);
            for &workers in &WORKER_COUNTS {
                // Fresh annotation target per run (no duplicate objects).
                let apr = Arc::new(Project::annotation("syn", "s"));
                let aeng: Engine = Arc::new(MemStore::new());
                let astore =
                    Arc::new(CuboidStore::new(Arc::clone(&ds), apr, Arc::clone(&aeng)));
                let anno = Arc::new(AnnotationDb::new(astore, aeng).unwrap());
                let pipeline = Arc::new(SynapsePipeline::new(
                    Arc::clone(&rt),
                    Arc::clone(&img),
                    anno,
                ));
                let (blocks, seconds) =
                    run(Arc::new(SynapseDetectJob::new(pipeline, 0, region)), workers);
                let r = Row { job: "synapse", workers, blocks, seconds };
                row(&[
                    r.job.to_string(),
                    r.workers.to_string(),
                    r.blocks.to_string(),
                    format!("{:.3}", r.seconds),
                    format!("{:.1}", r.blocks_per_sec()),
                ]);
                rows.push(r);
            }
        }
        Err(e) => {
            println!("\n(synapse rows skipped: no runtime — {e})");
        }
    }

    // Scaling sanity: more workers must not be slower than one worker
    // by any large margin (lock-step scheduling bugs show up here).
    let p1 = rows
        .iter()
        .find(|r| r.job == "propagate" && r.workers == 1)
        .map(Row::blocks_per_sec)
        .unwrap();
    let p8 = rows
        .iter()
        .find(|r| r.job == "propagate" && r.workers == 8)
        .map(Row::blocks_per_sec)
        .unwrap();
    println!("\npropagate 8-worker vs 1-worker: {:.1} vs {:.1} blocks/s ({:.2}x)", p8, p1, p8 / p1);

    // Machine-readable results.
    let out = std::env::var("OCPD_BENCH_OUT").unwrap_or_else(|_| "../BENCH_jobs.json".into());
    let mut json = String::from("{\n  \"bench\": \"bench_jobs\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"propagate_dims\": {PROP_DIMS:?}, \"synapse_dims\": {SYN_DIMS:?}}},\n"
    ));
    json.push_str("  \"provenance\": \"measured by cargo bench --bench bench_jobs\",\n");
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"job\": \"{}\", \"workers\": {}, \"blocks\": {}, \"seconds\": {:.4}, \
             \"blocks_per_sec\": {:.1}}}{}\n",
            r.job,
            r.workers,
            r.blocks,
            r.seconds,
            r.blocks_per_sec(),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
