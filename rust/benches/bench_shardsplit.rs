//! Shard-split money shot: what does a heat-driven split buy a skewed
//! workload, and what does the live move cost readers while it runs?
//!
//! A 2-shard engine over simulated seek-bound nodes (parallelism 1, so
//! a node serializes its ops — the contention a hot shard creates)
//! takes a 90%-hot skewed read/write workload:
//!
//! * **before** — the hot shard's node serializes ~90% of all traffic;
//! * **during** — the same workload runs while the copier drains the
//!   move window in chunks (read latencies collected mid-move);
//! * **after** — the hot shard is split at the heat tracker's
//!   `hot_split_key` and its upper half rehomed to a fresh node, so the
//!   hot traffic spreads over two devices.
//!
//! Prints the table and rewrites `../BENCH_shardsplit.json` (override
//! with `OCPD_BENCH_OUT`). `OCPD_BENCH_SMOKE=1` shrinks the workload
//! for CI. Acceptance (ISSUE 10): skewed throughput after the split is
//! >= 1.5x before, and no read during the move pays more than 10x the
//! steady-state p99.

#[path = "common/mod.rs"]
mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use ocpd::cluster::{ReplicaSet, ShardMove, ShardedEngine};
use ocpd::obs::heat::HeatTracker;
use ocpd::shard::ShardMap;
use ocpd::storage::{DeviceProfile, Engine, MemStore, SimulatedStore, StorageEngine};
use ocpd::util::Rng;

use common::*;

const TABLE: &str = "bench/data";

struct Workload {
    threads: usize,
    ops_per_thread: usize,
    value_bytes: usize,
    total_keys: u64,
    /// Fraction of ops aimed at the hot shard.
    hot_frac: f64,
    write_frac: f64,
    copy_chunk: usize,
}

fn workload() -> Workload {
    if std::env::var("OCPD_BENCH_SMOKE").is_ok() {
        Workload {
            threads: 4,
            ops_per_thread: 400,
            value_bytes: 256,
            total_keys: 4096,
            hot_frac: 0.9,
            write_frac: 0.2,
            copy_chunk: 16,
        }
    } else {
        Workload {
            threads: 4,
            ops_per_thread: 2500,
            value_bytes: 256,
            total_keys: 4096,
            hot_frac: 0.9,
            write_frac: 0.2,
            copy_chunk: 16,
        }
    }
}

/// A seek-bound single-spindle node: every op pays a positioning cost
/// and the device serializes (parallelism 1), so a hot shard's node is
/// a genuine bottleneck and a split genuinely parallelizes.
fn bench_profile() -> DeviceProfile {
    DeviceProfile {
        name: "bench-spindle",
        read_seek_us: 120.0,
        write_seek_us: 150.0,
        read_mbps: 1e6,
        write_mbps: 1e6,
        iops: 0.0,
        parallelism: 1,
    }
}

fn sim_node(mem: &Arc<MemStore>) -> Engine {
    Arc::new(SimulatedStore::new(Arc::clone(mem) as Engine, bench_profile(), 1.0))
}

/// One client thread's slice of the skewed workload. Returns the read
/// latencies (µs) it observed; `until` (if set) overrides the op count
/// and runs until the flag flips.
#[allow(clippy::too_many_arguments)]
fn client(
    s: &ShardedEngine,
    w: &Workload,
    heat: Option<&HeatTracker>,
    seed: u64,
    ops: usize,
    until: Option<&AtomicBool>,
    hot_lo: u64,
    value: &[u8],
) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let hot_span = w.total_keys - hot_lo;
    let mut lats = Vec::new();
    let mut done = 0usize;
    loop {
        match until {
            Some(stop) => {
                if stop.load(Ordering::Acquire) {
                    break;
                }
            }
            None => {
                if done >= ops {
                    break;
                }
            }
        }
        let k = if rng.chance(w.hot_frac) {
            hot_lo + rng.next_u64() % hot_span
        } else {
            rng.next_u64() % hot_lo
        };
        if rng.chance(w.write_frac) {
            s.put(TABLE, k, value).unwrap();
            if let Some(h) = heat {
                h.record_write(k, value.len() as u64);
            }
        } else {
            let t0 = Instant::now();
            let v = s.get(TABLE, k).unwrap();
            lats.push(t0.elapsed().as_secs_f64() * 1e6);
            assert!(v.is_some(), "preloaded key {k} missing");
            if let Some(h) = heat {
                h.record_read(k, value.len() as u64);
            }
        }
        done += 1;
    }
    lats
}

/// Run `threads` clients to completion; returns (wall seconds, ops,
/// all read latencies).
fn run_phase(
    s: &ShardedEngine,
    w: &Workload,
    heat: Option<&HeatTracker>,
    seed: u64,
    hot_lo: u64,
    value: &[u8],
) -> (f64, u64, Vec<f64>) {
    let t0 = Instant::now();
    let mut lats = Vec::new();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..w.threads)
            .map(|i| {
                scope.spawn(move || {
                    client(s, w, heat, seed ^ (i as u64) << 32, w.ops_per_thread, None, hot_lo, value)
                })
            })
            .collect();
        for h in handles {
            lats.extend(h.join().unwrap());
        }
    });
    (t0.elapsed().as_secs_f64(), (w.threads * w.ops_per_thread) as u64, lats)
}

fn p99_us(lats: &mut [f64]) -> f64 {
    assert!(!lats.is_empty(), "no read latencies collected");
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lats[(lats.len() * 99 / 100).min(lats.len() - 1)]
}

fn main() {
    let w = workload();
    let value = vec![0xCD_u8; w.value_bytes];

    // Two shards over two seek-bound nodes; shard 1 will run hot.
    let mems: Vec<Arc<MemStore>> = (0..3).map(|_| Arc::new(MemStore::new())).collect();
    let map = ShardMap::even(w.total_keys, vec![0, 1]).unwrap();
    let hot_lo = map.shard_range(1).0;
    // Preload every key straight into the backing stores (no simulated
    // latency for setup).
    for k in 0..w.total_keys {
        mems[map.nodes()[map.shard_for(k)]].put(TABLE, k, &value).unwrap();
    }
    let engines: Vec<Engine> = mems.iter().take(2).map(sim_node).collect();
    let map = Arc::new(map);
    let s = ShardedEngine::new(ShardMap::even(w.total_keys, vec![0, 1]).unwrap(), engines);
    let heat = HeatTracker::new(w.total_keys, Arc::clone(&map));

    // Phase A: steady state, skewed at the 2-shard layout.
    let (secs_before, ops, mut steady_lats) =
        run_phase(&s, &w, Some(&heat), 0xBE9C, hot_lo, &value);
    let thr_before = ops as f64 / secs_before;
    let p99_steady = p99_us(&mut steady_lats);

    // The tracker names the cut: hottest shard, Morton-block-snapped.
    let snap = heat.snapshot();
    let hot_shard = snap.shards.first().expect("heat snapshot empty").shard;
    assert_eq!(hot_shard, 1, "skew missed the intended shard");
    let cut = heat.hot_split_key(hot_shard).expect("no split key for the hot shard");

    // Phase B: open the move window and drain it while the same
    // workload keeps running; every read in this phase is a mid-move
    // read.
    let new_map =
        Arc::new(s.map().split(hot_shard, cut).unwrap().assign(hot_shard + 1, 2).unwrap());
    let to = ReplicaSet::solo(hot_shard + 1, 2, sim_node(&mems[2]));
    to.set_range(new_map.shard_range(hot_shard + 1));
    let from = Arc::clone(&s.sets()[hot_shard]);
    let mut sets = s.sets();
    sets.insert(hot_shard + 1, Arc::clone(&to));
    s.begin_move(ShardMove {
        range: new_map.shard_range(hot_shard + 1),
        from,
        to,
        scope: "bench".into(),
        map: Arc::clone(&new_map),
        sets,
    })
    .unwrap();

    let stop = AtomicBool::new(false);
    let mut move_lats: Vec<f64> = Vec::new();
    let mut keys_moved = 0u64;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..w.threads)
            .map(|i| {
                let s = &s;
                let w = &w;
                let stop = &stop;
                let value = &value[..];
                scope.spawn(move || {
                    client(s, w, None, 0x30BE ^ (i as u64) << 32, 0, Some(stop), hot_lo, value)
                })
            })
            .collect();
        keys_moved = s.copy_moving(w.copy_chunk).unwrap();
        s.commit_move().unwrap();
        stop.store(true, Ordering::Release);
        for h in handles {
            move_lats.extend(h.join().unwrap());
        }
    });
    let p99_move = p99_us(&mut move_lats);
    assert_eq!(s.map().num_shards(), 3, "split did not install");

    // Phase C: steady state again, hot traffic now spread over 2 nodes.
    let (secs_after, _, _) = run_phase(&s, &w, None, 0xAF7E9, hot_lo, &value);
    let thr_after = ops as f64 / secs_after;

    let speedup = thr_after / thr_before;
    let p99_ratio = p99_move / p99_steady;

    header(
        "skewed throughput, before/after heat-driven split",
        &["phase", "shards", "ops", "seconds", "ops/s", "speedup"],
    );
    row(&[
        "before".into(),
        "2".into(),
        ops.to_string(),
        format!("{secs_before:.4}"),
        format!("{thr_before:.0}"),
        "1.00x".into(),
    ]);
    row(&[
        "after".into(),
        "3".into(),
        ops.to_string(),
        format!("{secs_after:.4}"),
        format!("{thr_after:.0}"),
        format!("{speedup:.2}x"),
    ]);
    println!(
        "\nsplit: shard {hot_shard} at key {cut} (heat-driven, Morton-snapped), \
         {keys_moved} keys moved live"
    );
    println!(
        "read p99: steady={p99_steady:.0}µs during-move={p99_move:.0}µs \
         ratio={p99_ratio:.2}x (limit 10x)"
    );

    let speedup_ok = speedup >= 1.5;
    let p99_ok = p99_ratio < 10.0;
    if !speedup_ok || !p99_ok {
        println!("WARNING: acceptance not met (speedup_ok={speedup_ok} p99_ok={p99_ok})");
    }

    let out =
        std::env::var("OCPD_BENCH_OUT").unwrap_or_else(|_| "../BENCH_shardsplit.json".into());
    let mut json = String::from("{\n  \"bench\": \"bench_shardsplit\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"threads\": {}, \"ops_per_thread\": {}, \"value_bytes\": {}, \
         \"total_keys\": {}, \"hot_frac\": {}, \"write_frac\": {}, \"copy_chunk\": {}}},\n",
        w.threads, w.ops_per_thread, w.value_bytes, w.total_keys, w.hot_frac, w.write_frac,
        w.copy_chunk
    ));
    json.push_str("  \"provenance\": \"measured by cargo bench --bench bench_shardsplit\",\n");
    json.push_str(&format!("  \"split_cut\": {cut},\n"));
    json.push_str(&format!("  \"keys_moved\": {keys_moved},\n"));
    json.push_str(&format!(
        "  \"throughput_before_ops_per_sec\": {thr_before:.1},\n\
         \x20 \"throughput_after_ops_per_sec\": {thr_after:.1},\n\
         \x20 \"speedup\": {speedup:.3},\n\
         \x20 \"read_p99_steady_us\": {p99_steady:.1},\n\
         \x20 \"read_p99_move_us\": {p99_move:.1},\n\
         \x20 \"p99_ratio\": {p99_ratio:.3},\n"
    ));
    json.push_str(&format!(
        "  \"acceptance\": {{\"speedup_min\": 1.5, \"speedup_ok\": {speedup_ok}, \
         \"p99_ratio_max\": 10.0, \"p99_ratio_ok\": {p99_ok}}}\n"
    ));
    json.push_str("}\n");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
