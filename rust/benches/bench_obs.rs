//! Observability-overhead money shot: the same warm cutout read
//! workload with tracing off, sampled 1-in-64 (the default), and
//! always-on — each read wrapped in a root trace exactly the way the
//! HTTP dispatcher wraps a request. The claim under test (DESIGN.md
//! §9): recording is cheap enough that the default sampled
//! configuration costs < 2% cutout throughput.
//!
//! Prints the table and rewrites `../BENCH_obs.json` (override with
//! `OCPD_BENCH_OUT`). `OCPD_BENCH_SMOKE=1` shrinks the workload for CI
//! (and skips the <2% assertion — smoke timings are too noisy to gate
//! on).

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Project};
use ocpd::cutout::CutoutService;
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::obs::trace::{self, TraceConfig, TraceMode};

use common::*;

struct Workload {
    dims: [u64; 3],
    read_extent: [u64; 3],
    reads: usize,
    repeats: usize,
}

fn workload() -> Workload {
    if std::env::var("OCPD_BENCH_SMOKE").is_ok() {
        Workload { dims: [256, 256, 16], read_extent: [64, 64, 8], reads: 40, repeats: 3 }
    } else {
        Workload { dims: [512, 512, 32], read_extent: [128, 128, 16], reads: 400, repeats: 5 }
    }
}

fn boot(dims: [u64; 3]) -> std::sync::Arc<CutoutService> {
    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(DatasetBuilder::new("img", dims).levels(1).build());
    let img = cluster.create_image_project(Project::image("img", "img")).unwrap();
    let sv = generate(&SynthSpec::small(dims, 11));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    img
}

fn config_for(mode: &str) -> TraceConfig {
    TraceConfig {
        mode: match mode {
            "off" => TraceMode::Off,
            "always" => TraceMode::Always,
            _ => TraceMode::Sampled,
        },
        sample_every: 64,
        slow_threshold_us: 100_000,
        capacity: 256,
    }
}

/// `reads` warm cutout reads, each under its own root trace (the HTTP
/// dispatcher's shape); returns the median wall seconds over `repeats`.
fn run(svc: &CutoutService, w: &Workload, mode: &str) -> f64 {
    trace::tracer().configure(config_for(mode));
    let e = w.read_extent;
    let boxes: Vec<Box3> = (0..4)
        .map(|i| {
            let x0 = i * e[0];
            Box3::new([x0, 0, 0], [x0 + e[0], e[1], e[2]])
        })
        .collect();
    let timings: Vec<f64> = (0..w.repeats)
        .map(|_| {
            let t0 = Instant::now();
            for i in 0..w.reads {
                let bx = boxes[i % boxes.len()];
                let root = trace::start_trace("bench", "cutout", &format!("bench-{i}"));
                let out = svc.read::<u8>(0, 0, 0, bx).unwrap();
                drop(root);
                assert_eq!(out.len() as u64, bx.volume());
            }
            t0.elapsed().as_secs_f64()
        })
        .collect();
    let mut ts = timings;
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    trace::tracer().clear();
    ts[ts.len() / 2]
}

struct Row {
    mode: &'static str,
    reads: usize,
    seconds: f64,
    bytes: u64,
}

impl Row {
    fn reads_per_sec(&self) -> f64 {
        self.reads as f64 / self.seconds.max(1e-9)
    }
    fn mbps(&self) -> f64 {
        self.bytes as f64 / (1 << 20) as f64 / self.seconds.max(1e-9)
    }
}

fn main() {
    let w = workload();
    let svc = boot(w.dims);
    let e = w.read_extent;
    let read_bytes = e[0] * e[1] * e[2];

    // Warm the cuboid cache so rows compare tracing cost, not I/O.
    let warm = Box3::new([0, 0, 0], [4 * e[0], e[1], e[2]]);
    let _ = svc.read::<u8>(0, 0, 0, warm).unwrap();

    header(
        "warm cutout reads under tracing",
        &["mode", "reads", "seconds", "reads/s", "MB/s", "overhead"],
    );
    let mut rows: Vec<Row> = Vec::new();
    for mode in ["off", "sampled", "always"] {
        let seconds = run(&svc, &w, mode);
        rows.push(Row { mode, reads: w.reads, seconds, bytes: read_bytes * w.reads as u64 });
        let r = rows.last().unwrap();
        let overhead = 100.0 * (r.seconds / rows[0].seconds - 1.0);
        row(&[
            r.mode.to_string(),
            r.reads.to_string(),
            format!("{:.4}", r.seconds),
            format!("{:.0}", r.reads_per_sec()),
            format!("{:.1}", r.mbps()),
            format!("{overhead:+.2}%"),
        ]);
    }
    let overhead_pct =
        |i: usize| 100.0 * (rows[i].seconds / rows[0].seconds - 1.0);
    let sampled_overhead = overhead_pct(1);
    let always_overhead = overhead_pct(2);
    println!(
        "\nsampled(1-in-64) overhead: {sampled_overhead:+.2}%; always-on: {always_overhead:+.2}%"
    );
    if std::env::var("OCPD_BENCH_SMOKE").is_err() {
        assert!(
            sampled_overhead < 2.0,
            "default sampled tracing must cost < 2% ({sampled_overhead:.2}%)"
        );
    }

    let out = std::env::var("OCPD_BENCH_OUT").unwrap_or_else(|_| "../BENCH_obs.json".into());
    let mut json = String::from("{\n  \"bench\": \"bench_obs\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"dims\": [{}, {}, {}], \"read_extent\": [{}, {}, {}], \
         \"reads\": {}, \"cache\": \"warm\", \"sample_every\": 64}},\n",
        w.dims[0], w.dims[1], w.dims[2], e[0], e[1], e[2], w.reads
    ));
    json.push_str("  \"provenance\": \"measured by cargo bench --bench bench_obs\",\n");
    json.push_str(&format!(
        "  \"sampled_overhead_pct\": {sampled_overhead:.2},\n  \
         \"always_overhead_pct\": {always_overhead:.2},\n"
    ));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"mode\": \"{}\", \"reads\": {}, \"seconds\": {:.4}, \
             \"reads_per_sec\": {:.1}, \"mb_per_sec\": {:.1}, \"overhead_pct\": {:.2}}}{}\n",
            r.mode,
            r.reads,
            r.seconds,
            r.reads_per_sec(),
            r.mbps(),
            100.0 * (r.seconds / rows[0].seconds - 1.0),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
