//! Transport-tier money shot: the same small-request workload issued
//! close-per-request (the seed's `Connection: close` behavior),
//! keep-alive (pooled sockets), and pipelined (batched requests on one
//! socket), at 1/4/16 concurrent clients — plus buffered vs streamed
//! large-cutout delivery with a peak-memory proxy.
//!
//! * `close` — every request pays TCP connect + a server connection
//!   thread spawn + teardown.
//! * `keepalive` — the client pool reuses one socket per client thread.
//! * `pipelined` — requests are written in batches of 8 before any
//!   response is read, eliminating per-request round-trip stalls.
//!
//! Prints the table and rewrites `../BENCH_http.json` (override with
//! `OCPD_BENCH_OUT`). `OCPD_BENCH_SMOKE=1` shrinks the workload for CI.

#[path = "common/mod.rs"]
mod common;

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use ocpd::cluster::Cluster;
use ocpd::core::{DatasetBuilder, Project};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::web::http::{request, request_info, request_once};
use ocpd::web::{serve_with, ServeOptions, Server};

use common::*;

const PIPELINE_BATCH: usize = 8;

struct Workload {
    requests_per_client: usize,
    client_counts: Vec<usize>,
    cutout_dims: [u64; 3],
    /// Stream threshold for the streamed-cutout server (well under the
    /// cutout's raw size so it actually streams).
    stream_threshold: usize,
}

fn workload() -> Workload {
    if std::env::var("OCPD_BENCH_SMOKE").is_ok() {
        Workload {
            requests_per_client: 40,
            client_counts: vec![1, 4],
            cutout_dims: [64, 64, 64],
            stream_threshold: 128 << 10,
        }
    } else {
        Workload {
            requests_per_client: 400,
            client_counts: vec![1, 4, 16],
            cutout_dims: [256, 256, 256],
            stream_threshold: 1 << 20,
        }
    }
}

fn boot(dims: [u64; 3], stream_threshold: usize) -> Server {
    let cluster = Cluster::in_memory(1, 1);
    cluster.register_dataset(DatasetBuilder::new("img", dims).levels(1).build());
    let img = cluster.create_image_project(Project::image("img", "img")).unwrap();
    let sv = generate(&SynthSpec::small(dims, 3));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    serve_with(
        cluster,
        None,
        "127.0.0.1:0",
        ServeOptions { stream_threshold, ..ServeOptions::default() },
    )
    .unwrap()
}

/// `clients` threads each issuing `n` small requests; returns seconds.
fn hammer<F: Fn(&str) + Sync>(url: &str, clients: usize, n: usize, issue: F) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..clients {
            let issue = &issue;
            s.spawn(move || {
                for _ in 0..n {
                    issue(url);
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

/// One client's pipelined run: batches of `PIPELINE_BATCH` requests
/// written before any response is read.
fn pipelined_client(addr: std::net::SocketAddr, n: usize) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut done = 0usize;
    while done < n {
        let batch = PIPELINE_BATCH.min(n - done);
        let mut burst = String::new();
        for _ in 0..batch {
            burst.push_str("GET /wal/status/ HTTP/1.1\r\nHost: bench\r\n\r\n");
        }
        writer.write_all(burst.as_bytes()).unwrap();
        for _ in 0..batch {
            // Status line, headers (find content-length), body.
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("200"), "{line}");
            let mut content_length = 0usize;
            loop {
                let mut h = String::new();
                reader.read_line(&mut h).unwrap();
                let h = h.trim();
                if h.is_empty() {
                    break;
                }
                if let Some((k, v)) = h.split_once(':') {
                    if k.eq_ignore_ascii_case("content-length") {
                        content_length = v.trim().parse().unwrap();
                    }
                }
            }
            let mut body = vec![0u8; content_length];
            reader.read_exact(&mut body).unwrap();
        }
        done += batch;
    }
}

struct Row {
    config: &'static str,
    clients: usize,
    requests: usize,
    seconds: f64,
}

impl Row {
    fn req_per_sec(&self) -> f64 {
        self.requests as f64 / self.seconds.max(1e-9)
    }
}

fn main() {
    let w = workload();
    let server = boot([64, 64, 16], usize::MAX);
    let url = server.url();
    let addr = server.addr();

    let mut rows: Vec<Row> = Vec::new();
    header(
        "HTTP transport: small requests (GET /wal/status/)",
        &["config", "clients", "requests", "req/s"],
    );
    for &clients in &w.client_counts {
        let requests = clients * w.requests_per_client;
        for config in ["close", "keepalive", "pipelined"] {
            let seconds = match config {
                "close" => hammer(&url, clients, w.requests_per_client, |u| {
                    let (code, _) =
                        request_once("GET", &format!("{u}/wal/status/"), &[]).unwrap();
                    assert_eq!(code, 200);
                }),
                "keepalive" => hammer(&url, clients, w.requests_per_client, |u| {
                    let (code, _) = request("GET", &format!("{u}/wal/status/"), &[]).unwrap();
                    assert_eq!(code, 200);
                }),
                _ => {
                    let t0 = Instant::now();
                    std::thread::scope(|s| {
                        for _ in 0..clients {
                            s.spawn(move || pipelined_client(addr, w.requests_per_client));
                        }
                    });
                    t0.elapsed().as_secs_f64()
                }
            };
            rows.push(Row { config, clients, requests, seconds });
            let r = rows.last().unwrap();
            row(&[
                r.config.to_string(),
                r.clients.to_string(),
                r.requests.to_string(),
                format!("{:.0}", r.req_per_sec()),
            ]);
        }
    }

    let max_clients = *w.client_counts.last().unwrap();
    let rps = |config: &str| {
        rows.iter()
            .find(|r| r.config == config && r.clients == max_clients)
            .map(Row::req_per_sec)
            .unwrap()
    };
    let keepalive_gain = rps("keepalive") / rps("close");
    let pipeline_gain = rps("pipelined") / rps("close");
    println!(
        "\nkeep-alive vs close at {max_clients} clients: {:.2}x; pipelined: {:.2}x",
        keepalive_gain, pipeline_gain
    );
    assert!(
        keepalive_gain > 1.0,
        "keep-alive must beat close-per-request at {max_clients} clients"
    );
    drop(server);

    // Buffered vs streamed large cutout: same bytes, different peak
    // memory. The buffered server materializes the whole encoded body;
    // the streaming server's high-water mark is one slab chunk.
    header(
        "256^3-class cutout: buffered vs streamed",
        &["mode", "seconds", "bytes", "peak proxy"],
    );
    let d = w.cutout_dims;
    let path = format!("/img/ocpk/0/0,{}/0,{}/0,{}/", d[0], d[1], d[2]);

    let buffered = boot(d, usize::MAX);
    let t0 = Instant::now();
    let info = request_info("GET", &format!("{}{path}", buffered.url()), &[]).unwrap();
    let buffered_seconds = t0.elapsed().as_secs_f64();
    assert_eq!(info.status, 200);
    assert!(!info.chunked);
    let buffered_bytes = info.body.len();
    // Peak proxy: the whole encoded body lived in server memory at once.
    let buffered_peak = buffered_bytes;
    drop(buffered);
    row(&[
        "buffered".into(),
        format!("{buffered_seconds:.3}"),
        size_label(buffered_bytes as u64),
        size_label(buffered_peak as u64),
    ]);

    let streaming = boot(d, w.stream_threshold);
    let t0 = Instant::now();
    let info = request_info("GET", &format!("{}{path}", streaming.url()), &[]).unwrap();
    let streamed_seconds = t0.elapsed().as_secs_f64();
    assert_eq!(info.status, 200);
    assert!(info.chunked, "large cutout must stream at a 1 MiB threshold");
    let streamed_bytes = info.body.len();
    // Peak proxy: the server-side chunk high-water mark.
    let streamed_peak = streaming.metrics.stream_peak_chunk.get() as usize;
    assert!(streamed_peak > 0 && streamed_peak < buffered_peak);
    drop(streaming);
    row(&[
        "streamed".into(),
        format!("{streamed_seconds:.3}"),
        size_label(streamed_bytes as u64),
        size_label(streamed_peak as u64),
    ]);
    println!(
        "\nstreamed peak-RSS proxy: {} vs {} buffered ({:.1}x smaller)",
        size_label(streamed_peak as u64),
        size_label(buffered_peak as u64),
        buffered_peak as f64 / streamed_peak as f64
    );

    // Machine-readable results.
    let out = std::env::var("OCPD_BENCH_OUT").unwrap_or_else(|_| "../BENCH_http.json".into());
    let mut json = String::from("{\n  \"bench\": \"bench_http\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"requests_per_client\": {}, \"route\": \"/wal/status/\", \
         \"pipeline_batch\": {PIPELINE_BATCH}, \"cutout_dims\": [{}, {}, {}]}},\n",
        w.requests_per_client, d[0], d[1], d[2]
    ));
    json.push_str("  \"provenance\": \"measured by cargo bench --bench bench_http\",\n");
    json.push_str(&format!(
        "  \"keepalive_vs_close_at_max_clients\": {keepalive_gain:.2},\n"
    ));
    json.push_str(&format!(
        "  \"pipelined_vs_close_at_max_clients\": {pipeline_gain:.2},\n"
    ));
    json.push_str(&format!(
        "  \"cutout\": {{\"buffered_seconds\": {buffered_seconds:.4}, \
         \"streamed_seconds\": {streamed_seconds:.4}, \"bytes\": {streamed_bytes}, \
         \"buffered_peak_bytes\": {buffered_peak}, \"streamed_peak_bytes\": {streamed_peak}}},\n"
    ));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"config\": \"{}\", \"clients\": {}, \"requests\": {}, \
             \"seconds\": {:.4}, \"req_per_sec\": {:.1}}}{}\n",
            r.config,
            r.clients,
            r.requests,
            r.seconds,
            r.req_per_sec(),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
