//! Replication money shot: what does shipping every mutation round to
//! followers cost, and how fast is a failover? The same group-committed
//! put workload runs against a replica set at 1 (the seed's
//! unreplicated layout), 2, and 3 copies, then a 3-way set is promoted
//! repeatedly to measure time-to-promote (the unavailability window a
//! dead leader causes beyond its lease).
//!
//! Prints the table and rewrites `../BENCH_replication.json` (override
//! with `OCPD_BENCH_OUT`). `OCPD_BENCH_SMOKE=1` shrinks the workload
//! for CI.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::Instant;

use ocpd::cluster::{ReplicaSet, ReplicationConfig};
use ocpd::storage::{Engine, MemStore};

use common::*;

struct Workload {
    rounds: usize,
    batch: usize,
    value_bytes: usize,
    repeats: usize,
}

fn workload() -> Workload {
    if std::env::var("OCPD_BENCH_SMOKE").is_ok() {
        Workload { rounds: 60, batch: 16, value_bytes: 1024, repeats: 3 }
    } else {
        Workload { rounds: 400, batch: 32, value_bytes: 4096, repeats: 5 }
    }
}

fn build_set(replicas: usize) -> Arc<ReplicaSet> {
    let members: Vec<(usize, Engine)> =
        (0..replicas).map(|i| (i, Arc::new(MemStore::new()) as Engine)).collect();
    ReplicaSet::new("bench", 0, (0, u64::MAX), members, ReplicationConfig::default()).unwrap()
}

/// The put batches, framed once outside the timed region.
fn batches(w: &Workload) -> Vec<Vec<(u64, Vec<u8>)>> {
    (0..w.rounds)
        .map(|r| {
            (0..w.batch)
                .map(|j| (((r * w.batch + j) % 4096) as u64, vec![0xAB; w.value_bytes]))
                .collect()
        })
        .collect()
}

/// Median wall seconds to push the whole workload through one set.
fn run_puts(set: &ReplicaSet, rounds: &[Vec<(u64, Vec<u8>)>], repeats: usize) -> f64 {
    median_time(repeats, || {
        let epoch = set.epoch();
        for b in rounds {
            set.put_batch(epoch, "bench/data", b).unwrap();
        }
    })
}

/// Median promote latency (µs) on a written-to 3-way set; the demoted
/// leader is caught back up between promotions so every round has a
/// full candidate pool.
fn promote_latency_us(w: &Workload) -> f64 {
    let set = build_set(3);
    let rounds = batches(w);
    let mut ts: Vec<f64> = Vec::new();
    for _ in 0..w.repeats.max(3) {
        let epoch = set.epoch();
        for b in rounds.iter().take(8) {
            set.put_batch(epoch, "bench/data", b).unwrap();
        }
        let t0 = Instant::now();
        set.promote().unwrap();
        ts.push(t0.elapsed().as_secs_f64() * 1e6);
        set.catch_up();
    }
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts[ts.len() / 2]
}

struct Row {
    replicas: usize,
    seconds: f64,
    records: u64,
    bytes: u64,
}

impl Row {
    fn records_per_sec(&self) -> f64 {
        self.records as f64 / self.seconds.max(1e-9)
    }
    fn mbps(&self) -> f64 {
        self.bytes as f64 / (1 << 20) as f64 / self.seconds.max(1e-9)
    }
}

fn main() {
    let w = workload();
    let rounds = batches(&w);
    let records = (w.rounds * w.batch) as u64;
    let bytes = records * w.value_bytes as u64;

    header(
        "replicated put throughput (group-committed rounds)",
        &["replicas", "records", "seconds", "records/s", "MB/s", "overhead"],
    );
    let mut rows: Vec<Row> = Vec::new();
    for replicas in [1usize, 2, 3] {
        let set = build_set(replicas);
        let seconds = run_puts(&set, &rounds, w.repeats);
        rows.push(Row { replicas, seconds, records, bytes });
        let r = rows.last().unwrap();
        let overhead = 100.0 * (r.seconds / rows[0].seconds - 1.0);
        row(&[
            r.replicas.to_string(),
            r.records.to_string(),
            format!("{:.4}", r.seconds),
            format!("{:.0}", r.records_per_sec()),
            format!("{:.1}", r.mbps()),
            format!("{overhead:+.2}%"),
        ]);
    }

    let promote_us = promote_latency_us(&w);
    println!("\ntime-to-promote (3-way set, median): {promote_us:.0} µs");

    let out =
        std::env::var("OCPD_BENCH_OUT").unwrap_or_else(|_| "../BENCH_replication.json".into());
    let mut json = String::from("{\n  \"bench\": \"bench_replication\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"rounds\": {}, \"batch\": {}, \"value_bytes\": {}, \
         \"repeats\": {}}},\n",
        w.rounds, w.batch, w.value_bytes, w.repeats
    ));
    json.push_str("  \"provenance\": \"measured by cargo bench --bench bench_replication\",\n");
    json.push_str(&format!("  \"promote_latency_us\": {promote_us:.1},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"replicas\": {}, \"records\": {}, \"seconds\": {:.4}, \
             \"records_per_sec\": {:.1}, \"mb_per_sec\": {:.1}, \"overhead_pct\": {:.2}}}{}\n",
            r.replicas,
            r.records,
            r.seconds,
            r.records_per_sec(),
            r.mbps(),
            100.0 * (r.seconds / rows[0].seconds - 1.0),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
