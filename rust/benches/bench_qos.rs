//! QoS money shot (DESIGN.md §12): one interactive tenant reading small
//! cutouts over live HTTP while a bulk tenant storms annotation writes
//! and batch ingest jobs churn in the background — first with QoS
//! enforcement off, then on (bulk quota'd, interactive weighted up).
//! The claim under test: enforcement buys the interactive tenant at
//! least a 2x better p99 under the same storm.
//!
//! Prints the table and rewrites `../BENCH_qos.json` (override with
//! `OCPD_BENCH_OUT`). `OCPD_BENCH_SMOKE=1` shrinks the workload for CI
//! (and skips the 2x assertion — smoke timings are too noisy to gate
//! on).

#[path = "common/mod.rs"]
mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use ocpd::array::DenseVolume;
use ocpd::client::{self, OcpClient};
use ocpd::cluster::Cluster;
use ocpd::core::{Box3, DatasetBuilder, Dtype, Project};
use ocpd::ingest::{generate, ingest_volume, SynthSpec};
use ocpd::web::http::request_info;
use ocpd::web::{ocpk, Server};

use common::*;

struct Workload {
    dims: [u64; 3],
    read_extent: [u64; 3],
    reads: usize,
    bulk_threads: usize,
    /// Bulk write payload extent (u32 voxels).
    write_extent: [u64; 3],
    /// Background ingest jobs per phase.
    jobs: usize,
    job_dims: [u64; 3],
}

fn workload() -> Workload {
    if std::env::var("OCPD_BENCH_SMOKE").is_ok() {
        Workload {
            dims: [256, 256, 32],
            read_extent: [64, 64, 8],
            reads: 40,
            bulk_threads: 2,
            write_extent: [64, 64, 8],
            jobs: 1,
            job_dims: [128, 128, 16],
        }
    } else {
        Workload {
            dims: [256, 256, 32],
            read_extent: [64, 64, 16],
            reads: 300,
            bulk_threads: 4,
            write_extent: [128, 128, 16],
            jobs: 2,
            job_dims: [256, 256, 32],
        }
    }
}

fn boot(w: &Workload) -> (Arc<Cluster>, Server) {
    let cluster = Cluster::in_memory(2, 1);
    cluster.register_dataset(DatasetBuilder::new("img", w.dims).levels(1).build());
    let img = cluster.create_image_project(Project::image("img", "img")).unwrap();
    cluster.create_annotation_project(Project::annotation("ann", "img"), true).unwrap();
    let sv = generate(&SynthSpec::small(w.dims, 17));
    ingest_volume(&img, &sv.vol, [256, 256, 16]).unwrap();
    let server = ocpd::web::serve(Arc::clone(&cluster), None, "127.0.0.1:0", 8).unwrap();
    (cluster, server)
}

struct Row {
    mode: &'static str,
    reads: usize,
    p50_us: u64,
    p99_us: u64,
    bulk_ok: u64,
    bulk_throttled: u64,
    preemptions: u64,
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

/// One phase: boot a fresh cluster, optionally arm enforcement, start
/// the bulk storm + job churn, then measure the interactive tenant's
/// per-read latency from a cold start of the contention (cache warmed
/// first so both phases compare scheduling, not I/O).
fn run_mode(mode: &'static str, w: &Workload) -> Row {
    let (cluster, server) = boot(w);
    let url = server.url();
    if mode == "on" {
        client::qos_set_quota(&url, "ann", "req_per_s=8 bytes_per_s=4000000").unwrap();
        client::qos_set_quota(&url, "img", "req_per_s=unlimited weight=4").unwrap();
        client::qos_enforce(&url, "on", None).unwrap();
    }

    // Background job churn: synthetic ingest jobs whose block loop is
    // the preemption point under test.
    for i in 0..w.jobs {
        client::submit_job(
            &url,
            "ingest/img",
            &format!(
                "dims={},{},{} block=64,64,16 workers=2 seed={}",
                w.job_dims[0], w.job_dims[1], w.job_dims[2], 100 + i
            ),
        )
        .unwrap();
    }

    // Bulk storm: adversarial writers that hammer the annotation
    // project as fast as the server lets them, shrugging off 429s.
    let stop = Arc::new(AtomicBool::new(false));
    let e = w.write_extent;
    let vol = DenseVolume::<u32>::zeros(e);
    let body = Arc::new(ocpk::encode_volume(Dtype::U32, [0, 0, 0], &vol).unwrap());
    let mut writers = Vec::new();
    for _ in 0..w.bulk_threads {
        let url = url.clone();
        let stop = Arc::clone(&stop);
        let body = Arc::clone(&body);
        writers.push(std::thread::spawn(move || {
            let wurl = format!("{url}/ann/overwrite/0/");
            let (mut ok, mut throttled) = (0u64, 0u64);
            while !stop.load(Ordering::Relaxed) {
                match request_info("PUT", &wurl, &body) {
                    Ok(i) if i.status == 200 => ok += 1,
                    Ok(i) if i.status == 429 || i.status == 503 => {
                        throttled += 1;
                        // An over-quota tenant that won't back off still
                        // shouldn't spin the transport flat out.
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    _ => {}
                }
            }
            (ok, throttled)
        }));
    }

    // The interactive tenant: small human-scale cutouts, one at a time.
    let img = OcpClient::new(&url, "img");
    let re = w.read_extent;
    let boxes: Vec<Box3> = (0..4)
        .map(|i| {
            let x0 = i * re[0];
            Box3::new([x0, 0, 0], [x0 + re[0], re[1], re[2]])
        })
        .collect();
    for bx in &boxes {
        // Warm the cuboid cache: both phases measure contention.
        let _ = img.cutout_u8(0, *bx).unwrap();
    }
    let mut lat = Vec::with_capacity(w.reads);
    for i in 0..w.reads {
        let bx = boxes[i % boxes.len()];
        let t0 = Instant::now();
        let v = img.cutout_u8(0, bx).unwrap();
        lat.push(t0.elapsed().as_micros() as u64);
        assert_eq!(v.dims(), bx.extent());
    }

    stop.store(true, Ordering::Relaxed);
    let (mut bulk_ok, mut bulk_throttled) = (0u64, 0u64);
    for h in writers {
        let (ok, thr) = h.join().unwrap();
        bulk_ok += ok;
        bulk_throttled += thr;
    }
    lat.sort_unstable();
    Row {
        mode,
        reads: w.reads,
        p50_us: percentile(&lat, 0.50),
        p99_us: percentile(&lat, 0.99),
        bulk_ok,
        bulk_throttled,
        preemptions: cluster.qos().preemptions(),
    }
}

fn main() {
    let w = workload();
    header(
        "interactive cutout latency under a bulk storm + job churn",
        &["enforcement", "reads", "p50_us", "p99_us", "bulk_ok", "bulk_429", "preempt"],
    );
    let mut rows = Vec::new();
    for mode in ["off", "on"] {
        let r = run_mode(mode, &w);
        row(&[
            r.mode.to_string(),
            r.reads.to_string(),
            r.p50_us.to_string(),
            r.p99_us.to_string(),
            r.bulk_ok.to_string(),
            r.bulk_throttled.to_string(),
            r.preemptions.to_string(),
        ]);
        rows.push(r);
    }
    let improvement = rows[0].p99_us as f64 / rows[1].p99_us.max(1) as f64;
    println!("\np99 improvement (off/on): {improvement:.2}x");
    if std::env::var("OCPD_BENCH_SMOKE").is_err() {
        assert!(
            improvement >= 2.0,
            "enforcement must buy the interactive tenant >= 2x p99 ({improvement:.2}x)"
        );
    }

    let out = std::env::var("OCPD_BENCH_OUT").unwrap_or_else(|_| "../BENCH_qos.json".into());
    let mut json = String::from("{\n  \"bench\": \"bench_qos\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"dims\": [{}, {}, {}], \"read_extent\": [{}, {}, {}], \
         \"reads\": {}, \"bulk_threads\": {}, \"write_extent\": [{}, {}, {}], \
         \"jobs\": {}, \"quota\": \"ann req_per_s=8 bytes_per_s=4000000; img weight=4\"}},\n",
        w.dims[0],
        w.dims[1],
        w.dims[2],
        w.read_extent[0],
        w.read_extent[1],
        w.read_extent[2],
        w.reads,
        w.bulk_threads,
        w.write_extent[0],
        w.write_extent[1],
        w.write_extent[2],
        w.jobs
    ));
    json.push_str("  \"provenance\": \"measured by cargo bench --bench bench_qos\",\n");
    json.push_str(&format!("  \"p99_improvement\": {improvement:.2},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"enforcement\": \"{}\", \"reads\": {}, \"p50_us\": {}, \"p99_us\": {}, \
             \"bulk_ok\": {}, \"bulk_throttled\": {}, \"job_preemptions\": {}}}{}\n",
            r.mode,
            r.reads,
            r.p50_us,
            r.p99_us,
            r.bulk_ok,
            r.bulk_throttled,
            r.preemptions,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
