//! Figure 11: throughput of large cutout requests as a function of the
//! number of concurrent requests.
//!
//! The paper issues 256 MB cutouts at increasing parallelism and finds
//! throughput scales past the 8 physical cores — to 16 when reading from
//! disk and 32 from memory — before declining under resource contention.
//! We reproduce the sweep with a scaled request size; the shape to check
//! is rise → peak beyond the core count (I/O overlap) → decline.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::*;
use ocpd::chunkstore::CuboidStore;
use ocpd::core::{Box3, DatasetBuilder, Project};
use ocpd::cutout::CutoutService;
use ocpd::ingest::ingest_volume;
use ocpd::storage::{DeviceProfile, Engine, MemStore, SimulatedStore};
use ocpd::util::pool::scoped_map;
use ocpd::util::Rng;

const DIMS: [u64; 3] = [1024, 1024, 64];
// Scaled stand-in for the paper's 256MB requests.
const REQ_SHAPE: [u64; 3] = [512, 256, 32]; // 4 MB

fn service(sim: bool) -> Arc<CutoutService> {
    let ds = Arc::new(DatasetBuilder::new("ds", DIMS).levels(1).build());
    let pr = Arc::new(Project::image("img", "ds").with_gzip(0));
    let mem: Engine = Arc::new(MemStore::new());
    let engine: Engine = if sim {
        Arc::new(SimulatedStore::new(mem, DeviceProfile::hdd_array(), 1.0))
    } else {
        mem
    };
    let svc = Arc::new(CutoutService::new(Arc::new(CuboidStore::new(ds, pr, engine))));
    let vol = em_like_volume(DIMS, 3);
    ingest_volume(&svc, &vol, [512, 512, 16]).unwrap();
    svc
}

fn throughput(svc: &CutoutService, concurrency: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let boxes: Vec<Box3> = (0..concurrency)
        .map(|_| {
            let lo = [
                rng.below(DIMS[0] - REQ_SHAPE[0] + 1) / 128 * 128,
                rng.below(DIMS[1] - REQ_SHAPE[1] + 1) / 128 * 128,
                rng.below(DIMS[2] - REQ_SHAPE[2] + 1) / 16 * 16,
            ];
            Box3::at(lo, REQ_SHAPE)
        })
        .collect();
    let bytes = (REQ_SHAPE[0] * REQ_SHAPE[1] * REQ_SHAPE[2]) * concurrency as u64;
    let secs = median_time(3, || {
        scoped_map(concurrency, concurrency, |i| {
            svc.read::<u8>(0, 0, 0, boxes[i]).unwrap().len()
        });
    });
    bytes as f64 / 1e6 / secs
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    println!(
        "Figure 11: {}x{}x{} ({} MB) cutouts vs concurrency ({cores} cores)",
        REQ_SHAPE[0],
        REQ_SHAPE[1],
        REQ_SHAPE[2],
        REQ_SHAPE.iter().product::<u64>() / (1 << 20)
    );
    let mem = service(false);
    let disk = service(true);
    header("Fig 11: throughput (MB/s) vs concurrent requests", &["conc", "memory", "disk"]);
    for conc in [1usize, 2, 4, 8, 16, 32, 64] {
        let m = throughput(&mem, conc, conc as u64);
        let d = throughput(&disk, conc, conc as u64 + 100);
        row(&[conc.to_string(), format!("{m:.1}"), format!("{d:.1}")]);
    }
    println!(
        "\npaper shape: scales past the physical core count (I/O overlap +\n\
         hyperthreading), then declines under contention (§5, Fig 11)."
    );
}
