//! Ablations of the paper's design decisions (DESIGN.md §5):
//!
//! 1. Morton vs row-major cuboid keying — discontiguous runs per cutout
//!    and modeled disk time (§3's core physical-design bet).
//! 2. Dense cuboids vs sparse voxel lists for dense annotations (§3.2:
//!    "outperforms sparse lists by orders of magnitude").
//! 3. Batched vs per-object annotation writes (§4.2: batching 40 writes
//!    "doubled throughput").
//! 4. Cuboid size sweep around the paper's 2^18 compromise (§3.1).
//! 5. The exceptions flag's read-path cost (§3.2: "a minor runtime cost
//!    ... on every read, even if no exceptions are defined").

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::*;
use ocpd::annotation::{AnnotationDb, RamonObject, SynapseType};
use ocpd::chunkstore::CuboidStore;
use ocpd::core::{Box3, DatasetBuilder, Project, WriteDiscipline};
use ocpd::cutout::CutoutService;
use ocpd::ingest::ingest_volume;
use ocpd::morton;
use ocpd::storage::{DeviceProfile, Engine, MemStore, SimulatedStore};
use ocpd::util::Rng;
use ocpd::web::ocpk;

fn ablation_morton_vs_rowmajor() {
    // The paper's claim is *uniformity*: the Morton index "makes cutout
    // queries efficient and (mostly) uniform across lower dimensional
    // projections" (§1), and aligned power-of-two regions are wholly
    // contiguous (§3). Row-major keying is unbeatable for X-extended
    // queries and catastrophic for X-thin ones; Morton treats every
    // orientation alike and collapses aligned queries to one run.
    header(
        "A1: Morton vs row-major keying — runs by query orientation (32x32x8 grid)",
        &["query", "runs-morton", "runs-rowmajor", "mor-max/min", "row-max/min"],
    );
    let grid = [32u64, 32, 8];
    let mut rng = Rng::new(4);
    let trials = 60;
    let mean_runs = |rng: &mut Rng, shape: [u64; 3], keyer: &dyn Fn(u64, u64, u64) -> u64| {
        let mut total = 0usize;
        for _ in 0..trials {
            let lo = [
                rng.below(grid[0] - shape[0] + 1),
                rng.below(grid[1] - shape[1] + 1),
                rng.below(grid[2] - shape[2] + 1),
            ];
            let mut keys: Vec<u64> = Vec::new();
            for z in lo[2]..lo[2] + shape[2] {
                for y in lo[1]..lo[1] + shape[1] {
                    for x in lo[0]..lo[0] + shape[0] {
                        keys.push(keyer(x, y, z));
                    }
                }
            }
            keys.sort_unstable();
            total += morton::coalesce_runs(&keys).len();
        }
        total as f64 / trials as f64
    };
    let mor = |x: u64, y: u64, z: u64| morton::encode3(x, y, z);
    let rowm = move |x: u64, y: u64, z: u64| x + grid[0] * (y + grid[1] * z);

    // Three orientations of the same 256-cuboid query + the aligned case.
    let shapes: [([u64; 3], &str); 4] = [
        ([16, 4, 4], "16x4x4 (x-ext)"),
        ([4, 16, 4], "4x16x4 (y-ext)"),
        ([4, 4, 8], "4x4x8 (z-ext)"),
        ([8, 8, 4], "8x8x4"),
    ];
    let mut m_all = Vec::new();
    let mut r_all = Vec::new();
    for (shape, label) in shapes {
        let m = mean_runs(&mut rng, shape, &mor);
        let r = mean_runs(&mut rng, shape, &rowm);
        m_all.push(m);
        r_all.push(r);
        row(&[label.to_string(), format!("{m:.1}"), format!("{r:.1}"), "".into(), "".into()]);
    }
    let spread = |v: &[f64]| v.iter().cloned().fold(0.0f64, f64::max)
        / v.iter().cloned().fold(f64::MAX, f64::min);
    row(&[
        "orientation spread".into(),
        "".into(),
        "".into(),
        format!("{:.1}x", spread(&m_all)),
        format!("{:.1}x", spread(&r_all)),
    ]);
    // Aligned power-of-two box: wholly contiguous under Morton only.
    let aligned_m = morton::runs_in_box3([8, 8, 0], [16, 16, 8]).len();
    let mut keys = Vec::new();
    for z in 0..8u64 {
        for y in 8..16u64 {
            for x in 8..16u64 {
                keys.push(rowm(x, y, z));
            }
        }
    }
    keys.sort_unstable();
    let aligned_r = morton::coalesce_runs(&keys).len();
    row(&[
        "8x8x8 aligned".into(),
        aligned_m.to_string(),
        aligned_r.to_string(),
        "".into(),
        "".into(),
    ]);
    println!(
        "paper claim: Morton is (mostly) uniform across projections (§1) and\n\
         aligned power-of-two regions are wholly contiguous (§3); row-major is\n\
         optimal only for x-extended queries."
    );
}

fn ablation_dense_vs_sparse() {
    header(
        "A2: dense cuboids vs sparse voxel lists, >90%-labeled annotation regions",
        &["region", "cuboid-B", "voxlist-B", "cub-read-ms", "list-read-ms"],
    );
    for side in [32u64, 64, 128] {
        let dims = [side, side, side.min(32)];
        let ds = Arc::new(DatasetBuilder::new("ds", [256, 256, 32]).levels(1).build());
        let pr = Arc::new(Project::annotation("ann", "ds"));
        let engine: Engine = Arc::new(MemStore::new());
        let store = Arc::new(CuboidStore::new(ds, pr, Arc::clone(&engine)));
        let svc = CutoutService::new(Arc::clone(&store));
        let labels = dense_labels(dims, 16, side);
        let bx = Box3::at([0, 0, 0], dims);
        svc.write(0, 0, 0, bx, &labels).unwrap();

        // Dense representation: stored cuboid bytes + cutout read time.
        let stored: usize = store
            .stored_codes(0, 0)
            .unwrap()
            .iter()
            .map(|&c| store.stored_size(0, 0, c).unwrap().unwrap_or(0))
            .sum();
        let dense_ms = median_time(5, || {
            svc.read::<u32>(0, 0, 0, bx).unwrap();
        }) * 1000.0;

        // Sparse representation: explicit voxel list blob.
        let mut voxels = Vec::new();
        for z in 0..dims[2] {
            for y in 0..dims[1] {
                for x in 0..dims[0] {
                    if labels.get([x, y, z]) != 0 {
                        voxels.push([x, y, z]);
                    }
                }
            }
        }
        let blob = ocpk::encode_voxels(&voxels);
        engine.put("voxlist", 0, &blob).unwrap();
        let list_ms = median_time(5, || {
            let b = engine.get("voxlist", 0).unwrap().unwrap();
            let vs = ocpk::decode_voxels(&b).unwrap();
            // Materialize into a dense volume (what any consumer does).
            let mut v = ocpd::array::DenseVolume::<u32>::zeros(dims);
            for p in vs {
                v.set(p, 1);
            }
        }) * 1000.0;

        row(&[
            format!("{}^3", side),
            stored.to_string(),
            blob.len().to_string(),
            format!("{dense_ms:.2}"),
            format!("{list_ms:.2}"),
        ]);
    }
    println!("paper claim: for dense annotations cuboids beat sparse lists (§3.2).");
}

fn ablation_batching() {
    header(
        "A3: metadata write batching (SSD device model)",
        &["batch", "objects/s", "speedup"],
    );
    let mk = || {
        let ds = Arc::new(DatasetBuilder::new("ds", [256, 256, 32]).levels(1).build());
        let pr = Arc::new(Project::annotation("ann", "ds"));
        let engine: Engine = Arc::new(SimulatedStore::new(
            Arc::new(MemStore::new()),
            DeviceProfile::ssd_raid0(),
            1.0,
        ));
        AnnotationDb::new(Arc::new(CuboidStore::new(ds, pr, Arc::clone(&engine))), engine)
            .unwrap()
    };
    let n = 400usize;
    let mut base = 0.0;
    for batch in [1usize, 10, 40, 100] {
        let db = mk();
        let secs = time(|| {
            let mut remaining = n;
            while remaining > 0 {
                let take = batch.min(remaining);
                let objs: Vec<RamonObject> = (0..take)
                    .map(|_| RamonObject::synapse(0, 0.9, SynapseType::Unknown))
                    .collect();
                db.put_objects(objs).unwrap();
                remaining -= take;
            }
        });
        let rate = n as f64 / secs;
        if batch == 1 {
            base = rate;
        }
        row(&[batch.to_string(), format!("{rate:.0}"), format!("{:.2}x", rate / base)]);
    }
    println!("paper claim: batching 40 writes doubled synapse-finder throughput (§4.2).");
}

fn ablation_cuboid_size() {
    header(
        "A4: cuboid size sweep (1MB aligned cutouts + 1-section plane reads, HDD model)",
        &["cuboid", "voxels", "cutout-MB/s", "plane-ms"],
    );
    for (flat, label) in [
        ([32u64, 32, 8], "32x32x8"),
        ([64, 64, 16], "64x64x16"),
        ([128, 128, 16], "128x128x16"),
        ([256, 256, 16], "256x256x16"),
        ([256, 256, 64], "256x256x64"),
    ] {
        let dims = [1024u64, 1024, 64];
        let ds = Arc::new(
            DatasetBuilder::new("ds", dims).cuboids(flat, flat).levels(1).build(),
        );
        let pr = Arc::new(Project::image("img", "ds").with_gzip(0));
        let engine: Engine = Arc::new(SimulatedStore::new(
            Arc::new(MemStore::new()),
            DeviceProfile::hdd_array(),
            1.0,
        ));
        let svc = Arc::new(CutoutService::new(Arc::new(CuboidStore::new(ds, pr, engine))));
        let vol = em_like_volume(dims, 31);
        ingest_volume(&svc, &vol, [512, 512, 16]).unwrap();

        // 1MB cutout throughput (cubic-ish region).
        let bx = Box3::at([256, 256, 16], [256, 256, 16]);
        let secs = median_time(3, || {
            svc.read::<u8>(0, 0, 0, bx).unwrap();
        });
        // Single-plane read (visualization / projection workload) —
        // bigger cuboids mean more discarded data per plane.
        let plane_ms = median_time(3, || {
            svc.read_plane::<u8>(0, 0, 0, ocpd::array::Plane::Xy(32), [0, 0], [512, 512])
                .unwrap();
        }) * 1000.0;
        row(&[
            label.to_string(),
            (flat[0] * flat[1] * flat[2]).to_string(),
            format!("{:.1}", bx.volume() as f64 / 1e6 / secs),
            format!("{plane_ms:.1}"),
        ]);
    }
    println!(
        "paper claim: 2^18-voxel cuboids are a compromise — bigger helps streaming\n\
         cutouts, smaller helps plane/projection reads (§3.1)."
    );
}

fn ablation_exceptions_cost() {
    header(
        "A5: exceptions flag read cost (no exceptions actually stored)",
        &["config", "object-read-ms"],
    );
    for (exc, label) in [(false, "exceptions-off"), (true, "exceptions-on")] {
        let ds = Arc::new(DatasetBuilder::new("ds", [256, 256, 32]).levels(1).build());
        let mut pr = Project::annotation("ann", "ds");
        if exc {
            pr = pr.with_exceptions();
        }
        let engine: Engine = Arc::new(MemStore::new());
        let db = AnnotationDb::new(
            Arc::new(CuboidStore::new(ds, Arc::new(pr), Arc::clone(&engine))),
            engine,
        )
        .unwrap();
        let bx = Box3::new([0, 0, 0], [128, 128, 32]);
        let mut v = ocpd::array::DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), 5);
        db.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
        let ms = median_time(5, || {
            db.voxel_list(0, 5).unwrap();
        }) * 1000.0;
        row(&[label.to_string(), format!("{ms:.2}")]);
    }
    println!("paper claim: a minor per-read cost even with no exceptions defined (§3.2).");
}

fn main() {
    println!("Design ablations (DESIGN.md §5)");
    ablation_morton_vs_rowmajor();
    ablation_dense_vs_sparse();
    ablation_batching();
    ablation_cuboid_size();
    ablation_exceptions_cost();
}
