//! Figure 13: small random synapse writes — SSD node vs. Database node.
//!
//! The paper uploads all kasthuri11 synapse annotations in random order,
//! committing after each write, and finds the SSD node achieves more than
//! 150% of the database (RAID-6) node's throughput; absolute rates are
//! low (~6 RAMON objects/s) because each object write updates metadata
//! tables, the spatial index, and the volume database. With locality and
//! batching the production pipeline reached 73 objects/s/node.
//!
//! We reproduce all three rows: random-per-commit on both device models,
//! plus the batched+Morton-ordered configuration.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::*;
use ocpd::annotation::{AnnotationDb, RamonObject, SynapseType};
use ocpd::chunkstore::CuboidStore;
use ocpd::core::{DatasetBuilder, Project, Vec3, WriteDiscipline};
use ocpd::storage::{DeviceProfile, Engine, MemStore, SimulatedStore};
use ocpd::util::Rng;

const DIMS: [u64; 3] = [1024, 1024, 64];
const N_SYNAPSES: usize = 150;

fn db(profile: DeviceProfile) -> Arc<AnnotationDb> {
    let ds = Arc::new(DatasetBuilder::new("ds", DIMS).levels(1).build());
    let pr = Arc::new(Project::annotation("ann", "ds"));
    let engine: Engine =
        Arc::new(SimulatedStore::new(Arc::new(MemStore::new()), profile, 1.0));
    Arc::new(
        AnnotationDb::new(Arc::new(CuboidStore::new(ds, pr, Arc::clone(&engine))), engine)
            .unwrap(),
    )
}

/// kasthuri11-like synapse set: compact blobs at random positions.
fn synapses(seed: u64) -> Vec<(u32, Vec<Vec3>)> {
    let mut rng = Rng::new(seed);
    (0..N_SYNAPSES as u32)
        .map(|i| {
            let c = [rng.below(DIMS[0] - 6), rng.below(DIMS[1] - 6), rng.below(DIMS[2] - 3)];
            let mut voxels = Vec::new();
            for z in 0..3 {
                for y in 0..5 {
                    for x in 0..5 {
                        voxels.push([c[0] + x, c[1] + y, c[2] + z]);
                    }
                }
            }
            (i + 1, voxels)
        })
        .collect()
}

/// Random order, one commit per object (the Figure 13 workload).
fn random_per_commit(db: &AnnotationDb, seed: u64) -> f64 {
    let mut syns = synapses(seed);
    let mut rng = Rng::new(seed + 1);
    rng.shuffle(&mut syns);
    let secs = time(|| {
        for (id, voxels) in &syns {
            db.put_object(RamonObject::synapse(*id, 0.9, SynapseType::Unknown)).unwrap();
            db.write_voxels(0, *id, voxels, WriteDiscipline::Overwrite).unwrap();
        }
    });
    N_SYNAPSES as f64 / secs
}

/// Morton-ordered, metadata batched 40 at a time (the production
/// pipeline configuration, §4.2 "Batch Interfaces").
fn batched_with_locality(db: &AnnotationDb, seed: u64) -> f64 {
    let mut syns = synapses(seed);
    syns.sort_by_key(|(_, v)| ocpd::morton::encode3(v[0][0], v[0][1], v[0][2]));
    let secs = time(|| {
        for chunk in syns.chunks(40) {
            let objs: Vec<RamonObject> = chunk
                .iter()
                .map(|(id, _)| RamonObject::synapse(*id, 0.9, SynapseType::Unknown))
                .collect();
            db.put_objects(objs).unwrap();
            for (id, voxels) in chunk {
                db.write_voxels(0, *id, voxels, WriteDiscipline::Overwrite).unwrap();
            }
        }
    });
    N_SYNAPSES as f64 / secs
}

fn main() {
    println!("Figure 13: {N_SYNAPSES} synapse writes (25x5x3-voxel blobs), commit per write");
    header("Fig 13: RAMON objects/second", &["config", "db-node", "ssd-node", "ssd/db"]);

    let db_hdd = db(DeviceProfile::hdd_array());
    let db_ssd = db(DeviceProfile::ssd_raid0());
    let h = random_per_commit(&db_hdd, 5);
    let s = random_per_commit(&db_ssd, 5);
    row(&[
        "random".into(),
        format!("{h:.1}"),
        format!("{s:.1}"),
        format!("{:.2}x", s / h),
    ]);

    let db_hdd = db(DeviceProfile::hdd_array());
    let db_ssd = db(DeviceProfile::ssd_raid0());
    let hb = batched_with_locality(&db_hdd, 6);
    let sb = batched_with_locality(&db_ssd, 6);
    row(&[
        "batched+morton".into(),
        format!("{hb:.1}"),
        format!("{sb:.1}"),
        format!("{:.2}x", sb / hb),
    ]);

    println!(
        "\npaper shape: ssd >= 1.5x db on random small writes (Fig 13);\n\
         locality+batching lifts absolute rate by an order of magnitude\n\
         (6/s random -> 73/s in production, §5)."
    );
    assert!(s / h >= 1.5, "SSD advantage collapsed: {:.2}", s / h);
}
