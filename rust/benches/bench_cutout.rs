//! Figure 10 (a, b, c): cutout throughput vs. cutout size for the three
//! configurations of the paper's §5 —
//!
//! * **aligned memory** — data in cache, requests on cuboid boundaries:
//!   bounded by the application stack's in-memory assembly (paper peak
//!   173 MB/s);
//! * **aligned disk** — random offsets on cuboid boundaries over the
//!   RAID-6 device model (paper peak 121 MB/s);
//! * **unaligned** — offsets shifted off the cuboid grid, adding the
//!   partial-cuboid memory reorganization penalty (paper peak 61 MB/s).
//!
//! 16 parallel requests per measurement, as in the paper. We report MB/s
//! of cutout payload; absolute values differ from the paper's hardware
//! but the ordering (mem > aligned-disk > unaligned), the near-linear
//! scaling up to ~256K, and the continued slow growth from Morton-run
//! coalescing must reproduce. The device model runs at time_scale 1.0
//! (real charged latencies).

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::*;
use ocpd::chunkstore::CuboidStore;
use ocpd::core::{Box3, DatasetBuilder, Project, Vec3};
use ocpd::cutout::CutoutService;
use ocpd::ingest::ingest_volume;
use ocpd::storage::{DeviceProfile, Engine, MemStore, SimulatedStore};
use ocpd::util::pool::scoped_map;
use ocpd::util::Rng;

const DIMS: [u64; 3] = [1024, 1024, 64];
const PARALLEL: usize = 16;

fn service(sim: bool) -> Arc<CutoutService> {
    let ds = Arc::new(
        DatasetBuilder::new("kasthuri_like", DIMS).voxel_nm([3.0, 3.0, 30.0]).levels(1).build(),
    );
    // gzip off: EM data is incompressible and the paper's numbers are
    // about I/O + memory, not codec speed.
    let pr = Arc::new(Project::image("img", "kasthuri_like").with_gzip(0));
    let mem: Engine = Arc::new(MemStore::new());
    let engine: Engine = if sim {
        Arc::new(SimulatedStore::new(mem, DeviceProfile::hdd_array(), 1.0))
    } else {
        mem
    };
    let svc = Arc::new(CutoutService::new(Arc::new(CuboidStore::new(ds, pr, engine))));
    let vol = em_like_volume(DIMS, 7);
    ingest_volume(&svc, &vol, [512, 512, 16]).unwrap();
    svc
}

/// Cutout shape holding `bytes` voxels, roughly cubic in sample space
/// (xy:z of 4:1 matching flat cuboids).
fn shape_for(bytes: u64) -> Vec3 {
    let mut s = [16u64, 16, 1];
    let mut cur = 256;
    let mut axis = 0;
    while cur < bytes {
        s[axis % 3] *= 2;
        cur *= 2;
        axis += 1;
    }
    [s[0].min(DIMS[0]), s[1].min(DIMS[1]), s[2].min(DIMS[2])]
}

/// Aggregate MB/s of `PARALLEL` concurrent cutouts of `shape`.
fn throughput(svc: &CutoutService, shape: Vec3, aligned: bool, seed: u64) -> f64 {
    let cshape = svc.store().cuboid_shape(0).unwrap();
    let mut rng = Rng::new(seed);
    // Pre-generate request boxes.
    let boxes: Vec<Box3> = (0..PARALLEL)
        .map(|_| {
            let mut lo = [
                rng.below(DIMS[0] - shape[0] + 1),
                rng.below(DIMS[1] - shape[1] + 1),
                rng.below(DIMS[2] - shape[2] + 1),
            ];
            if aligned {
                for a in 0..3 {
                    lo[a] = (lo[a] / cshape[a]) * cshape[a];
                    lo[a] = lo[a].min(DIMS[a] - shape[a]);
                    lo[a] = (lo[a] / cshape[a]) * cshape[a];
                }
            } else {
                // Force off-grid offsets.
                for a in 0..3 {
                    if lo[a] % cshape[a] == 0 {
                        lo[a] = (lo[a] + cshape[a] / 2 + 1).min(DIMS[a] - shape[a]);
                    }
                }
            }
            Box3::at(lo, shape)
        })
        .collect();
    let bytes = shape[0] * shape[1] * shape[2] * PARALLEL as u64;
    let secs = median_time(3, || {
        scoped_map(PARALLEL, PARALLEL, |i| {
            svc.read::<u8>(0, 0, 0, boxes[i]).unwrap().len()
        });
    });
    bytes as f64 / 1e6 / secs
}

fn main() {
    println!("Figure 10: cutout throughput, {PARALLEL} parallel requests, volume {DIMS:?}");
    let mem = service(false);
    let disk = service(true);

    header(
        "Fig 10(a-c): throughput (MB/s) vs cutout size",
        &["size", "aligned-mem", "aligned-disk", "unaligned"],
    );
    let sizes: Vec<u64> =
        (0..9).map(|i| 64 * 1024u64 << i).collect(); // 64K .. 16M
    for &bytes in &sizes {
        let shape = shape_for(bytes);
        let m = throughput(&mem, shape, true, bytes);
        let d = throughput(&disk, shape, true, bytes ^ 1);
        let u = throughput(&disk, shape, false, bytes ^ 2);
        row(&[
            size_label(bytes),
            format!("{m:.1}"),
            format!("{d:.1}"),
            format!("{u:.1}"),
        ]);
    }
    println!(
        "\npaper shape: mem > aligned-disk > unaligned; near-linear to ~256K,\n\
         then slower growth as Morton runs lengthen (§5, Fig 10)."
    );
}
