//! Cutout read-path benches:
//!
//! 1. **Figure 10 (a, b, c)** — cutout throughput vs. cutout size for
//!    the three configurations of the paper's §5 (aligned memory /
//!    aligned disk / unaligned; 16 parallel requests per measurement).
//! 2. **Fan-out scaling** — one multi-cuboid cutout served by the
//!    parallel read engine at 1/2/4/8 workers over the RAID-6 device
//!    model: the paper's "a single request fans out across spindles"
//!    claim, measured.
//! 3. **Cold vs. warm cache** — the same cutout with the sharded LRU
//!    cuboid cache cleared vs. primed.
//!
//! Sections 2 and 3 are recorded in `../BENCH_cutout.json` (override
//! with `OCPD_BENCH_OUT`); the binary rewrites that file on every run.
//! Paper shape that must reproduce: mem > aligned-disk > unaligned,
//! near-linear scaling to ~256K (Fig 10); ≥2x at 8-worker fan-out and
//! ≥5x warm-over-cold (ROADMAP north star: reads as fast as the
//! hardware allows).

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::*;
use ocpd::chunkstore::{CacheConfig, CuboidCache, CuboidStore};
use ocpd::core::{Box3, DatasetBuilder, Project, Vec3};
use ocpd::cutout::{CutoutService, ReadConfig};
use ocpd::ingest::ingest_volume;
use ocpd::storage::{DeviceProfile, Engine, MemStore, SimulatedStore};
use ocpd::util::pool::scoped_map;
use ocpd::util::Rng;

const DIMS: [u64; 3] = [1024, 1024, 64];
const PARALLEL: usize = 16;

fn service(sim: bool) -> Arc<CutoutService> {
    let ds = Arc::new(
        DatasetBuilder::new("kasthuri_like", DIMS).voxel_nm([3.0, 3.0, 30.0]).levels(1).build(),
    );
    // gzip off: EM data is incompressible and the paper's numbers are
    // about I/O + memory, not codec speed.
    let pr = Arc::new(Project::image("img", "kasthuri_like").with_gzip(0));
    let mem: Engine = Arc::new(MemStore::new());
    let engine: Engine = if sim {
        Arc::new(SimulatedStore::new(mem, DeviceProfile::hdd_array(), 1.0))
    } else {
        mem
    };
    let svc = Arc::new(CutoutService::new(Arc::new(CuboidStore::new(ds, pr, engine))));
    let vol = em_like_volume(DIMS, 7);
    ingest_volume(&svc, &vol, [512, 512, 16]).unwrap();
    svc
}

/// Cutout shape holding `bytes` voxels, roughly cubic in sample space
/// (xy:z of 4:1 matching flat cuboids).
fn shape_for(bytes: u64) -> Vec3 {
    let mut s = [16u64, 16, 1];
    let mut cur = 256;
    let mut axis = 0;
    while cur < bytes {
        s[axis % 3] *= 2;
        cur *= 2;
        axis += 1;
    }
    [s[0].min(DIMS[0]), s[1].min(DIMS[1]), s[2].min(DIMS[2])]
}

/// Aggregate MB/s of `PARALLEL` concurrent cutouts of `shape`.
fn throughput(svc: &CutoutService, shape: Vec3, aligned: bool, seed: u64) -> f64 {
    let cshape = svc.store().cuboid_shape(0).unwrap();
    let mut rng = Rng::new(seed);
    // Pre-generate request boxes.
    let boxes: Vec<Box3> = (0..PARALLEL)
        .map(|_| {
            let mut lo = [
                rng.below(DIMS[0] - shape[0] + 1),
                rng.below(DIMS[1] - shape[1] + 1),
                rng.below(DIMS[2] - shape[2] + 1),
            ];
            if aligned {
                for a in 0..3 {
                    lo[a] = (lo[a] / cshape[a]) * cshape[a];
                    lo[a] = lo[a].min(DIMS[a] - shape[a]);
                    lo[a] = (lo[a] / cshape[a]) * cshape[a];
                }
            } else {
                // Force off-grid offsets.
                for a in 0..3 {
                    if lo[a] % cshape[a] == 0 {
                        lo[a] = (lo[a] + cshape[a] / 2 + 1).min(DIMS[a] - shape[a]);
                    }
                }
            }
            Box3::at(lo, shape)
        })
        .collect();
    let bytes = shape[0] * shape[1] * shape[2] * PARALLEL as u64;
    let secs = median_time(3, || {
        scoped_map(PARALLEL, PARALLEL, |i| {
            svc.read::<u8>(0, 0, 0, boxes[i]).unwrap().len()
        });
    });
    bytes as f64 / 1e6 / secs
}

// ----------------------------------------------------------------------
// Sections 2 + 3: the parallel read engine and the cuboid cache
// ----------------------------------------------------------------------

/// Store over the RAID-6 device model with a cuboid cache, pre-ingested
/// through the raw memory engine so setup pays no simulated latency.
fn engine_fixture() -> (Arc<CuboidStore>, Arc<CuboidCache>) {
    let ds = Arc::new(
        DatasetBuilder::new("kasthuri_like", DIMS).voxel_nm([3.0, 3.0, 30.0]).levels(1).build(),
    );
    let pr = Arc::new(Project::image("img", "kasthuri_like").with_gzip(0));
    let mem: Engine = Arc::new(MemStore::new());
    // Ingest straight into the memory engine.
    let plain = Arc::new(CuboidStore::new(Arc::clone(&ds), Arc::clone(&pr), Arc::clone(&mem)));
    let vol = em_like_volume(DIMS, 7);
    ingest_volume(&CutoutService::new(plain), &vol, [512, 512, 16]).unwrap();
    // Read through the device model, fronted by the cache.
    let engine: Engine = Arc::new(SimulatedStore::new(mem, DeviceProfile::hdd_array(), 1.0));
    let cache = Arc::new(CuboidCache::new(CacheConfig {
        shards: 16,
        capacity_bytes: 256 << 20,
    }));
    let store =
        Arc::new(CuboidStore::new(ds, pr, engine).with_cache(Arc::clone(&cache)));
    (store, cache)
}

/// Median seconds for one cutout of `bx` at the given fan-out width.
/// Cold runs clear the cache first; warm runs are primed.
fn timed_read(
    store: &Arc<CuboidStore>,
    cache: &Arc<CuboidCache>,
    workers: usize,
    warm: bool,
    bx: Box3,
) -> f64 {
    let svc = CutoutService::new(Arc::clone(store)).with_read_config(ReadConfig {
        workers,
        parallel_threshold: 1,
        batches_per_worker: 2,
    });
    if warm {
        let _ = svc.read::<u8>(0, 0, 0, bx).unwrap().len();
    }
    median_time(3, || {
        if !warm {
            cache.clear();
        }
        let _ = svc.read::<u8>(0, 0, 0, bx).unwrap().len();
    })
}

struct EngineRow {
    config: &'static str,
    cache: &'static str,
    workers: usize,
    seconds: f64,
    mbps: f64,
    speedup: f64,
}

fn main() {
    println!("Figure 10: cutout throughput, {PARALLEL} parallel requests, volume {DIMS:?}");
    let mem = service(false);
    let disk = service(true);

    header(
        "Fig 10(a-c): throughput (MB/s) vs cutout size",
        &["size", "aligned-mem", "aligned-disk", "unaligned"],
    );
    let sizes: Vec<u64> =
        (0..9).map(|i| 64 * 1024u64 << i).collect(); // 64K .. 16M
    for &bytes in &sizes {
        let shape = shape_for(bytes);
        let m = throughput(&mem, shape, true, bytes);
        let d = throughput(&disk, shape, true, bytes ^ 1);
        let u = throughput(&disk, shape, false, bytes ^ 2);
        row(&[
            size_label(bytes),
            format!("{m:.1}"),
            format!("{d:.1}"),
            format!("{u:.1}"),
        ]);
    }
    println!(
        "\npaper shape: mem > aligned-disk > unaligned; near-linear to ~256K,\n\
         then slower growth as Morton runs lengthen (§5, Fig 10)."
    );

    // ------------------------------------------------------------------
    // Fan-out scaling + cache, recorded to BENCH_cutout.json.
    // ------------------------------------------------------------------
    drop(mem);
    drop(disk);
    let (store, cache) = engine_fixture();
    let bx = Box3::new([0, 0, 0], DIMS); // 256 cuboids, 64 MB
    let bytes = bx.volume() as f64;
    let mut rows: Vec<EngineRow> = Vec::new();

    header(
        "Parallel fan-out: one 64M cutout on the RAID-6 model (cold cache)",
        &["workers", "seconds", "MB/s", "speedup"],
    );
    let seq_cold = timed_read(&store, &cache, 1, false, bx);
    for &w in &[1usize, 2, 4, 8] {
        let s = if w == 1 { seq_cold } else { timed_read(&store, &cache, w, false, bx) };
        let r = EngineRow {
            config: "fanout",
            cache: "cold",
            workers: w,
            seconds: s,
            mbps: bytes / 1e6 / s,
            speedup: seq_cold / s,
        };
        row(&[
            w.to_string(),
            format!("{:.4}", r.seconds),
            format!("{:.1}", r.mbps),
            format!("{:.2}x", r.speedup),
        ]);
        rows.push(r);
    }

    header(
        "Cuboid cache: same cutout, cold vs warm",
        &["workers", "state", "seconds", "MB/s", "speedup-vs-cold"],
    );
    for &w in &[1usize, 8] {
        let cold = rows
            .iter()
            .find(|r| r.workers == w && r.cache == "cold")
            .map(|r| r.seconds)
            .unwrap_or(seq_cold);
        let s = timed_read(&store, &cache, w, true, bx);
        let r = EngineRow {
            config: "cache",
            cache: "warm",
            workers: w,
            seconds: s,
            mbps: bytes / 1e6 / s,
            speedup: cold / s,
        };
        row(&[
            w.to_string(),
            "warm".to_string(),
            format!("{:.4}", r.seconds),
            format!("{:.1}", r.mbps),
            format!("{:.2}x", r.speedup),
        ]);
        rows.push(r);
    }
    let st = cache.status();
    println!(
        "\ncache: entries={} bytes={} hit_rate={:.3} evictions={}",
        st.entries,
        st.bytes,
        st.hit_rate(),
        st.evictions
    );

    // Rewrite the JSON record.
    let mut json = String::from("{\n  \"bench\": \"bench_cutout\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"cutout_bytes\": {}, \"cuboids\": 256, \"device\": \"raid6-sata\", \"time_scale\": 1.0}},\n",
        bx.volume()
    ));
    json.push_str(
        "  \"provenance\": \"measured by cargo bench --bench bench_cutout; \
         speedup is vs the 1-worker cold-cache read (fanout rows) or the \
         same-width cold read (cache rows)\",\n",
    );
    json.push_str("  \"rows\": [\n");
    let n = rows.len();
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"config\": \"{}\", \"cache\": \"{}\", \"workers\": {}, \"seconds\": {:.4}, \"mbps\": {:.1}, \"speedup\": {:.2}}}{}\n",
            r.config,
            r.cache,
            r.workers,
            r.seconds,
            r.mbps,
            r.speedup,
            if i + 1 == n { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let out = std::env::var("OCPD_BENCH_OUT").unwrap_or_else(|_| "../BENCH_cutout.json".into());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\ncould not write {out}: {e}"),
    }
}
