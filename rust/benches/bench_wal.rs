//! The SSD write-absorber's money shot: random small writes issued by
//! 1/4/16 concurrent writers, as direct per-write engine puts vs. group
//! committed WAL appends, on the paper's simulated device models.
//!
//! * `direct-hdd` — every put pays the RAID-6 parity read-modify-write
//!   seek (the seed's fate for cold projects under random writes).
//! * `direct-ssd` — the seed's "place the hot project on the SSD node"
//!   configuration.
//! * `wal-absorb` — puts group-commit into the SSD-resident log while
//!   the HDD array stays untouched; the drain row shows sealed segments
//!   applied to the HDD as Morton-sorted batches afterwards.
//!
//! Prints the table and rewrites `../BENCH_wal.json` (override with
//! `OCPD_BENCH_OUT`).

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;
use std::time::Instant;

use common::*;
use ocpd::storage::{DeviceProfile, Engine, MemStore, SimulatedStore};
use ocpd::util::Rng;
use ocpd::wal::{Wal, WalConfig, WalEngine};

const RECORDS_PER_WRITER: usize = 100;
const VALUE_BYTES: usize = 4096;
const WRITER_COUNTS: [usize; 3] = [1, 4, 16];
const TABLE: &str = "ann/cub/r0/c0";

fn sim(profile: DeviceProfile) -> Engine {
    Arc::new(SimulatedStore::new(Arc::new(MemStore::new()), profile, 1.0))
}

/// `writers` threads issuing random-key puts through `engine`; returns
/// elapsed seconds.
fn hammer(engine: &Engine, writers: usize) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..writers {
            let engine = Arc::clone(engine);
            s.spawn(move || {
                let mut rng = Rng::new(w as u64 + 1);
                let v = vec![0xabu8; VALUE_BYTES];
                for _ in 0..RECORDS_PER_WRITER {
                    // Scattered Morton keys: the random-write workload of
                    // a parallel vision pipeline (Figure 13).
                    engine.put(TABLE, rng.next_u64() >> 20, &v).unwrap();
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

struct Row {
    config: &'static str,
    writers: usize,
    records: usize,
    seconds: f64,
    mean_batch: f64,
    drain_seconds: f64,
}

impl Row {
    fn rec_per_sec(&self) -> f64 {
        self.records as f64 / self.seconds.max(1e-9)
    }
}

fn main() {
    let mut rows: Vec<Row> = Vec::new();
    header(
        "WAL write-absorber: random 4K writes (Figure 13 workload)",
        &["config", "writers", "rec/s", "mean batch", "drain ms"],
    );

    for &writers in &WRITER_COUNTS {
        let records = writers * RECORDS_PER_WRITER;

        // Direct puts against each device class.
        for (config, profile) in [
            ("direct-hdd", DeviceProfile::hdd_array()),
            ("direct-ssd", DeviceProfile::ssd_raid0()),
        ] {
            let engine = sim(profile);
            let seconds = hammer(&engine, writers);
            rows.push(Row { config, writers, records, seconds, mean_batch: 1.0, drain_seconds: 0.0 });
        }

        // Group-committed WAL: SSD log absorbing, HDD destination idle
        // until the drain.
        let log = sim(DeviceProfile::ssd_raid0());
        let dest = sim(DeviceProfile::hdd_array());
        let cfg = WalConfig { background_flush: false, ..WalConfig::default() };
        let wal = Wal::open("ann", log, dest, cfg).unwrap();
        let engine: Engine = Arc::new(WalEngine::new(Arc::clone(&wal)));
        let seconds = hammer(&engine, writers);
        let st = wal.status().unwrap();
        let t0 = Instant::now();
        wal.flush_now().unwrap();
        let drain_seconds = t0.elapsed().as_secs_f64();
        rows.push(Row {
            config: "wal-absorb",
            writers,
            records,
            seconds,
            mean_batch: st.mean_batch(),
            drain_seconds,
        });

        for r in rows.iter().skip(rows.len() - 3) {
            row(&[
                r.config.to_string(),
                r.writers.to_string(),
                format!("{:.0}", r.rec_per_sec()),
                format!("{:.1}", r.mean_batch),
                format!("{:.1}", r.drain_seconds * 1e3),
            ]);
        }
    }

    // The acceptance comparison: at 16 writers the absorber must beat
    // direct per-write puts on the HDD array.
    let direct_hdd_16 = rows
        .iter()
        .find(|r| r.config == "direct-hdd" && r.writers == 16)
        .map(Row::rec_per_sec)
        .unwrap();
    let wal_16 = rows
        .iter()
        .find(|r| r.config == "wal-absorb" && r.writers == 16)
        .map(Row::rec_per_sec)
        .unwrap();
    println!(
        "\nwal-absorb vs direct-hdd at 16 writers: {:.0} vs {:.0} rec/s ({:.1}x)",
        wal_16,
        direct_hdd_16,
        wal_16 / direct_hdd_16
    );
    assert!(
        wal_16 > direct_hdd_16,
        "WAL group commit must out-absorb direct HDD puts at 16 writers"
    );

    // Machine-readable results.
    let out = std::env::var("OCPD_BENCH_OUT").unwrap_or_else(|_| "../BENCH_wal.json".into());
    let mut json = String::from("{\n  \"bench\": \"bench_wal\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"records_per_writer\": {RECORDS_PER_WRITER}, \
         \"value_bytes\": {VALUE_BYTES}, \"time_scale\": 1.0}},\n"
    ));
    json.push_str("  \"provenance\": \"measured by cargo bench --bench bench_wal\",\n");
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"config\": \"{}\", \"writers\": {}, \"records\": {}, \
             \"seconds\": {:.4}, \"rec_per_sec\": {:.1}, \"mean_batch\": {:.2}, \
             \"drain_seconds\": {:.4}}}{}\n",
            r.config,
            r.writers,
            r.records,
            r.seconds,
            r.rec_per_sec(),
            r.mean_batch,
            r.drain_seconds,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
