//! Offline stub of the `xla` (PJRT) bridge crate.
//!
//! The real crate links the PJRT C API and executes AOT-compiled HLO;
//! that toolchain is not present in the offline vendor set, so this stub
//! provides the exact API surface `ocpd::runtime` consumes and fails at
//! client construction with a descriptive error. Everything downstream
//! (`Runtime::load_dir` callers, the vision pipeline, `ocpd serve`)
//! already degrades gracefully when no runtime is available.
//!
//! Swap the `xla` path dependency in `rust/Cargo.toml` for the real crate
//! to run the Layer-1/2 artifacts; no `ocpd` source changes are needed.

use std::fmt;

/// Error type mirroring `xla::Error`: a message, displayable.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla/PJRT backend unavailable: ocpd was built against the offline stub \
         (point the `xla` dependency at the real crate to execute artifacts)"
            .to_string(),
    ))
}

/// A parsed HLO module (stub: holds nothing).
#[derive(Debug, Default)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Default)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Host literal: a typed, shaped buffer.
#[derive(Debug, Default, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec(), dims: vec![v.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} incompatible with {} elements",
                dims,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: Clone + Default>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Device-side buffer handle.
#[derive(Debug, Default)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A compiled, loaded executable.
#[derive(Debug, Default)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle. The stub's constructor always errors, which is the
/// single gate all `ocpd` runtime users already handle.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surface_errors_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
    }
}
