//! Per-cuboid exception lists: multiple annotations per voxel (§3.2).
//!
//! A voxel in the spatial database carries one label; when a write with
//! the `Exception` discipline collides with an existing label, the new
//! label is recorded in the cuboid's exception list instead. Exceptions
//! are activated per project and — as the paper notes — "incur a minor
//! runtime cost to check for exceptions on every read, even if no
//! exceptions are defined"; the ablation bench measures exactly that.

use std::collections::BTreeMap;

use crate::core::Project;
use crate::storage::Engine;
use crate::util::codec::{Dec, Enc};
use crate::Result;

/// Exceptions for one cuboid: voxel linear offset → extra labels.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CuboidExceptions {
    pub by_voxel: BTreeMap<u32, Vec<u32>>,
}

impl CuboidExceptions {
    pub fn is_empty(&self) -> bool {
        self.by_voxel.is_empty()
    }

    /// Add `label` at `offset` (deduplicated).
    pub fn add(&mut self, offset: u32, label: u32) {
        let labels = self.by_voxel.entry(offset).or_default();
        if !labels.contains(&label) {
            labels.push(label);
        }
    }

    /// Remove every occurrence of `label`.
    pub fn remove_label(&mut self, label: u32) {
        self.by_voxel.retain(|_, ls| {
            ls.retain(|&l| l != label);
            !ls.is_empty()
        });
    }

    /// All distinct labels present in the list.
    pub fn labels(&self) -> Vec<u32> {
        let mut ls: Vec<u32> =
            self.by_voxel.values().flat_map(|v| v.iter().copied()).collect();
        ls.sort_unstable();
        ls.dedup();
        ls
    }

    /// Voxel offsets carrying `label`.
    pub fn offsets_of(&self, label: u32) -> Vec<u32> {
        self.by_voxel
            .iter()
            .filter(|(_, ls)| ls.contains(&label))
            .map(|(o, _)| *o)
            .collect()
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.varint(self.by_voxel.len() as u64);
        for (off, labels) in &self.by_voxel {
            e.u32(*off).u32s(labels);
        }
        e.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut d = Dec::new(buf);
        let n = d.varint()? as usize;
        let mut by_voxel = BTreeMap::new();
        for _ in 0..n {
            let off = d.u32()?;
            by_voxel.insert(off, d.u32s()?);
        }
        Ok(CuboidExceptions { by_voxel })
    }
}

/// Storage for exception lists, keyed by cuboid Morton code.
pub struct ExceptionStore {
    engine: Engine,
    project: std::sync::Arc<Project>,
}

impl ExceptionStore {
    pub fn new(project: std::sync::Arc<Project>, engine: Engine) -> Self {
        ExceptionStore { engine, project }
    }

    /// Load exceptions for one cuboid (empty if none stored).
    pub fn get(&self, res: u32, code: u64) -> Result<CuboidExceptions> {
        match self.engine.get(&self.project.exceptions_table(res), code)? {
            Some(v) => CuboidExceptions::decode(&v),
            None => Ok(CuboidExceptions::default()),
        }
    }

    /// Store exceptions for one cuboid; empty lists are deleted (lazy).
    pub fn put(&self, res: u32, code: u64, exc: &CuboidExceptions) -> Result<()> {
        let table = self.project.exceptions_table(res);
        if exc.is_empty() {
            self.engine.delete(&table, code)
        } else {
            self.engine.put(&table, code, &exc.encode())
        }
    }

    /// Cuboids with any exceptions at `res`.
    pub fn codes(&self, res: u32) -> Result<Vec<u64>> {
        self.engine.keys(&self.project.exceptions_table(res))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use std::sync::Arc;

    #[test]
    fn encode_decode_roundtrip() {
        let mut e = CuboidExceptions::default();
        e.add(5, 100);
        e.add(5, 200);
        e.add(9, 100);
        let b = e.encode();
        assert_eq!(CuboidExceptions::decode(&b).unwrap(), e);
    }

    #[test]
    fn add_dedups_and_remove_cleans() {
        let mut e = CuboidExceptions::default();
        e.add(1, 7);
        e.add(1, 7);
        assert_eq!(e.by_voxel[&1], vec![7]);
        e.add(1, 8);
        e.remove_label(7);
        assert_eq!(e.by_voxel[&1], vec![8]);
        e.remove_label(8);
        assert!(e.is_empty());
    }

    #[test]
    fn labels_and_offsets() {
        let mut e = CuboidExceptions::default();
        e.add(10, 3);
        e.add(20, 3);
        e.add(20, 4);
        assert_eq!(e.labels(), vec![3, 4]);
        assert_eq!(e.offsets_of(3), vec![10, 20]);
        assert_eq!(e.offsets_of(4), vec![20]);
        assert!(e.offsets_of(9).is_empty());
    }

    #[test]
    fn store_roundtrip_and_lazy_delete() {
        let p = Arc::new(Project::annotation("ann", "ds").with_exceptions());
        let s = ExceptionStore::new(p, Arc::new(MemStore::new()));
        assert!(s.get(0, 42).unwrap().is_empty());
        let mut e = CuboidExceptions::default();
        e.add(3, 9);
        s.put(0, 42, &e).unwrap();
        assert_eq!(s.get(0, 42).unwrap(), e);
        assert_eq!(s.codes(0).unwrap(), vec![42]);
        s.put(0, 42, &CuboidExceptions::default()).unwrap();
        assert!(s.codes(0).unwrap().is_empty());
    }
}
