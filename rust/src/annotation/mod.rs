//! Annotation databases: RAMON object metadata, the spatial annotation
//! volume with write disciplines and per-voxel exceptions, and predicate
//! queries over metadata (paper §3.2 and §4.2).
//!
//! An *annotation* is an object identifier linked to RAMON metadata plus
//! the set of voxels labeled with that identifier in the spatial database.
//! Writes follow the paper's read-modify-write path: (1) read previous
//! cuboids, (2) apply labels resolving per-voxel conflicts by discipline,
//! (3) write back, (4) read spatial-index entries, (5) union in new cuboid
//! locations, (6) write back the index (§5's six-step description).

mod db;
mod exceptions;
mod ramon;

pub use db::{AnnotationDb, RegionQuery, WriteOutcome};
pub use exceptions::ExceptionStore;
pub use ramon::{
    Predicate, PredicateOp, RamonObject, RamonStatus, RamonType, SynapseType,
};
