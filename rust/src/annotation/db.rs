//! The annotation database: RAMON metadata + spatial volume + per-object
//! index, with the paper's write disciplines and read interfaces (§3.2,
//! §4.2 "Object Representations").

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::annotation::exceptions::ExceptionStore;
use crate::annotation::ramon::{Predicate, RamonObject};
use crate::array::DenseVolume;
use crate::chunkstore::CuboidStore;
use crate::core::{Box3, Project, Vec3, WriteDiscipline};
use crate::cutout::CutoutService;
use crate::morton;
use crate::spatialindex::SpatialIndex;
use crate::storage::Engine;
use crate::wal::Wal;
use crate::{Error, Result};

/// Result of a spatial annotation write.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WriteOutcome {
    /// Voxels whose label changed.
    pub voxels_written: u64,
    /// Voxels kept under `Preserve` or diverted to exceptions.
    pub voxels_conflicted: u64,
    /// Exception entries added.
    pub exceptions_added: u64,
    /// Cuboids read-modified-written.
    pub cuboids_touched: u64,
}

/// Options for region queries.
#[derive(Clone, Copy, Debug, Default)]
pub struct RegionQuery {
    /// Include labels that exist only in exception lists.
    pub include_exceptions: bool,
}

/// One annotation project: spatial database + metadata + index.
pub struct AnnotationDb {
    pub project: Arc<Project>,
    pub cutout: CutoutService,
    pub index: SpatialIndex,
    pub exceptions: ExceptionStore,
    engine: Engine,
    /// The write-absorber this project writes through, when it is hot:
    /// `engine` is then a [`crate::wal::WalEngine`] and every mutation
    /// below group-commits to the SSD log instead of touching the
    /// database node directly.
    wal: Option<Arc<Wal>>,
    next_id: AtomicU32,
    /// Striped per-cuboid write locks: concurrent spatial writes that
    /// share a cuboid serialize their read-modify-write on it (the
    /// paper's MySQL row transactions play this role). 64 stripes keyed
    /// by Morton code.
    write_stripes: Vec<std::sync::Mutex<()>>,
}

impl AnnotationDb {
    pub fn new(store: Arc<CuboidStore>, engine: Engine) -> Result<Self> {
        Self::new_with_wal(store, engine, None)
    }

    /// Build a database whose `engine` routes through `wal` (the cluster
    /// passes the matching [`crate::wal::WalEngine`]); the handle is kept
    /// so callers can flush or inspect the log through the project.
    pub fn new_with_wal(
        store: Arc<CuboidStore>,
        engine: Engine,
        wal: Option<Arc<Wal>>,
    ) -> Result<Self> {
        let project = Arc::clone(&store.project);
        let index = SpatialIndex::new(Arc::clone(&project), Arc::clone(&engine));
        let exceptions = ExceptionStore::new(Arc::clone(&project), Arc::clone(&engine));
        // Resume id allocation above any persisted object. With a WAL
        // this merges unflushed ids from the overlay, so recovery never
        // re-issues an id that was assigned before a crash.
        let max_id = engine
            .keys(&project.ramon_table())?
            .into_iter()
            .max()
            .unwrap_or(0) as u32;
        Ok(AnnotationDb {
            project,
            cutout: CutoutService::new(store),
            index,
            exceptions,
            engine,
            wal,
            next_id: AtomicU32::new(max_id + 1),
            write_stripes: (0..64).map(|_| std::sync::Mutex::new(())).collect(),
        })
    }

    /// The project's write-ahead log, if it is hot.
    pub fn wal(&self) -> Option<&Arc<Wal>> {
        self.wal.as_ref()
    }

    /// Drain this project's log into its database node. Returns records
    /// applied (0 when the project has no log).
    pub fn flush_wal(&self) -> Result<u64> {
        match &self.wal {
            Some(w) => w.flush_now(),
            None => Ok(0),
        }
    }

    fn stripe(&self, code: u64) -> &std::sync::Mutex<()> {
        &self.write_stripes[(code % 64) as usize]
    }

    // ------------------------------------------------------------------
    // RAMON metadata
    // ------------------------------------------------------------------

    /// Store an object; id 0 means "server assigns a unique identifier"
    /// (§4.2 write semantics). Returns the id.
    pub fn put_object(&self, mut obj: RamonObject) -> Result<u32> {
        if obj.id == 0 {
            obj.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        } else {
            // Keep the allocator ahead of explicit ids.
            self.next_id.fetch_max(obj.id + 1, Ordering::Relaxed);
        }
        self.engine.put(&self.project.ramon_table(), obj.id as u64, &obj.encode())?;
        Ok(obj.id)
    }

    /// Batch object write: one storage transaction — the batch interface
    /// that doubled synapse-finder throughput (§4.2 "Batch Interfaces").
    pub fn put_objects(&self, objs: Vec<RamonObject>) -> Result<Vec<u32>> {
        let mut ids = Vec::with_capacity(objs.len());
        let mut batch = Vec::with_capacity(objs.len());
        for mut obj in objs {
            if obj.id == 0 {
                obj.id = self.next_id.fetch_add(1, Ordering::Relaxed);
            } else {
                self.next_id.fetch_max(obj.id + 1, Ordering::Relaxed);
            }
            ids.push(obj.id);
            batch.push((obj.id as u64, obj.encode()));
        }
        self.engine.put_batch(&self.project.ramon_table(), &batch)?;
        Ok(ids)
    }

    pub fn get_object(&self, id: u32) -> Result<RamonObject> {
        match self.engine.get(&self.project.ramon_table(), id as u64)? {
            Some(v) => RamonObject::decode(&v),
            None => Err(Error::NotFound(format!("annotation {id}"))),
        }
    }

    /// Batch read (Table 1 `/{id1},{id2},.../`).
    pub fn get_objects(&self, ids: &[u32]) -> Result<Vec<Option<RamonObject>>> {
        let keys: Vec<u64> = ids.iter().map(|&i| i as u64).collect();
        self.engine
            .get_batch(&self.project.ramon_table(), &keys)?
            .into_iter()
            .map(|v| v.map(|v| RamonObject::decode(&v)).transpose())
            .collect()
    }

    /// Predicate query over metadata (§4.2 "Querying Metadata"): returns
    /// matching ids, ascending.
    pub fn query(&self, predicates: &[Predicate]) -> Result<Vec<u32>> {
        let table = self.project.ramon_table();
        let mut out = Vec::new();
        for key in self.engine.keys(&table)? {
            if let Some(v) = self.engine.get(&table, key)? {
                let obj = RamonObject::decode(&v)?;
                if predicates.iter().all(|p| p.matches(&obj)) {
                    out.push(obj.id);
                }
            }
        }
        Ok(out)
    }

    /// Delete an object's metadata, spatial voxels, exceptions and index
    /// entries.
    pub fn delete_object(&self, res: u32, id: u32) -> Result<()> {
        let codes = self.index.cuboids_of(res, id)?;
        let store = self.cutout.store();
        for &code in &codes {
            let _txn = self.stripe(code).lock().unwrap();
            if let Some(mut cub) = store.read_cuboid::<u32>(res, 0, code)? {
                let mut changed = false;
                for v in cub.as_mut_slice() {
                    if *v == id {
                        *v = 0;
                        changed = true;
                    }
                }
                if changed {
                    store.write_cuboid(res, 0, code, &cub)?;
                }
            }
            if self.project.exceptions {
                let mut exc = self.exceptions.get(res, code)?;
                if !exc.is_empty() {
                    exc.remove_label(id);
                    self.exceptions.put(res, code, &exc)?;
                }
            }
        }
        self.index.delete(res, id)?;
        self.engine.delete(&self.project.ramon_table(), id as u64)?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Spatial writes
    // ------------------------------------------------------------------

    /// Write a labeled volume at `bx` with the given discipline — the
    /// paper's six-step read-modify-write path (§5). Labels are RAMON
    /// ids; 0 voxels are untouched.
    pub fn write_volume(
        &self,
        res: u32,
        bx: Box3,
        vol: &DenseVolume<u32>,
        discipline: WriteDiscipline,
    ) -> Result<WriteOutcome> {
        if vol.dims() != bx.extent() {
            return Err(Error::BadRequest("volume dims != box extent".into()));
        }
        if discipline == WriteDiscipline::Exception && !self.project.exceptions {
            return Err(Error::BadRequest(format!(
                "project '{}' does not support exceptions",
                self.project.token
            )));
        }
        let store = self.cutout.store();
        store.dataset.check_box(res, &bx)?;
        let cshape = store.cuboid_shape(res)?;
        let cover = bx.cuboid_cover(cshape);

        let mut outcome = WriteOutcome::default();
        let mut index_updates: HashMap<u32, Vec<u64>> = HashMap::new();

        for cz in cover.lo[2]..cover.hi[2] {
            for cy in cover.lo[1]..cover.hi[1] {
                for cx in cover.lo[0]..cover.hi[0] {
                    let code = morton::encode3(cx, cy, cz);
                    let cub_box =
                        Box3::at([cx * cshape[0], cy * cshape[1], cz * cshape[2]], cshape);
                    let isect = cub_box.intersect(&bx);
                    if isect.is_empty() {
                        continue;
                    }
                    // Per-cuboid transaction: the read-modify-write below
                    // must be atomic w.r.t. concurrent writers sharing
                    // this cuboid.
                    let _txn = self.stripe(code).lock().unwrap();
                    // (1) read previous annotations
                    let mut cub = store
                        .read_cuboid::<u32>(res, 0, code)?
                        .unwrap_or_else(|| DenseVolume::zeros(cshape));
                    let mut exc = if self.project.exceptions {
                        Some(self.exceptions.get(res, code)?)
                    } else {
                        None
                    };
                    let mut cub_changed = false;
                    let mut exc_changed = false;
                    // (2) apply new labels, resolving conflicts per voxel
                    for z in isect.lo[2]..isect.hi[2] {
                        for y in isect.lo[1]..isect.hi[1] {
                            for x in isect.lo[0]..isect.hi[0] {
                                let src =
                                    [x - bx.lo[0], y - bx.lo[1], z - bx.lo[2]];
                                let new = vol.get(src);
                                if new == 0 {
                                    continue;
                                }
                                let local =
                                    [x - cub_box.lo[0], y - cub_box.lo[1], z - cub_box.lo[2]];
                                let old = cub.get(local);
                                if old == 0 {
                                    cub.set(local, new);
                                    cub_changed = true;
                                    outcome.voxels_written += 1;
                                    index_updates.entry(new).or_default().push(code);
                                } else if old == new {
                                    index_updates.entry(new).or_default().push(code);
                                } else {
                                    match discipline {
                                        WriteDiscipline::Overwrite => {
                                            cub.set(local, new);
                                            cub_changed = true;
                                            outcome.voxels_written += 1;
                                            index_updates.entry(new).or_default().push(code);
                                        }
                                        WriteDiscipline::Preserve => {
                                            outcome.voxels_conflicted += 1;
                                        }
                                        WriteDiscipline::Exception => {
                                            let off = cub.index(local) as u32;
                                            exc.as_mut().unwrap().add(off, new);
                                            exc_changed = true;
                                            outcome.voxels_conflicted += 1;
                                            outcome.exceptions_added += 1;
                                            index_updates.entry(new).or_default().push(code);
                                        }
                                    }
                                }
                            }
                        }
                    }
                    // (3) write back while the cuboid transaction holds
                    if cub_changed {
                        store.write_cuboid(res, 0, code, &cub)?;
                        outcome.cuboids_touched += 1;
                    }
                    if exc_changed {
                        self.exceptions.put(res, code, exc.as_ref().unwrap())?;
                    }
                }
            }
        }
        // (4)(5)(6) read, union and write back the spatial index
        for codes in index_updates.values_mut() {
            codes.sort_unstable();
            codes.dedup();
        }
        self.index.append_batch(res, &index_updates)?;
        Ok(outcome)
    }

    /// Write one object's voxels from a sparse voxel list (the voxel-list
    /// upload interface).
    pub fn write_voxels(
        &self,
        res: u32,
        id: u32,
        voxels: &[Vec3],
        discipline: WriteDiscipline,
    ) -> Result<WriteOutcome> {
        if voxels.is_empty() {
            return Ok(WriteOutcome::default());
        }
        // Bounding box of the voxel list, then one dense write within it.
        let mut lo = voxels[0];
        let mut hi = voxels[0];
        for v in voxels {
            for a in 0..3 {
                lo[a] = lo[a].min(v[a]);
                hi[a] = hi[a].max(v[a]);
            }
        }
        let bx = Box3::new(lo, [hi[0] + 1, hi[1] + 1, hi[2] + 1]);
        let mut vol = DenseVolume::<u32>::zeros(bx.extent());
        for v in voxels {
            vol.set([v[0] - lo[0], v[1] - lo[1], v[2] - lo[2]], id);
        }
        self.write_volume(res, bx, &vol, discipline)
    }

    // ------------------------------------------------------------------
    // Spatial reads
    // ------------------------------------------------------------------

    /// Cuboid-granular bounding box from the index alone — no voxel I/O
    /// (§4.2: the `boundingbox` data option "queries a spatial index but
    /// does not access voxel data").
    pub fn bounding_box(&self, res: u32, id: u32) -> Result<Option<Box3>> {
        let codes = self.index.cuboids_of(res, id)?;
        if codes.is_empty() {
            return Ok(None);
        }
        let cshape = self.cutout.store().cuboid_shape(res)?;
        let mut bb: Option<Box3> = None;
        for code in codes {
            let (x, y, z) = morton::decode3(code);
            let cb = Box3::at([x * cshape[0], y * cshape[1], z * cshape[2]], cshape);
            bb = Some(match bb {
                Some(b) => b.union(&cb),
                None => cb,
            });
        }
        Ok(bb)
    }

    /// The object's voxels as global coordinates — retrieved in a single
    /// Morton-ordered sequential pass over its cuboids (Figure 9).
    pub fn voxel_list(&self, res: u32, id: u32) -> Result<Vec<Vec3>> {
        let codes = self.index.cuboids_of(res, id)?; // already sorted
        if codes.is_empty() {
            return Ok(Vec::new());
        }
        let store = self.cutout.store();
        let cshape = store.cuboid_shape(res)?;
        let cubs = store.read_cuboids::<u32>(res, 0, &codes)?;
        let mut out = Vec::new();
        for (code, cub) in codes.iter().zip(cubs) {
            let (cx, cy, cz) = morton::decode3(*code);
            let base = [cx * cshape[0], cy * cshape[1], cz * cshape[2]];
            if let Some(cub) = cub {
                for z in 0..cshape[2] {
                    for y in 0..cshape[1] {
                        for x in 0..cshape[0] {
                            if cub.get([x, y, z]) == id {
                                out.push([base[0] + x, base[1] + y, base[2] + z]);
                            }
                        }
                    }
                }
            }
            if self.project.exceptions {
                let exc = self.exceptions.get(res, *code)?;
                for off in exc.offsets_of(id) {
                    let off = off as u64;
                    let x = off % cshape[0];
                    let y = (off / cshape[0]) % cshape[1];
                    let z = off / (cshape[0] * cshape[1]);
                    out.push([base[0] + x, base[1] + y, base[2] + z]);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        Ok(out)
    }

    /// Dense read of one object: a cutout of its bounding box (optionally
    /// restricted to `region`) with all other labels filtered out in
    /// place (§4.2: "reads cuboids from disk and filters the data in
    /// place in the read buffer").
    pub fn dense_read(
        &self,
        res: u32,
        id: u32,
        region: Option<Box3>,
    ) -> Result<Option<(Box3, DenseVolume<u32>)>> {
        let Some(bb) = self.bounding_box(res, id)? else { return Ok(None) };
        let bounds = self.cutout.store().dataset.level(res)?.bounds();
        let mut bx = bb.intersect(&bounds);
        if let Some(r) = region {
            bx = bx.intersect(&r);
        }
        if bx.is_empty() {
            return Ok(None);
        }
        let mut vol = self.cutout.read::<u32>(res, 0, 0, bx)?;
        // Filter in place.
        for v in vol.as_mut_slice() {
            if *v != id {
                *v = 0;
            }
        }
        // Splice exception voxels back in.
        if self.project.exceptions {
            let cshape = self.cutout.store().cuboid_shape(res)?;
            for &code in &self.index.cuboids_of(res, id)? {
                let exc = self.exceptions.get(res, code)?;
                let (cx, cy, cz) = morton::decode3(code);
                let base = [cx * cshape[0], cy * cshape[1], cz * cshape[2]];
                for off in exc.offsets_of(id) {
                    let off = off as u64;
                    let p = [
                        base[0] + off % cshape[0],
                        base[1] + (off / cshape[0]) % cshape[1],
                        base[2] + off / (cshape[0] * cshape[1]),
                    ];
                    if bx.contains(p) {
                        vol.set([p[0] - bx.lo[0], p[1] - bx.lo[1], p[2] - bx.lo[2]], id);
                    }
                }
            }
        }
        Ok(Some((bx, vol)))
    }

    /// "What objects are in a region?" — cutout + unique labels (§4.2),
    /// plus exception labels when requested.
    pub fn objects_in_region(&self, res: u32, bx: Box3, q: RegionQuery) -> Result<Vec<u32>> {
        let vol = self.cutout.read::<u32>(res, 0, 0, bx)?;
        let mut ids = vol.unique_nonzero();
        if q.include_exceptions && self.project.exceptions {
            let cshape = self.cutout.store().cuboid_shape(res)?;
            let cover = bx.cuboid_cover(cshape);
            for cz in cover.lo[2]..cover.hi[2] {
                for cy in cover.lo[1]..cover.hi[1] {
                    for cx in cover.lo[0]..cover.hi[0] {
                        let exc = self.exceptions.get(res, morton::encode3(cx, cy, cz))?;
                        ids.extend(exc.labels());
                    }
                }
            }
            ids.sort_unstable();
            ids.dedup();
        }
        Ok(ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::annotation::ramon::{PredicateOp, RamonType, SynapseType};
    use crate::core::DatasetBuilder;
    use crate::storage::MemStore;

    fn db(exceptions: bool) -> AnnotationDb {
        let ds = Arc::new(DatasetBuilder::new("t", [256, 256, 32]).levels(2).build());
        let mut pr = Project::annotation("ann", "t");
        if exceptions {
            pr = pr.with_exceptions();
        }
        let engine: Engine = Arc::new(MemStore::new());
        let store =
            Arc::new(CuboidStore::new(ds, Arc::new(pr), Arc::clone(&engine)));
        AnnotationDb::new(store, engine).unwrap()
    }

    fn blob(db: &AnnotationDb, id: u32, bx: Box3) {
        let mut vol = DenseVolume::<u32>::zeros(bx.extent());
        vol.fill_box(Box3::new([0, 0, 0], bx.extent()), id);
        db.write_volume(0, bx, &vol, WriteDiscipline::Overwrite).unwrap();
    }

    #[test]
    fn id_assignment_and_metadata_roundtrip() {
        let db = db(false);
        let id1 = db.put_object(RamonObject::synapse(0, 0.9, SynapseType::Excitatory)).unwrap();
        let id2 = db.put_object(RamonObject::synapse(0, 0.5, SynapseType::Inhibitory)).unwrap();
        assert_ne!(id1, id2);
        assert_eq!(db.get_object(id1).unwrap().confidence, 0.9);
        assert!(db.get_object(9999).is_err());
        // Explicit id bumps the allocator.
        db.put_object(RamonObject::new(500, RamonType::Seed)).unwrap();
        let id3 = db.put_object(RamonObject::new(0, RamonType::Seed)).unwrap();
        assert!(id3 > 500);
    }

    #[test]
    fn batch_objects_and_batch_get() {
        let db = db(false);
        let objs: Vec<RamonObject> =
            (0..10).map(|_| RamonObject::synapse(0, 0.7, SynapseType::Unknown)).collect();
        let ids = db.put_objects(objs).unwrap();
        assert_eq!(ids.len(), 10);
        let got = db.get_objects(&ids).unwrap();
        assert!(got.iter().all(|o| o.is_some()));
        let got = db.get_objects(&[ids[0], 99999]).unwrap();
        assert!(got[0].is_some() && got[1].is_none());
    }

    #[test]
    fn query_predicates() {
        let db = db(false);
        let a = db.put_object(RamonObject::synapse(0, 0.995, SynapseType::Excitatory)).unwrap();
        let _b = db.put_object(RamonObject::synapse(0, 0.4, SynapseType::Excitatory)).unwrap();
        let c = db.put_object(RamonObject::segment(0, 7)).unwrap();
        let ids = db
            .query(&[
                Predicate::eq("type", "synapse"),
                Predicate::cmp("confidence", PredicateOp::Geq, 0.99),
            ])
            .unwrap();
        assert_eq!(ids, vec![a]);
        let segs = db.query(&[Predicate::eq("type", "segment")]).unwrap();
        assert_eq!(segs, vec![c]);
    }

    #[test]
    fn spatial_write_read_object() {
        let db = db(false);
        let bx = Box3::new([10, 20, 3], [40, 50, 9]);
        blob(&db, 42, bx);
        // Voxel list covers exactly the box.
        let vl = db.voxel_list(0, 42).unwrap();
        assert_eq!(vl.len() as u64, bx.volume());
        assert!(vl.contains(&[10, 20, 3]));
        assert!(vl.contains(&[39, 49, 8]));
        // Bounding box is cuboid-granular and contains the true box.
        let bb = db.bounding_box(0, 42).unwrap().unwrap();
        assert!(bb.lo[0] <= 10 && bb.hi[0] >= 40);
        // Dense read equals the blob within its box.
        let (dbx, dvol) = db.dense_read(0, 42, None).unwrap().unwrap();
        assert_eq!(dvol.count_eq(42), bx.volume());
        assert!(dbx.volume() >= bx.volume());
        // Restricted dense read.
        let r = Box3::new([10, 20, 3], [20, 30, 5]);
        let (_, rvol) = db.dense_read(0, 42, Some(r)).unwrap().unwrap();
        assert_eq!(rvol.count_eq(42), r.volume());
    }

    #[test]
    fn disciplines_overwrite_preserve() {
        let db = db(false);
        let bx = Box3::new([0, 0, 0], [16, 16, 4]);
        blob(&db, 1, bx);
        let mut v2 = DenseVolume::<u32>::zeros(bx.extent());
        v2.fill_box(Box3::new([0, 0, 0], [8, 16, 4]), 2);
        // Preserve: voxels stay 1.
        let o = db.write_volume(0, bx, &v2, WriteDiscipline::Preserve).unwrap();
        assert_eq!(o.voxels_written, 0);
        assert_eq!(o.voxels_conflicted, 8 * 16 * 4);
        assert!(db.voxel_list(0, 2).unwrap().is_empty());
        // Overwrite: voxels become 2.
        let o = db.write_volume(0, bx, &v2, WriteDiscipline::Overwrite).unwrap();
        assert_eq!(o.voxels_written, 8 * 16 * 4);
        assert_eq!(db.voxel_list(0, 2).unwrap().len() as u64, 8 * 16 * 4);
    }

    #[test]
    fn discipline_exception_records_both_labels() {
        let db = db(true);
        let bx = Box3::new([0, 0, 0], [8, 8, 2]);
        blob(&db, 1, bx);
        let mut v2 = DenseVolume::<u32>::zeros(bx.extent());
        v2.fill_box(Box3::new([0, 0, 0], [4, 8, 2]), 2);
        let o = db.write_volume(0, bx, &v2, WriteDiscipline::Exception).unwrap();
        assert_eq!(o.exceptions_added, 4 * 8 * 2);
        // Volume still shows 1; object 2 readable via exceptions.
        let vl1 = db.voxel_list(0, 1).unwrap();
        assert_eq!(vl1.len() as u64, bx.volume());
        let vl2 = db.voxel_list(0, 2).unwrap();
        assert_eq!(vl2.len() as u64, 4 * 8 * 2);
        // Dense read of 2 splices exceptions back in.
        let (_, dv) = db.dense_read(0, 2, None).unwrap().unwrap();
        assert_eq!(dv.count_eq(2), 4 * 8 * 2);
        // Region query sees both.
        let ids = db
            .objects_in_region(0, bx, RegionQuery { include_exceptions: true })
            .unwrap();
        assert_eq!(ids, vec![1, 2]);
        // Without exceptions only the volume label shows.
        let ids = db.objects_in_region(0, bx, RegionQuery::default()).unwrap();
        assert_eq!(ids, vec![1]);
    }

    #[test]
    fn exception_write_without_support_rejected() {
        let db = db(false);
        let bx = Box3::new([0, 0, 0], [4, 4, 1]);
        let vol = DenseVolume::<u32>::zeros(bx.extent());
        assert!(db.write_volume(0, bx, &vol, WriteDiscipline::Exception).is_err());
    }

    #[test]
    fn write_voxels_sparse() {
        let db = db(false);
        let voxels: Vec<Vec3> = vec![[5, 5, 1], [100, 7, 2], [5, 6, 1]];
        let o = db.write_voxels(0, 9, &voxels, WriteDiscipline::Overwrite).unwrap();
        assert_eq!(o.voxels_written, 3);
        let mut vl = db.voxel_list(0, 9).unwrap();
        vl.sort_unstable();
        let mut expect = voxels.clone();
        expect.sort_unstable();
        assert_eq!(vl, expect);
    }

    #[test]
    fn objects_in_region_unique() {
        let db = db(false);
        blob(&db, 1, Box3::new([0, 0, 0], [8, 8, 2]));
        blob(&db, 2, Box3::new([100, 100, 10], [108, 108, 12]));
        let ids = db
            .objects_in_region(0, Box3::new([0, 0, 0], [256, 256, 32]), RegionQuery::default())
            .unwrap();
        assert_eq!(ids, vec![1, 2]);
        let ids = db
            .objects_in_region(0, Box3::new([0, 0, 0], [16, 16, 4]), RegionQuery::default())
            .unwrap();
        assert_eq!(ids, vec![1]);
    }

    #[test]
    fn delete_object_removes_everything() {
        let db = db(true);
        let bx = Box3::new([0, 0, 0], [8, 8, 2]);
        blob(&db, 5, bx);
        db.put_object(RamonObject::new(5, RamonType::Synapse)).unwrap();
        db.delete_object(0, 5).unwrap();
        assert!(db.voxel_list(0, 5).unwrap().is_empty());
        assert!(db.bounding_box(0, 5).unwrap().is_none());
        assert!(db.get_object(5).is_err());
        let ids = db.objects_in_region(0, bx, RegionQuery::default()).unwrap();
        assert!(ids.is_empty());
    }

    #[test]
    fn missing_object_dense_read_none() {
        let db = db(false);
        assert!(db.dense_read(0, 777, None).unwrap().is_none());
        assert!(db.voxel_list(0, 777).unwrap().is_empty());
    }

    #[test]
    fn hot_db_reads_through_wal_overlay() {
        // AnnotationDb over a WalEngine: writes absorb into the log,
        // reads merge the overlay, and a flush moves everything to the
        // database node with identical answers before and after.
        use crate::wal::{Wal, WalConfig, WalEngine};
        let ds = Arc::new(DatasetBuilder::new("t", [256, 256, 32]).levels(1).build());
        let pr = Arc::new(Project::annotation("hot", "t"));
        let log: Engine = Arc::new(MemStore::new());
        let dest: Engine = Arc::new(MemStore::new());
        let cfg = WalConfig { background_flush: false, ..WalConfig::default() };
        let wal = Wal::open("hot", Arc::clone(&log), Arc::clone(&dest), cfg).unwrap();
        let engine: Engine = Arc::new(WalEngine::new(Arc::clone(&wal)));
        let store = Arc::new(CuboidStore::new(ds, pr, Arc::clone(&engine)));
        let db = AnnotationDb::new_with_wal(store, engine, Some(Arc::clone(&wal))).unwrap();

        let bx = Box3::new([10, 20, 3], [40, 50, 9]);
        blob(&db, 42, bx);
        let id = db.put_object(RamonObject::synapse(42, 0.9, SynapseType::Unknown)).unwrap();
        assert_eq!(id, 42);
        // Unflushed: the database node is untouched, reads still correct.
        assert!(wal.depth() > 0);
        assert!(dest.tables().unwrap().is_empty(), "dest written before flush");
        assert_eq!(db.voxel_list(0, 42).unwrap().len() as u64, bx.volume());
        // Flush, then identical answers served from the database node.
        let moved = db.flush_wal().unwrap();
        assert!(moved >= 2, "expected cuboids + index + metadata, got {moved}");
        assert_eq!(wal.depth(), 0);
        assert_eq!(db.voxel_list(0, 42).unwrap().len() as u64, bx.volume());
        assert_eq!(db.get_object(42).unwrap().confidence, 0.9);
        assert!(!dest.tables().unwrap().is_empty());
    }

    #[test]
    fn wal_id_allocation_survives_reopen_without_flush() {
        // The id allocator scans engine keys at open; with a WAL those
        // keys come from the overlay, so a crash between commit and
        // flush never re-issues an id.
        use crate::wal::{Wal, WalConfig, WalEngine};
        let ds = Arc::new(DatasetBuilder::new("t", [64, 64, 8]).levels(1).build());
        let pr = Arc::new(Project::annotation("hot", "t"));
        let log: Engine = Arc::new(MemStore::new());
        let dest: Engine = Arc::new(MemStore::new());
        let cfg = WalConfig { background_flush: false, ..WalConfig::default() };
        {
            let wal = Wal::open("hot", Arc::clone(&log), Arc::clone(&dest), cfg).unwrap();
            let engine: Engine = Arc::new(WalEngine::new(Arc::clone(&wal)));
            let store =
                Arc::new(CuboidStore::new(Arc::clone(&ds), Arc::clone(&pr), Arc::clone(&engine)));
            let db = AnnotationDb::new_with_wal(store, engine, Some(wal)).unwrap();
            db.put_object(RamonObject::new(7, RamonType::Seed)).unwrap();
            // Dropped without flushing — simulated crash.
        }
        let wal = Wal::open("hot", Arc::clone(&log), Arc::clone(&dest), cfg).unwrap();
        let engine: Engine = Arc::new(WalEngine::new(Arc::clone(&wal)));
        let store = Arc::new(CuboidStore::new(ds, pr, Arc::clone(&engine)));
        let db = AnnotationDb::new_with_wal(store, engine, Some(wal)).unwrap();
        assert_eq!(db.get_object(7).unwrap().id, 7);
        let next = db.put_object(RamonObject::new(0, RamonType::Seed)).unwrap();
        assert!(next > 7, "allocator must resume above replayed ids, got {next}");
    }
}
