//! RAMON (Reusable Annotation Markup for Open coNnectomes) — the
//! neuroscience ontology the paper links spatial annotations to ([19],
//! §3.2): synapses, seeds, segments, neurons, organelles, each with common
//! metadata (confidence, status, author, free key/value pairs) and
//! type-specific fields.

use std::collections::BTreeMap;

use crate::util::codec::{Dec, Enc};
use crate::{Error, Result};

/// RAMON object classes (§3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RamonType {
    Generic,
    Seed,
    Synapse,
    Segment,
    Neuron,
    Organelle,
}

impl RamonType {
    pub fn name(self) -> &'static str {
        match self {
            RamonType::Generic => "generic",
            RamonType::Seed => "seed",
            RamonType::Synapse => "synapse",
            RamonType::Segment => "segment",
            RamonType::Neuron => "neuron",
            RamonType::Organelle => "organelle",
        }
    }

    pub fn parse(s: &str) -> Result<RamonType> {
        Ok(match s {
            "generic" => RamonType::Generic,
            "seed" => RamonType::Seed,
            "synapse" => RamonType::Synapse,
            "segment" => RamonType::Segment,
            "neuron" => RamonType::Neuron,
            "organelle" => RamonType::Organelle,
            _ => return Err(Error::BadRequest(format!("unknown RAMON type '{s}'"))),
        })
    }

    fn tag(self) -> u8 {
        match self {
            RamonType::Generic => 0,
            RamonType::Seed => 1,
            RamonType::Synapse => 2,
            RamonType::Segment => 3,
            RamonType::Neuron => 4,
            RamonType::Organelle => 5,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => RamonType::Generic,
            1 => RamonType::Seed,
            2 => RamonType::Synapse,
            3 => RamonType::Segment,
            4 => RamonType::Neuron,
            5 => RamonType::Organelle,
            _ => return Err(Error::Codec(format!("bad RAMON tag {t}"))),
        })
    }
}

/// Processing status of an annotation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RamonStatus {
    #[default]
    Unprocessed,
    Locked,
    Processed,
    Ignored,
}

impl RamonStatus {
    pub fn name(self) -> &'static str {
        match self {
            RamonStatus::Unprocessed => "unprocessed",
            RamonStatus::Locked => "locked",
            RamonStatus::Processed => "processed",
            RamonStatus::Ignored => "ignored",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "unprocessed" => RamonStatus::Unprocessed,
            "locked" => RamonStatus::Locked,
            "processed" => RamonStatus::Processed,
            "ignored" => RamonStatus::Ignored,
            _ => return Err(Error::BadRequest(format!("unknown status '{s}'"))),
        })
    }

    fn tag(self) -> u8 {
        self as u8
    }

    fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => RamonStatus::Unprocessed,
            1 => RamonStatus::Locked,
            2 => RamonStatus::Processed,
            3 => RamonStatus::Ignored,
            _ => return Err(Error::Codec(format!("bad status tag {t}"))),
        })
    }
}

/// Synapse polarity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SynapseType {
    #[default]
    Unknown,
    Excitatory,
    Inhibitory,
}

impl SynapseType {
    pub fn name(self) -> &'static str {
        match self {
            SynapseType::Unknown => "unknown",
            SynapseType::Excitatory => "excitatory",
            SynapseType::Inhibitory => "inhibitory",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "unknown" => SynapseType::Unknown,
            "excitatory" => SynapseType::Excitatory,
            "inhibitory" => SynapseType::Inhibitory,
            _ => return Err(Error::BadRequest(format!("unknown synapse type '{s}'"))),
        })
    }
}

/// A RAMON annotation object: common metadata plus type-specific fields.
/// Unused type-specific fields stay at their defaults.
#[derive(Clone, Debug, PartialEq)]
pub struct RamonObject {
    pub id: u32,
    pub rtype: RamonType,
    pub confidence: f32,
    pub status: RamonStatus,
    pub author: String,
    /// Free-form key/value pairs (queryable with equality predicates).
    pub kv: BTreeMap<String, String>,

    // -- synapse fields --
    pub synapse_type: SynapseType,
    pub weight: f32,
    /// Segments this synapse connects (presynaptic, postsynaptic).
    pub segments: Vec<(u32, u32)>,
    /// Seeds used to detect this object.
    pub seeds: Vec<u32>,

    // -- seed fields --
    pub position: [u64; 3],
    pub parent: u32,

    // -- segment fields --
    pub neuron: u32,
    pub synapses: Vec<u32>,
    pub organelles: Vec<u32>,

    // -- neuron fields --
    pub neuron_segments: Vec<u32>,

    // -- organelle fields --
    pub organelle_class: u32,
}

impl RamonObject {
    /// A bare object of the given type (id 0 = "assign me one").
    pub fn new(id: u32, rtype: RamonType) -> Self {
        RamonObject {
            id,
            rtype,
            confidence: 0.0,
            status: RamonStatus::Unprocessed,
            author: String::new(),
            kv: BTreeMap::new(),
            synapse_type: SynapseType::Unknown,
            weight: 0.0,
            segments: Vec::new(),
            seeds: Vec::new(),
            position: [0, 0, 0],
            parent: 0,
            neuron: 0,
            synapses: Vec::new(),
            organelles: Vec::new(),
            neuron_segments: Vec::new(),
            organelle_class: 0,
        }
    }

    pub fn synapse(id: u32, confidence: f32, stype: SynapseType) -> Self {
        let mut o = RamonObject::new(id, RamonType::Synapse);
        o.confidence = confidence;
        o.synapse_type = stype;
        o
    }

    pub fn segment(id: u32, neuron: u32) -> Self {
        let mut o = RamonObject::new(id, RamonType::Segment);
        o.neuron = neuron;
        o
    }

    pub fn neuron(id: u32) -> Self {
        RamonObject::new(id, RamonType::Neuron)
    }

    pub fn seed(id: u32, position: [u64; 3]) -> Self {
        let mut o = RamonObject::new(id, RamonType::Seed);
        o.position = position;
        o
    }

    pub fn with_author(mut self, a: &str) -> Self {
        self.author = a.into();
        self
    }

    pub fn with_kv(mut self, k: &str, v: &str) -> Self {
        self.kv.insert(k.into(), v.into());
        self
    }

    /// Serialize (versioned record).
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::with_capacity(96);
        e.u8(1); // record version
        e.u32(self.id)
            .u8(self.rtype.tag())
            .f32(self.confidence)
            .u8(self.status.tag())
            .str(&self.author);
        e.varint(self.kv.len() as u64);
        for (k, v) in &self.kv {
            e.str(k).str(v);
        }
        e.u8(self.synapse_type as u8).f32(self.weight);
        e.varint(self.segments.len() as u64);
        for (a, b) in &self.segments {
            e.u32(*a).u32(*b);
        }
        e.u32s(&self.seeds);
        e.u64(self.position[0]).u64(self.position[1]).u64(self.position[2]);
        e.u32(self.parent).u32(self.neuron);
        e.u32s(&self.synapses);
        e.u32s(&self.organelles);
        e.u32s(&self.neuron_segments);
        e.u32(self.organelle_class);
        e.finish()
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut d = Dec::new(buf);
        let ver = d.u8()?;
        if ver != 1 {
            return Err(Error::Codec(format!("bad RAMON record version {ver}")));
        }
        let id = d.u32()?;
        let rtype = RamonType::from_tag(d.u8()?)?;
        let confidence = d.f32()?;
        let status = RamonStatus::from_tag(d.u8()?)?;
        let author = d.str()?;
        let nkv = d.varint()? as usize;
        let mut kv = BTreeMap::new();
        for _ in 0..nkv {
            let k = d.str()?;
            let v = d.str()?;
            kv.insert(k, v);
        }
        let synapse_type = match d.u8()? {
            0 => SynapseType::Unknown,
            1 => SynapseType::Excitatory,
            2 => SynapseType::Inhibitory,
            t => return Err(Error::Codec(format!("bad synapse type {t}"))),
        };
        let weight = d.f32()?;
        let nseg = d.varint()? as usize;
        let mut segments = Vec::with_capacity(nseg);
        for _ in 0..nseg {
            segments.push((d.u32()?, d.u32()?));
        }
        let seeds = d.u32s()?;
        let position = [d.u64()?, d.u64()?, d.u64()?];
        let parent = d.u32()?;
        let neuron = d.u32()?;
        let synapses = d.u32s()?;
        let organelles = d.u32s()?;
        let neuron_segments = d.u32s()?;
        let organelle_class = d.u32()?;
        Ok(RamonObject {
            id,
            rtype,
            confidence,
            status,
            author,
            kv,
            synapse_type,
            weight,
            segments,
            seeds,
            position,
            parent,
            neuron,
            synapses,
            organelles,
            neuron_segments,
            organelle_class,
        })
    }

    /// Value of a named field for predicate evaluation. String-valued
    /// fields return `Err(string)`, numeric fields `Ok(f64)`.
    fn field(&self, name: &str) -> Option<std::result::Result<f64, String>> {
        match name {
            "id" => Some(Ok(self.id as f64)),
            "type" => Some(Err(self.rtype.name().to_string())),
            "confidence" => Some(Ok(self.confidence as f64)),
            "status" => Some(Err(self.status.name().to_string())),
            "author" => Some(Err(self.author.clone())),
            "weight" => Some(Ok(self.weight as f64)),
            "synapse_type" => Some(Err(self.synapse_type.name().to_string())),
            "neuron" => Some(Ok(self.neuron as f64)),
            "parent" => Some(Ok(self.parent as f64)),
            "organelle_class" => Some(Ok(self.organelle_class as f64)),
            _ => self.kv.get(name).map(|v| Err(v.clone())),
        }
    }
}

/// Comparison operator in a metadata predicate (§4.2 "Querying Metadata":
/// equality on integers/enums/strings/KV pairs, ranges on floats).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredicateOp {
    Eq,
    Geq,
    Leq,
    Gt,
    Lt,
}

impl PredicateOp {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "eq" => PredicateOp::Eq,
            "geq" => PredicateOp::Geq,
            "leq" => PredicateOp::Leq,
            "gt" => PredicateOp::Gt,
            "lt" => PredicateOp::Lt,
            _ => return Err(Error::BadRequest(format!("unknown predicate op '{s}'"))),
        })
    }
}

/// One metadata predicate: `field op value`.
#[derive(Clone, Debug)]
pub struct Predicate {
    pub field: String,
    pub op: PredicateOp,
    pub value: String,
}

impl Predicate {
    pub fn eq(field: &str, value: &str) -> Self {
        Predicate { field: field.into(), op: PredicateOp::Eq, value: value.into() }
    }

    pub fn cmp(field: &str, op: PredicateOp, value: f64) -> Self {
        Predicate { field: field.into(), op, value: value.to_string() }
    }

    /// Evaluate against an object. Unknown fields never match.
    pub fn matches(&self, o: &RamonObject) -> bool {
        let Some(v) = o.field(&self.field) else { return false };
        match (v, self.op) {
            (Err(s), PredicateOp::Eq) => s == self.value,
            (Ok(x), op) => {
                let Ok(rhs) = self.value.parse::<f64>() else { return false };
                match op {
                    PredicateOp::Eq => x == rhs,
                    PredicateOp::Geq => x >= rhs,
                    PredicateOp::Leq => x <= rhs,
                    PredicateOp::Gt => x > rhs,
                    PredicateOp::Lt => x < rhs,
                }
            }
            (Err(_), _) => false, // range ops on string fields never match
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip_full() {
        let mut o = RamonObject::synapse(77, 0.993, SynapseType::Excitatory)
            .with_author("vision-v2")
            .with_kv("algo", "dog-3d")
            .with_kv("run", "17");
        o.weight = 2.5;
        o.segments = vec![(10, 11), (12, 13)];
        o.seeds = vec![1, 2, 3];
        let b = o.encode();
        assert_eq!(RamonObject::decode(&b).unwrap(), o);
    }

    #[test]
    fn encode_decode_all_types() {
        for t in [
            RamonType::Generic,
            RamonType::Seed,
            RamonType::Synapse,
            RamonType::Segment,
            RamonType::Neuron,
            RamonType::Organelle,
        ] {
            let o = RamonObject::new(5, t);
            assert_eq!(RamonObject::decode(&o.encode()).unwrap().rtype, t);
            assert_eq!(RamonType::parse(t.name()).unwrap(), t);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(RamonObject::decode(&[]).is_err());
        assert!(RamonObject::decode(&[9, 9, 9]).is_err());
    }

    #[test]
    fn predicates_match_paper_example() {
        // openconnecto.me/objects/type/synapse/confidence/geq/0.99/
        let hi = RamonObject::synapse(1, 0.995, SynapseType::Unknown);
        let lo = RamonObject::synapse(2, 0.42, SynapseType::Unknown);
        let seg = RamonObject::segment(3, 9);
        let p_type = Predicate::eq("type", "synapse");
        let p_conf = Predicate::cmp("confidence", PredicateOp::Geq, 0.99);
        assert!(p_type.matches(&hi) && p_conf.matches(&hi));
        assert!(p_type.matches(&lo) && !p_conf.matches(&lo));
        assert!(!p_type.matches(&seg));
    }

    #[test]
    fn kv_predicates() {
        let o = RamonObject::new(1, RamonType::Generic).with_kv("stain", "PSD95");
        assert!(Predicate::eq("stain", "PSD95").matches(&o));
        assert!(!Predicate::eq("stain", "synapsin").matches(&o));
        assert!(!Predicate::eq("missing", "x").matches(&o));
    }

    #[test]
    fn numeric_predicates_on_string_fields_never_match() {
        let o = RamonObject::new(1, RamonType::Generic).with_author("alice");
        assert!(!Predicate::cmp("author", PredicateOp::Geq, 1.0).matches(&o));
    }

    #[test]
    fn status_parse_roundtrip() {
        for s in
            [RamonStatus::Unprocessed, RamonStatus::Locked, RamonStatus::Processed, RamonStatus::Ignored]
        {
            assert_eq!(RamonStatus::parse(s.name()).unwrap(), s);
        }
    }
}
