//! The shipped [`JobSpec`]s — the paper's three batch workloads
//! re-expressed as checkpointed, parallel jobs:
//!
//! * [`PropagateJob`] — the §3.1/§3.2 background hierarchy build. The
//!   hierarchy is split into bands of up to three levels; each block
//!   reads its band's source level **once** and derives the band's
//!   coarser levels from the previous level *in memory*, instead of
//!   re-reading each freshly-built level from storage per destination
//!   level (halving the read I/O per level vs. the one-shot
//!   [`crate::resolution::Propagator`]; outputs are bit-identical —
//!   both compose the same per-level downsample). Bands run as ordered
//!   job phases, so deep hierarchies stay memory-bounded.
//! * [`SynapseDetectJob`] — the §2 synapse-finding workload, one
//!   detector core block per job block, RAMON metadata written in
//!   batches through the annotation project's engine (the WAL, when the
//!   project is hot).
//! * [`BulkIngestJob`] — the "image data streamed from the instruments"
//!   path (§4.1): chunked, cuboid-aligned ingest of a synthetic EM
//!   volume ([`crate::ingest::generate`]).

use std::sync::{Arc, OnceLock};

use crate::annotation::AnnotationDb;
use crate::array::{DenseVolume, VoxelScalar};
use crate::core::{Box3, Vec3};
use crate::cutout::CutoutService;
use crate::ingest::{block_boxes, generate, SynthSpec, SynthVolume};
use crate::jobs::{JobBlock, JobSpec};
use crate::morton;
use crate::resolution::{downsample_labels_u32, downsample_mean_u8};
use crate::shard::NodeId;
use crate::vision::SynapsePipeline;
use crate::Result;

/// Shard-affinity hint for a region: the node owning its first cuboid,
/// via the engine's shard map (`None` when the engine is unsharded).
fn shard_of(svc: &CutoutService, res: u32, bx: &Box3) -> Option<NodeId> {
    let map = svc.store().engine().shard_map()?;
    let cshape = svc.store().cuboid_shape(res).ok()?;
    let c = bx.cuboid_cover(cshape).lo;
    let code = if svc.store().dataset.timesteps > 1 {
        morton::encode4(c[0], c[1], c[2], 0)
    } else {
        morton::encode3(c[0], c[1], c[2])
    };
    Some(map.node_for(code))
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: u64, b: u64) -> u64 {
    a / gcd(a, b) * b
}

// ----------------------------------------------------------------------
// Propagate
// ----------------------------------------------------------------------

enum Target {
    Image(Arc<CutoutService>),
    Annotation(Arc<AnnotationDb>),
}

/// Levels each block derives in memory before the pyramid re-reads the
/// previously built level from storage. Bounds per-block memory at
/// `(cuboid << BAND_LEVELS)² × cuboid_z` voxels regardless of hierarchy
/// depth, while still skipping every per-level re-read *within* a band.
const BAND_LEVELS: u32 = 3;

/// One group of consecutive levels built from a single source level.
struct Band {
    /// Level this band's blocks read (base, or the previous band's top).
    src: u32,
    /// Highest level this band writes (inclusive).
    top: u32,
    /// Block extent at the source level.
    block: Vec3,
}

/// Resolution-hierarchy propagation as a batch job.
///
/// Levels are built in *bands* of [`BAND_LEVELS`]: each band's plan
/// tiles its source level into super-blocks whose XY extents are a
/// common multiple of every band level's cuboid extent scaled back to
/// the source. Three consequences:
///
/// * every level write is cuboid-aligned and disjoint across blocks —
///   parallel blocks never read-modify-write a shared cuboid;
/// * each block's 2x2 downsample windows never straddle a block
///   boundary, so its in-memory pyramid is self-contained: within a
///   band, level `l` is computed from the block's own level `l-1`
///   output without touching storage again;
/// * bands run as ordered job *phases* (the engine's barrier), so a
///   band reads the finished output of the band below it — per-block
///   memory stays bounded on arbitrarily deep hierarchies.
pub struct PropagateJob {
    target: Target,
    bands: Vec<Band>,
}

impl PropagateJob {
    /// Propagate an image project (box-mean downsampling).
    pub fn image(svc: Arc<CutoutService>) -> PropagateJob {
        let bands = Self::bands(&svc);
        PropagateJob { target: Target::Image(svc), bands }
    }

    /// Propagate an annotation project (majority-label downsampling).
    pub fn annotation(db: Arc<AnnotationDb>) -> PropagateJob {
        let bands = Self::bands(&db.cutout);
        PropagateJob { target: Target::Annotation(db), bands }
    }

    fn svc(&self) -> &CutoutService {
        match &self.target {
            Target::Image(svc) => svc,
            Target::Annotation(db) => &db.cutout,
        }
    }

    /// Split the hierarchy above the base resolution into bands, each
    /// with its source-level block extent: the LCM over the band's
    /// levels of the cuboid extent scaled to the source (XY; Z never
    /// scales), so block boundaries align to every band level's cuboid
    /// grid.
    fn bands(svc: &CutoutService) -> Vec<Band> {
        let ds = &svc.store().dataset;
        let base = svc.store().project.base_resolution;
        let levels = ds.num_levels();
        let mut out = Vec::new();
        let mut src = base;
        while src + 1 < levels {
            let top = (src + BAND_LEVELS).min(levels - 1);
            let mut ext = [1u64, 1, 1];
            for l in src..=top {
                let Ok(spec) = ds.level(l) else { continue };
                let shift = l - src;
                ext[0] = lcm(ext[0], spec.cuboid[0] << shift);
                ext[1] = lcm(ext[1], spec.cuboid[1] << shift);
                ext[2] = lcm(ext[2], spec.cuboid[2]);
            }
            out.push(Band { src, top, block: ext });
            src = top;
        }
        out
    }

    fn run_block_typed<T: VoxelScalar>(
        &self,
        block: &JobBlock,
        down: fn(&DenseVolume<T>) -> DenseVolume<T>,
    ) -> Result<u64> {
        let band = &self.bands[block.phase as usize];
        let svc = self.svc();
        let ds = Arc::clone(&svc.store().dataset);
        // One storage read per block; every coarser level of the band
        // derives from the in-memory previous level (the I/O-halving
        // contract within a band).
        let mut cur = svc.read::<T>(band.src, 0, 0, block.bx)?;
        if cur.all_zero() {
            return Ok(0); // lazy: empty space never materializes
        }
        let mut lo = block.bx.lo;
        let mut written = 0u64;
        for l in band.src + 1..=band.top {
            cur = down(&cur);
            lo = [lo[0] / 2, lo[1] / 2, lo[2]];
            let level = ds.level(l)?;
            let region = Box3::at(lo, cur.dims()).intersect(&level.bounds());
            if region.is_empty() {
                break;
            }
            let cshape = level.cuboid;
            let cover = region.cuboid_cover(cshape);
            for cz in cover.lo[2]..cover.hi[2] {
                for cy in cover.lo[1]..cover.hi[1] {
                    for cx in cover.lo[0]..cover.hi[0] {
                        let cub = Box3::at(
                            [cx * cshape[0], cy * cshape[1], cz * cshape[2]],
                            cshape,
                        )
                        .intersect(&region);
                        if cub.is_empty() {
                            continue;
                        }
                        let local = Box3::new(
                            [cub.lo[0] - lo[0], cub.lo[1] - lo[1], cub.lo[2] - lo[2]],
                            [cub.hi[0] - lo[0], cub.hi[1] - lo[1], cub.hi[2] - lo[2]],
                        );
                        let sub = cur.extract_box(local);
                        if sub.all_zero() {
                            continue; // lazy at cuboid granularity
                        }
                        svc.write(l, 0, 0, cub, &sub)?;
                        written += 1;
                    }
                }
            }
        }
        Ok(written)
    }
}

impl JobSpec for PropagateJob {
    fn name(&self) -> String {
        format!("propagate/{}", self.svc().store().project.token)
    }

    fn project(&self) -> Option<String> {
        Some(self.svc().store().project.token.clone())
    }

    fn plan(&self) -> Result<Vec<JobBlock>> {
        let svc = self.svc();
        let ds = &svc.store().dataset;
        let mut out = Vec::new();
        for (phase, band) in self.bands.iter().enumerate() {
            let dims = ds.level(band.src)?.dims;
            for bx in block_boxes(dims, band.block) {
                let index = out.len() as u64;
                let shard = shard_of(svc, band.src, &bx);
                out.push(JobBlock { index, res: band.src, bx, shard, phase: phase as u32 });
            }
        }
        Ok(out)
    }

    fn run_block(&self, block: &JobBlock) -> Result<u64> {
        match &self.target {
            Target::Image(_) => self.run_block_typed::<u8>(block, downsample_mean_u8),
            Target::Annotation(_) => {
                self.run_block_typed::<u32>(block, downsample_labels_u32)
            }
        }
    }
}

// ----------------------------------------------------------------------
// Synapse detection
// ----------------------------------------------------------------------

/// The §2 vision workload as a job: one detector core block per job
/// block. Each block cutouts its haloed image region, runs the AOT
/// detector graph, extracts components, and writes labels + batched
/// RAMON metadata through the annotation project (its WAL absorbs the
/// random writes when the project is hot). Completed blocks are
/// journaled, so a resumed job never re-detects (and never duplicates)
/// a finished block's synapses; an in-block failure compensates by
/// deleting the attempt's objects ([`SynapsePipeline::detect_block`]),
/// so retries are clean too. Only a hard kill in the narrow window
/// after a block's writes but before its journal frame re-runs that
/// one block on resume — the same double-report property the paper's
/// parallel instances have at block boundaries (§2).
pub struct SynapseDetectJob {
    pipeline: Arc<SynapsePipeline>,
    res: u32,
    region: Box3,
}

impl SynapseDetectJob {
    pub fn new(pipeline: Arc<SynapsePipeline>, res: u32, region: Box3) -> SynapseDetectJob {
        SynapseDetectJob { pipeline, res, region }
    }
}

impl JobSpec for SynapseDetectJob {
    fn name(&self) -> String {
        format!("synapse/{}", self.pipeline.annotations.project.token)
    }

    fn project(&self) -> Option<String> {
        Some(self.pipeline.annotations.project.token.clone())
    }

    fn plan(&self) -> Result<Vec<JobBlock>> {
        Ok(self
            .pipeline
            .core_blocks(self.res, self.region)?
            .into_iter()
            .enumerate()
            .map(|(i, bx)| JobBlock {
                index: i as u64,
                res: self.res,
                bx,
                shard: shard_of(&self.pipeline.image, self.res, &bx),
                phase: 0,
            })
            .collect())
    }

    fn run_block(&self, block: &JobBlock) -> Result<u64> {
        Ok(self.pipeline.detect_block(block.res, block.bx)?.len() as u64)
    }
}

// ----------------------------------------------------------------------
// Bulk ingest
// ----------------------------------------------------------------------

/// Chunked synthetic-EM ingest as a job (§4.1's instrument-streaming
/// path). The volume is generated deterministically from the spec on
/// first use — on a worker thread, not the submitting request — so a
/// resumed job regenerates byte-identical source data and re-ingests
/// only the blocks missing from the journal.
///
/// Blocks are cuboid-aligned, so every block write takes the write
/// engine's fast path: fully covered cuboids **elide** their
/// existing-cuboid read ([`crate::cutout::WriteMetrics::elided_reads`])
/// and the job's storage traffic is pure write I/O.
pub struct BulkIngestJob {
    svc: Arc<CutoutService>,
    spec: SynthSpec,
    block: Vec3,
    vol: OnceLock<SynthVolume>,
}

impl BulkIngestJob {
    /// `spec.dims` is clamped to the project's level-0 bounds (the
    /// generated volume must not outsize what the dataset can hold),
    /// and `block` is rounded up to the level-0 cuboid grid: parallel
    /// blocks must never share a cuboid, or their read-modify-writes
    /// would race.
    pub fn new(svc: Arc<CutoutService>, mut spec: SynthSpec, block: Vec3) -> BulkIngestJob {
        if let Ok(level) = svc.store().dataset.level(0) {
            spec.dims = [
                spec.dims[0].min(level.dims[0]).max(1),
                spec.dims[1].min(level.dims[1]).max(1),
                spec.dims[2].min(level.dims[2]).max(1),
            ];
        }
        let cshape = svc.store().cuboid_shape(0).unwrap_or(block);
        let block = [
            block[0].max(1).div_ceil(cshape[0]) * cshape[0],
            block[1].max(1).div_ceil(cshape[1]) * cshape[1],
            block[2].max(1).div_ceil(cshape[2]) * cshape[2],
        ];
        BulkIngestJob { svc, spec, block, vol: OnceLock::new() }
    }

    /// The generated source volume (plus ground-truth centroids).
    pub fn volume(&self) -> &SynthVolume {
        self.vol.get_or_init(|| generate(&self.spec))
    }
}

impl JobSpec for BulkIngestJob {
    fn name(&self) -> String {
        format!("ingest/{}", self.svc.store().project.token)
    }

    fn project(&self) -> Option<String> {
        Some(self.svc.store().project.token.clone())
    }

    fn plan(&self) -> Result<Vec<JobBlock>> {
        // `new()` already clamped the spec dims to the level-0 bounds.
        Ok(block_boxes(self.spec.dims, self.block)
            .into_iter()
            .enumerate()
            .map(|(i, bx)| JobBlock {
                index: i as u64,
                res: 0,
                bx,
                shard: shard_of(&self.svc, 0, &bx),
                phase: 0,
            })
            .collect())
    }

    fn run_block(&self, block: &JobBlock) -> Result<u64> {
        let sub = self.volume().vol.extract_box(block.bx);
        let bytes = sub.len() as u64;
        self.svc.write(0, 0, 0, block.bx, &sub)?;
        Ok(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunkstore::CuboidStore;
    use crate::core::{DatasetBuilder, Project};
    use crate::jobs::{JobConfig, JobManager};
    use crate::storage::MemStore;

    fn image_service(dims: Vec3, levels: u32) -> Arc<CutoutService> {
        let ds = Arc::new(DatasetBuilder::new("t", dims).levels(levels).build());
        let pr = Arc::new(Project::image("img", "t"));
        Arc::new(CutoutService::new(Arc::new(CuboidStore::new(
            ds,
            pr,
            Arc::new(MemStore::new()),
        ))))
    }

    #[test]
    fn propagate_bands_tile_the_hierarchy_and_align_every_level() {
        let svc = image_service([4096, 4096, 256], 8);
        let job = PropagateJob::image(Arc::clone(&svc));
        let ds = &svc.store().dataset;
        // Bands chain: first reads the base, each next reads the
        // previous band's top, the last writes the deepest level.
        assert!(job.bands.len() >= 2, "8 levels must span multiple bands");
        assert_eq!(job.bands[0].src, 0);
        assert_eq!(job.bands.last().unwrap().top, 7);
        for w in job.bands.windows(2) {
            assert_eq!(w[0].top, w[1].src);
        }
        for band in &job.bands {
            assert!(band.top - band.src <= BAND_LEVELS, "band too deep");
            for l in band.src..=band.top {
                let cub = ds.level(l).unwrap().cuboid;
                let shift = l - band.src;
                assert_eq!(band.block[0] % (cub[0] << shift), 0, "x misaligned, level {l}");
                assert_eq!(band.block[1] % (cub[1] << shift), 0, "y misaligned, level {l}");
                assert_eq!(band.block[2] % cub[2], 0, "z misaligned, level {l}");
            }
        }
        // Deterministic plan, stable indices, phases ascending.
        let a = job.plan().unwrap();
        let b = job.plan().unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.bx, y.bx);
            assert_eq!(x.phase, y.phase);
        }
        for w in a.windows(2) {
            assert!(w[0].phase <= w[1].phase, "plan must list phases in order");
        }
        assert_eq!(a.last().unwrap().phase as usize, job.bands.len() - 1);
    }

    #[test]
    fn single_level_propagate_plans_nothing() {
        let svc = image_service([128, 128, 16], 1);
        let job = PropagateJob::image(svc);
        assert!(job.plan().unwrap().is_empty());
    }

    #[test]
    fn bulk_ingest_job_roundtrips_the_volume() {
        let dims = [128u64, 128, 32];
        let svc = image_service(dims, 1);
        let spec = SynthSpec::small(dims, 11);
        let job = Arc::new(BulkIngestJob::new(Arc::clone(&svc), spec.clone(), [64, 64, 16]));
        let m = JobManager::new(Arc::new(MemStore::new()));
        let h = m.submit(Arc::clone(&job) as Arc<dyn JobSpec>, JobConfig::with_workers(3)).unwrap();
        assert_eq!(h.wait(), crate::jobs::JobState::Completed);
        let st = h.status();
        assert_eq!(st.items, dims[0] * dims[1] * dims[2], "every byte ingested");
        let truth = generate(&spec);
        let back = svc
            .read::<u8>(0, 0, 0, Box3::new([0, 0, 0], dims))
            .unwrap();
        assert_eq!(back, truth.vol);
    }

    #[test]
    fn bulk_ingest_job_never_reads_existing_cuboids() {
        // The write engine's RMW elision: cuboid-aligned ingest blocks
        // are fully covered overwrites, so the whole job performs zero
        // existing-cuboid reads — ingest bandwidth is pure write I/O.
        let dims = [256u64, 256, 32];
        let svc = image_service(dims, 1);
        let job = Arc::new(BulkIngestJob::new(
            Arc::clone(&svc),
            SynthSpec::small(dims, 3),
            [128, 128, 16],
        ));
        let m = JobManager::new(Arc::new(MemStore::new()));
        let h = m
            .submit(Arc::clone(&job) as Arc<dyn JobSpec>, JobConfig::with_workers(4))
            .unwrap();
        assert_eq!(h.wait(), crate::jobs::JobState::Completed);
        assert_eq!(svc.write_metrics.rmw_reads.get(), 0, "aligned ingest must not read");
        assert!(svc.write_metrics.elided_reads.get() >= 8);
        let s = svc.store().engine().stats().snapshot();
        assert_eq!(s.reads + s.run_reads + s.misses, 0, "engine saw read traffic");
    }
}
