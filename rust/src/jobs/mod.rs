//! The batch compute engine: checkpointed, parallel background jobs over
//! the cluster — the layer the paper's workloads actually ran on.
//!
//! §2's synapse workload ran "20 parallel instances ... in less than 3
//! days"; §3.1 builds annotation hierarchies "as a background, batch I/O
//! job". The seed executed both as one-shot synchronous calls on the
//! caller's thread. This subsystem turns them into first-class *jobs*:
//!
//! * **Blocks** — a [`JobSpec`] partitions its work into haloed,
//!   cuboid-aligned blocks ([`JobBlock`]), each independently executable
//!   and idempotent (or guarded by the journal, below).
//! * **Shard affinity** — blocks carry the node owning their first
//!   cuboid (via the engine's [`crate::shard::ShardMap`]); the scheduler
//!   keeps one queue per node and workers prefer "their" queue, so a
//!   worker's cutouts stay node-local, stealing only when idle.
//! * **Phases** — blocks carry a phase number; phases execute in
//!   ascending order with a barrier between them, so a later phase may
//!   consume earlier phases' output ([`PropagateJob`]'s banded pyramid
//!   reads the level the previous band built).
//! * **Checkpoint journal** — every completed block appends one
//!   CRC32-framed record (reusing [`crate::wal::record`]'s framing) to a
//!   per-job chunk table. A killed job resumes from the journal: intact
//!   frames name the blocks already done, torn tails drop cleanly, and
//!   the resumed run re-executes only the remainder — block outputs are
//!   deterministic, so the final volumes are identical to an
//!   uninterrupted run.
//! * **Jobs as objects** — [`JobManager`] registers every job under a
//!   numeric id with live [`JobStatus`] (state, progress, throughput,
//!   latency percentiles, retries), surfaced at `POST /jobs/{type}`,
//!   `GET /jobs/status/`, `POST /jobs/cancel/{id}` and `ocpd jobs`.
//!
//! The three shipped specs ([`specs`]) are the paper's workloads:
//! [`PropagateJob`] (resolution-hierarchy builds, reusing each level as
//! the next level's input), [`SynapseDetectJob`] (the §2 vision
//! pipeline, per-block), and [`BulkIngestJob`] (chunked synthetic-EM
//! ingest).

pub mod specs;

pub use specs::{BulkIngestJob, PropagateJob, SynapseDetectJob};

use std::collections::{BTreeMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

use crate::core::Box3;
use crate::metrics::{Counter, Gauge, Histogram};
use crate::shard::NodeId;
use crate::storage::Engine;
use crate::wal::record::{decode_chunk, WalRecord};
use crate::{Error, Result};

/// Hard ceiling on worker threads per job (requests may ask for fewer;
/// a hostile or typo'd `workers=100000` must not exhaust the host).
pub const MAX_WORKERS: usize = 64;

/// One schedulable unit of a job: a spatial block at a resolution.
#[derive(Clone, Debug)]
pub struct JobBlock {
    /// Stable index within the job's plan — the checkpoint journal keys
    /// completions by it, so [`JobSpec::plan`] must be deterministic.
    pub index: u64,
    /// Resolution level the block addresses.
    pub res: u32,
    /// The block's voxel box (already clipped to the volume).
    pub bx: Box3,
    /// Node owning the block's first cuboid — the scheduler's affinity
    /// hint. `None` when the backing engine is unsharded.
    pub shard: Option<NodeId>,
    /// Execution phase. Phases run in ascending order with a barrier
    /// between them: a block may read data written by any earlier
    /// phase ([`PropagateJob`]'s banded pyramid), never its own.
    pub phase: u32,
}

/// A batch workload: a deterministic block plan plus a per-block body.
///
/// `run_block` executions may be repeated after a crash (the in-flight
/// block at kill time is not journaled), so bodies should be idempotent
/// — all three shipped specs write voxel data, which overwrites to the
/// same values on re-execution.
pub trait JobSpec: Send + Sync {
    /// Human-readable job name, e.g. `propagate/synapses_v0`.
    fn name(&self) -> String;

    /// Project token this job's work is billed to (tenant accounting,
    /// DESIGN.md §11). `None` — the default — leaves the job unbilled.
    fn project(&self) -> Option<String> {
        None
    }

    /// The full block list. Must be identical across calls (and across
    /// process restarts) for checkpoint resume to be sound.
    fn plan(&self) -> Result<Vec<JobBlock>>;

    /// Execute one block; returns an item count for the status surface
    /// (cuboids written, synapses detected, bytes ingested).
    fn run_block(&self, block: &JobBlock) -> Result<u64>;
}

/// Scheduling knobs for one job run.
#[derive(Clone, Copy, Debug)]
pub struct JobConfig {
    /// Worker threads draining the block queues.
    pub workers: usize,
    /// Per-block retry budget before the job fails.
    pub retries: u32,
    /// Stop (as if killed) after this many block completions in this
    /// run, leaving the journal in place — the crash-injection hook the
    /// resume tests use. `None` runs to completion.
    pub max_blocks: Option<u64>,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig { workers: 4, retries: 2, max_blocks: None }
    }
}

impl JobConfig {
    /// `workers` workers, defaults elsewhere.
    pub fn with_workers(n: usize) -> Self {
        JobConfig { workers: n.max(1), ..JobConfig::default() }
    }
}

/// Lifecycle of a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, runner not yet scheduled.
    Queued,
    /// Workers are executing blocks.
    Running,
    /// Every block in the plan is journaled.
    Completed,
    /// A block exhausted its retries (or the journal broke); see
    /// [`JobStatus::error`].
    Failed,
    /// Cancelled (or stopped by [`JobConfig::max_blocks`]); the journal
    /// survives, so resubmitting the job id resumes it.
    Cancelled,
}

impl JobState {
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "completed",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Terminal states never transition again.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Completed | JobState::Failed | JobState::Cancelled)
    }
}

/// Per-job counters surfaced through `/jobs/status` and `ocpd jobs`.
#[derive(Debug, Default)]
pub struct JobMetrics {
    /// Fresh-block throughput this run, in milli-blocks per second (a
    /// [`Gauge`] holds integers; divide by 1000).
    pub blocks_per_sec_milli: Gauge,
    /// Wall latency per completed block.
    pub block_latency: Histogram,
    /// Block attempts retried after an error.
    pub retries: Counter,
}

/// Point-in-time summary of one job.
#[derive(Clone, Debug)]
pub struct JobStatus {
    pub id: u64,
    pub name: String,
    pub state: JobState,
    pub total_blocks: u64,
    /// Journaled blocks, including ones recovered from a prior run.
    pub completed_blocks: u64,
    /// Blocks already journaled when this run started.
    pub resumed_blocks: u64,
    /// Sum of per-block item counts (spec-defined units).
    pub items: u64,
    pub retries: u64,
    /// Fresh blocks per second over this run's wall clock.
    pub blocks_per_sec: f64,
    pub mean_block_ms: f64,
    pub p95_block_ms: f64,
    pub wall_secs: f64,
    pub error: Option<String>,
}

impl JobStatus {
    /// One status line (the `/jobs/status` and CLI rendering).
    pub fn line(&self) -> String {
        let mut s = format!(
            "{} {}: state={} blocks={}/{} resumed={} items={} retries={} \
             blocks_per_sec={:.1} mean_block_ms={:.1} p95_block_ms={:.1} wall={:.2}s",
            self.id,
            self.name,
            self.state.as_str(),
            self.completed_blocks,
            self.total_blocks,
            self.resumed_blocks,
            self.items,
            self.retries,
            self.blocks_per_sec,
            self.mean_block_ms,
            self.p95_block_ms,
            self.wall_secs
        );
        if let Some(e) = &self.error {
            s.push_str(&format!(" error={e}"));
        }
        s
    }
}

struct StateCell {
    state: JobState,
    error: Option<String>,
    /// Wall clock frozen at the terminal transition.
    wall_secs: Option<f64>,
}

/// A submitted job: shared handle for status, cancel, and wait.
pub struct JobHandle {
    pub id: u64,
    name: String,
    /// Released (set to `None`) at the terminal transition so finished
    /// jobs don't pin spec-held memory — e.g. [`BulkIngestJob`]'s
    /// generated source volume — for the life of the registry.
    spec: Mutex<Option<Arc<dyn JobSpec>>>,
    cfg: JobConfig,
    /// Engine holding the checkpoint journal chunk table.
    journal: Engine,
    cancel: AtomicBool,
    state: Mutex<StateCell>,
    state_cv: Condvar,
    total: AtomicU64,
    completed: AtomicU64,
    resumed: AtomicU64,
    items: AtomicU64,
    started: Instant,
    pub metrics: JobMetrics,
    /// Tenant ledger the workers bill block time to (resolved from the
    /// manager's accountant and the spec's project at submit).
    ledger: Option<Arc<crate::obs::account::Ledger>>,
    /// Project token the job runs against — the QoS tenant its block
    /// workers schedule under.
    tenant: Option<Arc<str>>,
    /// The cluster's QoS enforcer (set by the cluster on the manager):
    /// workers install a bulk-class context, yield to in-flight
    /// interactive work at block boundaries, and take job-gate slots.
    qos: Option<Arc<crate::qos::QosEnforcer>>,
}

impl JobHandle {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Storage table holding this job's checkpoint journal. `jobs` is a
    /// reserved token, so the prefix can never collide with a project.
    fn journal_table(&self) -> String {
        format!("jobs/{}/journal", self.id)
    }

    /// Request cancellation: workers stop after their current block.
    /// The journal survives, so the job id can be resubmitted to resume.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// True once [`JobHandle::cancel`] has been requested (the job may
    /// still be winding down its in-flight blocks).
    pub fn cancel_requested(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    pub fn state(&self) -> JobState {
        self.state.lock().unwrap().state
    }

    /// Block until the job reaches a terminal state.
    pub fn wait(&self) -> JobState {
        let mut st = self.state.lock().unwrap();
        while !st.state.is_terminal() {
            st = self.state_cv.wait(st).unwrap();
        }
        st.state
    }

    /// Like [`JobHandle::wait`], but gives up after `dur` and returns
    /// whatever state the job is in then.
    pub fn wait_terminal_for(&self, dur: std::time::Duration) -> JobState {
        let deadline = Instant::now() + dur;
        let mut st = self.state.lock().unwrap();
        while !st.state.is_terminal() {
            let now = Instant::now();
            if now >= deadline {
                return st.state;
            }
            let (guard, _) = self.state_cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        st.state
    }

    fn set_state(&self, state: JobState, error: Option<String>) {
        let mut st = self.state.lock().unwrap();
        st.state = state;
        if error.is_some() {
            st.error = error;
        }
        if state.is_terminal() && st.wall_secs.is_none() {
            st.wall_secs = Some(self.started.elapsed().as_secs_f64());
        }
        drop(st);
        self.state_cv.notify_all();
    }

    pub fn status(&self) -> JobStatus {
        let (state, error, wall) = {
            let st = self.state.lock().unwrap();
            (st.state, st.error.clone(), st.wall_secs)
        };
        let wall = wall.unwrap_or_else(|| self.started.elapsed().as_secs_f64());
        let completed = self.completed.load(Ordering::Relaxed);
        let resumed = self.resumed.load(Ordering::Relaxed);
        JobStatus {
            id: self.id,
            name: self.name.clone(),
            state,
            total_blocks: self.total.load(Ordering::Relaxed),
            completed_blocks: completed,
            resumed_blocks: resumed,
            items: self.items.load(Ordering::Relaxed),
            retries: self.metrics.retries.get(),
            blocks_per_sec: completed.saturating_sub(resumed) as f64 / wall.max(1e-9),
            mean_block_ms: self.metrics.block_latency.mean_us() / 1e3,
            p95_block_ms: self.metrics.block_latency.percentile_us(95.0) as f64 / 1e3,
            wall_secs: wall,
            error,
        }
    }
}

/// Pop the next block index, preferring the worker's own shard queue and
/// stealing from the others only when it is empty.
fn claim(queues: &Mutex<Vec<VecDeque<usize>>>, worker: usize) -> Option<usize> {
    let mut qs = queues.lock().unwrap();
    let n = qs.len();
    for i in 0..n {
        let qi = (worker + i) % n;
        if let Some(b) = qs[qi].pop_front() {
            return Some(b);
        }
    }
    None
}

/// The job body: plan, recover the journal, drain the block queues
/// phase by phase.
fn run_job(handle: &JobHandle) -> (JobState, Option<String>) {
    // Jobs run on detached threads with no live HTTP parent, so each
    // run is its own trace — request id `job-<id>`, one child span per
    // block.
    let mut root =
        crate::obs::trace::start_trace("job", handle.name.clone(), &format!("job-{}", handle.id));
    root.tag("job", handle.id.to_string());
    let Some(spec) = handle.spec.lock().unwrap().clone() else {
        return (JobState::Failed, Some("job spec already released".into()));
    };
    let plan = match spec.plan() {
        Ok(p) => p,
        Err(e) => return (JobState::Failed, Some(format!("plan failed: {e}"))),
    };
    handle.total.store(plan.len() as u64, Ordering::Relaxed);
    let table = handle.journal_table();

    // Recover: every intact frame names a completed block (its value
    // carries that block's item count); torn tails (a crash mid-append)
    // decode to their valid prefix and the block simply re-runs.
    let mut done: HashSet<u64> = HashSet::new();
    let mut resumed_items = 0u64;
    let mut next_seq = 0u64;
    let keys = match handle.journal.keys(&table) {
        Ok(k) => k,
        Err(e) => return (JobState::Failed, Some(format!("journal read failed: {e}"))),
    };
    for k in keys {
        next_seq = next_seq.max(k + 1);
        match handle.journal.get(&table, k) {
            Ok(Some(blob)) => {
                for r in decode_chunk(&blob).records {
                    if done.insert(r.key) {
                        if let Some(v) = &r.value {
                            if let Ok(b) = <[u8; 8]>::try_from(v.as_slice()) {
                                resumed_items += u64::from_le_bytes(b);
                            }
                        }
                    }
                }
            }
            Ok(None) => {}
            Err(e) => return (JobState::Failed, Some(format!("journal read failed: {e}"))),
        }
    }
    handle.items.store(resumed_items, Ordering::Relaxed);

    let pending: Vec<usize> = plan
        .iter()
        .enumerate()
        .filter(|(_, b)| !done.contains(&b.index))
        .map(|(i, _)| i)
        .collect();
    let resumed = (plan.len() - pending.len()) as u64;
    handle.resumed.store(resumed, Ordering::Relaxed);
    handle.completed.store(resumed, Ordering::Relaxed);
    if pending.is_empty() {
        return (JobState::Completed, None);
    }

    // Group pending blocks by phase; phases run in ascending order with
    // a barrier between them — a later phase may read what earlier
    // phases wrote (the banded propagation pyramid relies on this).
    let mut phases: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
    for i in pending {
        phases.entry(plan[i].phase).or_default().push(i);
    }
    let seq = AtomicU64::new(next_seq);
    let fresh = AtomicU64::new(0);
    let error: Mutex<Option<String>> = Mutex::new(None);

    for items in phases.into_values() {
        if handle.cancel.load(Ordering::Relaxed) || error.lock().unwrap().is_some() {
            break;
        }
        // One queue per shard (unsharded blocks share one); workers map
        // onto queues round-robin and steal when theirs runs dry.
        let mut by_shard: BTreeMap<u64, VecDeque<usize>> = BTreeMap::new();
        for i in items {
            let key = plan[i].shard.map(|n| n as u64).unwrap_or(u64::MAX);
            by_shard.entry(key).or_default().push_back(i);
        }
        let n_phase: usize = by_shard.values().map(|q| q.len()).sum();
        let queues = Mutex::new(by_shard.into_values().collect::<Vec<_>>());
        let workers = handle.cfg.workers.max(1).min(n_phase).min(MAX_WORKERS);

        let trace_ctx = crate::obs::trace::current();
        std::thread::scope(|s| {
            for w in 0..workers {
                let queues = &queues;
                let seq = &seq;
                let fresh = &fresh;
                let error = &error;
                let plan = &plan;
                let table = &table;
                let spec = &spec;
                let trace_ctx = trace_ctx.clone();
                s.spawn(move || {
                    let _trace = crate::obs::trace::install(trace_ctx);
                    // Workers run as bulk-class work attributed to the
                    // job's project: engine calls made inside a block
                    // queue behind interactive requests in the fair
                    // gates instead of competing head-to-head.
                    let _qos_ctx = crate::qos::ctx::install(
                        handle
                            .qos
                            .as_ref()
                            .map(|_| crate::qos::ctx::ReqCtx::bulk(handle.tenant.clone())),
                    );
                    loop {
                        if handle.cancel.load(Ordering::Relaxed) {
                            break;
                        }
                        let Some(bi) = claim(queues, w) else { break };
                        // Block boundary: cheap preemption (jobs
                        // checkpoint per block, so pausing here costs
                        // only the wait) and a fair job-gate slot held
                        // for the block's whole attempt loop.
                        let _slot = handle.qos.as_ref().map(|q| {
                            q.yield_to_interactive();
                            q.enter(crate::qos::Pool::Job)
                        });
                        let block = &plan[bi];
                        let mut sp =
                            crate::obs::trace::span("job", format!("block {}", block.index));
                        sp.tag("phase", block.phase.to_string());
                        if let Some(shard) = block.shard {
                            sp.tag("shard", shard.to_string());
                        }
                        let t0 = Instant::now();
                        let mut attempt = 0u32;
                        let outcome = loop {
                            match spec.run_block(block) {
                                Ok(n) => break Some(Ok(n)),
                                Err(e) => {
                                    // A cancel (user, budget stop, or another
                                    // worker's failure) arriving mid-retry is a
                                    // cancellation, not this block's failure.
                                    if handle.cancel.load(Ordering::Relaxed) {
                                        break None;
                                    }
                                    if attempt >= handle.cfg.retries {
                                        break Some(Err(e));
                                    }
                                    attempt += 1;
                                    handle.metrics.retries.inc();
                                }
                            }
                        };
                        let Some(outcome) = outcome else { break };
                        match outcome {
                            Ok(items) => {
                                // Checkpoint the completion as one CRC32 frame;
                                // the sync makes it crash-durable before the
                                // block counts as done.
                                let seq_key = seq.fetch_add(1, Ordering::Relaxed);
                                let rec = WalRecord {
                                    lsn: seq_key,
                                    table: handle.name.clone(),
                                    key: block.index,
                                    value: Some(items.to_le_bytes().to_vec()),
                                };
                                let mut frame = Vec::with_capacity(64);
                                rec.encode_into(&mut frame);
                                let put = handle
                                    .journal
                                    .put(table, seq_key, &frame)
                                    .and_then(|()| handle.journal.sync());
                                if let Err(e) = put {
                                    let mut g = error.lock().unwrap();
                                    if g.is_none() {
                                        *g = Some(format!("journal write failed: {e}"));
                                    }
                                    handle.cancel.store(true, Ordering::Relaxed);
                                    break;
                                }
                                handle.metrics.block_latency.record(t0.elapsed());
                                if let Some(ledger) = &handle.ledger {
                                    ledger.add_job_worker_us(t0.elapsed().as_micros() as u64);
                                }
                                handle.items.fetch_add(items, Ordering::Relaxed);
                                let done_total =
                                    handle.completed.fetch_add(1, Ordering::Relaxed) + 1;
                                let secs = handle.started.elapsed().as_secs_f64().max(1e-9);
                                let rate = done_total.saturating_sub(
                                    handle.resumed.load(Ordering::Relaxed),
                                ) as f64
                                    / secs;
                                handle.metrics.blocks_per_sec_milli.set((rate * 1e3) as u64);
                                let n = fresh.fetch_add(1, Ordering::Relaxed) + 1;
                                if let Some(budget) = handle.cfg.max_blocks {
                                    if n >= budget {
                                        handle.cancel.store(true, Ordering::Relaxed);
                                    }
                                }
                            }
                            Err(e) => {
                                let mut g = error.lock().unwrap();
                                if g.is_none() {
                                    *g = Some(format!(
                                        "block {} failed after {} attempts: {e}",
                                        block.index,
                                        attempt + 1
                                    ));
                                }
                                handle.cancel.store(true, Ordering::Relaxed);
                                break;
                            }
                        }
                    }
                });
            }
        });
    }

    let error = error.into_inner().unwrap();
    if let Some(e) = error {
        return (JobState::Failed, Some(e));
    }
    if handle.completed.load(Ordering::Relaxed) >= plan.len() as u64 {
        (JobState::Completed, None)
    } else {
        (JobState::Cancelled, None)
    }
}

/// The job registry: submits, tracks, cancels.
///
/// Checkpoint journals live in chunk tables `jobs/{id}/journal` on the
/// `journal` engine (the cluster passes its first database node), so a
/// persistent cluster's journals survive process restarts and
/// resubmitting a job id resumes it.
pub struct JobManager {
    journal: Engine,
    jobs: RwLock<BTreeMap<u64, Arc<JobHandle>>>,
    next_id: AtomicU64,
    /// Tenant accountant (set by the cluster): jobs whose spec names a
    /// project bill their block time to that project's ledger.
    accountant: RwLock<Option<Arc<crate::obs::account::Accountant>>>,
    /// QoS enforcer (set by the cluster): jobs submitted afterwards
    /// schedule their blocks under it.
    qos: RwLock<Option<Arc<crate::qos::QosEnforcer>>>,
}

impl JobManager {
    /// A manager journaling onto `journal`. Existing journal tables
    /// advance the id allocator so resumable ids are never reissued.
    pub fn new(journal: Engine) -> JobManager {
        let mut next = 1u64;
        if let Ok(tables) = journal.tables() {
            for t in tables {
                if let Some(rest) = t.strip_prefix("jobs/") {
                    if let Some((id, _)) = rest.split_once('/') {
                        if let Ok(id) = id.parse::<u64>() {
                            next = next.max(id + 1);
                        }
                    }
                }
            }
        }
        JobManager {
            journal,
            jobs: RwLock::new(BTreeMap::new()),
            next_id: AtomicU64::new(next),
            accountant: RwLock::new(None),
            qos: RwLock::new(None),
        }
    }

    /// Point job billing at the cluster's tenant accountant. Jobs
    /// submitted afterwards bill block time per their spec's
    /// [`JobSpec::project`].
    pub fn set_accountant(&self, accountant: Arc<crate::obs::account::Accountant>) {
        *self.accountant.write().unwrap() = Some(accountant);
    }

    /// Point job scheduling at the cluster's QoS enforcer. Jobs
    /// submitted afterwards run their blocks as bulk-class work: they
    /// yield to in-flight interactive requests at block boundaries and
    /// take weighted fair job-gate slots per block.
    pub fn set_qos(&self, qos: Arc<crate::qos::QosEnforcer>) {
        *self.qos.write().unwrap() = Some(qos);
    }

    /// Engine holding the checkpoint journals.
    pub fn journal_engine(&self) -> &Engine {
        &self.journal
    }

    /// Every submitted job's handle, in id order (the metrics
    /// registry's jobs collector reads counters straight off these).
    pub fn handles(&self) -> Vec<Arc<JobHandle>> {
        self.jobs.read().unwrap().values().cloned().collect()
    }

    /// Submit a job under a fresh id.
    pub fn submit(&self, spec: Arc<dyn JobSpec>, cfg: JobConfig) -> Result<Arc<JobHandle>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.launch(id, spec, cfg)
    }

    /// Submit under an explicit id — the resume path: a journal left by
    /// a killed or cancelled run of the same job picks up where it
    /// stopped. Rejected while that id is still running.
    pub fn submit_with_id(
        &self,
        id: u64,
        spec: Arc<dyn JobSpec>,
        cfg: JobConfig,
    ) -> Result<Arc<JobHandle>> {
        self.next_id.fetch_max(id + 1, Ordering::Relaxed);
        self.launch(id, spec, cfg)
    }

    fn launch(&self, id: u64, spec: Arc<dyn JobSpec>, cfg: JobConfig) -> Result<Arc<JobHandle>> {
        // Cancellation is asynchronous (workers finish their current
        // block first), so a cancel-then-resume sequence would race the
        // wind-down. Outside the registry lock, give an already-
        // cancelled job a bounded grace period to reach terminal.
        if let Some(existing) = self.get(id) {
            if !existing.state().is_terminal() && existing.cancel_requested() {
                existing.wait_terminal_for(std::time::Duration::from_secs(2));
            }
        }
        // Hold the registry lock across check-and-insert so concurrent
        // submits of one id cannot both pass the liveness check.
        let mut jobs = self.jobs.write().unwrap();
        if let Some(existing) = jobs.get(&id) {
            if !existing.state().is_terminal() {
                return Err(Error::BadRequest(format!(
                    "job {id} is still {} (cancellation finishes in-flight blocks; \
                     poll /jobs/status and resubmit once it reports a terminal state)",
                    existing.state().as_str()
                )));
            }
        }
        let name = spec.name();
        let project = spec.project();
        let ledger = self
            .accountant
            .read()
            .unwrap()
            .as_ref()
            .and_then(|a| project.as_ref().map(|p| a.ledger(p)));
        let handle = Arc::new(JobHandle {
            id,
            name,
            spec: Mutex::new(Some(spec)),
            cfg,
            journal: Arc::clone(&self.journal),
            cancel: AtomicBool::new(false),
            state: Mutex::new(StateCell { state: JobState::Queued, error: None, wall_secs: None }),
            state_cv: Condvar::new(),
            total: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            resumed: AtomicU64::new(0),
            items: AtomicU64::new(0),
            started: Instant::now(),
            metrics: JobMetrics::default(),
            ledger,
            tenant: project.map(Arc::from),
            qos: self.qos.read().unwrap().clone(),
        });
        let runner = Arc::clone(&handle);
        std::thread::Builder::new()
            .name(format!("ocpd-job-{id}"))
            .spawn(move || {
                runner.set_state(JobState::Running, None);
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_job(&runner)
                }));
                let (state, error) = out
                    .unwrap_or_else(|_| (JobState::Failed, Some("job runner panicked".into())));
                runner.set_state(state, error);
                // Release the spec: the registry keeps the handle (for
                // status history), not the workload's memory.
                *runner.spec.lock().unwrap() = None;
            })
            .map_err(|e| Error::Other(format!("spawn job runner: {e}")))?;
        jobs.insert(id, Arc::clone(&handle));
        Ok(handle)
    }

    pub fn get(&self, id: u64) -> Option<Arc<JobHandle>> {
        self.jobs.read().unwrap().get(&id).cloned()
    }

    /// Cancel a job (workers stop after their current block).
    pub fn cancel(&self, id: u64) -> Result<()> {
        match self.get(id) {
            Some(h) => {
                h.cancel();
                Ok(())
            }
            None => Err(Error::NotFound(format!("job {id}"))),
        }
    }

    /// Status of every registered job, ascending by id.
    pub fn statuses(&self) -> Vec<JobStatus> {
        self.jobs.read().unwrap().values().map(|h| h.status()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use std::time::Duration;

    /// A toy spec: `n` unit blocks striped across two fake shards, each
    /// bumping a shared counter exactly once per execution.
    struct CountJob {
        n: u64,
        fail_at: Option<u64>,
        sleep: Duration,
        counter: Arc<AtomicU64>,
    }

    impl CountJob {
        fn new(n: u64) -> CountJob {
            CountJob {
                n,
                fail_at: None,
                sleep: Duration::ZERO,
                counter: Arc::new(AtomicU64::new(0)),
            }
        }
    }

    impl JobSpec for CountJob {
        fn name(&self) -> String {
            "count".into()
        }

        fn plan(&self) -> Result<Vec<JobBlock>> {
            Ok((0..self.n)
                .map(|i| JobBlock {
                    index: i,
                    res: 0,
                    bx: Box3::new([0, 0, 0], [1, 1, 1]),
                    shard: Some((i % 2) as NodeId),
                    phase: 0,
                })
                .collect())
        }

        fn run_block(&self, block: &JobBlock) -> Result<u64> {
            if self.fail_at == Some(block.index) {
                return Err(Error::Other(format!("injected failure at {}", block.index)));
            }
            if !self.sleep.is_zero() {
                std::thread::sleep(self.sleep);
            }
            self.counter.fetch_add(1, Ordering::Relaxed);
            Ok(1)
        }
    }

    fn manager() -> JobManager {
        JobManager::new(Arc::new(MemStore::new()))
    }

    #[test]
    fn job_completes_and_reports() {
        let m = manager();
        let spec = Arc::new(CountJob::new(16));
        let counter = Arc::clone(&spec.counter);
        let h = m.submit(spec, JobConfig::default()).unwrap();
        assert_eq!(h.wait(), JobState::Completed);
        assert_eq!(counter.load(Ordering::Relaxed), 16);
        let st = h.status();
        assert_eq!(st.state, JobState::Completed);
        assert_eq!(st.total_blocks, 16);
        assert_eq!(st.completed_blocks, 16);
        assert_eq!(st.resumed_blocks, 0);
        assert_eq!(st.items, 16);
        assert_eq!(st.retries, 0);
        assert_eq!(h.metrics.block_latency.count(), 16);
        assert!(st.blocks_per_sec > 0.0);
        assert!(st.line().contains("state=completed"));
        // Registry sees it too.
        assert_eq!(m.statuses().len(), 1);
        assert!(m.get(h.id).is_some());
        assert!(m.get(999).is_none());
        assert!(m.cancel(999).is_err());
    }

    #[test]
    fn empty_plan_is_instantly_complete() {
        let m = manager();
        let h = m.submit(Arc::new(CountJob::new(0)), JobConfig::default()).unwrap();
        assert_eq!(h.wait(), JobState::Completed);
        assert_eq!(h.status().total_blocks, 0);
    }

    #[test]
    fn persistent_failure_fails_job_after_retries() {
        let m = manager();
        let spec = Arc::new(CountJob { fail_at: Some(5), ..CountJob::new(8) });
        let cfg = JobConfig { retries: 2, workers: 2, max_blocks: None };
        let h = m.submit(spec, cfg).unwrap();
        assert_eq!(h.wait(), JobState::Failed);
        let st = h.status();
        assert!(st.error.as_deref().unwrap().contains("block 5"), "{:?}", st.error);
        // Exactly the retry budget was spent on the poisoned block.
        assert_eq!(st.retries, 2);
        assert!(st.completed_blocks < 8);
    }

    #[test]
    fn budget_stops_then_resume_runs_each_block_exactly_once() {
        let m = manager();
        let spec = Arc::new(CountJob::new(24));
        let counter = Arc::clone(&spec.counter);
        // Run 1: stop after ~4 blocks, as if killed.
        let cfg = JobConfig { workers: 2, max_blocks: Some(4), ..JobConfig::default() };
        let h = m.submit(Arc::clone(&spec) as Arc<dyn JobSpec>, cfg).unwrap();
        assert_eq!(h.wait(), JobState::Cancelled);
        let first = h.status().completed_blocks;
        assert!(first >= 4 && first < 24, "completed {first}");

        // Run 2: same id resumes from the journal and finishes the rest.
        let h2 = m.submit_with_id(h.id, spec, JobConfig::default()).unwrap();
        assert_eq!(h2.wait(), JobState::Completed);
        let st = h2.status();
        assert_eq!(st.completed_blocks, 24);
        assert_eq!(st.resumed_blocks, first);
        // Every block executed exactly once across both runs.
        assert_eq!(counter.load(Ordering::Relaxed), 24);
    }

    #[test]
    fn cancel_stops_workers_and_is_resumable() {
        let m = manager();
        let spec = Arc::new(CountJob { sleep: Duration::from_millis(3), ..CountJob::new(64) });
        let h = m
            .submit(Arc::clone(&spec) as Arc<dyn JobSpec>, JobConfig::with_workers(2))
            .unwrap();
        m.cancel(h.id).unwrap();
        let state = h.wait();
        assert!(state == JobState::Cancelled || state == JobState::Completed);
        if state == JobState::Cancelled {
            assert!(h.status().completed_blocks < 64);
            // A live id cannot be double-submitted ... once terminal it can.
            let h2 = m.submit_with_id(h.id, spec, JobConfig::default()).unwrap();
            assert_eq!(h2.wait(), JobState::Completed);
            assert_eq!(h2.status().completed_blocks, 64);
        }
    }

    #[test]
    fn running_id_cannot_be_resubmitted() {
        let m = manager();
        let spec = Arc::new(CountJob { sleep: Duration::from_millis(5), ..CountJob::new(64) });
        let h = m.submit(Arc::clone(&spec) as Arc<dyn JobSpec>, JobConfig::with_workers(1)).unwrap();
        let err = m.submit_with_id(h.id, Arc::clone(&spec) as Arc<dyn JobSpec>, JobConfig::default());
        assert!(err.is_err(), "resubmitting a live id must be rejected");
        h.cancel();
        h.wait();
    }

    #[test]
    fn torn_journal_tail_reruns_only_unjournaled_blocks() {
        let journal: Engine = Arc::new(MemStore::new());
        let m = JobManager::new(Arc::clone(&journal));
        let spec = Arc::new(CountJob::new(6));
        let counter = Arc::clone(&spec.counter);
        // Pre-seed the journal: block 0 intact, block 1's frame torn.
        let table = "jobs/1/journal";
        let mut good = Vec::new();
        WalRecord { lsn: 0, table: "count".into(), key: 0, value: Some(vec![1]) }
            .encode_into(&mut good);
        journal.put(table, 0, &good).unwrap();
        let mut torn = Vec::new();
        WalRecord { lsn: 1, table: "count".into(), key: 1, value: Some(vec![1]) }
            .encode_into(&mut torn);
        torn.truncate(torn.len() - 2);
        journal.put(table, 1, &torn).unwrap();

        let h = m.submit_with_id(1, spec, JobConfig::default()).unwrap();
        assert_eq!(h.wait(), JobState::Completed);
        let st = h.status();
        assert_eq!(st.resumed_blocks, 1, "only the intact frame counts");
        // Blocks 1..6 re-ran; block 0 did not.
        assert_eq!(counter.load(Ordering::Relaxed), 5);
    }

    /// Two-phase spec recording completion order: phase 1 blocks must
    /// never start before every phase 0 block has finished.
    struct PhasedJob {
        order: Arc<Mutex<Vec<u64>>>,
    }

    impl JobSpec for PhasedJob {
        fn name(&self) -> String {
            "phased".into()
        }

        fn plan(&self) -> Result<Vec<JobBlock>> {
            Ok((0..12u64)
                .map(|i| JobBlock {
                    index: i,
                    res: 0,
                    bx: Box3::new([0, 0, 0], [1, 1, 1]),
                    shard: None,
                    phase: (i / 6) as u32,
                })
                .collect())
        }

        fn run_block(&self, block: &JobBlock) -> Result<u64> {
            std::thread::sleep(Duration::from_millis(1));
            self.order.lock().unwrap().push(block.index);
            Ok(1)
        }
    }

    #[test]
    fn phases_form_a_barrier() {
        let m = manager();
        let order = Arc::new(Mutex::new(Vec::new()));
        let spec = Arc::new(PhasedJob { order: Arc::clone(&order) });
        let h = m.submit(spec, JobConfig::with_workers(4)).unwrap();
        assert_eq!(h.wait(), JobState::Completed);
        let order = order.lock().unwrap();
        assert_eq!(order.len(), 12);
        let first_p1 = order.iter().position(|&i| i >= 6).unwrap();
        assert!(
            order[..first_p1].len() == 6 && order[..first_p1].iter().all(|&i| i < 6),
            "phase 1 started before phase 0 completed: {order:?}"
        );
    }

    #[test]
    fn manager_id_allocation_skips_existing_journals() {
        let journal: Engine = Arc::new(MemStore::new());
        journal.put("jobs/7/journal", 0, b"x").unwrap();
        let m = JobManager::new(journal);
        let h = m.submit(Arc::new(CountJob::new(1)), JobConfig::default()).unwrap();
        assert!(h.id > 7, "fresh ids must not collide with persisted journals");
        h.wait();
    }
}
