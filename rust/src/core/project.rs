//! Project configuration: a *project* is a concrete database bound to a
//! dataset — original imagery, cleaned imagery, or one of many annotation
//! databases (one per vision-algorithm parameterization, §3.2/§4.2).

use crate::core::Dtype;

/// What kind of database a project is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectKind {
    /// Image database (8/16-bit grayscale or RGBA).
    Image,
    /// Annotation database (32-bit identifiers + RAMON metadata).
    Annotation,
    /// Probability-map database (f32, written by the vision pipeline).
    Probability,
}

/// How a write treats voxels that already carry a label (§3.2/§4.2
/// "data options ... write discipline").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WriteDiscipline {
    /// Replace prior labels.
    #[default]
    Overwrite,
    /// Keep prior labels; only write into unlabeled voxels.
    Preserve,
    /// Keep the prior label and record the new one in the cuboid's
    /// exception list (requires `exceptions` on the project).
    Exception,
}

impl WriteDiscipline {
    pub fn parse(s: &str) -> Option<WriteDiscipline> {
        match s {
            "overwrite" => Some(WriteDiscipline::Overwrite),
            "preserve" => Some(WriteDiscipline::Preserve),
            "exception" => Some(WriteDiscipline::Exception),
            _ => None,
        }
    }
}

/// A project (one spatial database + optional metadata database) bound to
/// a dataset. `token` is the URL-visible name (Table 1).
#[derive(Clone, Debug)]
pub struct Project {
    pub token: String,
    pub dataset: String,
    pub kind: ProjectKind,
    pub dtype: Dtype,
    /// Support multiple labels per voxel via per-cuboid exception lists
    /// (§3.2). Incurs a small cost on every read even when no exceptions
    /// exist — measured by the ablation bench.
    pub exceptions: bool,
    /// Read-only databases reject writes (public released data).
    pub readonly: bool,
    /// Gzip level for cuboids on disk (0 = store raw).
    pub gzip_level: u32,
    /// Which resolution annotations are initially written at; propagation
    /// to other levels is a background batch job (§3.2).
    pub base_resolution: u32,
}

impl Project {
    /// An EM image project over `dataset`.
    pub fn image(token: &str, dataset: &str) -> Project {
        Project {
            token: token.into(),
            dataset: dataset.into(),
            kind: ProjectKind::Image,
            dtype: Dtype::U8,
            exceptions: false,
            readonly: false,
            gzip_level: 6,
            base_resolution: 0,
        }
    }

    /// An annotation project over `dataset`.
    pub fn annotation(token: &str, dataset: &str) -> Project {
        Project {
            token: token.into(),
            dataset: dataset.into(),
            kind: ProjectKind::Annotation,
            dtype: Dtype::U32,
            exceptions: false,
            readonly: false,
            gzip_level: 6,
            base_resolution: 0,
        }
    }

    /// A probability-map project (vision pipeline output).
    pub fn probability(token: &str, dataset: &str) -> Project {
        Project {
            token: token.into(),
            dataset: dataset.into(),
            kind: ProjectKind::Probability,
            dtype: Dtype::F32,
            exceptions: false,
            readonly: false,
            gzip_level: 1,
            base_resolution: 0,
        }
    }

    pub fn with_exceptions(mut self) -> Project {
        self.exceptions = true;
        self
    }

    pub fn readonly(mut self) -> Project {
        self.readonly = true;
        self
    }

    pub fn with_dtype(mut self, d: Dtype) -> Project {
        self.dtype = d;
        self
    }

    pub fn with_gzip(mut self, level: u32) -> Project {
        self.gzip_level = level;
        self
    }

    pub fn at_resolution(mut self, res: u32) -> Project {
        self.base_resolution = res;
        self
    }

    /// Storage-table name for cuboids at `(resolution, channel)`.
    /// Annotation and image cuboids of a project never share tables.
    pub fn cuboid_table(&self, res: u32, channel: u16) -> String {
        format!("{}/cub/r{res}/c{channel}", self.token)
    }

    /// Storage-table name for per-cuboid exception lists.
    pub fn exceptions_table(&self, res: u32) -> String {
        format!("{}/exc/r{res}", self.token)
    }

    /// Storage-table name for RAMON metadata.
    pub fn ramon_table(&self) -> String {
        format!("{}/ramon", self.token)
    }

    /// Storage-table name for the per-object spatial index at `res`.
    pub fn index_table(&self, res: u32) -> String {
        format!("{}/idx/r{res}", self.token)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let p = Project::image("bock11", "bock11");
        assert_eq!(p.kind, ProjectKind::Image);
        assert_eq!(p.dtype, Dtype::U8);
        let a = Project::annotation("syn_v1", "bock11").with_exceptions();
        assert_eq!(a.kind, ProjectKind::Annotation);
        assert_eq!(a.dtype, Dtype::U32);
        assert!(a.exceptions);
        assert!(!a.readonly);
        assert!(Project::image("x", "y").readonly().readonly);
    }

    #[test]
    fn table_names_distinct() {
        let p = Project::annotation("ann", "ds");
        let t1 = p.cuboid_table(0, 0);
        let t2 = p.cuboid_table(1, 0);
        let t3 = p.cuboid_table(0, 1);
        assert_ne!(t1, t2);
        assert_ne!(t1, t3);
        assert_ne!(p.ramon_table(), p.index_table(0));
    }

    #[test]
    fn discipline_parse() {
        assert_eq!(WriteDiscipline::parse("overwrite"), Some(WriteDiscipline::Overwrite));
        assert_eq!(WriteDiscipline::parse("preserve"), Some(WriteDiscipline::Preserve));
        assert_eq!(WriteDiscipline::parse("exception"), Some(WriteDiscipline::Exception));
        assert_eq!(WriteDiscipline::parse("merge"), None);
    }
}
