//! Dataset configuration: the spatial shape of a stored volume — base
//! dimensions, anisotropy, the multi-resolution hierarchy and the cuboid
//! shape at each level (paper §3.1, Figure 5).

use crate::core::{Box3, Vec3};
use crate::{Error, Result};

/// One level of the resolution hierarchy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LevelSpec {
    /// Resolution level (0 = native).
    pub level: u32,
    /// Volume dimensions in voxels at this level.
    pub dims: Vec3,
    /// Cuboid shape at this level. The paper uses flat (128,128,16)
    /// cuboids at the highest (most anisotropic) levels and cubic
    /// (64,64,64) below (Figure 5), keeping cuboids roughly isotropic in
    /// *sample* space while holding 2^18 voxels.
    pub cuboid: Vec3,
}

impl LevelSpec {
    /// Extent of the cuboid grid at this level.
    pub fn grid(&self) -> Vec3 {
        [
            self.dims[0].div_ceil(self.cuboid[0]),
            self.dims[1].div_ceil(self.cuboid[1]),
            self.dims[2].div_ceil(self.cuboid[2]),
        ]
    }

    /// Voxels per cuboid.
    pub fn cuboid_voxels(&self) -> u64 {
        self.cuboid[0] * self.cuboid[1] * self.cuboid[2]
    }

    /// The whole volume as a box.
    pub fn bounds(&self) -> Box3 {
        Box3::new([0, 0, 0], self.dims)
    }
}

/// A dataset describes the spatial configuration shared by every project
/// (database) registered against it: dimensions, number of resolutions,
/// optional time dimension and channel count (§4.2).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// Voxel size at level 0 in nanometres `[x, y, z]` — bock11 is
    /// (4, 4, 40): a 10x anisotropy between plane and section.
    pub voxel_nm: [f64; 3],
    /// Resolution hierarchy, level 0 first.
    pub levels: Vec<LevelSpec>,
    /// Number of time points (1 = static volume). Time joins the Morton
    /// index via the 4-d curve (§3.1).
    pub timesteps: u64,
    /// Number of channels (1 = single channel). Channels are *not* in the
    /// index; each channel has its own cuboid space (§3.1).
    pub channels: u16,
}

impl Dataset {
    /// Look up a level spec.
    pub fn level(&self, res: u32) -> Result<&LevelSpec> {
        self.levels
            .get(res as usize)
            .ok_or_else(|| Error::BadRequest(format!(
                "resolution {res} out of range (dataset '{}' has {} levels)",
                self.name,
                self.levels.len()
            )))
    }

    pub fn num_levels(&self) -> u32 {
        self.levels.len() as u32
    }

    /// Validate that a requested box lies within the volume at `res`.
    pub fn check_box(&self, res: u32, b: &Box3) -> Result<()> {
        let spec = self.level(res)?;
        for a in 0..3 {
            if b.hi[a] > spec.dims[a] || b.lo[a] >= b.hi[a] {
                return Err(Error::BadRequest(format!(
                    "box {:?}..{:?} outside volume {:?} at resolution {res}",
                    b.lo, b.hi, spec.dims
                )));
            }
        }
        Ok(())
    }

    pub fn check_timestep(&self, t: u64) -> Result<()> {
        if t >= self.timesteps {
            return Err(Error::BadRequest(format!(
                "timestep {t} out of range ({} timesteps)",
                self.timesteps
            )));
        }
        Ok(())
    }

    pub fn check_channel(&self, c: u16) -> Result<()> {
        if c >= self.channels {
            return Err(Error::BadRequest(format!(
                "channel {c} out of range ({} channels)",
                self.channels
            )));
        }
        Ok(())
    }
}

/// Builder implementing the paper's hierarchy policy: each level halves X
/// and Y but never Z, time, or channels (§3.1); cuboids are flat
/// (128,128,16) while the per-voxel Z length exceeds the XY length, and
/// cubic (64,64,64) beyond (Figure 5). Both shapes hold 2^18 voxels (§3.1:
/// "cuboids contain only 2^18 = 256K of data").
pub struct DatasetBuilder {
    name: String,
    dims: Vec3,
    voxel_nm: [f64; 3],
    levels: u32,
    timesteps: u64,
    channels: u16,
    flat_cuboid: Vec3,
    cubic_cuboid: Vec3,
}

impl DatasetBuilder {
    /// Start a builder for an EM-like volume of `dims` voxels.
    pub fn new(name: &str, dims: Vec3) -> Self {
        DatasetBuilder {
            name: name.to_string(),
            dims,
            voxel_nm: [4.0, 4.0, 40.0], // bock11-style default anisotropy
            levels: 1,
            timesteps: 1,
            channels: 1,
            flat_cuboid: [128, 128, 16],
            cubic_cuboid: [64, 64, 64],
        }
    }

    /// Physical voxel size at level 0 (nm), setting the anisotropy.
    pub fn voxel_nm(mut self, nm: [f64; 3]) -> Self {
        self.voxel_nm = nm;
        self
    }

    /// Number of hierarchy levels (bock11: 9, kasthuri11: 6).
    pub fn levels(mut self, n: u32) -> Self {
        self.levels = n.max(1);
        self
    }

    /// Time dimension (§3.1: 1000s of time points in MR data).
    pub fn timesteps(mut self, t: u64) -> Self {
        self.timesteps = t.max(1);
        self
    }

    /// Channel count (array tomography: up to 17 channels).
    pub fn channels(mut self, c: u16) -> Self {
        self.channels = c.max(1);
        self
    }

    /// Override cuboid shapes (the cuboid-size ablation bench uses this).
    pub fn cuboids(mut self, flat: Vec3, cubic: Vec3) -> Self {
        self.flat_cuboid = flat;
        self.cubic_cuboid = cubic;
        self
    }

    pub fn build(self) -> Dataset {
        let mut levels = Vec::with_capacity(self.levels as usize);
        let mut dims = self.dims;
        let mut nm = self.voxel_nm;
        for level in 0..self.levels {
            // Cuboid shape policy: while voxels are anisotropic (Z length
            // > 2x XY length) use flat cuboids, else cubic (Figure 5).
            let cuboid = if nm[2] > 2.0 * nm[0] { self.flat_cuboid } else { self.cubic_cuboid };
            let clamped = [
                cuboid[0].min(dims[0].next_power_of_two()),
                cuboid[1].min(dims[1].next_power_of_two()),
                cuboid[2].min(dims[2].next_power_of_two()),
            ];
            levels.push(LevelSpec { level, dims, cuboid: clamped });
            // Next level: halve X and Y only (§3.1: "we do not scale Z").
            dims = [(dims[0] / 2).max(1), (dims[1] / 2).max(1), dims[2]];
            nm = [nm[0] * 2.0, nm[1] * 2.0, nm[2]];
        }
        Dataset {
            name: self.name,
            voxel_nm: self.voxel_nm,
            levels,
            timesteps: self.timesteps,
            channels: self.channels,
        }
    }
}

/// The bock11 dataset configuration from the paper (§2): ~20 Tvox at
/// 4x4x40 nm, nine resolution levels. Scaled here by `scale` (1 = full
/// size; tests and examples use small scales).
pub fn bock11_like(scale_div: u64) -> Dataset {
    let d = scale_div.max(1);
    DatasetBuilder::new("bock11", [135_424 / d, 119_808 / d, 1_239.max(16 / d + 16)])
        .voxel_nm([4.0, 4.0, 40.0])
        .levels(9)
        .build()
}

/// The kasthuri11 dataset configuration (§2): 12000x12000x1850 voxels at
/// 3x3x30 nm, six levels.
pub fn kasthuri11_like(scale_div: u64) -> Dataset {
    let d = scale_div.max(1);
    DatasetBuilder::new("kasthuri11", [12_000 / d, 12_000 / d, (1_850 / d).max(32)])
        .voxel_nm([3.0, 3.0, 30.0])
        .levels(6)
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_halves_xy_not_z() {
        let ds = DatasetBuilder::new("t", [4096, 2048, 512]).levels(4).build();
        assert_eq!(ds.levels[0].dims, [4096, 2048, 512]);
        assert_eq!(ds.levels[1].dims, [2048, 1024, 512]);
        assert_eq!(ds.levels[3].dims, [512, 256, 512]);
    }

    #[test]
    fn cuboid_shape_switches_flat_to_cubic() {
        // 4x4x40nm: the highest levels are anisotropic (flat cuboids); by
        // level 3 the voxel is 32x32x40nm — roughly isotropic — and
        // cuboids go cubic. Mirrors the paper: "at the highest three
        // resolutions in bock11, cuboids are flat (128x128x16) ... Beyond
        // level 4, we shift to a cube of (64x64x64)".
        let ds = DatasetBuilder::new("t", [1 << 17, 1 << 17, 2048]).levels(9).build();
        for l in 0..=2 {
            assert_eq!(ds.levels[l].cuboid, [128, 128, 16], "level {l}");
        }
        for l in 3..9 {
            assert_eq!(ds.levels[l].cuboid, [64, 64, 64], "level {l}");
        }
    }

    #[test]
    fn both_cuboid_shapes_hold_2_18_voxels() {
        let ds = DatasetBuilder::new("t", [1 << 17, 1 << 17, 2048]).levels(9).build();
        assert_eq!(ds.levels[0].cuboid_voxels(), 1 << 18);
        assert_eq!(ds.levels[8].cuboid_voxels(), 1 << 18);
    }

    #[test]
    fn grid_rounds_up() {
        let spec = LevelSpec { level: 0, dims: [300, 128, 17], cuboid: [128, 128, 16] };
        assert_eq!(spec.grid(), [3, 1, 2]);
    }

    #[test]
    fn check_box_bounds() {
        let ds = DatasetBuilder::new("t", [256, 256, 64]).levels(2).build();
        assert!(ds.check_box(0, &Box3::new([0, 0, 0], [256, 256, 64])).is_ok());
        assert!(ds.check_box(0, &Box3::new([0, 0, 0], [257, 1, 1])).is_err());
        assert!(ds.check_box(5, &Box3::new([0, 0, 0], [1, 1, 1])).is_err());
        assert!(ds.check_box(1, &Box3::new([0, 0, 0], [128, 128, 64])).is_ok());
    }

    #[test]
    fn named_datasets() {
        let b = bock11_like(64);
        assert_eq!(b.num_levels(), 9);
        assert_eq!(b.voxel_nm, [4.0, 4.0, 40.0]);
        let k = kasthuri11_like(8);
        assert_eq!(k.num_levels(), 6);
    }

    #[test]
    fn small_volume_clamps_cuboid() {
        let ds = DatasetBuilder::new("t", [32, 32, 8]).levels(1).build();
        assert!(ds.levels[0].cuboid[0] <= 32);
        assert!(ds.levels[0].cuboid[2] <= 8);
    }
}
