//! Axis-aligned integer geometry used throughout the cutout and annotation
//! paths. Boxes are half-open `[lo, hi)` in voxel coordinates.

/// A 3-d point / extent in voxels, ordered `[x, y, z]`.
pub type Vec3 = [u64; 3];

/// A half-open axis-aligned box `[lo, hi)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Box3 {
    pub lo: Vec3,
    pub hi: Vec3,
}

impl Box3 {
    /// Construct, asserting a well-formed (possibly empty) box.
    pub fn new(lo: Vec3, hi: Vec3) -> Box3 {
        debug_assert!((0..3).all(|a| lo[a] <= hi[a]), "bad box {lo:?}..{hi:?}");
        Box3 { lo, hi }
    }

    /// Box at `lo` with the given extent.
    pub fn at(lo: Vec3, extent: Vec3) -> Box3 {
        Box3::new(lo, [lo[0] + extent[0], lo[1] + extent[1], lo[2] + extent[2]])
    }

    /// Extent along each axis.
    pub fn extent(&self) -> Vec3 {
        [self.hi[0] - self.lo[0], self.hi[1] - self.lo[1], self.hi[2] - self.lo[2]]
    }

    /// Number of voxels.
    pub fn volume(&self) -> u64 {
        let e = self.extent();
        e[0] * e[1] * e[2]
    }

    pub fn is_empty(&self) -> bool {
        (0..3).any(|a| self.lo[a] >= self.hi[a])
    }

    /// Intersection (possibly empty).
    pub fn intersect(&self, other: &Box3) -> Box3 {
        let lo = [
            self.lo[0].max(other.lo[0]),
            self.lo[1].max(other.lo[1]),
            self.lo[2].max(other.lo[2]),
        ];
        let hi = [
            self.hi[0].min(other.hi[0]).max(lo[0]),
            self.hi[1].min(other.hi[1]).max(lo[1]),
            self.hi[2].min(other.hi[2]).max(lo[2]),
        ];
        Box3 { lo, hi }
    }

    /// Smallest box containing both.
    pub fn union(&self, other: &Box3) -> Box3 {
        if self.is_empty() {
            return *other;
        }
        if other.is_empty() {
            return *self;
        }
        Box3 {
            lo: [
                self.lo[0].min(other.lo[0]),
                self.lo[1].min(other.lo[1]),
                self.lo[2].min(other.lo[2]),
            ],
            hi: [
                self.hi[0].max(other.hi[0]),
                self.hi[1].max(other.hi[1]),
                self.hi[2].max(other.hi[2]),
            ],
        }
    }

    /// Does the box contain the point?
    pub fn contains(&self, p: Vec3) -> bool {
        (0..3).all(|a| self.lo[a] <= p[a] && p[a] < self.hi[a])
    }

    /// The cuboid-grid box covering this voxel box for cuboids of shape
    /// `cshape`: lo rounded down, hi rounded up, in cuboid coordinates.
    pub fn cuboid_cover(&self, cshape: Vec3) -> Box3 {
        let lo = [
            self.lo[0] / cshape[0],
            self.lo[1] / cshape[1],
            self.lo[2] / cshape[2],
        ];
        let hi = [
            self.hi[0].div_ceil(cshape[0]).max(lo[0]),
            self.hi[1].div_ceil(cshape[1]).max(lo[1]),
            self.hi[2].div_ceil(cshape[2]).max(lo[2]),
        ];
        Box3 { lo, hi }
    }

    /// Is this voxel box exactly aligned to the cuboid grid? Aligned
    /// cutouts avoid partial-cuboid copies (§5 Fig 10's aligned/unaligned
    /// split).
    pub fn is_aligned(&self, cshape: Vec3) -> bool {
        (0..3).all(|a| self.lo[a] % cshape[a] == 0 && self.hi[a] % cshape[a] == 0)
    }

    /// Round outward to the cuboid grid (used by the tile prefetcher).
    pub fn align_outward(&self, cshape: Vec3) -> Box3 {
        let c = self.cuboid_cover(cshape);
        Box3 {
            lo: [c.lo[0] * cshape[0], c.lo[1] * cshape[1], c.lo[2] * cshape[2]],
            hi: [c.hi[0] * cshape[0], c.hi[1] * cshape[1], c.hi[2] * cshape[2]],
        }
    }

    /// Euclidean distance between box centers, in voxels (used by the
    /// spatial analysis example for synapse–dendrite distances).
    pub fn center_distance(&self, other: &Box3) -> f64 {
        let c = |b: &Box3, a: usize| (b.lo[a] + b.hi[a]) as f64 / 2.0;
        let mut s = 0.0;
        for a in 0..3 {
            let d = c(self, a) - c(other, a);
            s += d * d;
        }
        s.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::property;

    #[test]
    fn extent_volume() {
        let b = Box3::new([1, 2, 3], [4, 6, 8]);
        assert_eq!(b.extent(), [3, 4, 5]);
        assert_eq!(b.volume(), 60);
        assert!(!b.is_empty());
        assert!(Box3::new([1, 1, 1], [1, 5, 5]).is_empty());
    }

    #[test]
    fn intersect_union() {
        let a = Box3::new([0, 0, 0], [10, 10, 10]);
        let b = Box3::new([5, 5, 5], [15, 15, 15]);
        assert_eq!(a.intersect(&b), Box3::new([5, 5, 5], [10, 10, 10]));
        assert_eq!(a.union(&b), Box3::new([0, 0, 0], [15, 15, 15]));
        let c = Box3::new([20, 20, 20], [30, 30, 30]);
        assert!(a.intersect(&c).is_empty());
    }

    #[test]
    fn cuboid_cover_examples() {
        let b = Box3::new([100, 0, 5], [300, 128, 17]);
        let cover = b.cuboid_cover([128, 128, 16]);
        assert_eq!(cover, Box3::new([0, 0, 0], [3, 1, 2]));
        assert!(!b.is_aligned([128, 128, 16]));
        assert!(Box3::new([128, 0, 16], [256, 128, 32]).is_aligned([128, 128, 16]));
    }

    #[test]
    fn cover_contains_box_prop() {
        property("cuboid_cover_contains", 500, |g| {
            let (lo, hi) = g.boxed([4096, 4096, 512], 700);
            let b = Box3::new(lo, hi);
            let cs = [64, 64, 16];
            let outer = b.align_outward(cs);
            assert!(outer.lo[0] <= b.lo[0] && outer.hi[0] >= b.hi[0]);
            assert!(outer.lo[1] <= b.lo[1] && outer.hi[1] >= b.hi[1]);
            assert!(outer.lo[2] <= b.lo[2] && outer.hi[2] >= b.hi[2]);
            assert!(outer.is_aligned(cs));
            // Cover must be minimal: shrinking any face by one cuboid
            // must lose coverage.
            let cover = b.cuboid_cover(cs);
            for a in 0..3 {
                assert!(cover.lo[a] * cs[a] <= b.lo[a]);
                assert!((cover.lo[a] + 1) * cs[a] > b.lo[a]);
                assert!(cover.hi[a] * cs[a] >= b.hi[a]);
                assert!((cover.hi[a] - 1) * cs[a] < b.hi[a]);
            }
        });
    }

    #[test]
    fn intersect_commutes_prop() {
        property("intersect_commutes", 500, |g| {
            let (alo, ahi) = g.boxed([256, 256, 64], 64);
            let (blo, bhi) = g.boxed([256, 256, 64], 64);
            let a = Box3::new(alo, ahi);
            let b = Box3::new(blo, bhi);
            let ab = a.intersect(&b);
            let ba = b.intersect(&a);
            assert_eq!(ab.is_empty(), ba.is_empty());
            if !ab.is_empty() {
                assert_eq!(ab, ba);
                assert!(ab.volume() <= a.volume().min(b.volume()));
            }
        });
    }

    #[test]
    fn contains_center() {
        let b = Box3::new([0, 0, 0], [4, 4, 4]);
        assert!(b.contains([0, 0, 0]));
        assert!(b.contains([3, 3, 3]));
        assert!(!b.contains([4, 0, 0]));
    }
}
