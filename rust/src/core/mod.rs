//! Core data model: voxel datatypes, geometry, datasets (the spatial
//! configuration of a stored volume) and projects (a database bound to a
//! dataset) — paper §3 and §4.2 "Projects and Datasets".

mod dataset;
mod geometry;
mod project;

pub use dataset::{bock11_like, kasthuri11_like, Dataset, DatasetBuilder, LevelSpec};
pub use geometry::{Box3, Vec3};
pub use project::{Project, ProjectKind, WriteDiscipline};

use crate::{Error, Result};

/// Voxel datatype of a database. EM image databases are 8-bit grayscale;
/// annotation databases are 32-bit identifiers; 16-bit (TIFF-like) and
/// 32-bit RGBA image formats are also supported (§4.2 "Cutout").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// 8-bit grayscale (EM imagery).
    U8,
    /// 16-bit grayscale (e.g. array tomography channels).
    U16,
    /// 32-bit annotation identifiers.
    U32,
    /// 32-bit RGBA imagery.
    Rgba,
    /// 32-bit float (probability maps produced by the vision pipeline).
    F32,
}

impl Dtype {
    /// Bytes per voxel.
    pub fn bytes(self) -> usize {
        match self {
            Dtype::U8 => 1,
            Dtype::U16 => 2,
            Dtype::U32 | Dtype::Rgba | Dtype::F32 => 4,
        }
    }

    /// Wire tag used by the `ocpk` interchange format.
    pub fn tag(self) -> u8 {
        match self {
            Dtype::U8 => 1,
            Dtype::U16 => 2,
            Dtype::U32 => 3,
            Dtype::Rgba => 4,
            Dtype::F32 => 5,
        }
    }

    /// Inverse of [`Dtype::tag`].
    pub fn from_tag(t: u8) -> Result<Dtype> {
        Ok(match t {
            1 => Dtype::U8,
            2 => Dtype::U16,
            3 => Dtype::U32,
            4 => Dtype::Rgba,
            5 => Dtype::F32,
            _ => return Err(Error::Codec(format!("unknown dtype tag {t}"))),
        })
    }

    /// Parse from the names used in dataset configs and URLs.
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "u8" | "uint8" | "gray8" => Dtype::U8,
            "u16" | "uint16" => Dtype::U16,
            "u32" | "uint32" | "anno32" => Dtype::U32,
            "rgba" | "rgba32" => Dtype::Rgba,
            "f32" | "float32" => Dtype::F32,
            _ => return Err(Error::BadRequest(format!("unknown dtype '{s}'"))),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::U8 => "u8",
            Dtype::U16 => "u16",
            Dtype::U32 => "u32",
            Dtype::Rgba => "rgba",
            Dtype::F32 => "f32",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_tag_roundtrip() {
        for d in [Dtype::U8, Dtype::U16, Dtype::U32, Dtype::Rgba, Dtype::F32] {
            assert_eq!(Dtype::from_tag(d.tag()).unwrap(), d);
            assert_eq!(Dtype::parse(d.name()).unwrap(), d);
        }
        assert!(Dtype::from_tag(0).is_err());
        assert!(Dtype::parse("complex128").is_err());
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(Dtype::U8.bytes(), 1);
        assert_eq!(Dtype::U16.bytes(), 2);
        assert_eq!(Dtype::U32.bytes(), 4);
        assert_eq!(Dtype::Rgba.bytes(), 4);
        assert_eq!(Dtype::F32.bytes(), 4);
    }
}
