//! Persistent-connection HTTP/1.1 server.
//!
//! The paper's application stack ran each Web-service request on a
//! single Apache2/WSGI process thread and tore the connection down after
//! every response (§4.2/§5). Its successor ecosystem moved this tier to
//! persistent, streaming HTTP to serve interactive viewers at scale;
//! this server does the same over `std::net` (no external HTTP crates
//! exist in the offline vendor set, DESIGN.md §1):
//!
//! * **keep-alive** — each accepted connection runs a request loop;
//!   pipelined requests queued in the socket buffer are parsed and
//!   answered back-to-back in order.
//! * **admission gate** — at most [`ServerConfig::max_connections`]
//!   concurrent connections; excess connections are answered `503` with
//!   a `Retry-After` header and closed instead of queueing unboundedly.
//!   With a tenant-weight hook installed
//!   ([`Server::set_tenant_weights`], wired to `qos/` quota weights by
//!   the service layer) over-cap connections are shed
//!   lowest-tenant-weight first instead of FIFO.
//! * **streaming bodies** — handlers return a [`Body`], either buffered
//!   bytes or a chunk-producing stream written as chunked
//!   transfer-encoding, so multi-hundred-MB cutouts never materialize
//!   in server memory.
//! * **graceful drain** — [`Server::stop`] stops accepting, lets
//!   in-flight requests finish, marks the final response of every live
//!   connection `Connection: close`, and wakes idle keep-alive
//!   connections so drop does not hang on them.
//!
//! The parser remains hostile-input hardened: request heads are
//! size-capped, bodies are bounded (413 beyond the limit), garbage
//! request lines, conflicting `Content-Length` headers and chunked
//! request bodies produce 400s, and every read carries a timeout so a
//! stalled peer cannot pin a connection thread. Parse failures answer
//! and then close — the request framing can no longer be trusted.
//!
//! The client half (keep-alive connection pool, chunked decoding) lives
//! in `web/conn.rs`; [`request`] and friends are re-exported here so
//! callers keep one import path.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::metrics::{Counter, Gauge, Histogram};
use crate::Result;

pub use crate::web::conn::{
    request, request_info, request_once, request_with, RequestOpts, ResponseInfo, RetryPolicy,
};

/// Default request-body cap (64 MiB — comfortably above the largest
/// cutout upload the benches issue). See [`ServerConfig`].
pub const DEFAULT_MAX_BODY: usize = 64 << 20;

/// Default admission-gate width per configured worker (the `workers`
/// argument of [`Server::bind`] sizes the gate, not a thread pool: each
/// admitted connection gets its own request-loop thread).
pub const CONNS_PER_WORKER: usize = 32;

/// Cap on the request line + headers together.
const MAX_HEAD_BYTES: u64 = 64 << 10;

/// How long a worker waits on a silent peer mid-request before giving up.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Overall wall-clock budget for reading one request (head + body). A
/// peer that trickles bytes — each arriving just inside the socket
/// timeout — is cut off here instead of pinning a worker indefinitely.
const REQUEST_DEADLINE: Duration = Duration::from_secs(60);

/// How long an idle keep-alive connection is held open waiting for its
/// next request before the server closes it.
const IDLE_TIMEOUT: Duration = Duration::from_secs(30);

/// Poll granularity while idle-waiting between requests: bounds how
/// long a drain waits on idle connections.
const IDLE_POLL: Duration = Duration::from_millis(100);

/// What a 503 tells the client about when to come back.
const RETRY_AFTER_SECS: u64 = 1;

/// How long the weighted gate waits for an over-cap connection's
/// request line before treating it as lowest-weight and shedding it.
const PEEK_DEADLINE: Duration = Duration::from_millis(250);

/// Accept-loop backoff caps: transient `WouldBlock` idles back off to
/// stay responsive; real errors (EMFILE, ENFILE, ECONNABORTED storms)
/// back off much further instead of spinning the core.
const ACCEPT_IDLE_BACKOFF_START: Duration = Duration::from_micros(200);
const ACCEPT_IDLE_BACKOFF_CAP: Duration = Duration::from_millis(2);
const ACCEPT_ERROR_BACKOFF_START: Duration = Duration::from_millis(1);
const ACCEPT_ERROR_BACKOFF_CAP: Duration = Duration::from_millis(500);

/// A parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path, percent-decoding not needed for our grammar.
    pub path: String,
    pub body: Vec<u8>,
    /// Inbound `X-Request-Id`, if the client sent one; the service tier
    /// mints an id otherwise and echoes it on the response either way.
    pub request_id: Option<String>,
    /// Inbound `X-OCPD-Deadline-Ms`: the caller's latency budget. The
    /// admission layer converts it to an absolute deadline; engines
    /// abandon remaining work (504) once it passes.
    pub deadline_ms: Option<u64>,
    /// Whether the connection may serve another request after this one
    /// (HTTP/1.1 default, overridden by `Connection: close` or an
    /// HTTP/1.0 request line).
    keep_alive: bool,
    /// The request line said HTTP/1.0: such peers cannot parse chunked
    /// transfer-encoding, so streamed bodies go close-delimited.
    http10: bool,
}

/// A chunk-producing response body: each call returns the next chunk,
/// `Ok(None)` ends the stream. Chunks are written as chunked
/// transfer-encoding as they are produced — the server never holds more
/// than one chunk in memory.
pub type BodyStream = Box<dyn FnMut() -> Result<Option<Vec<u8>>> + Send>;

/// A response body: buffered bytes (`Content-Length` framing), shared
/// bytes (zero-copy responses from caches), or a stream (chunked
/// transfer-encoding).
pub enum Body {
    Bytes(Vec<u8>),
    /// Shared buffer — cached tiles answer many requests without a copy.
    Shared(Arc<Vec<u8>>),
    Stream(BodyStream),
}

impl Body {
    pub fn empty() -> Body {
        Body::Bytes(Vec::new())
    }

    /// Buffered length; `None` for streams (length unknown until drained).
    pub fn len(&self) -> Option<usize> {
        match self {
            Body::Bytes(b) => Some(b.len()),
            Body::Shared(b) => Some(b.len()),
            Body::Stream(_) => None,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == Some(0)
    }

    /// Buffered bytes, draining a stream if necessary (test helper and
    /// in-process callers; the wire path never drains).
    pub fn into_bytes(self) -> Result<Vec<u8>> {
        match self {
            Body::Bytes(b) => Ok(b),
            Body::Shared(b) => Ok(Arc::try_unwrap(b).unwrap_or_else(|a| (*a).clone())),
            Body::Stream(mut next) => {
                let mut out = Vec::new();
                while let Some(chunk) = next()? {
                    out.extend_from_slice(&chunk);
                }
                Ok(out)
            }
        }
    }
}

impl std::fmt::Debug for Body {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Body::Bytes(b) => write!(f, "Body::Bytes({} bytes)", b.len()),
            Body::Shared(b) => write!(f, "Body::Shared({} bytes)", b.len()),
            Body::Stream(_) => write!(f, "Body::Stream(..)"),
        }
    }
}

/// A response under construction.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Body,
    /// Methods advertised in an `Allow` header — set on 405 responses
    /// (RFC 9110 §15.5.6: a 405 "MUST generate an Allow header").
    pub allow: Option<String>,
    /// Seconds advertised in a `Retry-After` header (503 overload).
    pub retry_after: Option<u64>,
    /// Route label assigned by the router — keys the per-route latency
    /// histograms in [`HttpMetrics`].
    pub route: Option<&'static str>,
    /// Request id echoed as an `X-Request-Id` response header — set by
    /// the service tier from the inbound header (or minted there).
    pub request_id: Option<String>,
}

impl Response {
    fn with_body(status: u16, content_type: &'static str, body: Body) -> Response {
        Response {
            status,
            content_type,
            body,
            allow: None,
            retry_after: None,
            route: None,
            request_id: None,
        }
    }

    pub fn ok(body: Vec<u8>, content_type: &'static str) -> Response {
        Self::with_body(200, content_type, Body::Bytes(body))
    }

    pub fn text(s: impl Into<String>) -> Response {
        Response::ok(s.into().into_bytes(), "text/plain")
    }

    pub fn binary(body: Vec<u8>) -> Response {
        Response::ok(body, "application/x-ocpk")
    }

    /// Zero-copy binary response from a shared buffer (cached tiles).
    pub fn binary_shared(body: Arc<Vec<u8>>) -> Response {
        Self::with_body(200, "application/x-ocpk", Body::Shared(body))
    }

    /// Chunked-transfer streaming response: `stream` is called until it
    /// returns `Ok(None)`; each chunk goes on the wire immediately.
    pub fn stream(content_type: &'static str, stream: BodyStream) -> Response {
        Self::with_body(200, content_type, Body::Stream(stream))
    }

    pub fn error(status: u16, msg: impl Into<String>) -> Response {
        Self::with_body(status, "text/plain", Body::Bytes(msg.into().into_bytes()))
    }

    /// A 405 naming the methods the route does accept.
    pub fn method_not_allowed(allow: impl Into<String>) -> Response {
        let allow = allow.into();
        Response {
            status: 405,
            content_type: "text/plain",
            body: Body::Bytes(format!("method not allowed (allow: {allow})").into_bytes()),
            allow: Some(allow),
            retry_after: None,
            route: None,
            request_id: None,
        }
    }

    /// The admission gate's answer when the server is at capacity.
    pub fn overloaded() -> Response {
        Response {
            status: 503,
            content_type: "text/plain",
            body: Body::Bytes(b"server at connection capacity".to_vec()),
            allow: None,
            retry_after: Some(RETRY_AFTER_SECS),
            route: None,
            request_id: None,
        }
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            413 => "Payload Too Large",
            429 => "Too Many Requests",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Internal Server Error",
        }
    }
}

/// Transport-tier observability: the request counters and latency
/// histogram the server always kept, plus connection-reuse, in-flight,
/// admission, and per-route views — surfaced at `GET /http/status/` and
/// by the `ocpd http` CLI.
#[derive(Default)]
pub struct HttpMetrics {
    /// Requests answered (all connections). Shared with
    /// [`Server::requests`] — the two are the same counter.
    pub requests: Arc<Counter>,
    /// Per-request wall time: parse + handle + write. Shared with
    /// [`Server::latency`].
    pub latency: Arc<Histogram>,
    /// Connections accepted (admitted past the gate).
    pub connections: Counter,
    /// Connections rejected by the admission gate (503).
    pub rejected: Counter,
    /// Over-cap connections admitted anyway because their tenant
    /// outweighed every tenant currently holding a connection
    /// (weighted shedding; see [`Server::set_tenant_weights`]).
    pub priority_admits: Counter,
    /// Accept-loop errors (EMFILE and friends; `WouldBlock` idle polls
    /// are not errors and are not counted).
    pub accept_errors: Counter,
    /// Live connections (gauge).
    pub active_connections: Gauge,
    /// Requests currently being parsed/handled/written (gauge).
    pub in_flight: Gauge,
    /// Responses written as chunked transfer-encoding streams.
    pub streamed_responses: Counter,
    /// High-water mark of a single streamed chunk, in bytes — the
    /// streaming path's peak-memory proxy (a buffered response's peak
    /// is its whole body).
    pub stream_peak_chunk: Gauge,
    /// Per-route latency histograms, keyed by the router's route names.
    per_route: Mutex<HashMap<&'static str, Arc<Histogram>>>,
}

impl HttpMetrics {
    /// Requests per connection — 1.0 means close-per-request, higher
    /// means keep-alive is being reused.
    pub fn reuse_ratio(&self) -> f64 {
        let conns = self.connections.get();
        if conns == 0 {
            0.0
        } else {
            self.requests.get() as f64 / conns as f64
        }
    }

    /// The latency histogram for `route`, creating it on first use.
    pub fn route_latency(&self, route: &'static str) -> Arc<Histogram> {
        let mut guard = self.per_route.lock().unwrap();
        Arc::clone(guard.entry(route).or_insert_with(|| Arc::new(Histogram::new())))
    }

    /// Every route's latency histogram, sorted by name (the unified
    /// registry's per-route exposition).
    pub fn route_histograms(&self) -> Vec<(&'static str, Arc<Histogram>)> {
        let guard = self.per_route.lock().unwrap();
        let mut rows: Vec<_> =
            guard.iter().map(|(name, h)| (*name, Arc::clone(h))).collect();
        rows.sort_by_key(|r| r.0);
        rows
    }

    /// Snapshot of every route's (name, count, mean µs, p95 µs), sorted
    /// by name for stable output.
    pub fn route_snapshot(&self) -> Vec<(&'static str, u64, f64, u64)> {
        let guard = self.per_route.lock().unwrap();
        let mut rows: Vec<_> = guard
            .iter()
            .map(|(name, h)| (*name, h.count(), h.mean_us(), h.percentile_us(95.0)))
            .collect();
        rows.sort_by_key(|r| r.0);
        rows
    }

    /// The `GET /http/status/` body.
    pub fn status_text(&self) -> String {
        let mut out = String::from("http:\n");
        out.push_str(&format!(
            "  requests={} connections={} reuse={:.2} rejected_503={} priority_admits={} accept_errors={}\n",
            self.requests.get(),
            self.connections.get(),
            self.reuse_ratio(),
            self.rejected.get(),
            self.priority_admits.get(),
            self.accept_errors.get(),
        ));
        out.push_str(&format!(
            "  active_connections={} in_flight={} streamed={} stream_peak_chunk={}\n",
            self.active_connections.get(),
            self.in_flight.get(),
            self.streamed_responses.get(),
            self.stream_peak_chunk.get(),
        ));
        out.push_str(&format!(
            "  latency: mean_us={:.1} p50_us={} p95_us={} p99_us={}\n",
            self.latency.mean_us(),
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(95.0),
            self.latency.percentile_us(99.0),
        ));
        let routes = self.route_snapshot();
        if !routes.is_empty() {
            out.push_str("  routes:\n");
            for (name, n, mean, p95) in routes {
                out.push_str(&format!(
                    "    {name}: n={n} mean_us={mean:.1} p95_us={p95}\n"
                ));
            }
        }
        out
    }
}

/// Server tuning knobs. `Default` matches [`Server::bind`] with 16
/// workers.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Request-body cap: requests advertising a larger `Content-Length`
    /// are refused with `413` before any body byte is read or buffered.
    pub max_body: usize,
    /// Admission gate: connections past this limit are answered `503 ` +
    /// `Retry-After` and closed.
    pub max_connections: usize,
    /// How long an idle keep-alive connection is held before closing.
    pub idle_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_body: DEFAULT_MAX_BODY,
            max_connections: 16 * CONNS_PER_WORKER,
            idle_timeout: IDLE_TIMEOUT,
        }
    }
}

/// Resolves a tenant name to its admission weight (the service layer
/// wires this to `qos/` quota weights; unknown tenants weigh 1).
pub type WeightFn = Arc<dyn Fn(&str) -> u64 + Send + Sync>;

/// The tenant's-eye view of the admission gate: which tenants hold live
/// connections right now, plus the optional weight hook. With no hook
/// installed — or with every weight equal, the hook's answer for
/// unconfigured tenants — over-cap connections are shed FIFO exactly as
/// before; with differentiated weights the gate sheds
/// lowest-weight-first instead (see [`Server::set_tenant_weights`]).
struct Gate {
    /// tenant → number of live connections it holds. A connection
    /// registers its tenant when its first request line parses and
    /// deregisters when the connection ends.
    tenants: Mutex<HashMap<String, usize>>,
    weight_of: RwLock<Option<WeightFn>>,
}

impl Gate {
    fn new() -> Gate {
        Gate { tenants: Mutex::new(HashMap::new()), weight_of: RwLock::new(None) }
    }

    fn hook(&self) -> Option<WeightFn> {
        self.weight_of.read().unwrap().clone()
    }

    fn note_open(&self, tenant: &str) {
        *self.tenants.lock().unwrap().entry(tenant.to_string()).or_insert(0) += 1;
    }

    fn note_close(&self, tenant: &str) {
        let mut held = self.tenants.lock().unwrap();
        if let Some(n) = held.get_mut(tenant) {
            *n -= 1;
            if *n == 0 {
                held.remove(tenant);
            }
        }
    }

    /// The lowest weight among tenants currently holding a connection —
    /// the bar a newcomer must clear to be admitted past a full gate.
    /// 0 when no connection has identified its tenant yet, so any
    /// weighted tenant outranks a gate full of silent connections.
    fn min_active_weight(&self, weight_of: &WeightFn) -> u64 {
        self.tenants.lock().unwrap().keys().map(|t| weight_of(t)).min().unwrap_or(0)
    }
}

/// The tenant a connection's request belongs to: the first path
/// segment — the same attribution the QoS admission layer uses for
/// project routes (reserved surfaces resolve to the default weight).
fn tenant_of(path: &str) -> &str {
    path.split('/').find(|s| !s.is_empty()).unwrap_or("")
}

/// Hard ceiling on over-cap priority admissions: the configured gate
/// width plus a small bounded allowance, so weighted admission cannot
/// grow the connection count without limit under a heavy-tenant storm.
fn overflow_cap(cfg: &ServerConfig) -> usize {
    cfg.max_connections + cfg.max_connections / 8 + 1
}

/// Atomically claim a connection slot if `active` is still below `cap`.
fn try_reserve(active: &AtomicUsize, cap: usize) -> bool {
    let mut cur = active.load(Ordering::Acquire);
    loop {
        if cur >= cap {
            return false;
        }
        match active.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
}

/// A running HTTP server (drops → graceful drain: stop accepting, let
/// in-flight requests finish, close every connection).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    /// Transport metrics (the `/http/status/` surface).
    pub metrics: Arc<HttpMetrics>,
    /// Requests served — the same counter as `metrics.requests`, kept
    /// as a field for the original `Server` surface.
    pub requests: Arc<Counter>,
    /// Per-request latency — the same histogram as `metrics.latency`.
    pub latency: Arc<Histogram>,
    active: Arc<AtomicUsize>,
    gate: Arc<Gate>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve `handler`. `workers` sizes the admission gate
    /// ([`CONNS_PER_WORKER`] concurrent connections per worker) — each
    /// admitted connection runs its request loop on its own thread.
    pub fn bind<F>(addr: &str, workers: usize, handler: F) -> Result<Server>
    where
        F: Fn(Request) -> Response + Send + Sync + 'static,
    {
        let cfg = ServerConfig {
            max_connections: workers.max(1) * CONNS_PER_WORKER,
            ..ServerConfig::default()
        };
        Self::bind_with_config(addr, cfg, Arc::new(HttpMetrics::default()), handler)
    }

    /// [`Server::bind`] with an explicit request-body cap.
    pub fn bind_with_limit<F>(
        addr: &str,
        workers: usize,
        max_body: usize,
        handler: F,
    ) -> Result<Server>
    where
        F: Fn(Request) -> Response + Send + Sync + 'static,
    {
        let cfg = ServerConfig {
            max_body,
            max_connections: workers.max(1) * CONNS_PER_WORKER,
            ..ServerConfig::default()
        };
        Self::bind_with_config(addr, cfg, Arc::new(HttpMetrics::default()), handler)
    }

    /// Full-control bind: explicit [`ServerConfig`] and a shared
    /// [`HttpMetrics`] (pass the same `Arc` to the service layer so the
    /// `/http/status/` route can report it).
    pub fn bind_with_config<F>(
        addr: &str,
        cfg: ServerConfig,
        metrics: Arc<HttpMetrics>,
        handler: F,
    ) -> Result<Server>
    where
        F: Fn(Request) -> Response + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));
        let gate = Arc::new(Gate::new());
        let handler = Arc::new(handler);

        let stop2 = Arc::clone(&stop);
        let active2 = Arc::clone(&active);
        let metrics2 = Arc::clone(&metrics);
        let gate2 = Arc::clone(&gate);
        let accept_thread = std::thread::Builder::new()
            .name("ocpd-accept".into())
            .spawn(move || {
                accept_loop(listener, cfg, stop2, active2, metrics2, gate2, handler);
            })
            .expect("spawn accept thread");

        let requests = Arc::clone(&metrics.requests);
        let latency = Arc::clone(&metrics.latency);
        Ok(Server {
            addr,
            stop,
            metrics,
            requests,
            latency,
            active,
            gate,
            accept_thread: Some(accept_thread),
        })
    }

    /// Install the tenant-weight hook for the admission gate. With a
    /// hook installed, over-cap connections are no longer shed FIFO:
    /// the gate peeks the pending request line (bounded, without
    /// consuming it), resolves the tenant's weight, and admits the
    /// connection — within a small bounded overflow allowance — iff it
    /// outweighs every tenant currently holding a connection. Under a
    /// storm, the lowest-weight tenant is shed first. The service layer
    /// wires this to `qos/` quota weights, so with no quotas configured
    /// (all weights 1) the gate behaves exactly as the FIFO one.
    pub fn set_tenant_weights(&self, weight_of: WeightFn) {
        *self.gate.weight_of.write().unwrap() = Some(weight_of);
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Begin a graceful drain: stop accepting, finish in-flight
    /// requests, close idle keep-alive connections at their next poll.
    /// Returns immediately; [`Server::drain`] (or drop) waits.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// Block until every connection has closed or `deadline` passes.
    /// Returns the number of connections still live (0 = fully drained).
    pub fn drain(&self, deadline: Duration) -> usize {
        self.stop();
        let t0 = std::time::Instant::now();
        while self.active.load(Ordering::Acquire) > 0 && t0.elapsed() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.active.load(Ordering::Acquire)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Idle connections notice the drain within one IDLE_POLL; give
        // stragglers a bounded grace period rather than hanging drop.
        self.drain(Duration::from_secs(5));
    }
}

fn accept_loop<F>(
    listener: TcpListener,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    metrics: Arc<HttpMetrics>,
    gate: Arc<Gate>,
    handler: Arc<F>,
) where
    F: Fn(Request) -> Response + Send + Sync + 'static,
{
    let mut idle_backoff = ACCEPT_IDLE_BACKOFF_START;
    let mut error_backoff = ACCEPT_ERROR_BACKOFF_START;
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                idle_backoff = ACCEPT_IDLE_BACKOFF_START;
                error_backoff = ACCEPT_ERROR_BACKOFF_START;
                // Admission gate: answer 503 + Retry-After instead of
                // queueing more connections than we are willing to run.
                // Either way the decision runs on a disposable thread:
                // the 503 write, the bounded drain (closing with unread
                // data would RST the 503 out of the peer's receive
                // buffer), and the weighted gate's request-line peek
                // must not stall the accept loop — a trickling peer
                // could otherwise hold accepts for hundreds of ms. If
                // even that thread cannot spawn, just drop the socket.
                if active.load(Ordering::Acquire) >= cfg.max_connections {
                    if let Some(weight_of) = gate.hook() {
                        // Weighted shedding: peek the request line and
                        // shed the lowest-weight tenant first instead
                        // of FIFO.
                        let g = Arc::clone(&gate);
                        let st = Arc::clone(&stop);
                        let a = Arc::clone(&active);
                        let m = Arc::clone(&metrics);
                        let h = Arc::clone(&handler);
                        let spawned =
                            std::thread::Builder::new().name("ocpd-shed".into()).spawn(
                                move || shed_or_admit(stream, cfg, g, weight_of, st, a, m, h),
                            );
                        if spawned.is_err() {
                            metrics.rejected.inc();
                        }
                    } else {
                        metrics.rejected.inc();
                        let _ = std::thread::Builder::new()
                            .name("ocpd-shed".into())
                            .spawn(move || shed_503(stream));
                    }
                    continue;
                }
                metrics.connections.inc();
                active.fetch_add(1, Ordering::AcqRel);
                metrics.active_connections.add(1);
                let h = Arc::clone(&handler);
                let guard = ConnGuard {
                    active: Arc::clone(&active),
                    metrics: Arc::clone(&metrics),
                };
                let m = Arc::clone(&metrics);
                let g = Arc::clone(&gate);
                let stop = Arc::clone(&stop);
                let spawned = std::thread::Builder::new().name("ocpd-conn".into()).spawn(
                    move || {
                        // The guard decrements even if a handler panics
                        // (unwinding runs drops), so the admission gate
                        // and drain never count ghost connections.
                        let _guard = guard;
                        let _ = serve_connection(stream, h.as_ref(), &cfg, &m, &stop, &g);
                    },
                );
                if spawned.is_err() {
                    // Thread exhaustion: shed the connection (the
                    // failed spawn dropped the closure and with it the
                    // guard), count it.
                    metrics.accept_errors.inc();
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // Nothing to accept: exponential idle backoff (capped
                // low — this bounds accept latency) instead of a fixed
                // spin interval.
                std::thread::sleep(idle_backoff);
                idle_backoff = (idle_backoff * 2).min(ACCEPT_IDLE_BACKOFF_CAP);
            }
            Err(_) => {
                // EMFILE/ENFILE/ECONNABORTED storms: count, back off
                // exponentially (capped), and keep serving — the old
                // loop killed the server here.
                metrics.accept_errors.inc();
                std::thread::sleep(error_backoff);
                error_backoff = (error_backoff * 2).min(ACCEPT_ERROR_BACKOFF_CAP);
            }
        }
    }
}

/// Decrements the live-connection accounting when a connection thread
/// exits — by any path, including a panicking handler (unwinding runs
/// drops), so the admission gate and drain never count ghosts.
struct ConnGuard {
    active: Arc<AtomicUsize>,
    metrics: Arc<HttpMetrics>,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.active.fetch_sub(1, Ordering::AcqRel);
        self.metrics.active_connections.sub(1);
    }
}

/// Answer 503 + Retry-After and drain briefly, so closing with unread
/// data does not RST the response out of the peer's receive buffer.
fn shed_503(stream: TcpStream) {
    let _ = write_response(&stream, Response::overloaded(), false);
    stream.set_read_timeout(Some(Duration::from_millis(50))).ok();
    let mut sink = [0u8; 8192];
    for _ in 0..8 {
        match (&stream).read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}

/// Peek (without consuming) the pending request line of an over-cap
/// connection and return its path, waiting up to [`PEEK_DEADLINE`] for
/// the peer to send it. `None` — a silent, closed, or garbled peer —
/// means the caller sheds exactly as the FIFO gate would have.
fn peek_first_path(stream: &TcpStream) -> Option<String> {
    stream.set_read_timeout(Some(Duration::from_millis(50))).ok()?;
    let deadline = std::time::Instant::now() + PEEK_DEADLINE;
    let mut buf = [0u8; 2048];
    loop {
        match stream.peek(&mut buf) {
            Ok(0) => return None,
            Ok(n) => {
                if let Some(eol) = buf[..n].iter().position(|&b| b == b'\n') {
                    let line = String::from_utf8_lossy(&buf[..eol]);
                    let mut parts = line.split_whitespace();
                    let _method = parts.next()?;
                    return parts.next().map(str::to_string);
                }
                if n == buf.len() {
                    return None; // request line longer than any sane one
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => return None,
        }
        if std::time::Instant::now() >= deadline {
            return None;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// The weighted gate's over-capacity decision, run on the disposable
/// shed thread. Peek the pending request line, resolve its tenant's
/// weight, and admit the connection iff it outweighs every tenant
/// currently holding one AND a slot under the bounded overflow
/// allowance can be claimed; shed it with a 503 otherwise. `peek` does
/// not consume bytes, so the admitted connection runs the ordinary
/// request loop from byte zero.
#[allow(clippy::too_many_arguments)]
fn shed_or_admit<F>(
    stream: TcpStream,
    cfg: ServerConfig,
    gate: Arc<Gate>,
    weight_of: WeightFn,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    metrics: Arc<HttpMetrics>,
    handler: Arc<F>,
) where
    F: Fn(Request) -> Response + Send + Sync + 'static,
{
    let admit = peek_first_path(&stream).is_some_and(|path| {
        weight_of(tenant_of(&path)) > gate.min_active_weight(&weight_of)
            && try_reserve(&active, overflow_cap(&cfg))
    });
    if !admit {
        metrics.rejected.inc();
        shed_503(stream);
        return;
    }
    // The slot is claimed (try_reserve): mirror the admitted path's
    // accounting, with the guard releasing the slot on any exit.
    metrics.priority_admits.inc();
    metrics.connections.inc();
    metrics.active_connections.add(1);
    let _guard = ConnGuard { active, metrics: Arc::clone(&metrics) };
    let _ = serve_connection(stream, handler.as_ref(), &cfg, &metrics, &stop, &gate);
}

/// Decrements the in-flight gauge when request handling ends, panic or
/// not.
struct FlightGuard<'a>(&'a Gauge);

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        self.0.sub(1);
    }
}

/// Why the idle wait between keep-alive requests ended.
enum IdleOutcome {
    /// Bytes are buffered: parse the next request.
    Ready,
    /// Peer closed between requests — clean end of connection.
    PeerClosed,
    /// Idle timeout or server drain: close without a response.
    Close,
}

/// Wait (bounded) for the first byte of the next pipelined request,
/// polling so a server drain closes idle connections promptly.
fn await_next_request(
    reader: &mut BufReader<TcpStream>,
    stream: &TcpStream,
    idle_timeout: Duration,
    stop: &AtomicBool,
) -> IdleOutcome {
    if !reader.buffer().is_empty() {
        return IdleOutcome::Ready; // pipelined request already buffered
    }
    let t0 = std::time::Instant::now();
    stream.set_read_timeout(Some(IDLE_POLL)).ok();
    loop {
        if stop.load(Ordering::Relaxed) {
            return IdleOutcome::Close;
        }
        match reader.fill_buf() {
            Ok([]) => return IdleOutcome::PeerClosed,
            Ok(_) => return IdleOutcome::Ready,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if t0.elapsed() >= idle_timeout {
                    return IdleOutcome::Close;
                }
            }
            Err(_) => return IdleOutcome::Close,
        }
    }
}

/// Registers the connection's tenant (from its first parsed request)
/// with the admission gate, and deregisters on any exit path — panics
/// included — so [`Gate::min_active_weight`] never counts ghosts.
struct TenantGuard<'a> {
    gate: &'a Gate,
    tenant: Option<String>,
}

impl TenantGuard<'_> {
    fn register(&mut self, path: &str) {
        if self.tenant.is_none() {
            let t = tenant_of(path).to_string();
            self.gate.note_open(&t);
            self.tenant = Some(t);
        }
    }
}

impl Drop for TenantGuard<'_> {
    fn drop(&mut self) {
        if let Some(t) = &self.tenant {
            self.gate.note_close(t);
        }
    }
}

/// One connection's lifetime: a request loop until close/drain/error.
fn serve_connection<F: Fn(Request) -> Response>(
    stream: TcpStream,
    handler: &F,
    cfg: &ServerConfig,
    metrics: &HttpMetrics,
    stop: &AtomicBool,
    gate: &Gate,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut tenant = TenantGuard { gate, tenant: None };
    let mut served = 0usize;
    loop {
        if served > 0 {
            match await_next_request(&mut reader, &stream, cfg.idle_timeout, stop) {
                IdleOutcome::Ready => {}
                IdleOutcome::PeerClosed | IdleOutcome::Close => break,
            }
        }
        // A stalled or byte-at-a-time peer times out instead of pinning
        // the connection thread forever.
        stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
        let deadline = std::time::Instant::now() + REQUEST_DEADLINE;
        let t0 = std::time::Instant::now();
        metrics.in_flight.add(1);
        let flight = FlightGuard(&metrics.in_flight);
        let outcome = read_request(&mut reader, cfg.max_body, deadline);
        let result = match outcome {
            Ok(req) => {
                tenant.register(&req.path);
                // Drain takes priority over the client's preference; a
                // response during drain is the connection's last.
                let mut keep = req.keep_alive && !stop.load(Ordering::Relaxed);
                let http10 = req.http10;
                let resp = handler(req);
                let route = resp.route;
                // HTTP/1.0 peers cannot parse chunked framing: streamed
                // bodies go close-delimited, which spends the socket.
                if http10 && matches!(resp.body, Body::Stream(_)) {
                    keep = false;
                }
                let io = write_response_v(&stream, resp, keep, !http10);
                metrics.requests.inc();
                let dt = t0.elapsed();
                metrics.latency.record(dt);
                if let Some(route) = route {
                    metrics.route_latency(route).record(dt);
                }
                drop(flight);
                io?;
                served += 1;
                if !keep {
                    break;
                }
                Ok(())
            }
            Err(resp) => {
                // Parse failure: answer, drain what the peer already
                // sent (so the response is not reset out of its receive
                // buffer), close — framing is no longer trustworthy.
                metrics.requests.inc();
                metrics.latency.record(t0.elapsed());
                drop(flight);
                let io = write_response(&stream, resp, false);
                drain_peer(&stream, &mut reader);
                io?;
                Err(())
            }
        };
        if result.is_err() {
            break;
        }
    }
    Ok(())
}

/// Bounded (bytes AND time) sink of whatever the peer already sent.
fn drain_peer(stream: &TcpStream, reader: &mut BufReader<TcpStream>) {
    stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
    let deadline = std::time::Instant::now() + Duration::from_secs(2);
    let mut sink = [0u8; 8192];
    let mut budget = 256usize << 10;
    while budget > 0 && std::time::Instant::now() < deadline {
        match reader.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(n) => budget -= n.min(budget),
        }
    }
}

/// `read_line` under an overall deadline: bytes are consumed one at a
/// time through the `BufRead` buffer (cheap), with a deadline check
/// before every read, so a peer trickling one byte per almost-timeout
/// is bounded by `deadline + one socket timeout`, not `bytes x timeout`.
fn read_line_bounded<R: BufRead>(
    reader: &mut R,
    line: &mut String,
    deadline: std::time::Instant,
) -> std::io::Result<usize> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        // Every iteration: a single 1-byte read can block for the whole
        // socket timeout, so a sparser check would multiply the bound.
        if std::time::Instant::now() >= deadline {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "request deadline exceeded",
            ));
        }
        let mut b = [0u8; 1];
        match reader.read(&mut b) {
            Ok(0) => break,
            Ok(_) => {
                buf.push(b[0]);
                if b[0] == b'\n' {
                    break;
                }
            }
            // A read timeout mid-line is a stalled peer, not retryable.
            Err(e) => return Err(e),
        }
    }
    let n = buf.len();
    line.push_str(&String::from_utf8_lossy(&buf));
    Ok(n)
}

/// Parse one request, or produce the error response to send instead.
/// Every failure path is a response, never a panic, never an unbounded
/// buffer, and never an unbounded wait.
fn read_request(
    reader: &mut BufReader<TcpStream>,
    max_body: usize,
    deadline: std::time::Instant,
) -> std::result::Result<Request, Response> {
    // Cap the request line + headers together so hostile peers cannot
    // grow memory without bound.
    let mut head = Read::take(&mut *reader, MAX_HEAD_BYTES);
    let mut line = String::new();
    match read_line_bounded(&mut head, &mut line, deadline) {
        Ok(0) => return Err(Response::error(400, "empty request")),
        Ok(_) => {}
        Err(e) => return Err(Response::error(400, format!("unreadable request line: {e}"))),
    }
    if !line.ends_with('\n') {
        // EOF mid-line, or the head cap was hit before a newline.
        return Err(Response::error(400, "truncated or oversized request line"));
    }
    let mut parts = line.split_whitespace();
    let Some(method) = parts.next().map(str::to_string) else {
        return Err(Response::error(400, "empty request line"));
    };
    if !method.chars().all(|c| c.is_ascii_alphabetic()) || method.len() > 16 {
        return Err(Response::error(400, format!("bad method '{method}'")));
    }
    let Some(path) = parts.next().map(str::to_string) else {
        return Err(Response::error(400, "missing path"));
    };
    // HTTP/1.0 peers default to close; anything else (including the
    // absent version of a sloppy client) gets 1.1 keep-alive semantics.
    let http10 = parts.next() == Some("HTTP/1.0");

    // Headers.
    let mut content_length: Option<usize> = None;
    let mut connection_close = http10;
    let mut connection_keep = false;
    let mut request_id: Option<String> = None;
    let mut deadline_ms: Option<u64> = None;
    loop {
        let mut h = String::new();
        match read_line_bounded(&mut head, &mut h, deadline) {
            Ok(0) => return Err(Response::error(400, "truncated headers")),
            Ok(_) => {}
            Err(e) => return Err(Response::error(400, format!("unreadable header: {e}"))),
        }
        if !h.ends_with('\n') {
            return Err(Response::error(400, "truncated or oversized headers"));
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let v = v.trim();
            if k.eq_ignore_ascii_case("content-length") {
                let n: usize = match v.parse() {
                    Ok(n) => n,
                    Err(_) => {
                        return Err(Response::error(400, format!("bad content-length '{v}'")))
                    }
                };
                // Conflicting lengths are a request-smuggling vector:
                // refuse rather than pick one (RFC 9112 §6.3).
                if content_length.is_some_and(|prev| prev != n) {
                    return Err(Response::error(400, "conflicting content-length headers"));
                }
                content_length = Some(n);
            } else if k.eq_ignore_ascii_case("transfer-encoding") {
                // Chunked *request* bodies are not part of our grammar
                // (uploads are length-framed); refusing beats guessing
                // at framing.
                return Err(Response::error(
                    400,
                    format!("transfer-encoding '{v}' not supported for request bodies"),
                ));
            } else if k.eq_ignore_ascii_case("connection") {
                for token in v.split(',') {
                    let token = token.trim();
                    if token.eq_ignore_ascii_case("close") {
                        connection_close = true;
                    } else if token.eq_ignore_ascii_case("keep-alive") {
                        connection_keep = true;
                    }
                }
            } else if k.eq_ignore_ascii_case("x-ocpd-deadline-ms") {
                // An unparseable budget is ignored rather than refused:
                // deadlines are advisory, not part of the grammar.
                deadline_ms = v.parse::<u64>().ok().filter(|&ms| ms > 0);
            } else if k.eq_ignore_ascii_case("x-request-id") && !v.is_empty() {
                // Cap and sanitize: the id is echoed in a response
                // header and rendered in trace/log output.
                let id: String = v
                    .chars()
                    .take(64)
                    .filter(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
                    .collect();
                if !id.is_empty() {
                    request_id = Some(id);
                }
            }
        }
    }
    let content_length = content_length.unwrap_or(0);
    if content_length > max_body {
        return Err(Response::error(
            413,
            format!("body of {content_length} bytes exceeds the {max_body}-byte limit"),
        ));
    }
    // Body: chunked reads under the same overall deadline, so the
    // worker's total time on one request is bounded even when every
    // chunk arrives just inside the socket timeout.
    let mut body = vec![0u8; content_length];
    let mut filled = 0usize;
    while filled < content_length {
        if std::time::Instant::now() >= deadline {
            return Err(Response::error(400, "request body deadline exceeded"));
        }
        let want = (content_length - filled).min(64 << 10);
        match reader.read(&mut body[filled..filled + want]) {
            Ok(0) => return Err(Response::error(400, "truncated body")),
            Ok(n) => filled += n,
            Err(e) => return Err(Response::error(400, format!("truncated body: {e}"))),
        }
    }
    let keep_alive = !connection_close || (http10 && connection_keep);
    Ok(Request { method, path, body, request_id, deadline_ms, keep_alive, http10 })
}

/// [`write_response_v`] with chunked framing allowed (HTTP/1.1 peers).
fn write_response(stream: &TcpStream, resp: Response, keep: bool) -> Result<()> {
    write_response_v(stream, resp, keep, true)
}

/// Write one response. `chunked_ok = false` (HTTP/1.0 peer) turns a
/// streamed body into a close-delimited raw stream — no chunk framing,
/// `Connection: close`, body ends when the socket does.
fn write_response_v(
    mut stream: &TcpStream,
    resp: Response,
    keep: bool,
    chunked_ok: bool,
) -> Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\n",
        resp.status,
        resp.reason(),
        resp.content_type
    );
    if let Some(methods) = &resp.allow {
        head.push_str(&format!("Allow: {methods}\r\n"));
    }
    if let Some(secs) = resp.retry_after {
        head.push_str(&format!("Retry-After: {secs}\r\n"));
    }
    if let Some(id) = &resp.request_id {
        head.push_str(&format!("X-Request-Id: {id}\r\n"));
    }
    let conn = if keep { "keep-alive" } else { "close" };
    match resp.body {
        Body::Bytes(ref b) => {
            head.push_str(&format!(
                "Content-Length: {}\r\nConnection: {conn}\r\n\r\n",
                b.len()
            ));
            stream.write_all(head.as_bytes())?;
            stream.write_all(b)?;
        }
        Body::Shared(ref b) => {
            head.push_str(&format!(
                "Content-Length: {}\r\nConnection: {conn}\r\n\r\n",
                b.len()
            ));
            stream.write_all(head.as_bytes())?;
            stream.write_all(b)?;
        }
        Body::Stream(mut next) => {
            if chunked_ok {
                head.push_str(&format!(
                    "Transfer-Encoding: chunked\r\nConnection: {conn}\r\n\r\n"
                ));
            } else {
                // HTTP/1.0: close-delimited body (caller forces close).
                head.push_str("Connection: close\r\n\r\n");
            }
            stream.write_all(head.as_bytes())?;
            loop {
                match next() {
                    Ok(Some(chunk)) => {
                        if chunk.is_empty() {
                            continue; // an empty chunk would terminate the body
                        }
                        if chunked_ok {
                            write!(stream, "{:x}\r\n", chunk.len())?;
                            stream.write_all(&chunk)?;
                            stream.write_all(b"\r\n")?;
                        } else {
                            stream.write_all(&chunk)?;
                        }
                    }
                    Ok(None) => {
                        if chunked_ok {
                            stream.write_all(b"0\r\n\r\n")?;
                        }
                        break;
                    }
                    Err(e) => {
                        // The status line is gone; the only honest move
                        // is to abort the connection so the client sees
                        // a truncated body, not silent data loss.
                        let _ = stream.shutdown(std::net::Shutdown::Both);
                        return Err(e);
                    }
                }
            }
        }
    }
    stream.flush()?;
    Ok(())
}

/// Record a streamed response's chunk high-water mark (called by the
/// routes layer as it produces chunks).
pub(crate) fn note_stream_chunk(metrics: &HttpMetrics, bytes: usize) {
    metrics.stream_peak_chunk.record_max(bytes as u64);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        Server::bind("127.0.0.1:0", 4, |req| match req.path.as_str() {
            "/hello/" => Response::text("world"),
            "/echo/" => Response::binary(req.body),
            "/missing/" => Response::error(404, "nope"),
            "/stream/" => {
                let mut i = 0u32;
                Response::stream(
                    "text/plain",
                    Box::new(move || {
                        i += 1;
                        Ok((i <= 4).then(|| format!("chunk{i};").into_bytes()))
                    }),
                )
            }
            p => Response::text(format!("{} {p}", req.method)),
        })
        .unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let s = echo_server();
        let (code, body) = request("GET", &format!("{}/hello/", s.url()), &[]).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, b"world");
        assert_eq!(s.requests.get(), 1);
    }

    #[test]
    fn put_body_roundtrip() {
        let s = echo_server();
        let payload: Vec<u8> = (0..10_000u32).map(|i| i as u8).collect();
        let (code, body) = request("PUT", &format!("{}/echo/", s.url()), &payload).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, payload);
    }

    #[test]
    fn status_codes_propagate() {
        let s = echo_server();
        let (code, _) = request("GET", &format!("{}/missing/", s.url()), &[]).unwrap();
        assert_eq!(code, 404);
    }

    #[test]
    fn chunked_stream_roundtrip() {
        let s = echo_server();
        let info = request_info("GET", &format!("{}/stream/", s.url()), &[]).unwrap();
        assert_eq!(info.status, 200);
        assert!(info.chunked);
        assert_eq!(info.body, b"chunk1;chunk2;chunk3;chunk4;");
        assert!(info.max_chunk >= b"chunk1;".len());
    }

    /// The request counter increments after the response is written, so
    /// wait for it to catch up before asserting exact counts.
    fn await_requests(s: &Server, n: u64) {
        let t0 = std::time::Instant::now();
        while s.metrics.requests.get() < n && t0.elapsed() < Duration::from_secs(2) {
            std::thread::yield_now();
        }
    }

    #[test]
    fn keep_alive_reuses_one_connection() {
        let s = echo_server();
        // Sequential pooled requests ride the same socket: connection
        // count stays at 1 while the request count climbs.
        for _ in 0..5 {
            let (code, _) = request("GET", &format!("{}/hello/", s.url()), &[]).unwrap();
            assert_eq!(code, 200);
        }
        await_requests(&s, 5);
        assert_eq!(s.metrics.requests.get(), 5);
        assert_eq!(s.metrics.connections.get(), 1, "keep-alive must reuse the socket");
        assert!(s.metrics.reuse_ratio() >= 5.0);
    }

    #[test]
    fn close_per_request_opens_fresh_connections() {
        let s = echo_server();
        for _ in 0..3 {
            let (code, _) = request_once("GET", &format!("{}/hello/", s.url()), &[]).unwrap();
            assert_eq!(code, 200);
        }
        assert_eq!(s.metrics.connections.get(), 3);
    }

    #[test]
    fn concurrent_requests() {
        let s = echo_server();
        let url = s.url();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let url = url.clone();
                std::thread::spawn(move || {
                    // Retry transient connect failures (the suite runs many
                    // servers concurrently and SYN backlogs can overflow).
                    let mut last = None;
                    for _ in 0..10 {
                        match request("GET", &format!("{url}/req{i}/"), &[]) {
                            Ok((code, body)) => {
                                assert_eq!(code, 200);
                                assert_eq!(body, format!("GET /req{i}/").into_bytes());
                                return;
                            }
                            Err(e) => {
                                last = Some(e);
                                std::thread::sleep(std::time::Duration::from_millis(20));
                            }
                        }
                    }
                    panic!("request kept failing: {last:?}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // The counter increments after the response is written, so give
        // the worker threads a beat to record the last requests.
        let t0 = std::time::Instant::now();
        while s.requests.get() < 16 && t0.elapsed() < std::time::Duration::from_secs(2) {
            std::thread::yield_now();
        }
        assert!(s.requests.get() >= 16);
    }

    /// Write raw bytes to the server and return the status code it
    /// answers with.
    fn raw_status(addr: std::net::SocketAddr, payload: &[u8]) -> u16 {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
        // The server may answer (and close) before the payload is fully
        // written; that is fine — we only care about the status line.
        let _ = s.write_all(payload);
        let _ = s.shutdown(std::net::Shutdown::Write);
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line).unwrap();
        line.split_whitespace().nth(1).and_then(|v| v.parse().ok()).unwrap_or(0)
    }

    #[test]
    fn malformed_request_lines_get_400() {
        let s = echo_server();
        // No path.
        assert_eq!(raw_status(s.addr(), b"GARBAGE\r\n\r\n"), 400);
        // Empty request line.
        assert_eq!(raw_status(s.addr(), b"\r\n\r\n"), 400);
        // Binary junk where a method should be.
        assert_eq!(raw_status(s.addr(), b"\x00\x01\x02 /x/ HTTP/1.1\r\n\r\n"), 400);
        // Connection closed before any byte.
        assert_eq!(raw_status(s.addr(), b""), 400);
    }

    #[test]
    fn garbage_content_length_gets_400() {
        let s = echo_server();
        assert_eq!(
            raw_status(s.addr(), b"PUT /echo/ HTTP/1.1\r\nContent-Length: banana\r\n\r\n"),
            400
        );
        assert_eq!(
            raw_status(s.addr(), b"PUT /echo/ HTTP/1.1\r\nContent-Length: -5\r\n\r\n"),
            400
        );
        // Body shorter than advertised (peer hangs up): 400, not a hang.
        assert_eq!(
            raw_status(s.addr(), b"PUT /echo/ HTTP/1.1\r\nContent-Length: 50\r\n\r\nhi"),
            400
        );
    }

    #[test]
    fn conflicting_content_lengths_get_400() {
        let s = echo_server();
        assert_eq!(
            raw_status(
                s.addr(),
                b"PUT /echo/ HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 5\r\n\r\nhi"
            ),
            400
        );
        // Duplicate but agreeing lengths are tolerated.
        assert_eq!(
            raw_status(
                s.addr(),
                b"PUT /echo/ HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nhi"
            ),
            200
        );
    }

    #[test]
    fn chunked_request_bodies_rejected() {
        let s = echo_server();
        assert_eq!(
            raw_status(
                s.addr(),
                b"PUT /echo/ HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n2\r\nhi\r\n0\r\n\r\n"
            ),
            400
        );
    }

    #[test]
    fn oversized_body_gets_413() {
        let s = Server::bind_with_limit("127.0.0.1:0", 2, 1024, |req| {
            Response::binary(req.body)
        })
        .unwrap();
        // Advertised over the cap: refused before any body byte is read.
        assert_eq!(
            raw_status(s.addr(), b"PUT /echo/ HTTP/1.1\r\nContent-Length: 10000\r\n\r\n"),
            413
        );
        // At the cap: accepted.
        let payload = vec![7u8; 1024];
        let (code, body) = request("PUT", &format!("{}/echo/", s.url()), &payload).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, payload);
    }

    #[test]
    fn oversized_head_gets_400() {
        let s = echo_server();
        // A single endless header line (no terminator) must be cut off
        // at the head cap, not buffered forever.
        let mut payload = b"GET /hello/ HTTP/1.1\r\nX-Junk: ".to_vec();
        payload.extend(std::iter::repeat(b'a').take(80 << 10));
        assert_eq!(raw_status(s.addr(), &payload), 400);
    }

    #[test]
    fn method_not_allowed_carries_allow_header() {
        let s = Server::bind("127.0.0.1:0", 2, |_req| {
            Response::method_not_allowed("GET, PUT")
        })
        .unwrap();
        let mut stream = TcpStream::connect(s.addr()).unwrap();
        stream
            .write_all(b"DELETE /x/ HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap();
        let mut raw = String::new();
        BufReader::new(stream).read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 405 Method Not Allowed"), "{raw}");
        assert!(raw.contains("\r\nAllow: GET, PUT\r\n"), "{raw}");
    }

    #[test]
    fn admission_gate_answers_503_with_retry_after() {
        let cfg = ServerConfig { max_connections: 1, ..ServerConfig::default() };
        let s = Server::bind_with_config(
            "127.0.0.1:0",
            cfg,
            Arc::new(HttpMetrics::default()),
            |_req| Response::text("ok"),
        )
        .unwrap();
        // First connection occupies the only slot (keep-alive holds it).
        let mut held = TcpStream::connect(s.addr()).unwrap();
        held.write_all(b"GET /a/ HTTP/1.1\r\n\r\n").unwrap();
        let mut r = BufReader::new(held.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("200"), "{line}");

        // Second connection is shed at the gate.
        let t0 = std::time::Instant::now();
        let mut got_503 = false;
        while t0.elapsed() < Duration::from_secs(5) && !got_503 {
            let over = TcpStream::connect(s.addr()).unwrap();
            over.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            let mut raw = String::new();
            let mut rr = BufReader::new(over);
            // The gate answers without waiting for a request.
            if rr.read_to_string(&mut raw).is_ok() && raw.starts_with("HTTP/1.1 503") {
                assert!(raw.contains("\r\nRetry-After: 1\r\n"), "{raw}");
                got_503 = true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(got_503, "admission gate never rejected past capacity");
        assert!(s.metrics.rejected.get() >= 1);
    }

    /// Weighted shedding (ROADMAP item 2 leftover): with a QoS weight
    /// hook installed and the gate full, the heavy (low-weight) tenant
    /// is shed first while the high-weight tenant is admitted past the
    /// same full gate.
    #[test]
    fn admission_gate_sheds_lowest_weight_tenant_first() {
        let cfg = ServerConfig { max_connections: 1, ..ServerConfig::default() };
        let s = Server::bind_with_config(
            "127.0.0.1:0",
            cfg,
            Arc::new(HttpMetrics::default()),
            |_req| Response::text("ok"),
        )
        .unwrap();
        s.set_tenant_weights(Arc::new(|t: &str| if t == "vip" { 100 } else { 1 }));

        // A low-weight tenant's keep-alive connection occupies the only
        // slot; reading the response guarantees its tenant registered.
        let mut held = TcpStream::connect(s.addr()).unwrap();
        held.write_all(b"GET /bulk/a/ HTTP/1.1\r\n\r\n").unwrap();
        let mut r = BufReader::new(held.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("200"), "{line}");

        // Over-cap admissions release their slot when the connection
        // ends; wait for that before the next probe so the bounded
        // overflow allowance (1 here) is free again.
        let await_held_only = || {
            let t0 = std::time::Instant::now();
            while s.metrics.active_connections.get() > 1 {
                assert!(
                    t0.elapsed() < Duration::from_secs(2),
                    "over-cap connection never released its slot"
                );
                std::thread::sleep(Duration::from_millis(2));
            }
        };

        // Storm the full gate, alternating tenants: vip (weight 100)
        // outweighs the holder (weight 1) and is admitted every time;
        // bulk (weight 1) does not outweigh it and is shed every time.
        for _ in 0..5 {
            assert_eq!(
                raw_status(s.addr(), b"GET /vip/x/ HTTP/1.1\r\nConnection: close\r\n\r\n"),
                200,
                "high-weight tenant shed at the gate"
            );
            await_held_only();
            assert_eq!(
                raw_status(s.addr(), b"GET /bulk/x/ HTTP/1.1\r\nConnection: close\r\n\r\n"),
                503,
                "low-weight tenant admitted past a full gate"
            );
        }
        assert!(s.metrics.priority_admits.get() >= 5);
        assert!(s.metrics.rejected.get() >= 5);
        // The held connection still works after the storm. The reader
        // still holds the first response's unread headers and body, so
        // drain to EOF (`Connection: close` ends the socket) and look
        // for the second response's status line in the remainder.
        held.write_all(b"GET /bulk/a/ HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        let mut rest = String::new();
        r.read_to_string(&mut rest).unwrap();
        assert!(rest.contains("HTTP/1.1 200"), "{rest}");
    }

    #[test]
    fn graceful_drain_closes_idle_keepalive() {
        let s = echo_server();
        // An idle keep-alive connection...
        let mut held = TcpStream::connect(s.addr()).unwrap();
        held.write_all(b"GET /hello/ HTTP/1.1\r\n\r\n").unwrap();
        let mut r = BufReader::new(held.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("200"), "{line}");
        // ...drain the headers + body we didn't parse carefully.
        std::thread::sleep(Duration::from_millis(50));
        // Drain: the idle connection must close within the poll window.
        s.stop();
        assert_eq!(s.drain(Duration::from_secs(3)), 0, "idle connection did not drain");
    }

    #[test]
    fn stops_on_drop() {
        let url;
        {
            let s = echo_server();
            url = s.url();
        }
        // After drop, connection must fail (allow a beat for teardown).
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(request("GET", &format!("{url}/hello/"), &[]).is_err());
    }
}
