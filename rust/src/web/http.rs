//! Minimal HTTP/1.1 server and client.
//!
//! The paper's application stack runs each Web-service request on a
//! single process thread (Apache2 + Django/WSGI, §4.2/§5) and realizes
//! throughput by issuing many requests in parallel; this server does the
//! same with a thread pool over `std::net`. No external HTTP crates exist
//! in the offline vendor set (DESIGN.md §1).
//!
//! Supported surface: GET/PUT/DELETE request line, `Content-Length`
//! bodies, connection-close semantics.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::metrics::{Counter, Histogram};
use crate::util::ThreadPool;
use crate::{Error, Result};

/// A parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path, percent-decoding not needed for our grammar.
    pub path: String,
    pub body: Vec<u8>,
}

/// A response under construction.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn ok(body: Vec<u8>, content_type: &'static str) -> Response {
        Response { status: 200, content_type, body }
    }

    pub fn text(s: impl Into<String>) -> Response {
        Response::ok(s.into().into_bytes(), "text/plain")
    }

    pub fn binary(body: Vec<u8>) -> Response {
        Response::ok(body, "application/x-ocpk")
    }

    pub fn error(status: u16, msg: impl Into<String>) -> Response {
        Response { status, content_type: "text/plain", body: msg.into().into_bytes() }
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            _ => "Internal Server Error",
        }
    }
}

/// A running HTTP server (drops → stops accepting).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    pub requests: Arc<Counter>,
    pub latency: Arc<Histogram>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve `handler` on `workers` threads.
    pub fn bind<F>(addr: &str, workers: usize, handler: F) -> Result<Server>
    where
        F: Fn(Request) -> Response + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let requests = Arc::new(Counter::default());
        let latency = Arc::new(Histogram::new());
        let handler = Arc::new(handler);

        let stop2 = Arc::clone(&stop);
        let requests2 = Arc::clone(&requests);
        let latency2 = Arc::clone(&latency);
        let accept_thread = std::thread::Builder::new()
            .name("ocpd-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                loop {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let h = Arc::clone(&handler);
                            let reqs = Arc::clone(&requests2);
                            let lat = Arc::clone(&latency2);
                            pool.submit(move || {
                                let t0 = std::time::Instant::now();
                                let _ = handle_connection(stream, h.as_ref());
                                reqs.inc();
                                lat.record(t0.elapsed());
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn accept thread");

        Ok(Server { addr, stop, requests, latency, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection<F: Fn(Request) -> Response>(stream: TcpStream, handler: &F) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let req = match read_request(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            let resp = Response::error(400, format!("bad request: {e}"));
            write_response(&stream, &resp)?;
            return Ok(());
        }
    };
    let resp = handler(req);
    write_response(&stream, &resp)
}

fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Request> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| Error::BadRequest("empty request line".into()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| Error::BadRequest("missing path".into()))?
        .to_string();
    // Headers.
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .map_err(|_| Error::BadRequest("bad content-length".into()))?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Request { method, path, body })
}

fn write_response(mut stream: &TcpStream, resp: &Response) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        resp.reason(),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

/// Minimal blocking HTTP client (one request per connection — matches the
/// server's connection-close semantics).
pub fn request(method: &str, url: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| Error::BadRequest(format!("unsupported url '{url}'")))?;
    let (host, path) = match rest.split_once('/') {
        Some((h, p)) => (h, format!("/{p}")),
        None => (rest, "/".to_string()),
    };
    let mut stream = TcpStream::connect(host)?;
    stream.set_nodelay(true).ok();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {host}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Other(format!("bad status line '{status_line}'")))?;
    let mut content_length = None;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse::<usize>().ok();
            }
        }
    }
    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            body.resize(n, 0);
            reader.read_exact(&mut body)?;
        }
        None => {
            reader.read_to_end(&mut body)?;
        }
    }
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        Server::bind("127.0.0.1:0", 4, |req| match req.path.as_str() {
            "/hello/" => Response::text("world"),
            "/echo/" => Response::binary(req.body),
            "/missing/" => Response::error(404, "nope"),
            p => Response::text(format!("{} {p}", req.method)),
        })
        .unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let s = echo_server();
        let (code, body) = request("GET", &format!("{}/hello/", s.url()), &[]).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, b"world");
        assert_eq!(s.requests.get(), 1);
    }

    #[test]
    fn put_body_roundtrip() {
        let s = echo_server();
        let payload: Vec<u8> = (0..10_000u32).map(|i| i as u8).collect();
        let (code, body) = request("PUT", &format!("{}/echo/", s.url()), &payload).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, payload);
    }

    #[test]
    fn status_codes_propagate() {
        let s = echo_server();
        let (code, _) = request("GET", &format!("{}/missing/", s.url()), &[]).unwrap();
        assert_eq!(code, 404);
    }

    #[test]
    fn concurrent_requests() {
        let s = echo_server();
        let url = s.url();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let url = url.clone();
                std::thread::spawn(move || {
                    // Retry transient connect failures (the suite runs many
                    // servers concurrently and SYN backlogs can overflow).
                    let mut last = None;
                    for _ in 0..10 {
                        match request("GET", &format!("{url}/req{i}/"), &[]) {
                            Ok((code, body)) => {
                                assert_eq!(code, 200);
                                assert_eq!(body, format!("GET /req{i}/").into_bytes());
                                return;
                            }
                            Err(e) => {
                                last = Some(e);
                                std::thread::sleep(std::time::Duration::from_millis(20));
                            }
                        }
                    }
                    panic!("request kept failing: {last:?}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // The counter increments after the response is written, so give
        // the worker threads a beat to record the last requests.
        let t0 = std::time::Instant::now();
        while s.requests.get() < 16 && t0.elapsed() < std::time::Duration::from_secs(2) {
            std::thread::yield_now();
        }
        assert!(s.requests.get() >= 16);
    }

    #[test]
    fn stops_on_drop() {
        let url;
        {
            let s = echo_server();
            url = s.url();
        }
        // After drop, connection must fail (allow a beat for teardown).
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(request("GET", &format!("{url}/hello/"), &[]).is_err());
    }
}
