//! Minimal HTTP/1.1 server and client.
//!
//! The paper's application stack runs each Web-service request on a
//! single process thread (Apache2 + Django/WSGI, §4.2/§5) and realizes
//! throughput by issuing many requests in parallel; this server does the
//! same with a thread pool over `std::net`. No external HTTP crates exist
//! in the offline vendor set (DESIGN.md §1).
//!
//! Supported surface: GET/PUT/DELETE request line, `Content-Length`
//! bodies, connection-close semantics.
//!
//! The parser is hostile-input hardened: request heads are size-capped,
//! bodies are bounded (413 beyond the limit), garbage request lines and
//! `Content-Length` values produce 400s, and reads carry a timeout so a
//! stalled peer cannot pin a worker thread.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::metrics::{Counter, Histogram};
use crate::util::ThreadPool;
use crate::{Error, Result};

/// Default request-body cap (64 MiB — comfortably above the largest
/// cutout upload the benches issue). See [`Server::bind_with_limit`].
pub const DEFAULT_MAX_BODY: usize = 64 << 20;

/// Cap on the request line + headers together.
const MAX_HEAD_BYTES: u64 = 64 << 10;

/// How long a worker waits on a silent peer before giving up.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Overall wall-clock budget for reading one request (head + body). A
/// peer that trickles bytes — each arriving just inside the socket
/// timeout — is cut off here instead of pinning a worker indefinitely.
const REQUEST_DEADLINE: Duration = Duration::from_secs(60);

/// A parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path, percent-decoding not needed for our grammar.
    pub path: String,
    pub body: Vec<u8>,
}

/// A response under construction.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Methods advertised in an `Allow` header — set on 405 responses
    /// (RFC 9110 §15.5.6: a 405 "MUST generate an Allow header").
    pub allow: Option<&'static str>,
}

impl Response {
    pub fn ok(body: Vec<u8>, content_type: &'static str) -> Response {
        Response { status: 200, content_type, body, allow: None }
    }

    pub fn text(s: impl Into<String>) -> Response {
        Response::ok(s.into().into_bytes(), "text/plain")
    }

    pub fn binary(body: Vec<u8>) -> Response {
        Response::ok(body, "application/x-ocpk")
    }

    pub fn error(status: u16, msg: impl Into<String>) -> Response {
        Response { status, content_type: "text/plain", body: msg.into().into_bytes(), allow: None }
    }

    /// A 405 naming the methods the route does accept.
    pub fn method_not_allowed(allow: &'static str) -> Response {
        Response {
            status: 405,
            content_type: "text/plain",
            body: format!("method not allowed (allow: {allow})").into_bytes(),
            allow: Some(allow),
        }
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            413 => "Payload Too Large",
            _ => "Internal Server Error",
        }
    }
}

/// A running HTTP server (drops → stops accepting).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    pub requests: Arc<Counter>,
    pub latency: Arc<Histogram>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve `handler` on `workers` threads with the default
    /// body cap ([`DEFAULT_MAX_BODY`]).
    pub fn bind<F>(addr: &str, workers: usize, handler: F) -> Result<Server>
    where
        F: Fn(Request) -> Response + Send + Sync + 'static,
    {
        Self::bind_with_limit(addr, workers, DEFAULT_MAX_BODY, handler)
    }

    /// Bind with an explicit request-body cap: requests advertising a
    /// larger `Content-Length` are refused with `413` before any body
    /// byte is read or buffered.
    pub fn bind_with_limit<F>(
        addr: &str,
        workers: usize,
        max_body: usize,
        handler: F,
    ) -> Result<Server>
    where
        F: Fn(Request) -> Response + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let requests = Arc::new(Counter::default());
        let latency = Arc::new(Histogram::new());
        let handler = Arc::new(handler);

        let stop2 = Arc::clone(&stop);
        let requests2 = Arc::clone(&requests);
        let latency2 = Arc::clone(&latency);
        let accept_thread = std::thread::Builder::new()
            .name("ocpd-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                loop {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let h = Arc::clone(&handler);
                            let reqs = Arc::clone(&requests2);
                            let lat = Arc::clone(&latency2);
                            pool.submit(move || {
                                let t0 = std::time::Instant::now();
                                let _ = handle_connection(stream, h.as_ref(), max_body);
                                reqs.inc();
                                lat.record(t0.elapsed());
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn accept thread");

        Ok(Server { addr, stop, requests, latency, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection<F: Fn(Request) -> Response>(
    stream: TcpStream,
    handler: &F,
    max_body: usize,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // A stalled or byte-at-a-time peer times out instead of pinning the
    // worker thread forever.
    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let deadline = std::time::Instant::now() + REQUEST_DEADLINE;
    let (resp, rejected) = match read_request(&mut reader, max_body, deadline) {
        Ok(req) => (handler(req), false),
        Err(resp) => (resp, true),
    };
    write_response(&stream, &resp)?;
    if rejected {
        // Drain (bounded in bytes AND time) whatever the peer already
        // sent before the socket closes, so the error response is not
        // reset out of the peer's receive buffer mid-flight. The short
        // read timeout means a trickling peer cannot pin the worker.
        stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        let mut sink = [0u8; 8192];
        let mut budget = 256usize << 10;
        while budget > 0 && std::time::Instant::now() < deadline {
            match reader.read(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(n) => budget -= n.min(budget),
            }
        }
    }
    Ok(())
}

/// `read_line` under an overall deadline: bytes are consumed one at a
/// time through the `BufRead` buffer (cheap), with a deadline check
/// before every read, so a peer trickling one byte per almost-timeout
/// is bounded by `deadline + one socket timeout`, not `bytes x timeout`.
fn read_line_bounded<R: BufRead>(
    reader: &mut R,
    line: &mut String,
    deadline: std::time::Instant,
) -> std::io::Result<usize> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        // Every iteration: a single 1-byte read can block for the whole
        // socket timeout, so a sparser check would multiply the bound.
        if std::time::Instant::now() >= deadline {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "request deadline exceeded",
            ));
        }
        let mut b = [0u8; 1];
        match reader.read(&mut b) {
            Ok(0) => break,
            Ok(_) => {
                buf.push(b[0]);
                if b[0] == b'\n' {
                    break;
                }
            }
            Err(e) => return Err(e),
        }
    }
    let n = buf.len();
    line.push_str(&String::from_utf8_lossy(&buf));
    Ok(n)
}

/// Parse one request, or produce the error response to send instead.
/// Every failure path is a response, never a panic, never an unbounded
/// buffer, and never an unbounded wait.
fn read_request(
    reader: &mut BufReader<TcpStream>,
    max_body: usize,
    deadline: std::time::Instant,
) -> std::result::Result<Request, Response> {
    // Cap the request line + headers together so hostile peers cannot
    // grow memory without bound.
    let mut head = Read::take(&mut *reader, MAX_HEAD_BYTES);
    let mut line = String::new();
    match read_line_bounded(&mut head, &mut line, deadline) {
        Ok(0) => return Err(Response::error(400, "empty request")),
        Ok(_) => {}
        Err(e) => return Err(Response::error(400, format!("unreadable request line: {e}"))),
    }
    if !line.ends_with('\n') {
        // EOF mid-line, or the head cap was hit before a newline.
        return Err(Response::error(400, "truncated or oversized request line"));
    }
    let mut parts = line.split_whitespace();
    let Some(method) = parts.next().map(str::to_string) else {
        return Err(Response::error(400, "empty request line"));
    };
    if !method.chars().all(|c| c.is_ascii_alphabetic()) || method.len() > 16 {
        return Err(Response::error(400, format!("bad method '{method}'")));
    }
    let Some(path) = parts.next().map(str::to_string) else {
        return Err(Response::error(400, "missing path"));
    };

    // Headers.
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        match read_line_bounded(&mut head, &mut h, deadline) {
            Ok(0) => return Err(Response::error(400, "truncated headers")),
            Ok(_) => {}
            Err(e) => return Err(Response::error(400, format!("unreadable header: {e}"))),
        }
        if !h.ends_with('\n') {
            return Err(Response::error(400, "truncated or oversized headers"));
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = match v.trim().parse() {
                    Ok(n) => n,
                    Err(_) => {
                        return Err(Response::error(
                            400,
                            format!("bad content-length '{}'", v.trim()),
                        ))
                    }
                };
            }
        }
    }
    if content_length > max_body {
        return Err(Response::error(
            413,
            format!("body of {content_length} bytes exceeds the {max_body}-byte limit"),
        ));
    }
    // Body: chunked reads under the same overall deadline, so the
    // worker's total time on one request is bounded even when every
    // chunk arrives just inside the socket timeout.
    let mut body = vec![0u8; content_length];
    let mut filled = 0usize;
    while filled < content_length {
        if std::time::Instant::now() >= deadline {
            return Err(Response::error(400, "request body deadline exceeded"));
        }
        let want = (content_length - filled).min(64 << 10);
        match reader.read(&mut body[filled..filled + want]) {
            Ok(0) => return Err(Response::error(400, "truncated body")),
            Ok(n) => filled += n,
            Err(e) => return Err(Response::error(400, format!("truncated body: {e}"))),
        }
    }
    Ok(Request { method, path, body })
}

fn write_response(mut stream: &TcpStream, resp: &Response) -> Result<()> {
    let allow = match resp.allow {
        Some(methods) => format!("Allow: {methods}\r\n"),
        None => String::new(),
    };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\n{}Content-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        resp.reason(),
        resp.content_type,
        allow,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

/// Minimal blocking HTTP client (one request per connection — matches the
/// server's connection-close semantics).
pub fn request(method: &str, url: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| Error::BadRequest(format!("unsupported url '{url}'")))?;
    let (host, path) = match rest.split_once('/') {
        Some((h, p)) => (h, format!("/{p}")),
        None => (rest, "/".to_string()),
    };
    let mut stream = TcpStream::connect(host)?;
    stream.set_nodelay(true).ok();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {host}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::Other(format!("bad status line '{status_line}'")))?;
    let mut content_length = None;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse::<usize>().ok();
            }
        }
    }
    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            body.resize(n, 0);
            reader.read_exact(&mut body)?;
        }
        None => {
            reader.read_to_end(&mut body)?;
        }
    }
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        Server::bind("127.0.0.1:0", 4, |req| match req.path.as_str() {
            "/hello/" => Response::text("world"),
            "/echo/" => Response::binary(req.body),
            "/missing/" => Response::error(404, "nope"),
            p => Response::text(format!("{} {p}", req.method)),
        })
        .unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let s = echo_server();
        let (code, body) = request("GET", &format!("{}/hello/", s.url()), &[]).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, b"world");
        assert_eq!(s.requests.get(), 1);
    }

    #[test]
    fn put_body_roundtrip() {
        let s = echo_server();
        let payload: Vec<u8> = (0..10_000u32).map(|i| i as u8).collect();
        let (code, body) = request("PUT", &format!("{}/echo/", s.url()), &payload).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, payload);
    }

    #[test]
    fn status_codes_propagate() {
        let s = echo_server();
        let (code, _) = request("GET", &format!("{}/missing/", s.url()), &[]).unwrap();
        assert_eq!(code, 404);
    }

    #[test]
    fn concurrent_requests() {
        let s = echo_server();
        let url = s.url();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let url = url.clone();
                std::thread::spawn(move || {
                    // Retry transient connect failures (the suite runs many
                    // servers concurrently and SYN backlogs can overflow).
                    let mut last = None;
                    for _ in 0..10 {
                        match request("GET", &format!("{url}/req{i}/"), &[]) {
                            Ok((code, body)) => {
                                assert_eq!(code, 200);
                                assert_eq!(body, format!("GET /req{i}/").into_bytes());
                                return;
                            }
                            Err(e) => {
                                last = Some(e);
                                std::thread::sleep(std::time::Duration::from_millis(20));
                            }
                        }
                    }
                    panic!("request kept failing: {last:?}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // The counter increments after the response is written, so give
        // the worker threads a beat to record the last requests.
        let t0 = std::time::Instant::now();
        while s.requests.get() < 16 && t0.elapsed() < std::time::Duration::from_secs(2) {
            std::thread::yield_now();
        }
        assert!(s.requests.get() >= 16);
    }

    /// Write raw bytes to the server and return the status code it
    /// answers with.
    fn raw_status(addr: std::net::SocketAddr, payload: &[u8]) -> u16 {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
        // The server may answer (and close) before the payload is fully
        // written; that is fine — we only care about the status line.
        let _ = s.write_all(payload);
        let _ = s.shutdown(std::net::Shutdown::Write);
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line).unwrap();
        line.split_whitespace().nth(1).and_then(|v| v.parse().ok()).unwrap_or(0)
    }

    #[test]
    fn malformed_request_lines_get_400() {
        let s = echo_server();
        // No path.
        assert_eq!(raw_status(s.addr(), b"GARBAGE\r\n\r\n"), 400);
        // Empty request line.
        assert_eq!(raw_status(s.addr(), b"\r\n\r\n"), 400);
        // Binary junk where a method should be.
        assert_eq!(raw_status(s.addr(), b"\x00\x01\x02 /x/ HTTP/1.1\r\n\r\n"), 400);
        // Connection closed before any byte.
        assert_eq!(raw_status(s.addr(), b""), 400);
    }

    #[test]
    fn garbage_content_length_gets_400() {
        let s = echo_server();
        assert_eq!(
            raw_status(s.addr(), b"PUT /echo/ HTTP/1.1\r\nContent-Length: banana\r\n\r\n"),
            400
        );
        assert_eq!(
            raw_status(s.addr(), b"PUT /echo/ HTTP/1.1\r\nContent-Length: -5\r\n\r\n"),
            400
        );
        // Body shorter than advertised (peer hangs up): 400, not a hang.
        assert_eq!(
            raw_status(s.addr(), b"PUT /echo/ HTTP/1.1\r\nContent-Length: 50\r\n\r\nhi"),
            400
        );
    }

    #[test]
    fn oversized_body_gets_413() {
        let s = Server::bind_with_limit("127.0.0.1:0", 2, 1024, |req| {
            Response::binary(req.body)
        })
        .unwrap();
        // Advertised over the cap: refused before any body byte is read.
        assert_eq!(
            raw_status(s.addr(), b"PUT /echo/ HTTP/1.1\r\nContent-Length: 10000\r\n\r\n"),
            413
        );
        // At the cap: accepted.
        let payload = vec![7u8; 1024];
        let (code, body) = request("PUT", &format!("{}/echo/", s.url()), &payload).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, payload);
    }

    #[test]
    fn oversized_head_gets_400() {
        let s = echo_server();
        // A single endless header line (no terminator) must be cut off
        // at the head cap, not buffered forever.
        let mut payload = b"GET /hello/ HTTP/1.1\r\nX-Junk: ".to_vec();
        payload.extend(std::iter::repeat(b'a').take(80 << 10));
        assert_eq!(raw_status(s.addr(), &payload), 400);
    }

    #[test]
    fn method_not_allowed_carries_allow_header() {
        let s = Server::bind("127.0.0.1:0", 2, |_req| {
            Response::method_not_allowed("GET, PUT")
        })
        .unwrap();
        let mut stream = TcpStream::connect(s.addr()).unwrap();
        stream.write_all(b"DELETE /x/ HTTP/1.1\r\n\r\n").unwrap();
        let mut raw = String::new();
        BufReader::new(stream).read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 405 Method Not Allowed"), "{raw}");
        assert!(raw.contains("\r\nAllow: GET, PUT\r\n"), "{raw}");
    }

    #[test]
    fn stops_on_drop() {
        let url;
        {
            let s = echo_server();
            url = s.url();
        }
        // After drop, connection must fail (allow a beat for teardown).
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(request("GET", &format!("{url}/hello/"), &[]).is_err());
    }
}
