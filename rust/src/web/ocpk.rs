//! `ocpk`: the interchange format for volumes and voxel lists.
//!
//! The paper ships HDF5 over the wire for its multidimensional-array
//! support; no pure-Rust HDF5 implementation exists in the offline vendor
//! set, so `ocpk` carries the identical payload (DESIGN.md §1):
//!
//! ```text
//! magic "OCPK" | version u8 | kind u8 | dtype u8 | flags u8
//! kind=1 volume:  lo[3] u64 | dims[3] u64 | payload (gzip if flag bit 0)
//! kind=2 voxels:  count varint | delta-coded sorted (x,y,z) triples
//! kind=3 objects: count varint | length-prefixed RAMON records
//! ```

use crate::annotation::RamonObject;
use crate::array::{DenseVolume, VoxelScalar};
use crate::core::{Box3, Dtype, Vec3};
use crate::util::codec::{Dec, Enc};
use crate::util::gzip;
use crate::{Error, Result};

const MAGIC: &[u8; 4] = b"OCPK";
const VERSION: u8 = 1;
const KIND_VOLUME: u8 = 1;
const KIND_VOXELS: u8 = 2;
const KIND_OBJECTS: u8 = 3;
const FLAG_GZIP: u8 = 1;

fn header(kind: u8, dtype: u8, flags: u8) -> Enc {
    let mut e = Enc::with_capacity(64);
    e.u8(MAGIC[0]).u8(MAGIC[1]).u8(MAGIC[2]).u8(MAGIC[3]);
    e.u8(VERSION).u8(kind).u8(dtype).u8(flags);
    e
}

fn check_header(d: &mut Dec) -> Result<(u8, u8, u8)> {
    let m = [d.u8()?, d.u8()?, d.u8()?, d.u8()?];
    if &m != MAGIC {
        return Err(Error::Codec("not an OCPK frame".into()));
    }
    let v = d.u8()?;
    if v != VERSION {
        return Err(Error::Codec(format!("unsupported OCPK version {v}")));
    }
    Ok((d.u8()?, d.u8()?, d.u8()?))
}

/// Encode a volume positioned at `lo` (gzip payload when it pays).
pub fn encode_volume<T: VoxelScalar>(
    dtype: Dtype,
    lo: Vec3,
    vol: &DenseVolume<T>,
) -> Result<Vec<u8>> {
    let raw = vol.as_bytes();
    let z = gzip::compress(raw, 6)?;
    let (flags, payload): (u8, &[u8]) =
        if z.len() < raw.len() { (FLAG_GZIP, &z) } else { (0, raw) };
    let mut buf = volume_head(dtype, lo, vol.dims(), raw.len() as u64, flags);
    buf.extend_from_slice(payload);
    Ok(buf)
}

/// Encode just the frame head of an **uncompressed** volume whose
/// `raw_len` payload bytes follow separately — the streaming path emits
/// this as its first chunk, then raw z-slab bytes chunk by chunk, and
/// the concatenation decodes exactly like a buffered uncompressed
/// [`encode_volume`] frame.
pub fn encode_volume_header(dtype: Dtype, lo: Vec3, dims: Vec3, raw_len: u64) -> Vec<u8> {
    volume_head(dtype, lo, dims, raw_len, 0)
}

fn volume_head(dtype: Dtype, lo: Vec3, dims: Vec3, raw_len: u64, flags: u8) -> Vec<u8> {
    let mut e = header(KIND_VOLUME, dtype.tag(), flags);
    for v in lo {
        e.u64(v);
    }
    for v in dims {
        e.u64(v);
    }
    e.varint(raw_len);
    e.finish()
}

/// Decode a volume frame; returns `(dtype, box, raw payload bytes)`.
pub fn decode_volume_raw(buf: &[u8]) -> Result<(Dtype, Box3, Vec<u8>)> {
    let mut d = Dec::new(buf);
    let (kind, dtype, flags) = check_header(&mut d)?;
    if kind != KIND_VOLUME {
        return Err(Error::Codec(format!("expected volume frame, got kind {kind}")));
    }
    let dtype = Dtype::from_tag(dtype)?;
    let lo = [d.u64()?, d.u64()?, d.u64()?];
    let dims = [d.u64()?, d.u64()?, d.u64()?];
    let raw_len = d.varint()? as usize;
    let payload = &buf[buf.len() - d.remaining()..];
    let raw = if flags & FLAG_GZIP != 0 {
        gzip::decompress(payload, raw_len)?
    } else {
        payload.to_vec()
    };
    if raw.len() != raw_len {
        return Err(Error::Codec(format!("payload {} != declared {raw_len}", raw.len())));
    }
    Ok((dtype, Box3::at(lo, dims), raw))
}

/// Decode a typed volume.
pub fn decode_volume<T: VoxelScalar>(buf: &[u8]) -> Result<(Dtype, Box3, DenseVolume<T>)> {
    let (dtype, bx, raw) = decode_volume_raw(buf)?;
    if dtype.bytes() != T::BYTES {
        return Err(Error::Codec(format!(
            "dtype {} is {}B, requested {}B",
            dtype.name(),
            dtype.bytes(),
            T::BYTES
        )));
    }
    Ok((dtype, bx, DenseVolume::from_bytes(bx.extent(), &raw)?))
}

/// Encode a sorted voxel list (delta-coded Morton-free triples).
pub fn encode_voxels(voxels: &[Vec3]) -> Vec<u8> {
    let mut e = header(KIND_VOXELS, 0, 0);
    e.varint(voxels.len() as u64);
    let mut prev = [0u64; 3];
    for v in voxels {
        // Delta on x re-zeroes when y/z change; plain varints are simple
        // and compact enough (sorted lists share long prefixes).
        e.varint(v[0] ^ prev[0]).varint(v[1] ^ prev[1]).varint(v[2] ^ prev[2]);
        prev = *v;
    }
    e.finish()
}

/// Decode a voxel list.
pub fn decode_voxels(buf: &[u8]) -> Result<Vec<Vec3>> {
    let mut d = Dec::new(buf);
    let (kind, _, _) = check_header(&mut d)?;
    if kind != KIND_VOXELS {
        return Err(Error::Codec(format!("expected voxel frame, got kind {kind}")));
    }
    let n = d.varint()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 22));
    let mut prev = [0u64; 3];
    for _ in 0..n {
        let v = [d.varint()? ^ prev[0], d.varint()? ^ prev[1], d.varint()? ^ prev[2]];
        out.push(v);
        prev = v;
    }
    Ok(out)
}

/// Encode RAMON objects (batch read/write bodies).
pub fn encode_objects(objs: &[RamonObject]) -> Vec<u8> {
    let mut e = header(KIND_OBJECTS, 0, 0);
    e.varint(objs.len() as u64);
    let mut buf = e.finish();
    for o in objs {
        let rec = o.encode();
        let mut le = Enc::new();
        le.bytes(&rec);
        buf.extend_from_slice(&le.finish());
    }
    buf
}

/// Decode RAMON objects.
pub fn decode_objects(buf: &[u8]) -> Result<Vec<RamonObject>> {
    let mut d = Dec::new(buf);
    let (kind, _, _) = check_header(&mut d)?;
    if kind != KIND_OBJECTS {
        return Err(Error::Codec(format!("expected objects frame, got kind {kind}")));
    }
    let n = d.varint()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        out.push(RamonObject::decode(d.bytes()?)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::annotation::{RamonObject, SynapseType};
    use crate::util::Rng;

    #[test]
    fn volume_roundtrip_u8_and_u32() {
        let mut rng = Rng::new(1);
        let dims = [16u64, 12, 4];
        let v8 = DenseVolume::<u8>::from_vec(
            dims,
            (0..768).map(|_| rng.next_u32() as u8).collect(),
        )
        .unwrap();
        let b = encode_volume(Dtype::U8, [5, 6, 7], &v8).unwrap();
        let (dt, bx, back) = decode_volume::<u8>(&b).unwrap();
        assert_eq!(dt, Dtype::U8);
        assert_eq!(bx, Box3::at([5, 6, 7], dims));
        assert_eq!(back, v8);

        let mut v32 = DenseVolume::<u32>::zeros(dims);
        v32.fill_box(Box3::new([0, 0, 0], [8, 8, 2]), 99);
        let b = encode_volume(Dtype::U32, [0, 0, 0], &v32).unwrap();
        // Labels compress: frame smaller than raw.
        assert!(b.len() < v32.as_bytes().len() / 4);
        let (_, _, back) = decode_volume::<u32>(&b).unwrap();
        assert_eq!(back, v32);
    }

    #[test]
    fn streamed_header_plus_raw_slabs_decodes_like_buffered() {
        // The streaming path's wire bytes: header chunk, then raw
        // payload split at arbitrary boundaries. Reassembled, they must
        // decode exactly like a buffered uncompressed frame.
        let mut rng = Rng::new(7);
        let dims = [8u64, 6, 10];
        let vol = DenseVolume::<u8>::from_vec(
            dims,
            (0..480).map(|_| rng.next_u32() as u8).collect(),
        )
        .unwrap();
        let raw = vol.as_bytes();
        let mut wire = encode_volume_header(Dtype::U8, [4, 5, 6], dims, raw.len() as u64);
        // Split as three "slabs" of unequal size.
        wire.extend_from_slice(&raw[..100]);
        wire.extend_from_slice(&raw[100..333]);
        wire.extend_from_slice(&raw[333..]);
        let (dt, bx, back) = decode_volume::<u8>(&wire).unwrap();
        assert_eq!(dt, Dtype::U8);
        assert_eq!(bx, Box3::at([4, 5, 6], dims));
        assert_eq!(back, vol);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let v = DenseVolume::<u8>::zeros([4, 4, 1]);
        let b = encode_volume(Dtype::U8, [0, 0, 0], &v).unwrap();
        assert!(decode_volume::<u32>(&b).is_err());
    }

    #[test]
    fn garbage_rejected() {
        assert!(decode_volume_raw(b"HDF5 is elsewhere").is_err());
        assert!(decode_voxels(&[]).is_err());
    }

    #[test]
    fn voxels_roundtrip() {
        let mut voxels: Vec<Vec3> =
            (0..500u64).map(|i| [i % 64, (i / 7) % 64, i % 16]).collect();
        voxels.sort_unstable();
        voxels.dedup();
        let b = encode_voxels(&voxels);
        assert_eq!(decode_voxels(&b).unwrap(), voxels);
        assert!(decode_voxels(&encode_voxels(&[])).unwrap().is_empty());
    }

    #[test]
    fn objects_roundtrip() {
        let objs = vec![
            RamonObject::synapse(7, 0.9, SynapseType::Excitatory).with_author("a"),
            RamonObject::neuron(9).with_kv("k", "v"),
        ];
        let b = encode_objects(&objs);
        assert_eq!(decode_objects(&b).unwrap(), objs);
    }

    #[test]
    fn frame_kinds_not_interchangeable() {
        let b = encode_voxels(&[[1, 2, 3]]);
        assert!(decode_objects(&b).is_err());
        assert!(decode_volume_raw(&b).is_err());
    }
}
