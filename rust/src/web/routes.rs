//! Request dispatch: Table 1's URL grammar bound to the cluster services.

use std::sync::Arc;

use crate::annotation::{Predicate, PredicateOp, RegionQuery};
use crate::array::Plane;
use crate::cluster::Cluster;
use crate::core::{Box3, Dtype, WriteDiscipline};
use crate::ingest::SynthSpec;
use crate::jobs::{BulkIngestJob, JobConfig, JobSpec, PropagateJob, SynapseDetectJob};
use crate::runtime::Runtime;
use crate::tiles::{TileKey, TileService};
use crate::vision::SynapsePipeline;
use crate::web::http::{Request, Response};
use crate::web::ocpk;
use crate::{Error, Result};

/// Upper bound on a server-side synthetic-ingest request, in voxels.
/// The generator materializes the whole volume (8 B/voxel accumulator
/// plus the u8 output), so this caps the per-request allocation at
/// ~1.2 GiB regardless of how large the registered dataset is.
const MAX_INGEST_VOXELS: u64 = 1 << 27;

/// The Web-service layer over a cluster (the paper's "application
/// server" role).
pub struct OcpService {
    cluster: Arc<Cluster>,
    /// Loaded vision runtime; `POST /jobs/synapse/...` requires it.
    runtime: Option<Arc<Runtime>>,
    tiles: std::sync::Mutex<std::collections::HashMap<String, Arc<TileService>>>,
}

impl OcpService {
    pub fn new(cluster: Arc<Cluster>, runtime: Option<Arc<Runtime>>) -> Self {
        OcpService {
            cluster,
            runtime,
            tiles: std::sync::Mutex::new(std::collections::HashMap::new()),
        }
    }

    /// Entry point: map a request to a response, turning errors into
    /// their HTTP status codes.
    pub fn handle(&self, req: Request) -> Response {
        match self.dispatch(&req) {
            Ok(resp) => resp,
            Err(e) => Response::error(e.http_status(), e.to_string()),
        }
    }

    fn dispatch(&self, req: &Request) -> Result<Response> {
        let segs: Vec<&str> =
            req.path.split('/').filter(|s| !s.is_empty()).collect();
        if segs.is_empty() {
            return Ok(Response::text("ocpd: Open Connectome Project data cluster"));
        }
        match (req.method.as_str(), segs[0]) {
            (_, "info") => self.info(),
            // `wal`, `cache`, `jobs`, and `write` are reserved top-level
            // names (like `info`): the write-absorber's, the cuboid
            // cache's, the batch compute engine's, and the parallel
            // write engine's surfaces. Wrong-method requests answer 405
            // + `Allow` here instead of falling through to the project
            // handlers and emitting a confusing 400 ("unknown write
            // discipline 'status'").
            ("GET", "wal") => self.wal_get(&segs[1..]),
            ("PUT" | "POST", "wal") => self.wal_flush(&segs[1..]),
            (_, "wal") => Ok(Response::method_not_allowed("GET, PUT, POST")),
            ("GET", "cache") => self.cache_get(&segs[1..]),
            (_, "cache") => Ok(Response::method_not_allowed("GET")),
            ("GET", "jobs") => self.jobs_get(&segs[1..]),
            ("PUT" | "POST", "jobs") => self.jobs_post(&segs[1..], &req.body),
            (_, "jobs") => Ok(Response::method_not_allowed("GET, PUT, POST")),
            ("GET", "write") => self.write_get(&segs[1..]),
            ("PUT" | "POST", "write") => self.write_set(&segs[1..]),
            (_, "write") => Ok(Response::method_not_allowed("GET, PUT, POST")),
            ("GET", token) => self.get(token, &segs[1..]),
            ("PUT" | "POST", token) => self.put(token, &segs[1..], &req.body),
            _ => Ok(Response::method_not_allowed("GET, PUT, POST")),
        }
    }

    // ------------------------------------------------------------------
    // WAL routes
    // ------------------------------------------------------------------

    /// GET /wal/status/ — one line per hot project's log.
    fn wal_get(&self, rest: &[&str]) -> Result<Response> {
        match rest {
            ["status"] => {
                let statuses = self.cluster.wal_status()?;
                let mut out = String::from("wal:\n");
                for s in statuses {
                    out.push_str(&format!(
                        "  {}: depth={} records ({} bytes) active_seg={} sealed={} \
                         commits={} mean_batch={:.1} flushed={} lag_ms={:.1}\n",
                        s.scope,
                        s.depth_records,
                        s.depth_bytes,
                        s.active_segment,
                        s.sealed_segments,
                        s.commit_batches,
                        s.mean_batch(),
                        s.flushed_records,
                        s.flush_lag_ms
                    ));
                }
                Ok(Response::text(out))
            }
            ["flush", ..] => Ok(Response::method_not_allowed("PUT, POST")),
            _ => Err(Error::BadRequest(format!("unrecognized GET /wal/{}", rest.join("/")))),
        }
    }

    /// PUT /wal/flush/ (all logs) or /wal/flush/{token}/ (one log).
    fn wal_flush(&self, rest: &[&str]) -> Result<Response> {
        match rest {
            ["flush"] => {
                let n = self.cluster.flush_all_wals()?;
                Ok(Response::text(format!("flushed={n}")))
            }
            ["flush", token] => {
                let n = self.cluster.flush_wal(token)?;
                Ok(Response::text(format!("flushed={n}")))
            }
            _ => Err(Error::BadRequest(format!("unrecognized PUT /wal/{}", rest.join("/")))),
        }
    }

    // ------------------------------------------------------------------
    // Cache routes
    // ------------------------------------------------------------------

    /// GET /cache/status/ — one line per project's cuboid cache.
    fn cache_get(&self, rest: &[&str]) -> Result<Response> {
        match rest {
            ["status"] => {
                let mut out = String::from("cache:\n");
                for (token, s) in self.cluster.cache_status() {
                    out.push_str(&format!(
                        "  {token}: entries={} bytes={}/{} shards={} hits={} misses={} \
                         hit_rate={:.3} inserts={} evictions={} invalidations={}\n",
                        s.entries,
                        s.bytes,
                        s.capacity_bytes,
                        s.shards,
                        s.hits,
                        s.misses,
                        s.hit_rate(),
                        s.inserts,
                        s.evictions,
                        s.invalidations
                    ));
                }
                Ok(Response::text(out))
            }
            _ => {
                Err(Error::BadRequest(format!("unrecognized GET /cache/{}", rest.join("/"))))
            }
        }
    }

    // ------------------------------------------------------------------
    // Write-engine routes
    // ------------------------------------------------------------------

    /// GET /write/status/ — one line per project's write engine.
    fn write_get(&self, rest: &[&str]) -> Result<Response> {
        match rest {
            ["status"] => {
                let mut out = String::from("write:\n");
                for (token, s) in self.cluster.write_status() {
                    out.push_str(&format!(
                        "  {token}: workers={} threshold={} seq={} par={} \
                         elided_reads={} rmw_reads={} merge_mean_us={:.1} merge_p95_us={}\n",
                        s.workers,
                        s.parallel_threshold,
                        s.sequential_writes,
                        s.parallel_writes,
                        s.elided_reads,
                        s.rmw_reads,
                        s.merge_mean_us,
                        s.merge_p95_us
                    ));
                }
                Ok(Response::text(out))
            }
            ["workers", ..] => Ok(Response::method_not_allowed("PUT, POST")),
            _ => {
                Err(Error::BadRequest(format!("unrecognized GET /write/{}", rest.join("/"))))
            }
        }
    }

    /// PUT /write/workers/{n}/ — retune every project's write fan-out.
    fn write_set(&self, rest: &[&str]) -> Result<Response> {
        match rest {
            ["workers", n] => {
                let n = (parse_num(n)? as usize).clamp(1, crate::jobs::MAX_WORKERS);
                let projects = self.cluster.set_write_workers(n);
                Ok(Response::text(format!("workers={n} projects={projects}")))
            }
            ["status", ..] => Ok(Response::method_not_allowed("GET")),
            _ => {
                Err(Error::BadRequest(format!("unrecognized PUT /write/{}", rest.join("/"))))
            }
        }
    }

    // ------------------------------------------------------------------
    // Job routes (the batch compute engine)
    // ------------------------------------------------------------------

    /// GET /jobs/status/ (all jobs) or /jobs/status/{id}/ (one job).
    fn jobs_get(&self, rest: &[&str]) -> Result<Response> {
        match rest {
            ["status"] => {
                let mut out = String::from("jobs:\n");
                for s in self.cluster.jobs().statuses() {
                    out.push_str(&format!("  {}\n", s.line()));
                }
                Ok(Response::text(out))
            }
            ["status", id] => {
                let id = parse_num(id)?;
                match self.cluster.jobs().get(id) {
                    Some(h) => Ok(Response::text(h.status().line())),
                    None => Err(Error::NotFound(format!("job {id}"))),
                }
            }
            ["cancel", ..] => Ok(Response::method_not_allowed("POST, PUT")),
            _ => Err(Error::BadRequest(format!("unrecognized GET /jobs/{}", rest.join("/")))),
        }
    }

    /// POST /jobs/{propagate|synapse|ingest}/... (submit) and
    /// POST /jobs/cancel/{id}/ — body: whitespace-separated `key=value`
    /// params (`workers=N`, `job=ID` to resume, plus per-type extras).
    fn jobs_post(&self, rest: &[&str], body: &[u8]) -> Result<Response> {
        let params = parse_params(body);
        match rest {
            ["cancel", id] => {
                let id = parse_num(id)?;
                self.cluster.jobs().cancel(id)?;
                Ok(Response::text(format!("cancelled={id}")))
            }
            // POST /jobs/propagate/{token}/ — build the resolution
            // hierarchy of an image or annotation project.
            ["propagate", token] => {
                let spec: Arc<dyn JobSpec> = match self.cluster.image(token) {
                    Ok(svc) => Arc::new(PropagateJob::image(svc)),
                    Err(_) => Arc::new(PropagateJob::annotation(self.cluster.annotation(token)?)),
                };
                self.submit(spec, &params)
            }
            // POST /jobs/synapse/{image}/{annotation}/ — the §2 vision
            // workload; needs the AOT runtime.
            ["synapse", img, ann] => {
                let runtime = self.runtime.clone().ok_or_else(|| {
                    Error::BadRequest(
                        "no vision runtime loaded (start the server with artifacts)".into(),
                    )
                })?;
                let image = self.cluster.image(img)?;
                let anno = self.cluster.annotation(ann)?;
                let res = param_num(&params, "res", 0)? as u32;
                let region = image.store().dataset.level(res)?.bounds();
                let pipeline = Arc::new(SynapsePipeline::new(runtime, image, anno));
                self.submit(Arc::new(SynapseDetectJob::new(pipeline, res, region)), &params)
            }
            // POST /jobs/ingest/{token}/ — chunked synthetic-EM ingest
            // (`dims=X,Y,Z` required; `seed=N` optional).
            ["ingest", token] => {
                let svc = self.cluster.image(token)?;
                let dims = params
                    .get("dims")
                    .ok_or_else(|| Error::BadRequest("ingest needs dims=X,Y,Z".into()))?;
                let dims = parse_triple(dims)?;
                // Clamp to the project's level-0 bounds, then cap the
                // total volume: the generator holds the whole volume in
                // memory (an f64 accumulator, 8 B/voxel), so client
                // dims must never size an arbitrary allocation — a
                // registered dataset's bounds alone can exceed RAM.
                let bounds = svc.store().dataset.level(0)?.dims;
                let dims = [
                    dims[0].min(bounds[0]).max(1),
                    dims[1].min(bounds[1]).max(1),
                    dims[2].min(bounds[2]).max(1),
                ];
                let voxels = dims[0].saturating_mul(dims[1]).saturating_mul(dims[2]);
                if voxels > MAX_INGEST_VOXELS {
                    return Err(Error::BadRequest(format!(
                        "ingest volume of {voxels} voxels exceeds the \
                         {MAX_INGEST_VOXELS}-voxel limit (ingest a sub-volume, or use \
                         client-side uploads for full-scale data)"
                    )));
                }
                let seed = param_num(&params, "seed", 2013)?;
                let block = match params.get("block") {
                    Some(b) => parse_triple(b)?,
                    None => [256, 256, 16],
                };
                let spec = SynthSpec::small(dims, seed);
                self.submit(Arc::new(BulkIngestJob::new(svc, spec, block)), &params)
            }
            ["status", ..] => Ok(Response::method_not_allowed("GET")),
            _ => Err(Error::BadRequest(format!("unrecognized POST /jobs/{}", rest.join("/")))),
        }
    }

    /// Launch a job (fresh id, or resume via `job=ID`) and report it.
    fn submit(
        &self,
        spec: Arc<dyn JobSpec>,
        params: &std::collections::HashMap<String, String>,
    ) -> Result<Response> {
        // `MAX_WORKERS` also guards inside the engine; clamping here
        // keeps a typo'd `workers=100000` from even trying.
        let cfg = JobConfig {
            workers: (param_num(params, "workers", 4)? as usize)
                .clamp(1, crate::jobs::MAX_WORKERS),
            ..JobConfig::default()
        };
        let handle = match params.get("job") {
            Some(id) => self.cluster.jobs().submit_with_id(parse_num(id)?, spec, cfg)?,
            None => self.cluster.jobs().submit(spec, cfg)?,
        };
        Ok(Response::text(format!(
            "id={} name={} state={}",
            handle.id,
            handle.name(),
            handle.state().as_str()
        )))
    }

    fn info(&self) -> Result<Response> {
        let mut out = String::from("ocpd cluster\nprojects:\n");
        for t in self.cluster.tokens() {
            out.push_str(&format!("  {t}\n"));
        }
        out.push_str("nodes:\n");
        for (name, s) in self.cluster.node_stats() {
            out.push_str(&format!(
                "  {name}: reads={} read_bytes={} writes={} write_bytes={}\n",
                s.reads, s.read_bytes, s.writes, s.write_bytes
            ));
        }
        let wals = self.cluster.wal_status()?;
        if !wals.is_empty() {
            out.push_str("wal:\n");
            for s in wals {
                out.push_str(&format!(
                    "  {}: depth={} flushed={}\n",
                    s.scope, s.depth_records, s.flushed_records
                ));
            }
        }
        Ok(Response::text(out))
    }

    // ------------------------------------------------------------------
    // GET routes
    // ------------------------------------------------------------------

    fn get(&self, token: &str, rest: &[&str]) -> Result<Response> {
        match rest {
            // /{token}/ocpk/{res}/{xr}/{yr}/{zr}/
            ["ocpk", res, xr, yr, zr] => {
                let bx = parse_box(xr, yr, zr)?;
                let res = parse_res(res)?;
                self.cutout(token, res, bx)
            }
            // /{token}/xy/{res}/{z}/{xr}/{yr}/
            ["xy", res, z, xr, yr] => {
                let res = parse_res(res)?;
                let z: u64 = parse_num(z)?;
                let (x0, x1) = parse_range(xr)?;
                let (y0, y1) = parse_range(yr)?;
                let svc = self.cluster.image(token)?;
                let (w, h, data) =
                    svc.read_plane::<u8>(res, 0, 0, Plane::Xy(z), [x0, y0], [x1, y1])?;
                let vol = crate::array::DenseVolume::from_vec([w, h, 1], data)?;
                Ok(Response::binary(ocpk::encode_volume(Dtype::U8, [x0, y0, z], &vol)?))
            }
            // /{token}/tile/{res}/{z}/{y}_{x}.gray
            ["tile", res, z, yx] => {
                let res = parse_res(res)?;
                let z: u64 = parse_num(z)?;
                let (y, x) = yx
                    .strip_suffix(".gray")
                    .and_then(|s| s.split_once('_'))
                    .ok_or_else(|| Error::BadRequest(format!("bad tile name '{yx}'")))?;
                let key = TileKey { res, z, y: parse_num(y)?, x: parse_num(x)? };
                let ts = self.tile_service(token)?;
                Ok(Response::binary(ts.get_tile(key)?))
            }
            // /{token}/objects/{field}/{value}/... predicate query
            ["objects", preds @ ..] => {
                let db = self.cluster.annotation(token)?;
                let predicates = parse_predicates(preds)?;
                let ids = db.query(&predicates)?;
                Ok(Response::text(
                    ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(","),
                ))
            }
            // /{token}/region/{res}/{xr}/{yr}/{zr}/ — ids in region
            ["region", res, xr, yr, zr] => {
                let db = self.cluster.annotation(token)?;
                let ids = db.objects_in_region(
                    parse_res(res)?,
                    parse_box(xr, yr, zr)?,
                    RegionQuery { include_exceptions: true },
                )?;
                Ok(Response::text(
                    ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(","),
                ))
            }
            // /{token}/{id}/voxels/
            [id, "voxels"] => {
                let db = self.cluster.annotation(token)?;
                let voxels = db.voxel_list(db.project.base_resolution, parse_num(id)? as u32)?;
                Ok(Response::binary(ocpk::encode_voxels(&voxels)))
            }
            // /{token}/{id}/boundingbox/
            [id, "boundingbox"] => {
                let db = self.cluster.annotation(token)?;
                match db.bounding_box(db.project.base_resolution, parse_num(id)? as u32)? {
                    Some(b) => Ok(Response::text(format!(
                        "{},{}/{},{}/{},{}",
                        b.lo[0], b.hi[0], b.lo[1], b.hi[1], b.lo[2], b.hi[2]
                    ))),
                    None => Err(Error::NotFound(format!("annotation {id} has no voxels"))),
                }
            }
            // /{token}/{id}/cutout/ — dense object read
            [id, "cutout"] => {
                let db = self.cluster.annotation(token)?;
                let res = db.project.base_resolution;
                match db.dense_read(res, parse_num(id)? as u32, None)? {
                    Some((bx, vol)) => {
                        Ok(Response::binary(ocpk::encode_volume(Dtype::U32, bx.lo, &vol)?))
                    }
                    None => Err(Error::NotFound(format!("annotation {id} has no voxels"))),
                }
            }
            // /{token}/{id}/cutout/{res}/{xr}/{yr}/{zr}/ — restricted
            [id, "cutout", res, xr, yr, zr] => {
                let db = self.cluster.annotation(token)?;
                let bx = parse_box(xr, yr, zr)?;
                match db.dense_read(parse_res(res)?, parse_num(id)? as u32, Some(bx))? {
                    Some((bx, vol)) => {
                        Ok(Response::binary(ocpk::encode_volume(Dtype::U32, bx.lo, &vol)?))
                    }
                    None => Err(Error::NotFound(format!("annotation {id} has no voxels"))),
                }
            }
            // /{token}/{id}/ or /{token}/{id1},{id2},.../ — metadata
            [ids] => {
                let db = self.cluster.annotation(token)?;
                let ids: Vec<u32> = ids
                    .split(',')
                    .map(|s| parse_num(s).map(|v| v as u32))
                    .collect::<Result<_>>()?;
                let objs = db.get_objects(&ids)?;
                let found: Vec<_> = objs.into_iter().flatten().collect();
                if found.is_empty() {
                    return Err(Error::NotFound("no matching annotations".into()));
                }
                Ok(Response::binary(ocpk::encode_objects(&found)))
            }
            _ => Err(Error::BadRequest(format!("unrecognized GET /{token}/{}", rest.join("/")))),
        }
    }

    /// Image cutout if the token is an image project, else annotation.
    fn cutout(&self, token: &str, res: u32, bx: Box3) -> Result<Response> {
        if let Ok(svc) = self.cluster.image(token) {
            let vol = svc.read::<u8>(res, 0, 0, bx)?;
            return Ok(Response::binary(ocpk::encode_volume(Dtype::U8, bx.lo, &vol)?));
        }
        let db = self.cluster.annotation(token)?;
        let vol = db.cutout.read::<u32>(res, 0, 0, bx)?;
        Ok(Response::binary(ocpk::encode_volume(Dtype::U32, bx.lo, &vol)?))
    }

    fn tile_service(&self, token: &str) -> Result<Arc<TileService>> {
        let mut guard = self.tiles.lock().unwrap();
        if let Some(t) = guard.get(token) {
            return Ok(Arc::clone(t));
        }
        let svc = self.cluster.image(token)?;
        let ts = Arc::new(TileService::new(svc, 256, 1024));
        guard.insert(token.to_string(), Arc::clone(&ts));
        Ok(ts)
    }

    // ------------------------------------------------------------------
    // PUT routes
    // ------------------------------------------------------------------

    fn put(&self, token: &str, rest: &[&str], body: &[u8]) -> Result<Response> {
        match rest {
            // PUT /{token}/ramon/ — batch metadata write; server assigns
            // ids for id=0 objects (§4.2).
            ["ramon"] => {
                let db = self.cluster.annotation(token)?;
                let objs = ocpk::decode_objects(body)?;
                let ids = db.put_objects(objs)?;
                Ok(Response::text(
                    ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(","),
                ))
            }
            // PUT /{token}/image/{res}/ — image ingest (OCPK u8 volume).
            ["image", res] => {
                let svc = self.cluster.image(token)?;
                let (_dt, bx, vol) = ocpk::decode_volume::<u8>(body)?;
                svc.write(parse_res(res)?, 0, 0, bx, &vol)?;
                Ok(Response::text("ok"))
            }
            // PUT /{token}/{discipline}/{res}/ with an OCPK volume body
            // (frame carries its own offset).
            [disc, res] => {
                let discipline = WriteDiscipline::parse(disc).ok_or_else(|| {
                    Error::BadRequest(format!("unknown write discipline '{disc}'"))
                })?;
                let db = self.cluster.annotation(token)?;
                let (_dt, bx, vol) = ocpk::decode_volume::<u32>(body)?;
                let outcome = db.write_volume(parse_res(res)?, bx, &vol, discipline)?;
                Ok(Response::text(format!(
                    "written={} conflicted={} exceptions={} cuboids={}",
                    outcome.voxels_written,
                    outcome.voxels_conflicted,
                    outcome.exceptions_added,
                    outcome.cuboids_touched
                )))
            }
            _ => Err(Error::BadRequest(format!("unrecognized PUT /{token}/{}", rest.join("/")))),
        }
    }
}

// ----------------------------------------------------------------------
// URL parsing helpers
// ----------------------------------------------------------------------

fn parse_num(s: &str) -> Result<u64> {
    s.parse().map_err(|_| Error::BadRequest(format!("bad number '{s}'")))
}

/// Whitespace-separated `key=value` pairs (job-submission bodies).
fn parse_params(body: &[u8]) -> std::collections::HashMap<String, String> {
    let mut out = std::collections::HashMap::new();
    for pair in String::from_utf8_lossy(body).split_whitespace() {
        if let Some((k, v)) = pair.split_once('=') {
            out.insert(k.to_string(), v.to_string());
        }
    }
    out
}

/// Numeric param with a default; present-but-garbled values are 400s.
fn param_num(
    params: &std::collections::HashMap<String, String>,
    key: &str,
    default: u64,
) -> Result<u64> {
    match params.get(key) {
        Some(v) => parse_num(v),
        None => Ok(default),
    }
}

/// `"X,Y,Z"` → `[X, Y, Z]` (job dims/block params).
fn parse_triple(s: &str) -> Result<[u64; 3]> {
    let v: Vec<u64> = s.split(',').map(parse_num).collect::<Result<_>>()?;
    if v.len() != 3 {
        return Err(Error::BadRequest(format!("bad triple '{s}' (want X,Y,Z)")));
    }
    Ok([v[0], v[1], v[2]])
}

fn parse_res(s: &str) -> Result<u32> {
    Ok(parse_num(s)? as u32)
}

/// `"lo,hi"` → half-open range.
fn parse_range(s: &str) -> Result<(u64, u64)> {
    let (a, b) = s
        .split_once(',')
        .ok_or_else(|| Error::BadRequest(format!("bad range '{s}' (want lo,hi)")))?;
    let (lo, hi) = (parse_num(a)?, parse_num(b)?);
    if lo >= hi {
        return Err(Error::BadRequest(format!("empty range '{s}'")));
    }
    Ok((lo, hi))
}

fn parse_box(xr: &str, yr: &str, zr: &str) -> Result<Box3> {
    let (x0, x1) = parse_range(xr)?;
    let (y0, y1) = parse_range(yr)?;
    let (z0, z1) = parse_range(zr)?;
    Ok(Box3::new([x0, y0, z0], [x1, y1, z1]))
}

/// Predicate segments: `field/value` pairs, with `field/op/value` for
/// range operators (§4.2: equality everywhere, inequalities on floats).
fn parse_predicates(segs: &[&str]) -> Result<Vec<Predicate>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < segs.len() {
        let field = segs[i];
        if i + 1 >= segs.len() {
            return Err(Error::BadRequest(format!("predicate '{field}' missing value")));
        }
        if let Ok(op) = PredicateOp::parse(segs[i + 1]) {
            if op != PredicateOp::Eq {
                if i + 2 >= segs.len() {
                    return Err(Error::BadRequest(format!(
                        "predicate '{field}/{}' missing value",
                        segs[i + 1]
                    )));
                }
                out.push(Predicate {
                    field: field.to_string(),
                    op,
                    value: segs[i + 2].to_string(),
                });
                i += 3;
                continue;
            }
        }
        out.push(Predicate::eq(field, segs[i + 1]));
        i += 2;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_parsing() {
        assert_eq!(parse_range("5,10").unwrap(), (5, 10));
        assert!(parse_range("10,5").is_err());
        assert!(parse_range("abc").is_err());
        assert!(parse_range("5").is_err());
    }

    #[test]
    fn predicate_parsing_paper_example() {
        // objects/type/synapse/confidence/geq/0.99/
        let p = parse_predicates(&["type", "synapse", "confidence", "geq", "0.99"]).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].field, "type");
        assert_eq!(p[0].op, PredicateOp::Eq);
        assert_eq!(p[1].op, PredicateOp::Geq);
        assert_eq!(p[1].value, "0.99");
        assert!(parse_predicates(&["type"]).is_err());
        assert!(parse_predicates(&["confidence", "geq"]).is_err());
    }

    #[test]
    fn box_parsing() {
        let b = parse_box("0,128", "128,256", "0,16").unwrap();
        assert_eq!(b, Box3::new([0, 128, 0], [128, 256, 16]));
    }

    #[test]
    fn job_param_parsing() {
        let p = parse_params(b"workers=8 dims=512,512,64\nseed=7");
        assert_eq!(p.get("workers").unwrap(), "8");
        assert_eq!(param_num(&p, "workers", 4).unwrap(), 8);
        assert_eq!(param_num(&p, "absent", 4).unwrap(), 4);
        assert_eq!(parse_triple(p.get("dims").unwrap()).unwrap(), [512, 512, 64]);
        assert!(parse_triple("1,2").is_err());
        assert!(parse_triple("a,b,c").is_err());
        // Garbled present values are errors, not silent defaults.
        let bad = parse_params(b"workers=banana");
        assert!(param_num(&bad, "workers", 4).is_err());
    }
}
