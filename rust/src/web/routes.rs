//! The Web-service layer: Table 1's URL grammar bound to the cluster
//! services through a declarative routing table.
//!
//! Every route is one [`Route`] row in [`route_table`]; dispatch, 405
//! `Allow` derivation, the `/info/` route listing, and per-route
//! latency metrics all read the same table. Handler bodies live in
//! [`crate::web::handlers`], one module per subsystem.

use std::sync::{Arc, OnceLock};

use crate::annotation::{Predicate, PredicateOp};
use crate::cluster::Cluster;
use crate::core::Box3;
use crate::runtime::Runtime;
use crate::tiles::TileService;
use crate::web::handlers::{
    cache, cluster, jobs, obs, projects, qos, shards, system, telemetry, wal, write_engine,
};
use crate::web::http::{HttpMetrics, Request, Response};
use crate::web::router::{Outcome, Route, Router, Seg};
use crate::{Error, Result};

/// Default raw-byte size at which a cutout response switches from a
/// buffered OCPK frame to a chunked stream of cuboid-aligned z-slabs
/// (8 MiB — a 256³ u8 cutout streams, interactive viewer tiles do not).
pub const DEFAULT_STREAM_THRESHOLD: usize = 8 << 20;

/// Reserved top-level names — never project tokens; the router's
/// token segments refuse them so `/wal/...` can never be shadowed, and
/// the cluster refuses to create projects under them.
pub const RESERVED: &[&str] = &[
    "info", "http", "wal", "cache", "jobs", "write", "metrics", "trace", "cluster", "heat",
    "account", "slo", "qos", "shards",
];

/// The Web-service layer over a cluster (the paper's "application
/// server" role).
pub struct OcpService {
    pub(crate) cluster: Arc<Cluster>,
    /// Loaded vision runtime; `POST /jobs/synapse/...` requires it.
    pub(crate) runtime: Option<Arc<Runtime>>,
    pub(crate) tiles: std::sync::Mutex<std::collections::HashMap<String, Arc<TileService>>>,
    /// Transport metrics shared with the [`crate::web::http::Server`]
    /// (the `/http/status/` surface); `None` when the service is driven
    /// without a server (unit tests).
    pub(crate) http: Option<Arc<HttpMetrics>>,
    /// Cutout responses at or above this raw size stream as chunked
    /// transfer-encoding.
    pub(crate) stream_threshold: usize,
}

impl OcpService {
    pub fn new(cluster: Arc<Cluster>, runtime: Option<Arc<Runtime>>) -> Self {
        OcpService {
            cluster,
            runtime,
            tiles: std::sync::Mutex::new(std::collections::HashMap::new()),
            http: None,
            stream_threshold: DEFAULT_STREAM_THRESHOLD,
        }
    }

    /// Attach the server's transport metrics so `/http/status/` can
    /// report them (done by [`crate::web::serve`]).
    pub fn with_http_metrics(mut self, metrics: Arc<HttpMetrics>) -> Self {
        self.http = Some(metrics);
        self
    }

    /// Override the buffered-vs-streamed cutout threshold (benches).
    pub fn with_stream_threshold(mut self, bytes: usize) -> Self {
        self.stream_threshold = bytes;
        self
    }

    /// Entry point: map a request to a response. Routing errors become
    /// their HTTP status codes; handlers never panic the connection.
    ///
    /// Every request gets a request id — the inbound `X-Request-Id` if
    /// the client sent one, a minted one otherwise — echoed on the
    /// response and naming the request's trace (root span opened here;
    /// the layers below attach children through the thread-local
    /// context).
    pub fn handle(&self, req: Request) -> Response {
        use std::sync::atomic::{AtomicU64, Ordering};
        static REQ_SEQ: AtomicU64 = AtomicU64::new(1);
        let request_id = req
            .request_id
            .clone()
            .unwrap_or_else(|| format!("req-{:06x}", REQ_SEQ.fetch_add(1, Ordering::Relaxed)));
        let name = format!("{} {}", req.method, req.path);
        let mut root = crate::obs::trace::start_trace("http", name, &request_id);
        root.tag("method", req.method.clone());
        let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
        // ---- QoS admission ------------------------------------------
        // Classify BEFORE dispatch (match-only router peek → SLO route
        // class; tenant = the project the request touches), so denials
        // cost a table walk and a map lookup, never a handler.
        let route_name = router().peek(req.method.as_str(), &segs);
        let class = route_name
            .map(crate::obs::slo::class_of_route)
            .unwrap_or(crate::obs::slo::RouteClass::Status);
        let tenant = tenant_of(&self.cluster, &segs);
        let deadline = req
            .deadline_ms
            .map(|ms| std::time::Instant::now() + std::time::Duration::from_millis(ms));
        let qos = self.cluster.qos();
        let admit = qos.admit(tenant, class, req.body.len() as u64);
        // The context rides a thread-local so engines deep below see the
        // class/tenant (fair gates) and deadline (batch-loop checks).
        let _qos_ctx = crate::qos::ctx::install(Some(crate::qos::ctx::ReqCtx {
            class,
            tenant: tenant.map(Arc::from),
            deadline,
        }));
        let mut resp = match admit {
            Err(denial) => {
                let mut r = Response::error(denial.http_status(), denial.message());
                r.retry_after = Some(denial.retry_after_secs());
                r.route = route_name;
                r
            }
            Ok(_admitted) => {
                // `_admitted` holds the in-flight accounting (and the
                // interactive-preemption signal) until dispatch returns.
                if segs.is_empty() {
                    Response::text("ocpd: Open Connectome Project data cluster")
                } else if crate::qos::ctx::check_deadline().is_err() {
                    Response::error(504, "deadline expired before dispatch")
                } else {
                    match router().dispatch(self, req.method.as_str(), &segs, &req.body) {
                        Outcome::Handled(resp) | Outcome::MethodNotAllowed(resp) => resp,
                        Outcome::NoMatch => {
                            if !matches!(req.method.as_str(), "GET" | "PUT" | "POST") {
                                // Methods outside the grammar entirely.
                                Response::method_not_allowed("GET, POST, PUT")
                            } else {
                                Response::error(
                                    400,
                                    format!(
                                        "bad request: unrecognized {} /{}",
                                        req.method,
                                        segs.join("/")
                                    ),
                                )
                            }
                        }
                    }
                }
            }
        };
        if resp.status == 504 {
            qos.note_deadline_expired();
        }
        if let Some(route) = resp.route {
            root.tag("route", route);
        }
        root.tag("status", resp.status.to_string());
        // Tenant accounting, at the one place every project request
        // passes through. Only live tokens mint ledgers (an unknown
        // first segment must not grow the accountant unboundedly);
        // streamed bodies count zero out-bytes — their length is
        // unknown until the connection drains them.
        if let Some(&token) = segs.first() {
            if !RESERVED.contains(&token) && self.cluster.has_project(token) {
                let out = resp.body.len().unwrap_or(0) as u64;
                self.cluster
                    .accountant()
                    .ledger(token)
                    .record_request(req.body.len() as u64, out);
            }
        }
        resp.request_id = Some(request_id);
        resp
    }

    pub(crate) fn tile_service(&self, token: &str) -> Result<Arc<TileService>> {
        let mut guard = self.tiles.lock().unwrap();
        if let Some(t) = guard.get(token) {
            return Ok(Arc::clone(t));
        }
        let svc = self.cluster.image(token)?;
        let ts = Arc::new(TileService::new(svc, 256, 1024));
        guard.insert(token.to_string(), Arc::clone(&ts));
        Ok(ts)
    }
}

/// The project a request touches, for QoS attribution: the first path
/// segment when it names a live project, or the job target for the
/// `/jobs/{propagate|synapse|ingest}/{token}` submission surfaces (a
/// tenant's batch jobs bill against — and are throttled by — that
/// tenant's quota, not a shared anonymous pool). Unknown tokens
/// attribute to no tenant, so garbage paths never mint quota state.
fn tenant_of<'a>(cluster: &Cluster, segs: &'a [&'a str]) -> Option<&'a str> {
    if segs.len() >= 3
        && segs[0] == "jobs"
        && matches!(segs[1], "propagate" | "synapse" | "ingest")
        && cluster.has_project(segs[2])
    {
        return Some(segs[2]);
    }
    match segs.first() {
        Some(&tok) if !RESERVED.contains(&tok) && cluster.has_project(tok) => Some(tok),
        _ => None,
    }
}

/// The routing table. Order matters only among rows that can match the
/// same path: literal-prefixed rows come first so reserved surfaces win
/// over project-token patterns.
fn route_table() -> Vec<Route<OcpService>> {
    use Seg::{Lit, Param, Rest, Token};
    const GET: &[&str] = &["GET"];
    const PUT_POST: &[&str] = &["PUT", "POST"];
    vec![
        // ---- cluster-wide surfaces -----------------------------------
        Route {
            name: "info",
            methods: GET,
            pattern: &[Lit("info")],
            handler: system::info,
            doc: "cluster projects, nodes, and this route listing",
        },
        Route {
            name: "http-status",
            methods: GET,
            pattern: &[Lit("http"), Lit("status")],
            handler: system::http_status,
            doc: "transport metrics: reuse ratio, in-flight, per-route latency",
        },
        // ---- observability -------------------------------------------
        Route {
            name: "metrics",
            methods: GET,
            pattern: &[Lit("metrics")],
            handler: obs::metrics,
            doc: "unified Prometheus-text exposition of every subsystem's metrics",
        },
        Route {
            name: "trace-status",
            methods: GET,
            pattern: &[Lit("trace"), Lit("status")],
            handler: obs::trace_status,
            doc: "tracer config, retention counters, and ring occupancy",
        },
        Route {
            name: "trace-recent",
            methods: GET,
            pattern: &[Lit("trace"), Lit("recent")],
            handler: obs::trace_recent,
            doc: "sampled recent traces as span trees",
        },
        Route {
            name: "trace-slow",
            methods: GET,
            pattern: &[Lit("trace"), Lit("slow")],
            handler: obs::trace_slow,
            doc: "slow traces (above the threshold) as span trees",
        },
        // ---- workload telemetry --------------------------------------
        Route {
            name: "heat-status",
            methods: GET,
            pattern: &[Lit("heat"), Lit("status")],
            handler: telemetry::heat_status,
            doc: "per-project shard heat ranking and top hot key ranges",
        },
        Route {
            name: "account-status",
            methods: GET,
            pattern: &[Lit("account"), Lit("status")],
            handler: telemetry::account_status,
            doc: "per-project request, byte, and worker-second ledgers",
        },
        Route {
            name: "slo-status",
            methods: GET,
            pattern: &[Lit("slo"), Lit("status")],
            handler: telemetry::slo_status,
            doc: "latency-objective attainment and error-budget burn per route class",
        },
        // ---- QoS (multi-tenant admission + fair sharing) -------------
        Route {
            name: "qos-status",
            methods: GET,
            pattern: &[Lit("qos"), Lit("status")],
            handler: qos::status,
            doc: "enforcement state, per-tenant quotas/tokens, pool-gate queues",
        },
        Route {
            name: "qos-quota",
            methods: PUT_POST,
            pattern: &[Lit("qos"), Lit("quota"), Param],
            handler: qos::set_quota,
            doc: "set one tenant's req_per_s / bytes_per_s / weight quota",
        },
        Route {
            name: "qos-enforce",
            methods: PUT_POST,
            pattern: &[Lit("qos"), Lit("enforce"), Param],
            handler: qos::enforce,
            doc: "toggle enforcement on|off (body may override high_water)",
        },
        // ---- WAL (SSD write-absorber) --------------------------------
        Route {
            name: "wal-status",
            methods: GET,
            pattern: &[Lit("wal"), Lit("status")],
            handler: wal::status,
            doc: "per-project write-log depth and flush lag",
        },
        Route {
            name: "wal-flush",
            methods: PUT_POST,
            pattern: &[Lit("wal"), Lit("flush")],
            handler: wal::flush_all,
            doc: "drain every write log",
        },
        Route {
            name: "wal-flush-one",
            methods: PUT_POST,
            pattern: &[Lit("wal"), Lit("flush"), Param],
            handler: wal::flush_one,
            doc: "drain one project's write log",
        },
        // ---- replication control plane -------------------------------
        Route {
            name: "cluster-status",
            methods: GET,
            pattern: &[Lit("cluster"), Lit("status")],
            handler: cluster::status,
            doc: "node health, replica-set epochs/lag, failover counters",
        },
        Route {
            name: "cluster-failover",
            methods: PUT_POST,
            pattern: &[Lit("cluster"), Lit("failover"), Param, Param],
            handler: cluster::failover,
            doc: "force a leader promotion on one project shard",
        },
        // ---- dynamic sharding ----------------------------------------
        Route {
            name: "shards-status",
            methods: GET,
            pattern: &[Lit("shards"), Lit("status")],
            handler: shards::status,
            doc: "shard maps, move windows, and split-planner counters",
        },
        Route {
            name: "shards-split",
            methods: PUT_POST,
            pattern: &[Lit("shards"), Lit("split"), Param, Param],
            handler: shards::split,
            doc: "split one project shard at its heat median and rehome the hot half",
        },
        Route {
            name: "shards-auto",
            methods: PUT_POST,
            pattern: &[Lit("shards"), Lit("auto"), Param],
            handler: shards::auto,
            doc: "toggle heat-driven auto splitting on|off",
        },
        // ---- cuboid cache --------------------------------------------
        Route {
            name: "cache-status",
            methods: GET,
            pattern: &[Lit("cache"), Lit("status")],
            handler: cache::status,
            doc: "per-project cuboid-cache hit rates",
        },
        // ---- parallel write engine -----------------------------------
        Route {
            name: "write-status",
            methods: GET,
            pattern: &[Lit("write"), Lit("status")],
            handler: write_engine::status,
            doc: "per-project write-engine fan-out and RMW elision",
        },
        Route {
            name: "write-workers",
            methods: PUT_POST,
            pattern: &[Lit("write"), Lit("workers"), Param],
            handler: write_engine::set_workers,
            doc: "retune every project's write fan-out",
        },
        // ---- batch compute jobs --------------------------------------
        Route {
            name: "jobs-status",
            methods: GET,
            pattern: &[Lit("jobs"), Lit("status")],
            handler: jobs::status_all,
            doc: "every batch job's state",
        },
        Route {
            name: "jobs-status-one",
            methods: GET,
            pattern: &[Lit("jobs"), Lit("status"), Param],
            handler: jobs::status_one,
            doc: "one batch job's state",
        },
        Route {
            name: "jobs-cancel",
            methods: PUT_POST,
            pattern: &[Lit("jobs"), Lit("cancel"), Param],
            handler: jobs::cancel,
            doc: "cancel a job (checkpoint journal survives)",
        },
        Route {
            name: "jobs-propagate",
            methods: PUT_POST,
            pattern: &[Lit("jobs"), Lit("propagate"), Param],
            handler: jobs::propagate,
            doc: "submit a resolution-hierarchy build",
        },
        Route {
            name: "jobs-synapse",
            methods: PUT_POST,
            pattern: &[Lit("jobs"), Lit("synapse"), Param, Param],
            handler: jobs::synapse,
            doc: "submit synapse detection (needs the vision runtime)",
        },
        Route {
            name: "jobs-ingest",
            methods: PUT_POST,
            pattern: &[Lit("jobs"), Lit("ingest"), Param],
            handler: jobs::ingest,
            doc: "submit a chunked synthetic-EM ingest",
        },
        // ---- project reads -------------------------------------------
        Route {
            name: "cutout",
            methods: GET,
            pattern: &[Token, Lit("ocpk"), Param, Param, Param, Param],
            handler: projects::cutout,
            doc: "volume cutout (streams above the threshold)",
        },
        Route {
            name: "plane",
            methods: GET,
            pattern: &[Token, Lit("xy"), Param, Param, Param, Param],
            handler: projects::plane,
            doc: "XY plane projection",
        },
        Route {
            name: "tile",
            methods: GET,
            pattern: &[Token, Lit("tile"), Param, Param, Param],
            handler: projects::tile,
            doc: "stored-layout viewer tile (zero-copy from cache)",
        },
        Route {
            name: "objects-query",
            methods: GET,
            pattern: &[Token, Lit("objects"), Rest],
            handler: projects::objects_query,
            doc: "RAMON predicate query",
        },
        Route {
            name: "region",
            methods: GET,
            pattern: &[Token, Lit("region"), Param, Param, Param, Param],
            handler: projects::region,
            doc: "annotation ids intersecting a region",
        },
        Route {
            name: "voxels",
            methods: GET,
            pattern: &[Token, Param, Lit("voxels")],
            handler: projects::voxels,
            doc: "one object's voxel list",
        },
        Route {
            name: "boundingbox",
            methods: GET,
            pattern: &[Token, Param, Lit("boundingbox")],
            handler: projects::bounding_box,
            doc: "one object's bounding box",
        },
        Route {
            name: "object-cutout",
            methods: GET,
            pattern: &[Token, Param, Lit("cutout")],
            handler: projects::object_cutout,
            doc: "dense single-object read",
        },
        Route {
            name: "object-cutout-box",
            methods: GET,
            pattern: &[Token, Param, Lit("cutout"), Param, Param, Param, Param],
            handler: projects::object_cutout_box,
            doc: "dense single-object read restricted to a region",
        },
        Route {
            name: "metadata",
            methods: GET,
            pattern: &[Token, Param],
            handler: projects::metadata,
            doc: "RAMON metadata (single id or comma-separated batch)",
        },
        // ---- project writes ------------------------------------------
        Route {
            name: "ramon-put",
            methods: PUT_POST,
            pattern: &[Token, Lit("ramon")],
            handler: projects::ramon_put,
            doc: "batch RAMON metadata write (server assigns ids)",
        },
        Route {
            name: "image-put",
            methods: PUT_POST,
            pattern: &[Token, Lit("image"), Param],
            handler: projects::image_put,
            doc: "image volume ingest (OCPK u8 frame)",
        },
        Route {
            name: "annotation-put",
            methods: PUT_POST,
            pattern: &[Token, Param, Param],
            handler: projects::annotation_put,
            doc: "annotation volume write under a discipline",
        },
    ]
}

/// The process-wide router (the table is static data; build it once).
pub(crate) fn router() -> &'static Router<OcpService> {
    static ROUTER: OnceLock<Router<OcpService>> = OnceLock::new();
    ROUTER.get_or_init(|| Router::new(route_table(), RESERVED))
}

// ----------------------------------------------------------------------
// URL parsing helpers (shared by the handler modules)
// ----------------------------------------------------------------------

pub(crate) fn parse_num(s: &str) -> Result<u64> {
    s.parse().map_err(|_| Error::BadRequest(format!("bad number '{s}'")))
}

/// Whitespace-separated `key=value` pairs (job-submission bodies).
pub(crate) fn parse_params(body: &[u8]) -> std::collections::HashMap<String, String> {
    let mut out = std::collections::HashMap::new();
    for pair in String::from_utf8_lossy(body).split_whitespace() {
        if let Some((k, v)) = pair.split_once('=') {
            out.insert(k.to_string(), v.to_string());
        }
    }
    out
}

/// Numeric param with a default; present-but-garbled values are 400s.
pub(crate) fn param_num(
    params: &std::collections::HashMap<String, String>,
    key: &str,
    default: u64,
) -> Result<u64> {
    match params.get(key) {
        Some(v) => parse_num(v),
        None => Ok(default),
    }
}

/// `"X,Y,Z"` → `[X, Y, Z]` (job dims/block params).
pub(crate) fn parse_triple(s: &str) -> Result<[u64; 3]> {
    let v: Vec<u64> = s.split(',').map(parse_num).collect::<Result<_>>()?;
    if v.len() != 3 {
        return Err(Error::BadRequest(format!("bad triple '{s}' (want X,Y,Z)")));
    }
    Ok([v[0], v[1], v[2]])
}

pub(crate) fn parse_res(s: &str) -> Result<u32> {
    Ok(parse_num(s)? as u32)
}

/// `"lo,hi"` → half-open range.
pub(crate) fn parse_range(s: &str) -> Result<(u64, u64)> {
    let (a, b) = s
        .split_once(',')
        .ok_or_else(|| Error::BadRequest(format!("bad range '{s}' (want lo,hi)")))?;
    let (lo, hi) = (parse_num(a)?, parse_num(b)?);
    if lo >= hi {
        return Err(Error::BadRequest(format!("empty range '{s}'")));
    }
    Ok((lo, hi))
}

pub(crate) fn parse_box(xr: &str, yr: &str, zr: &str) -> Result<Box3> {
    let (x0, x1) = parse_range(xr)?;
    let (y0, y1) = parse_range(yr)?;
    let (z0, z1) = parse_range(zr)?;
    Ok(Box3::new([x0, y0, z0], [x1, y1, z1]))
}

/// Predicate segments: `field/value` pairs, with `field/op/value` for
/// range operators (§4.2: equality everywhere, inequalities on floats).
pub(crate) fn parse_predicates(segs: &[&str]) -> Result<Vec<Predicate>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < segs.len() {
        let field = segs[i];
        if i + 1 >= segs.len() {
            return Err(Error::BadRequest(format!("predicate '{field}' missing value")));
        }
        if let Ok(op) = PredicateOp::parse(segs[i + 1]) {
            if op != PredicateOp::Eq {
                if i + 2 >= segs.len() {
                    return Err(Error::BadRequest(format!(
                        "predicate '{field}/{}' missing value",
                        segs[i + 1]
                    )));
                }
                out.push(Predicate {
                    field: field.to_string(),
                    op,
                    value: segs[i + 2].to_string(),
                });
                i += 3;
                continue;
            }
        }
        out.push(Predicate::eq(field, segs[i + 1]));
        i += 2;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_parsing() {
        assert_eq!(parse_range("5,10").unwrap(), (5, 10));
        assert!(parse_range("10,5").is_err());
        assert!(parse_range("abc").is_err());
        assert!(parse_range("5").is_err());
    }

    #[test]
    fn predicate_parsing_paper_example() {
        // objects/type/synapse/confidence/geq/0.99/
        let p = parse_predicates(&["type", "synapse", "confidence", "geq", "0.99"]).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].field, "type");
        assert_eq!(p[0].op, PredicateOp::Eq);
        assert_eq!(p[1].op, PredicateOp::Geq);
        assert_eq!(p[1].value, "0.99");
        assert!(parse_predicates(&["type"]).is_err());
        assert!(parse_predicates(&["confidence", "geq"]).is_err());
    }

    #[test]
    fn box_parsing() {
        let b = parse_box("0,128", "128,256", "0,16").unwrap();
        assert_eq!(b, Box3::new([0, 128, 0], [128, 256, 16]));
    }

    #[test]
    fn job_param_parsing() {
        let p = parse_params(b"workers=8 dims=512,512,64\nseed=7");
        assert_eq!(p.get("workers").unwrap(), "8");
        assert_eq!(param_num(&p, "workers", 4).unwrap(), 8);
        assert_eq!(param_num(&p, "absent", 4).unwrap(), 4);
        assert_eq!(parse_triple(p.get("dims").unwrap()).unwrap(), [512, 512, 64]);
        assert!(parse_triple("1,2").is_err());
        assert!(parse_triple("a,b,c").is_err());
        // Garbled present values are errors, not silent defaults.
        let bad = parse_params(b"workers=banana");
        assert!(param_num(&bad, "workers", 4).is_err());
    }

    #[test]
    fn route_table_is_well_formed() {
        let r = router();
        // Every reserved name that owns routes appears as a literal
        // first segment; every route has methods and a doc line.
        let listing = r.listing();
        for reserved in [
            "info", "http", "wal", "cache", "jobs", "write", "metrics", "trace", "cluster",
            "heat", "account", "slo", "qos", "shards",
        ] {
            assert!(listing.contains(&format!("/{reserved}")), "{reserved} missing:\n{listing}");
        }
        for label in [
            "cutout", "metadata", "ramon-put", "http-status", "trace-slow", "heat-status",
            "qos-status",
        ] {
            assert!(listing.contains(label), "{label} missing:\n{listing}");
        }
    }
}
