//! Cuboid-cache routes.

use crate::web::http::Response;
use crate::web::router::Ctx;
use crate::web::routes::OcpService;
use crate::Result;

/// GET /cache/status/ — one line per project's cuboid cache.
pub(crate) fn status(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    let mut out = String::from("cache:\n");
    for (token, s) in svc.cluster.cache_status() {
        out.push_str(&format!(
            "  {token}: entries={} bytes={}/{} shards={} hits={} misses={} \
             hit_rate={:.3} inserts={} evictions={} invalidations={}\n",
            s.entries,
            s.bytes,
            s.capacity_bytes,
            s.shards,
            s.hits,
            s.misses,
            s.hit_rate(),
            s.inserts,
            s.evictions,
            s.invalidations
        ));
    }
    Ok(Response::text(out))
}
