//! QoS control-plane routes: enforcement toggle, per-tenant quotas,
//! and the admission/fair-sharing status surface.

use crate::qos::Quota;
use crate::web::http::Response;
use crate::web::router::Ctx;
use crate::web::routes::{parse_params, OcpService};
use crate::{Error, Result};

/// GET /qos/status/ — enforcement state, in-flight accounting,
/// admission counters, pool-gate queues, and per-tenant quota/token
/// levels.
pub(crate) fn status(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    Ok(Response::text(svc.cluster.qos().status_text()))
}

/// PUT/POST /qos/quota/{token}/ — set one tenant's quota. Body is
/// whitespace-separated `key=value` pairs: `req_per_s`, `bytes_per_s`
/// (both float; omitted = unlimited) and `weight` (integer ≥ 1,
/// default 1). Replaces the tenant's whole quota — token buckets
/// restart full at the new rates.
pub(crate) fn set_quota(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let token = ctx.params[0];
    if !svc.cluster.has_project(token) {
        return Err(Error::NotFound(format!("project '{token}'")));
    }
    let params = parse_params(ctx.body);
    let mut quota = Quota::default();
    if let Some(v) = params.get("req_per_s") {
        quota.req_per_s = parse_rate(v, "req_per_s")?;
    }
    if let Some(v) = params.get("bytes_per_s") {
        quota.bytes_per_s = parse_rate(v, "bytes_per_s")?;
    }
    if let Some(v) = params.get("weight") {
        quota.weight = v
            .parse::<u64>()
            .ok()
            .filter(|&w| w >= 1)
            .ok_or_else(|| Error::BadRequest(format!("bad weight '{v}' (want integer >= 1)")))?;
    }
    svc.cluster.qos().set_quota(token, quota);
    Ok(Response::text(format!(
        "quota {token}: req_per_s={} bytes_per_s={} weight={}\n",
        rate_str(quota.req_per_s),
        rate_str(quota.bytes_per_s),
        quota.weight
    )))
}

/// PUT/POST /qos/enforce/{mode}/ — `on` or `off`. The body may carry
/// `high_water=<bytes>` to retune the overload-shed threshold.
pub(crate) fn enforce(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let enabled = match ctx.params[0] {
        "on" => true,
        "off" => false,
        other => {
            return Err(Error::BadRequest(format!("bad enforce mode '{other}' (want on|off)")))
        }
    };
    let qos = svc.cluster.qos();
    let params = parse_params(ctx.body);
    if let Some(v) = params.get("high_water") {
        let hw = v
            .parse::<u64>()
            .ok()
            .filter(|&b| b > 0)
            .ok_or_else(|| Error::BadRequest(format!("bad high_water '{v}'")))?;
        qos.set_high_water(hw);
    }
    qos.set_enabled(enabled);
    Ok(Response::text(format!(
        "qos enforcement {} (high_water={})\n",
        if enabled { "on" } else { "off" },
        qos.high_water()
    )))
}

/// A quota rate: positive float, or `inf`/`unlimited` for no limit.
fn parse_rate(v: &str, key: &str) -> Result<f64> {
    if matches!(v, "inf" | "unlimited") {
        return Ok(f64::INFINITY);
    }
    v.parse::<f64>()
        .ok()
        .filter(|r| *r > 0.0)
        .ok_or_else(|| Error::BadRequest(format!("bad {key} '{v}' (want positive number or inf)")))
}

fn rate_str(r: f64) -> String {
    if r.is_infinite() {
        "unlimited".to_string()
    } else {
        format!("{r}")
    }
}
