//! Replication control-plane routes (node health, replica sets,
//! manual failover).

use crate::web::http::Response;
use crate::web::router::Ctx;
use crate::web::routes::{parse_num, OcpService};
use crate::Result;

/// GET /cluster/status/ — node health, control-plane counters, and
/// every project's replica sets (epoch, leader, lag, failovers).
pub(crate) fn status(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    Ok(Response::text(svc.cluster.cluster_status()))
}

/// POST /cluster/failover/{token}/{shard}/ — force a leader promotion
/// on one project shard (operator-driven failover drill).
pub(crate) fn failover(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let token = ctx.params[0];
    let shard = parse_num(ctx.params[1])? as usize;
    let r = svc.cluster.failover(token, shard)?;
    Ok(Response::text(format!(
        "promoted: project={token} shard={} from=node{} to=node{} epoch={} lost_lsns={}\n",
        r.shard, r.from, r.to, r.epoch, r.lost_lsns
    )))
}
