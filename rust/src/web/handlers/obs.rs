//! Observability surfaces: the unified `/metrics/` exposition and the
//! `/trace/*` span-tree views.

use crate::obs::trace::{render_traces, tracer};
use crate::web::http::Response;
use crate::web::router::Ctx;
use crate::web::routes::OcpService;
use crate::Result;

/// GET /metrics/ — every registered subsystem's counters, gauges, and
/// histograms in Prometheus text format (version 0.0.4).
pub(crate) fn metrics(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    let body = svc.cluster.registry().render();
    Ok(Response::ok(body.into_bytes(), "text/plain; version=0.0.4"))
}

/// GET /trace/status/ — tracer configuration and retention counters.
pub(crate) fn trace_status(_svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    Ok(Response::text(tracer().status_text()))
}

/// GET /trace/recent/ — sampled recent traces, newest first.
pub(crate) fn trace_recent(_svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    Ok(Response::text(render_traces(&tracer().recent())))
}

/// GET /trace/slow/ — traces above the slow threshold, newest first.
pub(crate) fn trace_slow(_svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    Ok(Response::text(render_traces(&tracer().slow())))
}
