//! Cluster-wide surfaces: the root greeting, `/info/` (projects, nodes,
//! WAL summary, and the auto-generated route listing), and
//! `/http/status/` (the transport metrics).

use crate::web::http::Response;
use crate::web::router::Ctx;
use crate::web::routes::{router, OcpService};
use crate::Result;

/// GET /info/ — projects, node I/O, WAL depth, and the route table.
pub(crate) fn info(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    let mut out = String::from("ocpd cluster\nprojects:\n");
    for t in svc.cluster.tokens() {
        out.push_str(&format!("  {t}\n"));
    }
    out.push_str("nodes:\n");
    for (name, s) in svc.cluster.node_stats() {
        out.push_str(&format!(
            "  {name}: reads={} read_bytes={} writes={} write_bytes={}\n",
            s.reads, s.read_bytes, s.writes, s.write_bytes
        ));
    }
    let wals = svc.cluster.wal_status()?;
    if !wals.is_empty() {
        out.push_str("wal:\n");
        for s in wals {
            out.push_str(&format!(
                "  {}: depth={} flushed={}\n",
                s.scope, s.depth_records, s.flushed_records
            ));
        }
    }
    // The route listing derives from the same table that dispatched
    // this request — it cannot drift from the real grammar.
    out.push_str("routes:\n");
    out.push_str(&router().listing());
    Ok(Response::text(out))
}

/// GET /http/status/ — requests, reuse ratio, in-flight, admission
/// rejections, accept errors, latency, and per-route histograms.
pub(crate) fn http_status(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    match &svc.http {
        Some(m) => Ok(Response::text(m.status_text())),
        None => Ok(Response::text(
            "http:\n  (no transport metrics attached; serve() wires them)\n",
        )),
    }
}
