//! Route handlers, one module per subsystem surface.
//!
//! Each handler is a plain `fn(&OcpService, &Ctx) -> Result<Response>`
//! registered in the routing table ([`crate::web::routes`]); the table,
//! not the handlers, owns method sets, 405 derivation, and route
//! naming. Handlers parse their captured segments with the helpers in
//! [`crate::web::routes`] and talk to the cluster services directly.

pub(crate) mod cache;
pub(crate) mod cluster;
pub(crate) mod jobs;
pub(crate) mod obs;
pub(crate) mod projects;
pub(crate) mod qos;
pub(crate) mod shards;
pub(crate) mod system;
pub(crate) mod telemetry;
pub(crate) mod wal;
pub(crate) mod write_engine;
