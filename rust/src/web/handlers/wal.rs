//! Write-ahead-log routes (the SSD write-absorber's surface).

use crate::web::http::Response;
use crate::web::router::Ctx;
use crate::web::routes::OcpService;
use crate::Result;

/// GET /wal/status/ — one line per hot project's log.
pub(crate) fn status(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    let statuses = svc.cluster.wal_status()?;
    let mut out = String::from("wal:\n");
    for s in statuses {
        out.push_str(&format!(
            "  {}: depth={} records ({} bytes) active_seg={} sealed={} \
             commits={} mean_batch={:.1} flushed={} lag_ms={:.1} \
             replicas={} lagging={} shipped={}\n",
            s.scope,
            s.depth_records,
            s.depth_bytes,
            s.active_segment,
            s.sealed_segments,
            s.commit_batches,
            s.mean_batch(),
            s.flushed_records,
            s.flush_lag_ms,
            s.replicas,
            s.replicas_lagging,
            s.shipped_chunks
        ));
    }
    Ok(Response::text(out))
}

/// PUT /wal/flush/ — drain every hot project's log.
pub(crate) fn flush_all(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    let n = svc.cluster.flush_all_wals()?;
    Ok(Response::text(format!("flushed={n}")))
}

/// PUT /wal/flush/{token}/ — drain one project's log.
pub(crate) fn flush_one(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let n = svc.cluster.flush_wal(ctx.params[0])?;
    Ok(Response::text(format!("flushed={n}")))
}
