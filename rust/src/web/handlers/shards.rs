//! Dynamic-sharding routes: topology status, manual splits, and the
//! heat-driven auto balancer switch (DESIGN.md §13).

use crate::web::http::Response;
use crate::web::router::Ctx;
use crate::web::routes::{parse_num, OcpService};
use crate::{Error, Result};

/// GET /shards/status/ — every sharded project's topology (map
/// generation, per-shard ranges/owners/epochs, open move windows) plus
/// the balancer's counters and recent splits.
pub(crate) fn status(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    Ok(Response::text(svc.cluster.shard_status_text()))
}

/// POST /shards/split/{token}/{shard}/ — split one shard at its heat
/// median (block-snapped range midpoint when cold) and rehome the upper
/// half through the dual-route move window.
pub(crate) fn split(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let token = ctx.params[0];
    let shard = parse_num(ctx.params[1])? as usize;
    let r = svc.cluster.split_shard(token, shard)?;
    Ok(Response::text(format!(
        "split: project={} shard={} cut={} target=node{} moved={} purged={} map_version={}\n",
        r.token, r.shard, r.cut, r.target_node, r.keys_moved, r.keys_purged, r.map_version
    )))
}

/// PUT /shards/auto/{on|off}/ — switch the background heat-driven
/// splitter on or off.
pub(crate) fn auto(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let on = match ctx.params[0] {
        "on" => true,
        "off" => false,
        other => {
            return Err(Error::BadRequest(format!("bad auto mode '{other}' (want on|off)")))
        }
    };
    svc.cluster.set_auto_balance(on);
    Ok(Response::text(format!("auto balance: {}\n", if on { "on" } else { "off" })))
}
