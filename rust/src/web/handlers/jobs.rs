//! Batch-compute-job routes.

use std::sync::Arc;

use crate::ingest::SynthSpec;
use crate::jobs::{BulkIngestJob, JobConfig, JobSpec, PropagateJob, SynapseDetectJob};
use crate::vision::SynapsePipeline;
use crate::web::http::Response;
use crate::web::router::Ctx;
use crate::web::routes::{param_num, parse_num, parse_params, parse_triple, OcpService};
use crate::{Error, Result};

/// Upper bound on a server-side synthetic-ingest request, in voxels.
/// The generator materializes the whole volume (8 B/voxel accumulator
/// plus the u8 output), so this caps the per-request allocation at
/// ~1.2 GiB regardless of how large the registered dataset is.
const MAX_INGEST_VOXELS: u64 = 1 << 27;

/// GET /jobs/status/ — every job.
pub(crate) fn status_all(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    let mut out = String::from("jobs:\n");
    for s in svc.cluster.jobs().statuses() {
        out.push_str(&format!("  {}\n", s.line()));
    }
    Ok(Response::text(out))
}

/// GET /jobs/status/{id}/ — one job.
pub(crate) fn status_one(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let id = parse_num(ctx.params[0])?;
    match svc.cluster.jobs().get(id) {
        Some(h) => Ok(Response::text(h.status().line())),
        None => Err(Error::NotFound(format!("job {id}"))),
    }
}

/// POST /jobs/cancel/{id}/.
pub(crate) fn cancel(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let id = parse_num(ctx.params[0])?;
    svc.cluster.jobs().cancel(id)?;
    Ok(Response::text(format!("cancelled={id}")))
}

/// POST /jobs/propagate/{token}/ — build the resolution hierarchy of an
/// image or annotation project.
pub(crate) fn propagate(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let token = ctx.params[0];
    let spec: Arc<dyn JobSpec> = match svc.cluster.image(token) {
        Ok(s) => Arc::new(PropagateJob::image(s)),
        Err(_) => Arc::new(PropagateJob::annotation(svc.cluster.annotation(token)?)),
    };
    submit(svc, spec, ctx.body)
}

/// POST /jobs/synapse/{image}/{annotation}/ — the §2 vision workload;
/// needs the AOT runtime.
pub(crate) fn synapse(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let (img, ann) = (ctx.params[0], ctx.params[1]);
    let runtime = svc.runtime.clone().ok_or_else(|| {
        Error::BadRequest("no vision runtime loaded (start the server with artifacts)".into())
    })?;
    let image = svc.cluster.image(img)?;
    let anno = svc.cluster.annotation(ann)?;
    let params = parse_params(ctx.body);
    let res = param_num(&params, "res", 0)? as u32;
    let region = image.store().dataset.level(res)?.bounds();
    let pipeline = Arc::new(SynapsePipeline::new(runtime, image, anno));
    submit(svc, Arc::new(SynapseDetectJob::new(pipeline, res, region)), ctx.body)
}

/// POST /jobs/ingest/{token}/ — chunked synthetic-EM ingest
/// (`dims=X,Y,Z` required; `seed=N` optional).
pub(crate) fn ingest(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let token = ctx.params[0];
    let params = parse_params(ctx.body);
    let s = svc.cluster.image(token)?;
    let dims = params
        .get("dims")
        .ok_or_else(|| Error::BadRequest("ingest needs dims=X,Y,Z".into()))?;
    let dims = parse_triple(dims)?;
    // Clamp to the project's level-0 bounds, then cap the total volume:
    // the generator holds the whole volume in memory (an f64
    // accumulator, 8 B/voxel), so client dims must never size an
    // arbitrary allocation — a registered dataset's bounds alone can
    // exceed RAM.
    let bounds = s.store().dataset.level(0)?.dims;
    let dims = [
        dims[0].min(bounds[0]).max(1),
        dims[1].min(bounds[1]).max(1),
        dims[2].min(bounds[2]).max(1),
    ];
    let voxels = dims[0].saturating_mul(dims[1]).saturating_mul(dims[2]);
    if voxels > MAX_INGEST_VOXELS {
        return Err(Error::BadRequest(format!(
            "ingest volume of {voxels} voxels exceeds the \
             {MAX_INGEST_VOXELS}-voxel limit (ingest a sub-volume, or use \
             client-side uploads for full-scale data)"
        )));
    }
    let seed = param_num(&params, "seed", 2013)?;
    let block = match params.get("block") {
        Some(b) => parse_triple(b)?,
        None => [256, 256, 16],
    };
    let spec = SynthSpec::small(dims, seed);
    submit(svc, Arc::new(BulkIngestJob::new(s, spec, block)), ctx.body)
}

/// Launch a job (fresh id, or resume via `job=ID`) and report it.
fn submit(svc: &OcpService, spec: Arc<dyn JobSpec>, body: &[u8]) -> Result<Response> {
    let params = parse_params(body);
    // `MAX_WORKERS` also guards inside the engine; clamping here keeps
    // a typo'd `workers=100000` from even trying.
    let cfg = JobConfig {
        workers: (param_num(&params, "workers", 4)? as usize).clamp(1, crate::jobs::MAX_WORKERS),
        ..JobConfig::default()
    };
    let handle = match params.get("job") {
        Some(id) => svc.cluster.jobs().submit_with_id(parse_num(id)?, spec, cfg)?,
        None => svc.cluster.jobs().submit(spec, cfg)?,
    };
    Ok(Response::text(format!(
        "id={} name={} state={}",
        handle.id,
        handle.name(),
        handle.state().as_str()
    )))
}
