//! Parallel-write-engine routes.

use crate::web::http::Response;
use crate::web::router::Ctx;
use crate::web::routes::{parse_num, OcpService};
use crate::Result;

/// GET /write/status/ — one line per project's write engine.
pub(crate) fn status(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    let mut out = String::from("write:\n");
    for (token, s) in svc.cluster.write_status() {
        out.push_str(&format!(
            "  {token}: workers={} threshold={} seq={} par={} \
             elided_reads={} rmw_reads={} merge_mean_us={:.1} merge_p95_us={}\n",
            s.workers,
            s.parallel_threshold,
            s.sequential_writes,
            s.parallel_writes,
            s.elided_reads,
            s.rmw_reads,
            s.merge_mean_us,
            s.merge_p95_us
        ));
    }
    Ok(Response::text(out))
}

/// PUT /write/workers/{n}/ — retune every project's write fan-out.
pub(crate) fn set_workers(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let n = (parse_num(ctx.params[0])? as usize).clamp(1, crate::jobs::MAX_WORKERS);
    let projects = svc.cluster.set_write_workers(n);
    Ok(Response::text(format!("workers={n} projects={projects}")))
}
