//! Per-project routes: cutouts, planes, tiles, RAMON metadata and
//! object reads, volume writes.
//!
//! Large cutouts stream: instead of materializing the whole encoded
//! volume, the handler emits an OCPK header followed by raw
//! cuboid-aligned z-slabs as chunked transfer-encoding — each slab is
//! read through the parallel read engine only when the previous one is
//! already on the wire, so server-side peak memory is one slab, not the
//! full volume.

use std::sync::Arc;

use crate::array::{DenseVolume, Plane, VoxelScalar};
use crate::core::{Box3, Dtype, WriteDiscipline};
use crate::cutout::CutoutService;
use crate::tiles::TileKey;
use crate::web::http::{BodyStream, Response};
use crate::web::ocpk;
use crate::web::router::Ctx;
use crate::web::routes::{
    parse_box, parse_num, parse_predicates, parse_range, parse_res, OcpService,
};
use crate::{Error, Result};

/// Bounds on the voxel-data bytes per streamed slab. The target is a
/// quarter of the service's stream threshold (so a streamed response
/// always spans several chunks), clamped into this window and rounded
/// up to whole cuboid-aligned z-layer groups.
const STREAM_SLAB_MIN_BYTES: usize = 64 << 10;
const STREAM_SLAB_MAX_BYTES: usize = 2 << 20;

/// GET /{token}/ocpk/{res}/{xr}/{yr}/{zr}/ — image cutout if the token
/// is an image project, else annotation cutout.
pub(crate) fn cutout(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let token = ctx.params[0];
    let res = parse_res(ctx.params[1])?;
    let bx = parse_box(ctx.params[2], ctx.params[3], ctx.params[4])?;
    if let Ok(is) = svc.cluster.image(token) {
        let slabs = Arc::clone(&is);
        return volume_response::<u8, _>(svc, &slabs, Dtype::U8, res, bx, move |r, b| {
            is.read::<u8>(r, 0, 0, b)
        });
    }
    let db = svc.cluster.annotation(token)?;
    let slabs = Arc::clone(&db);
    volume_response::<u32, _>(svc, &slabs.cutout, Dtype::U32, res, bx, move |r, b| {
        db.cutout.read::<u32>(r, 0, 0, b)
    })
}

/// Buffered OCPK volume under the stream threshold, chunked stream of
/// cuboid-aligned z-slabs above it.
fn volume_response<T, F>(
    svc: &OcpService,
    cs: &CutoutService,
    dtype: Dtype,
    res: u32,
    bx: Box3,
    read: F,
) -> Result<Response>
where
    T: VoxelScalar,
    F: Fn(u32, Box3) -> Result<DenseVolume<T>> + Send + 'static,
{
    let raw_bytes = (bx.volume() as usize).saturating_mul(T::BYTES);
    if raw_bytes < svc.stream_threshold {
        let vol = read(res, bx)?;
        return Ok(Response::binary(ocpk::encode_volume(dtype, bx.lo, &vol)?));
    }
    // Plan (and validate) the slabs BEFORE committing to a 200 status
    // line — a bad box fails here as a clean 400, not a mid-stream
    // abort.
    let slab_bytes = (svc.stream_threshold / 4).clamp(STREAM_SLAB_MIN_BYTES, STREAM_SLAB_MAX_BYTES);
    let slabs = cs.slab_boxes(res, bx, slab_bytes / T::BYTES.max(1))?;
    let mut header =
        Some(ocpk::encode_volume_header(dtype, bx.lo, bx.extent(), raw_bytes as u64));
    let metrics = svc.http.clone();
    if let Some(m) = &metrics {
        m.streamed_responses.inc();
    }
    let mut iter = slabs.into_iter();
    let stream: BodyStream = Box::new(move || {
        if let Some(h) = header.take() {
            return Ok(Some(h));
        }
        match iter.next() {
            Some(slab) => {
                let bytes = volume_into_bytes(read(res, slab)?);
                if let Some(m) = &metrics {
                    crate::web::http::note_stream_chunk(m, bytes.len());
                }
                Ok(Some(bytes))
            }
            None => Ok(None),
        }
    });
    Ok(Response::stream("application/x-ocpk", stream))
}

/// A volume's raw little-endian payload as an owned buffer. For `u8`
/// (the large-EM streaming case) this hands back the read buffer
/// itself — no copy; wider scalars pay one copy (a `Vec<T>` allocation
/// cannot be retagged as `Vec<u8>` without an alignment-mismatched
/// dealloc).
fn volume_into_bytes<T: VoxelScalar>(vol: DenseVolume<T>) -> Vec<u8> {
    if std::any::TypeId::of::<T>() == std::any::TypeId::of::<u8>() {
        let mut v = std::mem::ManuallyDrop::new(vol.into_vec());
        // Safety: T IS u8 (checked above), so pointer, length, capacity
        // and allocation layout are already exactly a Vec<u8>'s.
        unsafe { Vec::from_raw_parts(v.as_mut_ptr() as *mut u8, v.len(), v.capacity()) }
    } else {
        vol.as_bytes().to_vec()
    }
}

/// GET /{token}/xy/{res}/{z}/{xr}/{yr}/ — plane projection.
pub(crate) fn plane(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let token = ctx.params[0];
    let res = parse_res(ctx.params[1])?;
    let z: u64 = parse_num(ctx.params[2])?;
    let (x0, x1) = parse_range(ctx.params[3])?;
    let (y0, y1) = parse_range(ctx.params[4])?;
    let s = svc.cluster.image(token)?;
    let (w, h, data) = s.read_plane::<u8>(res, 0, 0, Plane::Xy(z), [x0, y0], [x1, y1])?;
    let vol = DenseVolume::from_vec([w, h, 1], data)?;
    Ok(Response::binary(ocpk::encode_volume(Dtype::U8, [x0, y0, z], &vol)?))
}

/// GET /{token}/tile/{res}/{z}/{y}_{x}.gray — stored-layout tile,
/// served zero-copy from the tile cache.
pub(crate) fn tile(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let token = ctx.params[0];
    let res = parse_res(ctx.params[1])?;
    let z: u64 = parse_num(ctx.params[2])?;
    let yx = ctx.params[3];
    let (y, x) = yx
        .strip_suffix(".gray")
        .and_then(|s| s.split_once('_'))
        .ok_or_else(|| Error::BadRequest(format!("bad tile name '{yx}'")))?;
    let key = TileKey { res, z, y: parse_num(y)?, x: parse_num(x)? };
    let ts = svc.tile_service(token)?;
    Ok(Response::binary_shared(ts.get_tile_shared(key)?))
}

/// GET /{token}/objects/{field}/{value}/... — predicate query.
pub(crate) fn objects_query(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let db = svc.cluster.annotation(ctx.params[0])?;
    let predicates = parse_predicates(ctx.rest)?;
    let ids = db.query(&predicates)?;
    Ok(Response::text(ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")))
}

/// GET /{token}/region/{res}/{xr}/{yr}/{zr}/ — ids in region.
pub(crate) fn region(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let db = svc.cluster.annotation(ctx.params[0])?;
    let ids = db.objects_in_region(
        parse_res(ctx.params[1])?,
        parse_box(ctx.params[2], ctx.params[3], ctx.params[4])?,
        crate::annotation::RegionQuery { include_exceptions: true },
    )?;
    Ok(Response::text(ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")))
}

/// GET /{token}/{id}/voxels/.
pub(crate) fn voxels(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let db = svc.cluster.annotation(ctx.params[0])?;
    let voxels =
        db.voxel_list(db.project.base_resolution, parse_num(ctx.params[1])? as u32)?;
    Ok(Response::binary(ocpk::encode_voxels(&voxels)))
}

/// GET /{token}/{id}/boundingbox/.
pub(crate) fn bounding_box(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let db = svc.cluster.annotation(ctx.params[0])?;
    let id = parse_num(ctx.params[1])? as u32;
    match db.bounding_box(db.project.base_resolution, id)? {
        Some(b) => Ok(Response::text(format!(
            "{},{}/{},{}/{},{}",
            b.lo[0], b.hi[0], b.lo[1], b.hi[1], b.lo[2], b.hi[2]
        ))),
        None => Err(Error::NotFound(format!("annotation {id} has no voxels"))),
    }
}

/// GET /{token}/{id}/cutout/ — dense object read.
pub(crate) fn object_cutout(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let db = svc.cluster.annotation(ctx.params[0])?;
    let id = parse_num(ctx.params[1])? as u32;
    let res = db.project.base_resolution;
    match db.dense_read(res, id, None)? {
        Some((bx, vol)) => Ok(Response::binary(ocpk::encode_volume(Dtype::U32, bx.lo, &vol)?)),
        None => Err(Error::NotFound(format!("annotation {id} has no voxels"))),
    }
}

/// GET /{token}/{id}/cutout/{res}/{xr}/{yr}/{zr}/ — restricted.
pub(crate) fn object_cutout_box(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let db = svc.cluster.annotation(ctx.params[0])?;
    let id = parse_num(ctx.params[1])? as u32;
    let bx = parse_box(ctx.params[3], ctx.params[4], ctx.params[5])?;
    match db.dense_read(parse_res(ctx.params[2])?, id, Some(bx))? {
        Some((bx, vol)) => Ok(Response::binary(ocpk::encode_volume(Dtype::U32, bx.lo, &vol)?)),
        None => Err(Error::NotFound(format!("annotation {id} has no voxels"))),
    }
}

/// GET /{token}/{id}/ or /{token}/{id1},{id2},.../ — metadata.
pub(crate) fn metadata(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let db = svc.cluster.annotation(ctx.params[0])?;
    let ids: Vec<u32> = ctx.params[1]
        .split(',')
        .map(|s| parse_num(s).map(|v| v as u32))
        .collect::<Result<_>>()?;
    let objs = db.get_objects(&ids)?;
    let found: Vec<_> = objs.into_iter().flatten().collect();
    if found.is_empty() {
        return Err(Error::NotFound("no matching annotations".into()));
    }
    Ok(Response::binary(ocpk::encode_objects(&found)))
}

/// PUT /{token}/ramon/ — batch metadata write; server assigns ids for
/// id=0 objects (§4.2).
pub(crate) fn ramon_put(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let db = svc.cluster.annotation(ctx.params[0])?;
    let objs = ocpk::decode_objects(ctx.body)?;
    let ids = db.put_objects(objs)?;
    Ok(Response::text(ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")))
}

/// PUT /{token}/image/{res}/ — image ingest (OCPK u8 volume).
pub(crate) fn image_put(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let s = svc.cluster.image(ctx.params[0])?;
    let (_dt, bx, vol) = ocpk::decode_volume::<u8>(ctx.body)?;
    s.write(parse_res(ctx.params[1])?, 0, 0, bx, &vol)?;
    Ok(Response::text("ok"))
}

/// PUT /{token}/{discipline}/{res}/ with an OCPK volume body (frame
/// carries its own offset).
pub(crate) fn annotation_put(svc: &OcpService, ctx: &Ctx<'_>) -> Result<Response> {
    let disc = ctx.params[1];
    let discipline = WriteDiscipline::parse(disc)
        .ok_or_else(|| Error::BadRequest(format!("unknown write discipline '{disc}'")))?;
    let db = svc.cluster.annotation(ctx.params[0])?;
    let (_dt, bx, vol) = ocpk::decode_volume::<u32>(ctx.body)?;
    let outcome = db.write_volume(parse_res(ctx.params[2])?, bx, &vol, discipline)?;
    Ok(Response::text(format!(
        "written={} conflicted={} exceptions={} cuboids={}",
        outcome.voxels_written,
        outcome.voxels_conflicted,
        outcome.exceptions_added,
        outcome.cuboids_touched
    )))
}
