//! Workload-telemetry routes: shard heat maps, tenant ledgers, and
//! SLO attainment.

use crate::web::http::Response;
use crate::web::router::Ctx;
use crate::web::routes::OcpService;
use crate::Result;

/// Hot key ranges listed per project on `GET /heat/status/`.
const TOP_K: usize = 5;

/// GET /heat/status/ — per-project shard ranking (hottest first) plus
/// the top-K hot key ranges, from the decayed EWMA heat map.
pub(crate) fn heat_status(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    let mut out = String::from("heat:\n");
    for (token, snap) in svc.cluster.heat_status() {
        out.push_str(&format!("  {token}: total_score={:.0}\n", snap.total_score));
        for sh in &snap.shards {
            out.push_str(&format!(
                "    shard {} [{},{}): score={:.0} read_bytes={:.0} write_bytes={:.0} \
                 read_ops={:.1} write_ops={:.1}\n",
                sh.shard, sh.lo, sh.hi, sh.score, sh.read_bytes, sh.write_bytes, sh.read_ops,
                sh.write_ops
            ));
        }
        for b in snap.top_buckets(TOP_K) {
            out.push_str(&format!(
                "    hot [{},{}): score={:.0} read_bytes={:.0} write_bytes={:.0}\n",
                b.lo, b.hi, b.score, b.read_bytes, b.write_bytes
            ));
        }
    }
    Ok(Response::text(out))
}

/// GET /account/status/ — one ledger line per project: requests,
/// bytes in/out, and busy worker-microseconds per pool.
pub(crate) fn account_status(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    let mut out = String::from("account:\n");
    for (token, s) in svc.cluster.account_status() {
        out.push_str(&format!(
            "  {token}: requests={} bytes_in={} bytes_out={} read_worker_us={} \
             write_worker_us={} job_worker_us={}\n",
            s.requests, s.bytes_in, s.bytes_out, s.read_worker_us, s.write_worker_us,
            s.job_worker_us
        ));
    }
    Ok(Response::text(out))
}

/// GET /slo/status/ — latency-objective attainment and error-budget
/// burn per route class, from the transport's per-route histograms.
pub(crate) fn slo_status(svc: &OcpService, _ctx: &Ctx<'_>) -> Result<Response> {
    match &svc.http {
        Some(m) => {
            let report = crate::obs::slo::evaluate(&m.route_histograms());
            Ok(Response::text(report.render_text()))
        }
        None => Ok(Response::text("slo: no transport metrics (service driven without a server)\n")),
    }
}
