//! Keep-alive HTTP client: a process-wide connection pool with
//! chunked-response decoding.
//!
//! Every wire client in the system — the typed [`crate::client`] layer,
//! the CLI subcommands, the job submitters, the benches, and the test
//! suites — funnels through [`request`], so all of them ride pooled
//! persistent connections automatically. A socket is checked out of the
//! pool (or freshly connected), carries one request/response exchange,
//! and is returned for the next caller unless either side asked to
//! close.
//!
//! Staleness is handled by retrying once: a pooled socket whose server
//! closed it (idle timeout, server drain, restart) fails on write or on
//! the first response byte — the pool discards it and repeats the
//! exchange on a fresh connection. The retry only happens when no
//! response byte was seen, and only for requests that started on a
//! *pooled* socket. POSTs (job submissions — the grammar's only
//! non-idempotent verb) never check out a pooled socket at all: a
//! fresh connection cannot be stale, so a POST is never replayed after
//! the server may have already processed it. GET/PUT are idempotent in
//! this grammar, so their single retry is safe.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::util::Rng;
use crate::{Error, Result};

/// Per-host cap on parked idle connections; excess sockets are closed
/// on return rather than pooled. Sized above the widest client fan-out
/// the benches drive (16) so every concurrent caller can park and
/// reuse its socket.
const MAX_IDLE_PER_HOST: usize = 32;

/// Total parked connections across all hosts (the test suite talks to
/// dozens of short-lived servers; dead sockets must not pile up).
const MAX_IDLE_TOTAL: usize = 64;

/// Idle sockets older than this are discarded at checkout — the server
/// side times idle connections out at ~30s, so anything near that is
/// better reconnected than raced.
const MAX_IDLE_AGE: Duration = Duration::from_secs(20);

/// Client-side socket timeout: a server that stops mid-response fails
/// the call instead of hanging the caller.
const CLIENT_READ_TIMEOUT: Duration = Duration::from_secs(60);

/// Everything [`request_info`] learned about one exchange.
#[derive(Debug)]
pub struct ResponseInfo {
    pub status: u16,
    pub body: Vec<u8>,
    /// Response arrived as chunked transfer-encoding (a streamed body).
    pub chunked: bool,
    /// Largest single chunk, in bytes (0 for `Content-Length` bodies) —
    /// the client-visible proxy for the server's streaming granularity.
    pub max_chunk: usize,
    /// The exchange rode a pooled (reused) connection.
    pub reused: bool,
    /// The server's `X-Request-Id` echo — names the request's trace on
    /// the server's `/trace/*` surface (DESIGN.md §9).
    pub request_id: Option<String>,
    /// Seconds from a `Retry-After` header (429 throttle / 503 shed).
    pub retry_after: Option<u64>,
    /// Throttle retries [`request_with`] performed before this answer.
    pub retries: u32,
}

/// Backoff schedule for throttled (429) and overloaded (503) answers:
/// capped exponential with full jitter, floored at whatever the server
/// advertised in `Retry-After`. Used by [`request_with`]; only
/// idempotent methods (anything but POST) are ever retried.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (total attempts = this + 1).
    pub max_retries: u32,
    /// Backoff scale: attempt `n` draws uniform from
    /// `[0, min(cap, base * 2^n)]`.
    pub base: Duration,
    /// Ceiling on the drawn backoff (the `Retry-After` floor still
    /// applies on top).
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 4,
            base: Duration::from_millis(100),
            cap: Duration::from_secs(5),
        }
    }
}

impl RetryPolicy {
    /// Sleep before retry `attempt` (0-based): full-jitter backoff, but
    /// never less than the server's `Retry-After` when one was sent.
    fn delay(&self, attempt: u32, retry_after: Option<u64>, rng: &mut Rng) -> Duration {
        let ceil = self
            .cap
            .min(self.base.saturating_mul(1u32 << attempt.min(20)))
            .as_millis() as u64;
        let jittered = Duration::from_millis(if ceil == 0 { 0 } else { rng.next_u64() % (ceil + 1) });
        jittered.max(retry_after.map(Duration::from_secs).unwrap_or(Duration::ZERO))
    }
}

/// Per-request knobs for [`request_with`].
#[derive(Default, Clone, Copy, Debug)]
pub struct RequestOpts {
    /// Latency budget sent as `X-OCPD-Deadline-Ms`: the server abandons
    /// remaining batch work and answers 504 once it expires.
    pub deadline_ms: Option<u64>,
    /// Retry 429/503 answers under this policy (idempotent methods
    /// only — POST is returned to the caller on the first answer).
    pub retry: Option<RetryPolicy>,
}

/// Deterministic-per-process jitter seed stream: splitmix increments
/// give each retry loop its own sequence without consulting a clock.
fn jitter_seed() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0x0cd9_1dc3_9f1a_5a21);
    SEQ.fetch_add(0x9e37_79b9_7f4a_7c15, Ordering::Relaxed)
}

struct IdleConn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    parked_at: Instant,
}

#[derive(Default)]
struct Pool {
    idle: Mutex<HashMap<String, Vec<IdleConn>>>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(Pool::default)
}

impl Pool {
    fn checkout(&self, host: &str) -> Option<IdleConn> {
        let mut guard = self.idle.lock().unwrap();
        let conns = guard.get_mut(host)?;
        while let Some(c) = conns.pop() {
            if c.parked_at.elapsed() < MAX_IDLE_AGE {
                return Some(c);
            }
            // Too old: likely already closed server-side; drop it.
        }
        None
    }

    fn park(&self, host: &str, conn: IdleConn) {
        let mut guard = self.idle.lock().unwrap();
        let total: usize = guard.values().map(Vec::len).sum();
        if total >= MAX_IDLE_TOTAL {
            // Evict the stalest parked socket anywhere to make room.
            if let Some(key) = guard
                .iter()
                .filter(|(_, v)| !v.is_empty())
                .min_by_key(|(_, v)| v.iter().map(|c| c.parked_at).min())
                .map(|(k, _)| k.clone())
            {
                if let Some(v) = guard.get_mut(&key) {
                    if !v.is_empty() {
                        v.remove(0);
                    }
                }
            }
        }
        let conns = guard.entry(host.to_string()).or_default();
        if conns.len() < MAX_IDLE_PER_HOST {
            conns.push(conn);
        }
    }
}

fn split_url(url: &str) -> Result<(&str, String)> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| Error::BadRequest(format!("unsupported url '{url}'")))?;
    Ok(match rest.split_once('/') {
        Some((h, p)) => (h, format!("/{p}")),
        None => (rest, "/".to_string()),
    })
}

fn connect(host: &str) -> Result<IdleConn> {
    let stream = TcpStream::connect(host)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT)).ok();
    let reader = BufReader::new(stream.try_clone()?);
    Ok(IdleConn { stream, reader, parked_at: Instant::now() })
}

/// One request/response exchange on an open connection. `Err(io)` means
/// the socket is dead; the bool in `Ok` is "no response byte was read
/// yet" never escapes — instead a dead-before-response socket maps to
/// `Err` with `retryable` true.
struct Exchange {
    info: ResponseInfo,
    keep: bool,
}

fn exchange(
    conn: &mut IdleConn,
    method: &str,
    host: &str,
    path: &str,
    body: &[u8],
    close: bool,
    deadline_ms: Option<u64>,
) -> std::result::Result<Exchange, (bool, Error)> {
    // retryable=true until the first response byte arrives.
    // Propagate the caller's trace context: a client call made inside a
    // traced request (or job) stamps its request id on the outbound
    // exchange, so server-side traces correlate across hops.
    let req_id = crate::obs::trace::current_request_id()
        .map(|id| format!("X-Request-Id: {id}\r\n"))
        .unwrap_or_default();
    let deadline = deadline_ms
        .map(|ms| format!("X-OCPD-Deadline-Ms: {ms}\r\n"))
        .unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {host}\r\nContent-Length: {}\r\n{req_id}{deadline}{}\r\n",
        body.len(),
        if close { "Connection: close\r\n" } else { "" }
    );
    let write = (|| -> std::io::Result<()> {
        conn.stream.write_all(head.as_bytes())?;
        conn.stream.write_all(body)?;
        conn.stream.flush()
    })();
    if let Err(e) = write {
        return Err((true, e.into()));
    }

    let mut status_line = String::new();
    match conn.reader.read_line(&mut status_line) {
        Ok(0) => return Err((true, Error::Other("connection closed before response".into()))),
        Ok(_) => {}
        Err(e) => return Err((true, e.into())),
    }
    // A response byte arrived: any failure past here is NOT retryable.
    let fatal = |e: Error| (false, e);
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| fatal(Error::Other(format!("bad status line '{status_line}'"))))?;

    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    let mut server_close = close;
    let mut request_id: Option<String> = None;
    let mut retry_after: Option<u64> = None;
    loop {
        let mut h = String::new();
        match conn.reader.read_line(&mut h) {
            Ok(0) => return Err(fatal(Error::Other("connection closed mid-headers".into()))),
            Ok(_) => {}
            Err(e) => return Err(fatal(e.into())),
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let v = v.trim();
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.parse::<usize>().ok();
            } else if k.eq_ignore_ascii_case("transfer-encoding") {
                chunked = v.eq_ignore_ascii_case("chunked");
            } else if k.eq_ignore_ascii_case("connection") && v.eq_ignore_ascii_case("close") {
                server_close = true;
            } else if k.eq_ignore_ascii_case("x-request-id") && !v.is_empty() {
                request_id = Some(v.to_string());
            } else if k.eq_ignore_ascii_case("retry-after") {
                retry_after = v.parse::<u64>().ok();
            }
        }
    }

    let mut body_out = Vec::new();
    let mut max_chunk = 0usize;
    if chunked {
        loop {
            let mut size_line = String::new();
            match conn.reader.read_line(&mut size_line) {
                Ok(0) => return Err(fatal(Error::Other("truncated chunked body".into()))),
                Ok(_) => {}
                Err(e) => return Err(fatal(e.into())),
            }
            let size = usize::from_str_radix(size_line.trim(), 16)
                .map_err(|_| fatal(Error::Other(format!("bad chunk size '{size_line}'"))))?;
            if size == 0 {
                // Trailer section: read through the final blank line.
                loop {
                    let mut t = String::new();
                    match conn.reader.read_line(&mut t) {
                        Ok(0) => break,
                        Ok(_) if t.trim().is_empty() => break,
                        Ok(_) => {}
                        Err(e) => return Err(fatal(e.into())),
                    }
                }
                break;
            }
            max_chunk = max_chunk.max(size);
            let at = body_out.len();
            body_out.resize(at + size, 0);
            if let Err(e) = conn.reader.read_exact(&mut body_out[at..]) {
                return Err(fatal(e.into()));
            }
            let mut crlf = [0u8; 2];
            if let Err(e) = conn.reader.read_exact(&mut crlf) {
                return Err(fatal(e.into()));
            }
        }
    } else {
        match content_length {
            Some(n) => {
                body_out.resize(n, 0);
                if let Err(e) = conn.reader.read_exact(&mut body_out) {
                    return Err(fatal(e.into()));
                }
            }
            None => {
                // Legacy framing: body runs to EOF; connection is spent.
                server_close = true;
                if let Err(e) = conn.reader.read_to_end(&mut body_out) {
                    return Err(fatal(e.into()));
                }
            }
        }
    }

    Ok(Exchange {
        info: ResponseInfo {
            status,
            body: body_out,
            chunked,
            max_chunk,
            reused: false,
            request_id,
            retry_after,
            retries: 0,
        },
        keep: !server_close,
    })
}

/// Issue `method url` with `body`, reusing a pooled keep-alive
/// connection when one is parked for the host (retrying once on a fresh
/// socket when the pooled one turns out stale). Returns
/// `(status, body)`; chunked responses are reassembled transparently.
pub fn request(method: &str, url: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
    let info = request_info(method, url, body)?;
    Ok((info.status, info.body))
}

/// [`request`] with transport detail: whether the connection was
/// reused, whether the response streamed, and the peak chunk size.
pub fn request_info(method: &str, url: &str, body: &[u8]) -> Result<ResponseInfo> {
    request_inner(method, url, body, false, None)
}

/// [`request_info`] with per-request knobs: a deadline header and/or a
/// throttle-retry policy. On 429/503 the retry sleeps
/// `max(server Retry-After, full-jitter backoff)` and re-issues the
/// exchange, up to `max_retries` times — but only for idempotent
/// methods (POST answers are returned as-is, never replayed). The
/// final answer's `retries` field counts the sleeps taken.
pub fn request_with(method: &str, url: &str, body: &[u8], opts: &RequestOpts) -> Result<ResponseInfo> {
    let mut info = request_inner(method, url, body, false, opts.deadline_ms)?;
    let Some(policy) = opts.retry else { return Ok(info) };
    if method.eq_ignore_ascii_case("POST") {
        return Ok(info);
    }
    let mut rng = Rng::new(jitter_seed());
    let mut retries = 0;
    while (info.status == 429 || info.status == 503) && retries < policy.max_retries {
        std::thread::sleep(policy.delay(retries, info.retry_after, &mut rng));
        retries += 1;
        info = request_inner(method, url, body, false, opts.deadline_ms)?;
    }
    info.retries = retries;
    Ok(info)
}

/// Close-per-request exchange on a dedicated socket (`Connection:
/// close`), bypassing the pool — the pre-keep-alive behavior, kept for
/// the transport benches' baseline.
pub fn request_once(method: &str, url: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
    let info = request_inner(method, url, body, true, None)?;
    Ok((info.status, info.body))
}

fn request_inner(
    method: &str,
    url: &str,
    body: &[u8],
    close: bool,
    deadline_ms: Option<u64>,
) -> Result<ResponseInfo> {
    let (host, path) = split_url(url)?;
    // POST is the grammar's one non-idempotent verb: always start it on
    // a fresh socket so the stale-retry path (which replays the
    // request) can never fire for it. The socket is still parked for
    // reuse afterwards.
    let reuse_ok = !close && !method.eq_ignore_ascii_case("POST");
    let pooled = if reuse_ok { pool().checkout(host) } else { None };
    let reused = pooled.is_some();
    let mut conn = match pooled {
        Some(c) => c,
        None => connect(host)?,
    };
    match exchange(&mut conn, method, host, &path, body, close, deadline_ms) {
        Ok(Exchange { mut info, keep }) => {
            info.reused = reused;
            if keep && !close {
                conn.parked_at = Instant::now();
                pool().park(host, conn);
            }
            Ok(info)
        }
        Err((retryable, e)) => {
            // Stale pooled socket: the server closed it between uses.
            // One fresh-connection retry; errors there are real.
            if retryable && reused {
                let mut fresh = connect(host)?;
                let Exchange { mut info, keep } =
                    exchange(&mut fresh, method, host, &path, body, close, deadline_ms)
                        .map_err(|(_, e)| e)?;
                info.reused = false;
                if keep && !close {
                    fresh.parked_at = Instant::now();
                    pool().park(host, fresh);
                }
                return Ok(info);
            }
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::web::http::{Response, Server};

    #[test]
    fn pool_retries_once_on_stale_socket() {
        // Server A answers, then dies; server B takes over the port?
        // Ports are ephemeral, so instead: park a connection, drop the
        // server, and verify the retry path surfaces a clean error
        // (fresh connect refused) rather than a stale-socket panic.
        let url;
        {
            let s = Server::bind("127.0.0.1:0", 2, |_req| Response::text("ok")).unwrap();
            url = s.url();
            let (code, _) = request("GET", &format!("{url}/x/"), &[]).unwrap();
            assert_eq!(code, 200);
            // The connection is now parked in the pool.
        }
        std::thread::sleep(Duration::from_millis(50));
        // Pooled socket is dead AND the listener is gone: the retry
        // must fail with an error, not hang or return garbage.
        assert!(request("GET", &format!("{url}/x/"), &[]).is_err());
    }

    #[test]
    fn stale_pooled_socket_recovers_when_server_lives() {
        let s = Server::bind("127.0.0.1:0", 2, |_req| Response::text("ok")).unwrap();
        let url = s.url();
        let (code, _) = request("GET", &format!("{url}/a/"), &[]).unwrap();
        assert_eq!(code, 200);
        // Sabotage the parked socket by shutting it down client-side.
        let host = url.strip_prefix("http://").unwrap().to_string();
        if let Some(conn) = pool().checkout(&host) {
            conn.stream.shutdown(std::net::Shutdown::Both).ok();
            pool().park(&host, conn);
        }
        // Next request hits the dead socket, retries fresh, succeeds.
        let (code, _) = request("GET", &format!("{url}/b/"), &[]).unwrap();
        assert_eq!(code, 200);
    }

    #[test]
    fn url_parsing_rejects_non_http() {
        assert!(request("GET", "ftp://host/x", &[]).is_err());
    }

    #[test]
    fn retry_policy_backs_off_429_until_success() {
        use std::sync::atomic::{AtomicU32, Ordering};
        use std::sync::Arc;
        let hits = Arc::new(AtomicU32::new(0));
        let h = Arc::clone(&hits);
        let s = Server::bind("127.0.0.1:0", 2, move |_req| {
            if h.fetch_add(1, Ordering::SeqCst) < 2 {
                let mut r = Response::error(429, "throttled");
                r.retry_after = Some(0);
                r
            } else {
                Response::text("ok")
            }
        })
        .unwrap();
        let opts = RequestOpts {
            deadline_ms: None,
            retry: Some(RetryPolicy {
                max_retries: 4,
                base: Duration::from_millis(1),
                cap: Duration::from_millis(5),
            }),
        };
        let info = request_with("GET", &format!("{}/x/", s.url()), &[], &opts).unwrap();
        assert_eq!(info.status, 200);
        assert_eq!(info.retries, 2);
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn retry_gives_up_after_max_and_reports_retry_after() {
        let s = Server::bind("127.0.0.1:0", 2, |_req| {
            let mut r = Response::error(429, "throttled");
            r.retry_after = Some(0);
            r
        })
        .unwrap();
        let opts = RequestOpts {
            deadline_ms: None,
            retry: Some(RetryPolicy {
                max_retries: 2,
                base: Duration::from_millis(1),
                cap: Duration::from_millis(2),
            }),
        };
        let info = request_with("GET", &format!("{}/x/", s.url()), &[], &opts).unwrap();
        assert_eq!(info.status, 429);
        assert_eq!(info.retries, 2);
        assert_eq!(info.retry_after, Some(0));
    }

    #[test]
    fn post_is_never_replayed_on_throttle() {
        use std::sync::atomic::{AtomicU32, Ordering};
        use std::sync::Arc;
        let hits = Arc::new(AtomicU32::new(0));
        let h = Arc::clone(&hits);
        let s = Server::bind("127.0.0.1:0", 2, move |_req| {
            h.fetch_add(1, Ordering::SeqCst);
            let mut r = Response::error(429, "throttled");
            r.retry_after = Some(0);
            r
        })
        .unwrap();
        let opts = RequestOpts { deadline_ms: None, retry: Some(RetryPolicy::default()) };
        let info = request_with("POST", &format!("{}/jobs/x/", s.url()), b"k=v", &opts).unwrap();
        assert_eq!(info.status, 429);
        assert_eq!(info.retries, 0);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn deadline_header_reaches_the_server() {
        let s = Server::bind("127.0.0.1:0", 2, |req| {
            Response::text(format!("{:?}", req.deadline_ms))
        })
        .unwrap();
        let opts = RequestOpts { deadline_ms: Some(1234), retry: None };
        let info = request_with("GET", &format!("{}/x/", s.url()), &[], &opts).unwrap();
        assert_eq!(String::from_utf8_lossy(&info.body), "Some(1234)");
        let info = request_with("GET", &format!("{}/x/", s.url()), &[], &RequestOpts::default())
            .unwrap();
        assert_eq!(String::from_utf8_lossy(&info.body), "None");
    }

    #[test]
    fn retry_delay_respects_floor_and_cap() {
        let p = RetryPolicy {
            max_retries: 3,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(40),
        };
        let mut rng = Rng::new(7);
        for attempt in 0..6 {
            let d = p.delay(attempt, None, &mut rng);
            assert!(d <= Duration::from_millis(40), "{d:?}");
        }
        // The server floor dominates a small jitter draw.
        let d = p.delay(0, Some(2), &mut rng);
        assert!(d >= Duration::from_secs(2), "{d:?}");
    }
}
