//! Keep-alive HTTP client: a process-wide connection pool with
//! chunked-response decoding.
//!
//! Every wire client in the system — the typed [`crate::client`] layer,
//! the CLI subcommands, the job submitters, the benches, and the test
//! suites — funnels through [`request`], so all of them ride pooled
//! persistent connections automatically. A socket is checked out of the
//! pool (or freshly connected), carries one request/response exchange,
//! and is returned for the next caller unless either side asked to
//! close.
//!
//! Staleness is handled by retrying once: a pooled socket whose server
//! closed it (idle timeout, server drain, restart) fails on write or on
//! the first response byte — the pool discards it and repeats the
//! exchange on a fresh connection. The retry only happens when no
//! response byte was seen, and only for requests that started on a
//! *pooled* socket. POSTs (job submissions — the grammar's only
//! non-idempotent verb) never check out a pooled socket at all: a
//! fresh connection cannot be stale, so a POST is never replayed after
//! the server may have already processed it. GET/PUT are idempotent in
//! this grammar, so their single retry is safe.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::{Error, Result};

/// Per-host cap on parked idle connections; excess sockets are closed
/// on return rather than pooled. Sized above the widest client fan-out
/// the benches drive (16) so every concurrent caller can park and
/// reuse its socket.
const MAX_IDLE_PER_HOST: usize = 32;

/// Total parked connections across all hosts (the test suite talks to
/// dozens of short-lived servers; dead sockets must not pile up).
const MAX_IDLE_TOTAL: usize = 64;

/// Idle sockets older than this are discarded at checkout — the server
/// side times idle connections out at ~30s, so anything near that is
/// better reconnected than raced.
const MAX_IDLE_AGE: Duration = Duration::from_secs(20);

/// Client-side socket timeout: a server that stops mid-response fails
/// the call instead of hanging the caller.
const CLIENT_READ_TIMEOUT: Duration = Duration::from_secs(60);

/// Everything [`request_info`] learned about one exchange.
#[derive(Debug)]
pub struct ResponseInfo {
    pub status: u16,
    pub body: Vec<u8>,
    /// Response arrived as chunked transfer-encoding (a streamed body).
    pub chunked: bool,
    /// Largest single chunk, in bytes (0 for `Content-Length` bodies) —
    /// the client-visible proxy for the server's streaming granularity.
    pub max_chunk: usize,
    /// The exchange rode a pooled (reused) connection.
    pub reused: bool,
    /// The server's `X-Request-Id` echo — names the request's trace on
    /// the server's `/trace/*` surface (DESIGN.md §9).
    pub request_id: Option<String>,
}

struct IdleConn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    parked_at: Instant,
}

#[derive(Default)]
struct Pool {
    idle: Mutex<HashMap<String, Vec<IdleConn>>>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(Pool::default)
}

impl Pool {
    fn checkout(&self, host: &str) -> Option<IdleConn> {
        let mut guard = self.idle.lock().unwrap();
        let conns = guard.get_mut(host)?;
        while let Some(c) = conns.pop() {
            if c.parked_at.elapsed() < MAX_IDLE_AGE {
                return Some(c);
            }
            // Too old: likely already closed server-side; drop it.
        }
        None
    }

    fn park(&self, host: &str, conn: IdleConn) {
        let mut guard = self.idle.lock().unwrap();
        let total: usize = guard.values().map(Vec::len).sum();
        if total >= MAX_IDLE_TOTAL {
            // Evict the stalest parked socket anywhere to make room.
            if let Some(key) = guard
                .iter()
                .filter(|(_, v)| !v.is_empty())
                .min_by_key(|(_, v)| v.iter().map(|c| c.parked_at).min())
                .map(|(k, _)| k.clone())
            {
                if let Some(v) = guard.get_mut(&key) {
                    if !v.is_empty() {
                        v.remove(0);
                    }
                }
            }
        }
        let conns = guard.entry(host.to_string()).or_default();
        if conns.len() < MAX_IDLE_PER_HOST {
            conns.push(conn);
        }
    }
}

fn split_url(url: &str) -> Result<(&str, String)> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| Error::BadRequest(format!("unsupported url '{url}'")))?;
    Ok(match rest.split_once('/') {
        Some((h, p)) => (h, format!("/{p}")),
        None => (rest, "/".to_string()),
    })
}

fn connect(host: &str) -> Result<IdleConn> {
    let stream = TcpStream::connect(host)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT)).ok();
    let reader = BufReader::new(stream.try_clone()?);
    Ok(IdleConn { stream, reader, parked_at: Instant::now() })
}

/// One request/response exchange on an open connection. `Err(io)` means
/// the socket is dead; the bool in `Ok` is "no response byte was read
/// yet" never escapes — instead a dead-before-response socket maps to
/// `Err` with `retryable` true.
struct Exchange {
    info: ResponseInfo,
    keep: bool,
}

fn exchange(
    conn: &mut IdleConn,
    method: &str,
    host: &str,
    path: &str,
    body: &[u8],
    close: bool,
) -> std::result::Result<Exchange, (bool, Error)> {
    // retryable=true until the first response byte arrives.
    // Propagate the caller's trace context: a client call made inside a
    // traced request (or job) stamps its request id on the outbound
    // exchange, so server-side traces correlate across hops.
    let req_id = crate::obs::trace::current_request_id()
        .map(|id| format!("X-Request-Id: {id}\r\n"))
        .unwrap_or_default();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {host}\r\nContent-Length: {}\r\n{req_id}{}\r\n",
        body.len(),
        if close { "Connection: close\r\n" } else { "" }
    );
    let write = (|| -> std::io::Result<()> {
        conn.stream.write_all(head.as_bytes())?;
        conn.stream.write_all(body)?;
        conn.stream.flush()
    })();
    if let Err(e) = write {
        return Err((true, e.into()));
    }

    let mut status_line = String::new();
    match conn.reader.read_line(&mut status_line) {
        Ok(0) => return Err((true, Error::Other("connection closed before response".into()))),
        Ok(_) => {}
        Err(e) => return Err((true, e.into())),
    }
    // A response byte arrived: any failure past here is NOT retryable.
    let fatal = |e: Error| (false, e);
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| fatal(Error::Other(format!("bad status line '{status_line}'"))))?;

    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    let mut server_close = close;
    let mut request_id: Option<String> = None;
    loop {
        let mut h = String::new();
        match conn.reader.read_line(&mut h) {
            Ok(0) => return Err(fatal(Error::Other("connection closed mid-headers".into()))),
            Ok(_) => {}
            Err(e) => return Err(fatal(e.into())),
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let v = v.trim();
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.parse::<usize>().ok();
            } else if k.eq_ignore_ascii_case("transfer-encoding") {
                chunked = v.eq_ignore_ascii_case("chunked");
            } else if k.eq_ignore_ascii_case("connection") && v.eq_ignore_ascii_case("close") {
                server_close = true;
            } else if k.eq_ignore_ascii_case("x-request-id") && !v.is_empty() {
                request_id = Some(v.to_string());
            }
        }
    }

    let mut body_out = Vec::new();
    let mut max_chunk = 0usize;
    if chunked {
        loop {
            let mut size_line = String::new();
            match conn.reader.read_line(&mut size_line) {
                Ok(0) => return Err(fatal(Error::Other("truncated chunked body".into()))),
                Ok(_) => {}
                Err(e) => return Err(fatal(e.into())),
            }
            let size = usize::from_str_radix(size_line.trim(), 16)
                .map_err(|_| fatal(Error::Other(format!("bad chunk size '{size_line}'"))))?;
            if size == 0 {
                // Trailer section: read through the final blank line.
                loop {
                    let mut t = String::new();
                    match conn.reader.read_line(&mut t) {
                        Ok(0) => break,
                        Ok(_) if t.trim().is_empty() => break,
                        Ok(_) => {}
                        Err(e) => return Err(fatal(e.into())),
                    }
                }
                break;
            }
            max_chunk = max_chunk.max(size);
            let at = body_out.len();
            body_out.resize(at + size, 0);
            if let Err(e) = conn.reader.read_exact(&mut body_out[at..]) {
                return Err(fatal(e.into()));
            }
            let mut crlf = [0u8; 2];
            if let Err(e) = conn.reader.read_exact(&mut crlf) {
                return Err(fatal(e.into()));
            }
        }
    } else {
        match content_length {
            Some(n) => {
                body_out.resize(n, 0);
                if let Err(e) = conn.reader.read_exact(&mut body_out) {
                    return Err(fatal(e.into()));
                }
            }
            None => {
                // Legacy framing: body runs to EOF; connection is spent.
                server_close = true;
                if let Err(e) = conn.reader.read_to_end(&mut body_out) {
                    return Err(fatal(e.into()));
                }
            }
        }
    }

    Ok(Exchange {
        info: ResponseInfo {
            status,
            body: body_out,
            chunked,
            max_chunk,
            reused: false,
            request_id,
        },
        keep: !server_close,
    })
}

/// Issue `method url` with `body`, reusing a pooled keep-alive
/// connection when one is parked for the host (retrying once on a fresh
/// socket when the pooled one turns out stale). Returns
/// `(status, body)`; chunked responses are reassembled transparently.
pub fn request(method: &str, url: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
    let info = request_info(method, url, body)?;
    Ok((info.status, info.body))
}

/// [`request`] with transport detail: whether the connection was
/// reused, whether the response streamed, and the peak chunk size.
pub fn request_info(method: &str, url: &str, body: &[u8]) -> Result<ResponseInfo> {
    request_inner(method, url, body, false)
}

/// Close-per-request exchange on a dedicated socket (`Connection:
/// close`), bypassing the pool — the pre-keep-alive behavior, kept for
/// the transport benches' baseline.
pub fn request_once(method: &str, url: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
    let info = request_inner(method, url, body, true)?;
    Ok((info.status, info.body))
}

fn request_inner(method: &str, url: &str, body: &[u8], close: bool) -> Result<ResponseInfo> {
    let (host, path) = split_url(url)?;
    // POST is the grammar's one non-idempotent verb: always start it on
    // a fresh socket so the stale-retry path (which replays the
    // request) can never fire for it. The socket is still parked for
    // reuse afterwards.
    let reuse_ok = !close && !method.eq_ignore_ascii_case("POST");
    let pooled = if reuse_ok { pool().checkout(host) } else { None };
    let reused = pooled.is_some();
    let mut conn = match pooled {
        Some(c) => c,
        None => connect(host)?,
    };
    match exchange(&mut conn, method, host, &path, body, close) {
        Ok(Exchange { mut info, keep }) => {
            info.reused = reused;
            if keep && !close {
                conn.parked_at = Instant::now();
                pool().park(host, conn);
            }
            Ok(info)
        }
        Err((retryable, e)) => {
            // Stale pooled socket: the server closed it between uses.
            // One fresh-connection retry; errors there are real.
            if retryable && reused {
                let mut fresh = connect(host)?;
                let Exchange { mut info, keep } =
                    exchange(&mut fresh, method, host, &path, body, close)
                        .map_err(|(_, e)| e)?;
                info.reused = false;
                if keep && !close {
                    fresh.parked_at = Instant::now();
                    pool().park(host, fresh);
                }
                return Ok(info);
            }
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::web::http::{Response, Server};

    #[test]
    fn pool_retries_once_on_stale_socket() {
        // Server A answers, then dies; server B takes over the port?
        // Ports are ephemeral, so instead: park a connection, drop the
        // server, and verify the retry path surfaces a clean error
        // (fresh connect refused) rather than a stale-socket panic.
        let url;
        {
            let s = Server::bind("127.0.0.1:0", 2, |_req| Response::text("ok")).unwrap();
            url = s.url();
            let (code, _) = request("GET", &format!("{url}/x/"), &[]).unwrap();
            assert_eq!(code, 200);
            // The connection is now parked in the pool.
        }
        std::thread::sleep(Duration::from_millis(50));
        // Pooled socket is dead AND the listener is gone: the retry
        // must fail with an error, not hang or return garbage.
        assert!(request("GET", &format!("{url}/x/"), &[]).is_err());
    }

    #[test]
    fn stale_pooled_socket_recovers_when_server_lives() {
        let s = Server::bind("127.0.0.1:0", 2, |_req| Response::text("ok")).unwrap();
        let url = s.url();
        let (code, _) = request("GET", &format!("{url}/a/"), &[]).unwrap();
        assert_eq!(code, 200);
        // Sabotage the parked socket by shutting it down client-side.
        let host = url.strip_prefix("http://").unwrap().to_string();
        if let Some(conn) = pool().checkout(&host) {
            conn.stream.shutdown(std::net::Shutdown::Both).ok();
            pool().park(&host, conn);
        }
        // Next request hits the dead socket, retries fresh, succeeds.
        let (code, _) = request("GET", &format!("{url}/b/"), &[]).unwrap();
        assert_eq!(code, 200);
    }

    #[test]
    fn url_parsing_rejects_non_http() {
        assert!(request("GET", "ftp://host/x", &[]).is_err());
    }
}
