//! RESTful Web services: the URL grammar of the paper's Table 1 over a
//! minimal HTTP/1.1 server (§4.2 "Web Services").
//!
//! All interfaces are stateless GET/PUT requests to human-readable URLs.
//! The interchange format is `ocpk` (a self-describing nd-array framing —
//! the offline stand-in for the paper's HDF5, DESIGN.md §1).
//!
//! Route grammar (Table 1 with `hdf5` → `ocpk`):
//!
//! ```text
//! GET /{token}/ocpk/{res}/{x0},{x1}/{y0},{y1}/{z0},{z1}/          cutout
//! GET /{token}/xy/{res}/{z}/{x0},{x1}/{y0},{y1}/                  plane
//! GET /{token}/tile/{res}/{z}/{y}_{x}.gray                        tile
//! GET /{token}/{id}/                                              RAMON metadata
//! GET /{token}/{id}/voxels/                                       voxel list
//! GET /{token}/{id}/boundingbox/                                  bounding box
//! GET /{token}/{id}/cutout/                                       dense object
//! GET /{token}/{id}/cutout/{res}/{x0},{x1}/{y0},{y1}/{z0},{z1}/   restricted
//! GET /{token}/{id1},{id2},.../                                   batch metadata
//! GET /{token}/objects/{field}/{value}/...                        predicate query
//! GET /{token}/objects/{field}/{geq|leq|gt|lt}/{value}/...        range predicate
//! PUT /{token}/{overwrite|preserve|exception}/{res}/{x0},..{z1}/  write volume
//! PUT /{token}/ramon/                                             write objects
//! GET /info/                                                      cluster info
//! GET /wal/status/                                                write-log status
//! PUT /wal/flush/  |  PUT /wal/flush/{token}/                     drain write logs
//! GET /cache/status/                                              cuboid-cache status
//! POST /jobs/propagate/{token}/                                   submit hierarchy build
//! POST /jobs/synapse/{image}/{annotation}/                        submit synapse detection
//! POST /jobs/ingest/{token}/                                      submit bulk ingest
//! GET /jobs/status/  |  GET /jobs/status/{id}/                    job status
//! POST /jobs/cancel/{id}/                                         cancel a job
//! ```
//!
//! `info`, `wal`, `cache`, and `jobs` are reserved top-level names, not
//! project tokens; wrong-method requests to them answer `405` with an
//! `Allow` header.

pub mod http;
pub mod ocpk;
mod routes;

pub use http::{Request, Response, Server};
pub use routes::OcpService;

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::runtime::Runtime;

/// Build an HTTP server serving the OCP Web services for `cluster`.
pub fn serve(
    cluster: Arc<Cluster>,
    runtime: Option<Arc<Runtime>>,
    addr: &str,
    workers: usize,
) -> crate::Result<Server> {
    let svc = Arc::new(OcpService::new(cluster, runtime));
    Server::bind(addr, workers, move |req| svc.handle(req))
}
