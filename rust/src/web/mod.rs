//! RESTful Web services: the URL grammar of the paper's Table 1 over a
//! persistent-connection HTTP/1.1 server (§4.2 "Web Services").
//!
//! All interfaces are stateless GET/PUT requests to human-readable URLs.
//! The interchange format is `ocpk` (a self-describing nd-array framing —
//! the offline stand-in for the paper's HDF5, DESIGN.md §1). The
//! transport (DESIGN.md §8) is keep-alive with pipelining: clients reuse
//! pooled sockets, and cutouts above the streaming threshold arrive as
//! chunked transfer-encoding, slab by slab.
//!
//! Route grammar (Table 1 with `hdf5` → `ocpk`) — the authoritative,
//! auto-generated listing is served at `GET /info/`:
//!
//! ```text
//! GET /{token}/ocpk/{res}/{x0},{x1}/{y0},{y1}/{z0},{z1}/          cutout (streams when large)
//! GET /{token}/xy/{res}/{z}/{x0},{x1}/{y0},{y1}/                  plane
//! GET /{token}/tile/{res}/{z}/{y}_{x}.gray                        tile
//! GET /{token}/{id}/                                              RAMON metadata
//! GET /{token}/{id}/voxels/                                       voxel list
//! GET /{token}/{id}/boundingbox/                                  bounding box
//! GET /{token}/{id}/cutout/                                       dense object
//! GET /{token}/{id}/cutout/{res}/{x0},{x1}/{y0},{y1}/{z0},{z1}/   restricted
//! GET /{token}/{id1},{id2},.../                                   batch metadata
//! GET /{token}/objects/{field}/{value}/...                        predicate query
//! GET /{token}/objects/{field}/{geq|leq|gt|lt}/{value}/...        range predicate
//! PUT /{token}/{overwrite|preserve|exception}/{res}/              write volume
//! PUT /{token}/image/{res}/                                       image ingest
//! PUT /{token}/ramon/                                             write objects
//! GET /info/                                                      cluster info + route listing
//! GET /http/status/                                               transport metrics
//! GET /wal/status/                                                write-log status
//! PUT /wal/flush/  |  PUT /wal/flush/{token}/                     drain write logs
//! GET /cache/status/                                              cuboid-cache status
//! GET /write/status/  |  PUT /write/workers/{n}/                  write engine
//! POST /jobs/{propagate|synapse|ingest}/...                       submit batch jobs
//! GET /jobs/status/  |  GET /jobs/status/{id}/                    job status
//! POST /jobs/cancel/{id}/                                         cancel a job
//! GET /metrics/                                                   unified Prometheus exposition
//! GET /trace/status/                                              tracer config + retention
//! GET /trace/recent/  |  GET /trace/slow/                         retained span trees
//! GET /heat/status/                                               shard heat ranking + hot ranges
//! GET /account/status/                                            per-tenant ledgers
//! GET /slo/status/                                                latency-objective attainment
//! GET /qos/status/                                                QoS admission + fair sharing
//! PUT /qos/quota/{token}/                                         set a tenant's quota/weight
//! PUT /qos/enforce/{on|off}/                                      toggle QoS enforcement
//! GET /shards/status/                                             shard maps + move windows
//! POST /shards/split/{token}/{shard}/                             split a shard, rehome hot half
//! PUT /shards/auto/{on|off}/                                      toggle heat-driven splitting
//! ```
//!
//! `info`, `http`, `wal`, `cache`, `jobs`, `write`, `metrics`,
//! `trace`, `cluster`, `heat`, `account`, `slo`, `qos`, and `shards`
//! are reserved top-level names, not project tokens;
//! wrong-method requests anywhere in the grammar answer `405` with an
//! auto-derived `Allow` header. Every response carries an
//! `X-Request-Id` header (echoing the request's, if sent) naming the
//! request's trace (DESIGN.md §9). Requests may carry an
//! `X-OCPD-Deadline-Ms` latency budget — once it expires the engines
//! abandon remaining batch work and the answer is `504`. Over-quota
//! tenants get `429` and overload sheds get `503`, both with a
//! `Retry-After` header (DESIGN.md §12).

pub(crate) mod conn;
mod handlers;
pub mod http;
pub mod ocpk;
mod router;
mod routes;

pub use http::{Body, HttpMetrics, Request, Response, Server, ServerConfig};
pub use routes::{OcpService, DEFAULT_STREAM_THRESHOLD, RESERVED};

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::runtime::Runtime;

/// Serving knobs beyond [`serve`]'s defaults.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Request-body cap (413 beyond it).
    pub max_body: usize,
    /// Admission gate: concurrent-connection cap (503 + `Retry-After`
    /// past it).
    pub max_connections: usize,
    /// Cutout responses at or above this raw size stream as chunked
    /// transfer-encoding instead of buffering.
    pub stream_threshold: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_body: http::DEFAULT_MAX_BODY,
            max_connections: 16 * http::CONNS_PER_WORKER,
            stream_threshold: DEFAULT_STREAM_THRESHOLD,
        }
    }
}

/// Build an HTTP server serving the OCP Web services for `cluster`.
/// `workers` sizes the connection-admission gate
/// ([`http::CONNS_PER_WORKER`] concurrent connections per worker).
pub fn serve(
    cluster: Arc<Cluster>,
    runtime: Option<Arc<Runtime>>,
    addr: &str,
    workers: usize,
) -> crate::Result<Server> {
    serve_with(
        cluster,
        runtime,
        addr,
        ServeOptions {
            max_connections: workers.max(1) * http::CONNS_PER_WORKER,
            ..ServeOptions::default()
        },
    )
}

/// [`serve`] with explicit transport options. One [`HttpMetrics`] is
/// shared between the server (which records into it) and the service
/// (which reports it at `GET /http/status/`).
pub fn serve_with(
    cluster: Arc<Cluster>,
    runtime: Option<Arc<Runtime>>,
    addr: &str,
    opts: ServeOptions,
) -> crate::Result<Server> {
    let metrics = Arc::new(HttpMetrics::default());
    register_http_metrics(cluster.registry(), &metrics);
    let qos = Arc::clone(cluster.qos());
    let svc = Arc::new(
        OcpService::new(cluster, runtime)
            .with_http_metrics(Arc::clone(&metrics))
            .with_stream_threshold(opts.stream_threshold),
    );
    let cfg = ServerConfig {
        max_body: opts.max_body,
        max_connections: opts.max_connections,
        ..ServerConfig::default()
    };
    let server = Server::bind_with_config(addr, cfg, metrics, move |req| svc.handle(req))?;
    // Over-cap connections are shed lowest-tenant-weight first, using
    // the same `qos/` quota weights the fair-sharing gates use (weight
    // 1 for unconfigured tenants, so with no quotas set the gate sheds
    // FIFO exactly as before).
    server.set_tenant_weights(Arc::new(move |tenant| qos.weight(tenant)));
    Ok(server)
}

/// Register the transport's collector into the cluster's unified
/// registry (the `ocpd_http_*` family on `GET /metrics/`).
fn register_http_metrics(
    registry: &Arc<crate::obs::registry::MetricsRegistry>,
    metrics: &Arc<HttpMetrics>,
) {
    use crate::obs::registry::Sample;
    let m = Arc::clone(metrics);
    registry.register("http", move |out| {
        for (name, help, v) in [
            ("ocpd_http_requests_total", "Requests answered.", m.requests.get()),
            ("ocpd_http_connections_total", "Connections admitted.", m.connections.get()),
            (
                "ocpd_http_rejected_total",
                "Connections rejected by the admission gate.",
                m.rejected.get(),
            ),
            (
                "ocpd_http_priority_admits_total",
                "Over-cap connections admitted by tenant weight.",
                m.priority_admits.get(),
            ),
            ("ocpd_http_accept_errors_total", "Accept-loop errors.", m.accept_errors.get()),
            (
                "ocpd_http_streamed_responses_total",
                "Responses streamed as chunked transfer-encoding.",
                m.streamed_responses.get(),
            ),
        ] {
            out.push(Sample::counter(name, help, v));
        }
        for (name, help, v) in [
            ("ocpd_http_active_connections", "Live connections.", m.active_connections.get()),
            ("ocpd_http_in_flight", "Requests currently in flight.", m.in_flight.get()),
            (
                "ocpd_http_stream_peak_chunk_bytes",
                "High-water mark of one streamed chunk.",
                m.stream_peak_chunk.get(),
            ),
        ] {
            out.push(Sample::gauge(name, help, v));
        }
        out.push(Sample::histogram(
            "ocpd_http_request_latency_us",
            "Per-request wall time (parse + handle + write), microseconds.",
            m.latency.snapshot(),
        ));
        for (route, hist) in m.route_histograms() {
            out.push(
                Sample::histogram(
                    "ocpd_http_route_latency_us",
                    "Per-route request latency, microseconds.",
                    hist.snapshot(),
                )
                .label("route", route),
            );
        }
    });
    let m = Arc::clone(metrics);
    registry.register("slo", move |out| {
        for c in crate::obs::slo::evaluate(&m.route_histograms()).classes {
            let labeled =
                |s: Sample| s.label("class", c.class.name().to_string());
            out.push(labeled(Sample::counter(
                "ocpd_slo_requests_total",
                "Requests observed in the class.",
                c.total,
            )));
            out.push(labeled(Sample::counter(
                "ocpd_slo_within_total",
                "Requests that finished under the class threshold.",
                c.within,
            )));
            out.push(labeled(Sample::gauge(
                "ocpd_slo_threshold_us",
                "Latency threshold of the class, microseconds.",
                c.threshold_us,
            )));
            out.push(labeled(Sample::gauge(
                "ocpd_slo_attainment_milli",
                "Under-threshold fraction, milli (1000 = 100%).",
                c.attainment_milli,
            )));
            out.push(labeled(Sample::gauge(
                "ocpd_slo_burn_milli",
                "Error-budget burn, milli (>= 1000 = objective missed).",
                c.burn_milli,
            )));
        }
    });
}
