//! Declarative request routing: a table of (methods, segment pattern,
//! handler) replacing the hand-rolled `match` dispatch chains.
//!
//! Each route is one row — the URL grammar is *data*, so the `405`
//! `Allow` sets and the route listing in `/info/` derive from the same
//! table that dispatches, and per-route latency histograms key off the
//! route names automatically.
//!
//! Matching walks the table in order (register literal-prefixed routes
//! before parameterized ones); the first row whose pattern AND method
//! match wins. If some row matches the path but none matches the
//! method, the router answers `405` with an `Allow` header naming the
//! union of the matching rows' methods (RFC 9110 §15.5.6). A path that
//! matches nothing is [`Outcome::NoMatch`] — the service layer decides
//! between 400 (reserved name, bad shape) and 404 semantics, preserving
//! the original grammar's behavior exactly.

use crate::web::http::Response;
use crate::Result;

/// One segment of a route pattern.
#[derive(Clone, Copy, Debug)]
pub enum Seg {
    /// Exact literal (reserved top-level names, fixed verbs).
    Lit(&'static str),
    /// A project token: matches any single segment EXCEPT the reserved
    /// top-level names, so `/wal/...` can never be shadowed by a
    /// project called `wal`.
    Token,
    /// Any single segment, captured into `Ctx::params`.
    Param,
    /// Zero or more trailing segments, captured into `Ctx::rest`
    /// (predicate queries). Must be the pattern's last element.
    Rest,
}

/// Captures handed to a handler.
pub struct Ctx<'a> {
    /// `Token`/`Param` captures, in pattern order.
    pub params: Vec<&'a str>,
    /// Trailing segments captured by [`Seg::Rest`] (empty otherwise).
    pub rest: &'a [&'a str],
    /// Request body.
    pub body: &'a [u8],
}

pub type Handler<S> = fn(&S, &Ctx<'_>) -> Result<Response>;

/// One row of the routing table.
pub struct Route<S> {
    /// Stable label: keys per-route latency histograms and names the
    /// route in listings.
    pub name: &'static str,
    /// Accepted methods (the `Allow` set when only the method differs).
    pub methods: &'static [&'static str],
    pub pattern: &'static [Seg],
    pub handler: Handler<S>,
    /// One-line human description for the `/info/` route listing.
    pub doc: &'static str,
}

/// What dispatch concluded.
pub enum Outcome {
    /// A handler ran (response carries its route label).
    Handled(Response),
    /// Path known, method not: a ready-made 405 with its `Allow` set.
    MethodNotAllowed(Response),
    /// No row matched the path.
    NoMatch,
}

pub struct Router<S> {
    routes: Vec<Route<S>>,
    reserved: &'static [&'static str],
}

impl<S> Router<S> {
    pub fn new(routes: Vec<Route<S>>, reserved: &'static [&'static str]) -> Self {
        Router { routes, reserved }
    }

    /// The reserved top-level names ([`Seg::Token`] refuses them).
    pub fn reserved(&self) -> &'static [&'static str] {
        self.reserved
    }

    fn matches<'a>(
        &self,
        pattern: &[Seg],
        segs: &'a [&'a str],
    ) -> Option<(Vec<&'a str>, &'a [&'a str])> {
        let has_rest = matches!(pattern.last(), Some(Seg::Rest));
        let fixed = if has_rest { pattern.len() - 1 } else { pattern.len() };
        if has_rest {
            if segs.len() < fixed {
                return None;
            }
        } else if segs.len() != fixed {
            return None;
        }
        let mut params = Vec::new();
        for (seg, &s) in pattern[..fixed].iter().zip(segs) {
            match seg {
                Seg::Lit(l) => {
                    if *l != s {
                        return None;
                    }
                }
                Seg::Token => {
                    if self.reserved.contains(&s) {
                        return None;
                    }
                    params.push(s);
                }
                Seg::Param => params.push(s),
                Seg::Rest => unreachable!("Rest is always last"),
            }
        }
        Some((params, if has_rest { &segs[fixed..] } else { &segs[..0] }))
    }

    /// Dispatch `method segs` against the table.
    pub fn dispatch(&self, svc: &S, method: &str, segs: &[&str], body: &[u8]) -> Outcome {
        // First row matching path AND method wins.
        for r in &self.routes {
            if !r.methods.contains(&method) {
                continue;
            }
            if let Some((params, rest)) = self.matches(r.pattern, segs) {
                let ctx = Ctx { params, rest, body };
                let mut resp = match (r.handler)(svc, &ctx) {
                    Ok(resp) => resp,
                    Err(e) => Response::error(e.http_status(), e.to_string()),
                };
                resp.route = Some(r.name);
                return Outcome::Handled(resp);
            }
        }
        // Path matches under some other method → auto-derived 405.
        let mut allow: Vec<&'static str> = Vec::new();
        for r in &self.routes {
            if self.matches(r.pattern, segs).is_some() {
                for m in r.methods {
                    if !allow.contains(m) {
                        allow.push(m);
                    }
                }
            }
        }
        if !allow.is_empty() {
            allow.sort_unstable();
            return Outcome::MethodNotAllowed(Response::method_not_allowed(allow.join(", ")));
        }
        Outcome::NoMatch
    }

    /// Match-only lookup: the route name `method segs` would dispatch
    /// to, without running its handler. The admission layer uses this
    /// to classify a request (route class, tenant attribution) *before*
    /// deciding whether to run it at all.
    pub fn peek(&self, method: &str, segs: &[&str]) -> Option<&'static str> {
        self.routes
            .iter()
            .find(|r| r.methods.contains(&method) && self.matches(r.pattern, segs).is_some())
            .map(|r| r.name)
    }

    /// Render the table: one `METHODS PATTERN  name — doc` line per
    /// route (the `/info/` route listing).
    pub fn listing(&self) -> String {
        let mut out = String::new();
        for r in &self.routes {
            let mut path = String::new();
            for seg in r.pattern {
                path.push('/');
                match seg {
                    Seg::Lit(l) => path.push_str(l),
                    Seg::Token => path.push_str("{token}"),
                    Seg::Param => path.push_str("{arg}"),
                    Seg::Rest => path.push_str("..."),
                }
            }
            path.push('/');
            out.push_str(&format!(
                "  {:<9} {:<46} {} — {}\n",
                r.methods.join("|"),
                path,
                r.name,
                r.doc
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Nop;

    fn ok(_: &Nop, _: &Ctx<'_>) -> Result<Response> {
        Ok(Response::text("ok"))
    }

    fn echo_params(_: &Nop, ctx: &Ctx<'_>) -> Result<Response> {
        Ok(Response::text(ctx.params.join(",")))
    }

    fn echo_rest(_: &Nop, ctx: &Ctx<'_>) -> Result<Response> {
        Ok(Response::text(ctx.rest.join(",")))
    }

    fn router() -> Router<Nop> {
        Router::new(
            vec![
                Route {
                    name: "status",
                    methods: &["GET"],
                    pattern: &[Seg::Lit("wal"), Seg::Lit("status")],
                    handler: ok,
                    doc: "status",
                },
                Route {
                    name: "flush",
                    methods: &["PUT", "POST"],
                    pattern: &[Seg::Lit("wal"), Seg::Lit("flush")],
                    handler: ok,
                    doc: "flush",
                },
                Route {
                    name: "cutout",
                    methods: &["GET"],
                    pattern: &[Seg::Token, Seg::Lit("ocpk"), Seg::Param],
                    handler: echo_params,
                    doc: "cutout",
                },
                Route {
                    name: "query",
                    methods: &["GET"],
                    pattern: &[Seg::Token, Seg::Lit("objects"), Seg::Rest],
                    handler: echo_rest,
                    doc: "query",
                },
            ],
            &["info", "wal"],
        )
    }

    fn body_text(resp: Response) -> String {
        String::from_utf8(resp.body.into_bytes().unwrap()).unwrap()
    }

    #[test]
    fn literal_and_param_matching() {
        let r = router();
        let Outcome::Handled(resp) = r.dispatch(&Nop, "GET", &["wal", "status"], &[]) else {
            panic!("expected handled");
        };
        assert_eq!(resp.route, Some("status"));

        let Outcome::Handled(resp) = r.dispatch(&Nop, "GET", &["tok", "ocpk", "5"], &[]) else {
            panic!("expected handled");
        };
        assert_eq!(body_text(resp), "tok,5");
    }

    #[test]
    fn rest_captures_tail() {
        let r = router();
        let Outcome::Handled(resp) =
            r.dispatch(&Nop, "GET", &["tok", "objects", "a", "b", "c"], &[])
        else {
            panic!("expected handled");
        };
        assert_eq!(body_text(resp), "a,b,c");
        // Rest may be empty.
        let Outcome::Handled(resp) = r.dispatch(&Nop, "GET", &["tok", "objects"], &[]) else {
            panic!("expected handled");
        };
        assert_eq!(body_text(resp), "");
    }

    #[test]
    fn auto_405_derives_allow_union() {
        let r = router();
        let Outcome::MethodNotAllowed(resp) =
            r.dispatch(&Nop, "DELETE", &["wal", "flush"], &[])
        else {
            panic!("expected 405");
        };
        assert_eq!(resp.status, 405);
        assert_eq!(resp.allow.as_deref(), Some("POST, PUT"));
    }

    #[test]
    fn reserved_names_never_match_token() {
        let r = router();
        // "wal" as a token would match the cutout pattern; it must not.
        assert!(matches!(
            r.dispatch(&Nop, "GET", &["wal", "ocpk", "5"], &[]),
            Outcome::NoMatch
        ));
    }

    #[test]
    fn peek_names_the_route_without_dispatching() {
        let r = router();
        assert_eq!(r.peek("GET", &["wal", "status"]), Some("status"));
        assert_eq!(r.peek("GET", &["tok", "ocpk", "5"]), Some("cutout"));
        // Wrong method / unknown path: no name.
        assert_eq!(r.peek("DELETE", &["wal", "flush"]), None);
        assert_eq!(r.peek("GET", &["nope"]), None);
    }

    #[test]
    fn listing_renders_every_route() {
        let r = router();
        let l = r.listing();
        assert!(l.contains("GET"), "{l}");
        assert!(l.contains("/wal/status/"), "{l}");
        assert!(l.contains("/{token}/ocpk/{arg}/"), "{l}");
        assert!(l.contains("PUT|POST"), "{l}");
    }
}
