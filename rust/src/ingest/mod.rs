//! Synthetic EM volume generation and bulk ingest.
//!
//! The paper's data (bock11, kasthuri11) are real serial-section EM
//! volumes we cannot redistribute; this generator produces volumes that
//! exercise the same code paths (DESIGN.md §1): textured background,
//! dendrite tubes, large vessels, compact bright synapse blobs (with
//! recorded ground-truth centroids — something the paper *didn't* have,
//! letting us report detector precision/recall), per-section exposure
//! drift (the Figure 6 pathology), and sensor noise.

use crate::array::DenseVolume;
use crate::core::{Box3, Vec3};
use crate::cutout::CutoutService;
use crate::util::Rng;
use crate::Result;

/// Parameters for the synthetic EM volume.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub dims: Vec3,
    pub seed: u64,
    /// Number of planted synapses (compact bright blobs).
    pub n_synapses: usize,
    /// Number of dendrite tubes (random walks).
    pub n_dendrites: usize,
    /// Number of large vessels (thick straight tubes).
    pub n_vessels: usize,
    /// Gaussian sensor noise sigma (gray levels).
    pub noise_sigma: f64,
    /// Peak-to-peak per-section exposure drift (gray levels); 0 disables.
    pub exposure_amp: f64,
}

impl SynthSpec {
    pub fn small(dims: Vec3, seed: u64) -> Self {
        let vol = (dims[0] * dims[1] * dims[2]) as f64;
        SynthSpec {
            dims,
            seed,
            // Realistic-ish densities: ~1 synapse per 50k voxels.
            n_synapses: (vol / 50_000.0).ceil() as usize,
            n_dendrites: (vol / 400_000.0).ceil() as usize,
            n_vessels: 1,
            noise_sigma: 6.0,
            exposure_amp: 0.0,
        }
    }

    pub fn with_exposure(mut self, amp: f64) -> Self {
        self.exposure_amp = amp;
        self
    }

    pub fn with_synapses(mut self, n: usize) -> Self {
        self.n_synapses = n;
        self
    }
}

/// A generated volume plus its ground truth.
pub struct SynthVolume {
    pub vol: DenseVolume<u8>,
    /// Ground-truth synapse centroids.
    pub synapses: Vec<Vec3>,
}

const BG: f64 = 110.0;
const SYNAPSE_AMP: f64 = 110.0;
const SYNAPSE_SIGMA: [f64; 3] = [2.0, 2.0, 1.0];

/// Generate a synthetic EM volume.
pub fn generate(spec: &SynthSpec) -> SynthVolume {
    let mut rng = Rng::new(spec.seed);
    let d = spec.dims;
    let mut acc = vec![BG; (d[0] * d[1] * d[2]) as usize];
    let idx = |x: u64, y: u64, z: u64| (x + d[0] * (y + d[1] * z)) as usize;

    // Dendrite tubes: random walks painted as darker cylinders.
    for _ in 0..spec.n_dendrites {
        let mut p = [
            rng.below(d[0]) as f64,
            rng.below(d[1]) as f64,
            rng.below(d[2]) as f64,
        ];
        let mut dir = [rng.f64() - 0.5, rng.f64() - 0.5, (rng.f64() - 0.5) * 0.3];
        let steps = (d[0] + d[1]) as usize;
        let r = 2.5 + rng.f64() * 2.0;
        for _ in 0..steps {
            paint_sphere(&mut acc, d, p, r, -35.0);
            for a in 0..3 {
                dir[a] += (rng.f64() - 0.5) * 0.25;
                let n = (dir[0] * dir[0] + dir[1] * dir[1] + dir[2] * dir[2]).sqrt().max(1e-6);
                dir[a] /= n;
                p[a] += dir[a] * 2.0;
                if p[a] < 0.0 || p[a] >= d[a] as f64 {
                    dir[a] = -dir[a];
                    p[a] = p[a].clamp(0.0, d[a] as f64 - 1.0);
                }
            }
        }
    }

    // Vessels: thick bright straight tubes along Y.
    for _ in 0..spec.n_vessels {
        let cx = rng.below(d[0]) as f64;
        let cz = rng.below(d[2]) as f64;
        let r = 10.0 + rng.f64() * 6.0;
        for y in 0..d[1] {
            paint_sphere(&mut acc, d, [cx, y as f64, cz], r, 0.35 * SYNAPSE_AMP);
        }
    }

    // Synapses: compact bright blobs; ground truth recorded. Keep them
    // inside the volume by a margin so the full blob is present.
    let mut synapses = Vec::with_capacity(spec.n_synapses);
    let margin = [6u64, 6, 3];
    for _ in 0..spec.n_synapses {
        let c = [
            rng.range(margin[0], d[0] - margin[0]),
            rng.range(margin[1], d[1] - margin[1]),
            rng.range(margin[2], d[2] - margin[2]),
        ];
        paint_gaussian(&mut acc, d, c, SYNAPSE_SIGMA, SYNAPSE_AMP);
        synapses.push(c);
    }

    // Exposure drift per section + noise, then quantize.
    let mut vol = DenseVolume::<u8>::zeros(d);
    for z in 0..d[2] {
        let drift = if spec.exposure_amp > 0.0 {
            // Alternating + slow sinusoid: the serial-section signature.
            let alt = if z % 2 == 0 { 1.0 } else { -1.0 };
            0.5 * spec.exposure_amp * alt
                + 0.3 * spec.exposure_amp * (z as f64 * 0.7).sin()
        } else {
            0.0
        };
        for y in 0..d[1] {
            for x in 0..d[0] {
                let v = acc[idx(x, y, z)] + drift + rng.normal() * spec.noise_sigma;
                vol.set([x, y, z], v.clamp(0.0, 255.0) as u8);
            }
        }
    }
    SynthVolume { vol, synapses }
}

fn paint_sphere(acc: &mut [f64], d: Vec3, c: [f64; 3], r: f64, amp: f64) {
    let lo = |a: usize| ((c[a] - r).floor().max(0.0)) as u64;
    let hi = |a: usize| ((c[a] + r).ceil().min(d[a] as f64 - 1.0)) as u64;
    for z in lo(2)..=hi(2) {
        for y in lo(1)..=hi(1) {
            for x in lo(0)..=hi(0) {
                let dx = x as f64 - c[0];
                let dy = y as f64 - c[1];
                let dz = (z as f64 - c[2]) * 2.0; // anisotropy
                if dx * dx + dy * dy + dz * dz <= r * r {
                    acc[(x + d[0] * (y + d[1] * z)) as usize] += amp;
                }
            }
        }
    }
}

fn paint_gaussian(acc: &mut [f64], d: Vec3, c: Vec3, sigma: [f64; 3], amp: f64) {
    let r = [
        (3.0 * sigma[0]).ceil() as u64,
        (3.0 * sigma[1]).ceil() as u64,
        (3.0 * sigma[2]).ceil() as u64,
    ];
    let lo = [c[0].saturating_sub(r[0]), c[1].saturating_sub(r[1]), c[2].saturating_sub(r[2])];
    let hi = [
        (c[0] + r[0]).min(d[0] - 1),
        (c[1] + r[1]).min(d[1] - 1),
        (c[2] + r[2]).min(d[2] - 1),
    ];
    for z in lo[2]..=hi[2] {
        for y in lo[1]..=hi[1] {
            for x in lo[0]..=hi[0] {
                let dx = (x as f64 - c[0] as f64) / sigma[0];
                let dy = (y as f64 - c[1] as f64) / sigma[1];
                let dz = (z as f64 - c[2] as f64) / sigma[2];
                acc[(x + d[0] * (y + d[1] * z)) as usize] +=
                    amp * (-0.5 * (dx * dx + dy * dy + dz * dz)).exp();
            }
        }
    }
}

/// Block boxes tiling `[0, dims)` in `block`-sized steps, z-major order
/// — the bulk-ingest unit, shared by [`ingest_volume`] and the batch
/// job engine's [`crate::jobs::BulkIngestJob`] so both walk the exact
/// same block sequence.
pub fn block_boxes(dims: Vec3, block: Vec3) -> Vec<Box3> {
    let block = [block[0].max(1), block[1].max(1), block[2].max(1)];
    let mut out = Vec::new();
    let mut z = 0;
    while z < dims[2] {
        let ze = (z + block[2]).min(dims[2]);
        let mut y = 0;
        while y < dims[1] {
            let ye = (y + block[1]).min(dims[1]);
            let mut x = 0;
            while x < dims[0] {
                let xe = (x + block[0]).min(dims[0]);
                out.push(Box3::new([x, y, z], [xe, ye, ze]));
                x = xe;
            }
            y = ye;
        }
        z = ze;
    }
    out
}

/// Bulk-ingest a volume into an image project in cuboid-aligned blocks —
/// the "image data streamed from the instruments" path (§4.1). Aligned
/// blocks are fully covered overwrites, so the write engine elides every
/// existing-cuboid read (ingest performs zero read I/O) and scatters
/// each block's commit across the shards. Returns bytes ingested.
pub fn ingest_volume(
    svc: &CutoutService,
    vol: &DenseVolume<u8>,
    block: Vec3,
) -> Result<u64> {
    let mut bytes = 0u64;
    for bx in block_boxes(vol.dims(), block) {
        let sub = vol.extract_box(bx);
        bytes += sub.len() as u64;
        svc.write(0, 0, 0, bx, &sub)?;
    }
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunkstore::CuboidStore;
    use crate::core::{DatasetBuilder, Project};
    use crate::storage::MemStore;
    use std::sync::Arc;

    #[test]
    fn generator_deterministic() {
        let spec = SynthSpec::small([64, 64, 16], 5);
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.vol, b.vol);
        assert_eq!(a.synapses, b.synapses);
    }

    #[test]
    fn synapses_are_bright_spots() {
        let spec = SynthSpec { noise_sigma: 0.0, ..SynthSpec::small([96, 96, 24], 7) };
        let sv = generate(&spec);
        assert!(!sv.synapses.is_empty());
        for &c in &sv.synapses {
            let at = sv.vol.get(c) as f64;
            assert!(at > BG + 60.0, "synapse at {c:?} only {at}");
        }
    }

    #[test]
    fn exposure_drift_alternates_sections() {
        let spec =
            SynthSpec { noise_sigma: 0.0, n_synapses: 0, n_dendrites: 0, n_vessels: 0, ..SynthSpec::small([32, 32, 8], 3).with_exposure(30.0) };
        let sv = generate(&spec);
        let mean = |z: u64| {
            let mut s = 0u64;
            for y in 0..32 {
                for x in 0..32 {
                    s += sv.vol.get([x, y, z]) as u64;
                }
            }
            s as f64 / 1024.0
        };
        // Adjacent sections differ by ~exposure_amp.
        assert!((mean(0) - mean(1)).abs() > 15.0, "{} vs {}", mean(0), mean(1));
    }

    #[test]
    fn block_boxes_tile_exactly() {
        let dims = [100u64, 64, 17];
        let boxes = block_boxes(dims, [64, 64, 16]);
        // Tiles cover every voxel exactly once.
        let total: u64 = boxes.iter().map(|b| b.volume()).sum();
        assert_eq!(total, dims[0] * dims[1] * dims[2]);
        for w in boxes.windows(2) {
            assert_ne!(w[0], w[1]);
        }
        // Degenerate block extents are clamped, not an infinite loop.
        assert_eq!(block_boxes([4, 4, 4], [0, 0, 0]).len(), 64);
    }

    #[test]
    fn ingest_roundtrip() {
        let ds = Arc::new(DatasetBuilder::new("t", [128, 128, 32]).levels(1).build());
        let pr = Arc::new(Project::image("img", "t"));
        let svc =
            CutoutService::new(Arc::new(CuboidStore::new(ds, pr, Arc::new(MemStore::new()))));
        let sv = generate(&SynthSpec::small([128, 128, 32], 9));
        let bytes = ingest_volume(&svc, &sv.vol, [64, 64, 16]).unwrap();
        assert_eq!(bytes, 128 * 128 * 32);
        let back = svc.read::<u8>(0, 0, 0, Box3::new([0, 0, 0], [128, 128, 32])).unwrap();
        assert_eq!(back, sv.vol);
    }
}
