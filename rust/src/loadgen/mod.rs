//! Open-loop load generator (`ocpd loadgen`): drive a live server with
//! a mixed, skewable workload at a fixed arrival rate and measure
//! latency without coordinated omission.
//!
//! **Open loop**: arrivals are scheduled on a fixed timetable
//! (`i / rate` seconds after start) *before* any response comes back,
//! and each request's latency is measured from its *scheduled* start —
//! so a stalled server inflates the recorded tail instead of silently
//! slowing the offered load, the classic closed-loop measurement bug.
//! Workers claim arrivals from a shared counter; when all workers are
//! busy, late arrivals accumulate queueing delay that the histogram
//! keeps.
//!
//! Scenarios model the paper's traffic classes: interactive cutout
//! reads and tile zooms, annotation writes through the SSD
//! write-absorber, and job-status polls. The `hotspot` knob skews
//! spatial scenarios onto the volume's origin corner, which is what
//! lights up one shard in the heat map (`GET /heat/status/`) — the
//! skewed-workload integration test drives exactly that.
//!
//! All requests ride the pooled keep-alive client
//! ([`crate::web::http::request`]); 429/503 answers and transport
//! errors are counted per scenario, never silently retried.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::array::DenseVolume;
use crate::core::Dtype;
use crate::metrics::{Counter, Histogram};
use crate::util::Rng;
use crate::web::http::{request_with, RequestOpts};
use crate::web::ocpk;
use crate::{Error, Result};

/// The workload scenarios.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// `GET /{token}/ocpk/0/...` — interactive volume read.
    CutoutRead,
    /// `GET /{token}/tile/0/...` — viewer tile fetch.
    TileZoom,
    /// `PUT /{ann}/overwrite/0/` — annotation volume write.
    AnnotationWrite,
    /// `GET /jobs/status/` — cheap status poll.
    JobPoll,
}

impl Scenario {
    pub fn name(self) -> &'static str {
        match self {
            Scenario::CutoutRead => "cutout_read",
            Scenario::TileZoom => "tile_zoom",
            Scenario::AnnotationWrite => "annotation_write",
            Scenario::JobPoll => "job_poll",
        }
    }
}

const SCENARIOS: [Scenario; 4] =
    [Scenario::CutoutRead, Scenario::TileZoom, Scenario::AnnotationWrite, Scenario::JobPoll];

/// Relative scenario weights (zero disables a scenario).
#[derive(Clone, Copy, Debug)]
pub struct ScenarioMix {
    pub cutout: u32,
    pub tile: u32,
    pub write: u32,
    pub poll: u32,
}

impl Default for ScenarioMix {
    /// Read-heavy interactive traffic with a write and poll trickle —
    /// the shape §4.2's visualization workload takes.
    fn default() -> Self {
        ScenarioMix { cutout: 6, tile: 2, write: 1, poll: 1 }
    }
}

impl ScenarioMix {
    fn weight(&self, s: Scenario) -> u32 {
        match s {
            Scenario::CutoutRead => self.cutout,
            Scenario::TileZoom => self.tile,
            Scenario::AnnotationWrite => self.write,
            Scenario::JobPoll => self.poll,
        }
    }
}

/// One load-generation run.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server base URL, e.g. `http://127.0.0.1:8642`.
    pub base_url: String,
    /// Image project token for cutout/tile scenarios.
    pub image_token: String,
    /// Annotation project token for write scenarios; `None` disables
    /// writes regardless of the mix weight.
    pub annotation_token: Option<String>,
    /// Level-0 dims of the image project (bounds request boxes).
    pub dims: [u64; 3],
    /// Target arrival rate, requests/second.
    pub rate: f64,
    /// Run length.
    pub duration: Duration,
    /// Worker threads issuing requests.
    pub concurrency: usize,
    /// RNG seed; every arrival derives its own generator from it, so a
    /// run is reproducible independent of worker scheduling.
    pub seed: u64,
    /// Probability that a spatial scenario targets the origin-corner
    /// hot region instead of a uniformly random box.
    pub hotspot: f64,
    /// Cutout read extent (clamped to `dims`).
    pub read_extent: [u64; 3],
    pub mix: ScenarioMix,
    /// Per-request latency budget, sent as `X-OCPD-Deadline-Ms`; the
    /// server answers 504 (counted separately) once it expires.
    pub deadline_ms: Option<u64>,
}

impl LoadgenConfig {
    pub fn new(base_url: &str, image_token: &str) -> Self {
        LoadgenConfig {
            base_url: base_url.trim_end_matches('/').to_string(),
            image_token: image_token.to_string(),
            annotation_token: None,
            dims: [256, 256, 32],
            rate: 100.0,
            duration: Duration::from_secs(5),
            concurrency: 4,
            seed: 1,
            hotspot: 0.0,
            read_extent: [64, 64, 8],
            mix: ScenarioMix::default(),
            deadline_ms: None,
        }
    }
}

/// Latency and outcome counters for one scenario.
#[derive(Default)]
struct Stats {
    hist: Histogram,
    ok: Counter,
    http_429: Counter,
    http_503: Counter,
    /// Deadline expiries: the server abandoned remaining work.
    http_504: Counter,
    /// Non-2xx answers other than 429/503/504.
    http_errors: Counter,
    /// Connect/read/write failures — the request never got an answer.
    transport_errors: Counter,
}

impl Stats {
    fn record(&self, latency: Duration, outcome: &Result<(u16, Vec<u8>)>) {
        self.hist.record(latency);
        match outcome {
            Ok((200, _)) => self.ok.inc(),
            Ok((429, _)) => self.http_429.inc(),
            Ok((503, _)) => self.http_503.inc(),
            Ok((504, _)) => self.http_504.inc(),
            Ok(_) => self.http_errors.inc(),
            Err(_) => self.transport_errors.inc(),
        }
    }

    fn row(&self, scenario: &str) -> ScenarioRow {
        let snap = self.hist.snapshot();
        ScenarioRow {
            scenario: scenario.to_string(),
            requests: snap.count,
            ok: self.ok.get(),
            http_429: self.http_429.get(),
            http_503: self.http_503.get(),
            http_504: self.http_504.get(),
            http_errors: self.http_errors.get(),
            transport_errors: self.transport_errors.get(),
            mean_us: snap.mean(),
            p50_us: snap.percentile(50.0),
            p95_us: snap.percentile(95.0),
            p99_us: snap.percentile(99.0),
            p999_us: snap.percentile(99.9),
        }
    }
}

/// One row of the report: a scenario's outcome counts and latency
/// percentiles (µs, log2-bucket upper edges).
#[derive(Clone, Debug)]
pub struct ScenarioRow {
    pub scenario: String,
    pub requests: u64,
    pub ok: u64,
    pub http_429: u64,
    pub http_503: u64,
    pub http_504: u64,
    pub http_errors: u64,
    pub transport_errors: u64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
}

impl ScenarioRow {
    /// Render as a JSON object (the `rows` entries of
    /// `BENCH_loadgen.json`).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"scenario\": \"{}\", \"requests\": {}, \"ok\": {}, \"http_429\": {}, \
             \"http_503\": {}, \"http_504\": {}, \"http_errors\": {}, \
             \"transport_errors\": {}, \
             \"mean_us\": {:.1}, \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}, \
             \"p999_us\": {}}}",
            self.scenario,
            self.requests,
            self.ok,
            self.http_429,
            self.http_503,
            self.http_504,
            self.http_errors,
            self.transport_errors,
            self.mean_us,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.p999_us
        )
    }
}

/// The result of one run at one concurrency level.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub concurrency: usize,
    pub target_rps: f64,
    /// Requests actually issued over the wall time.
    pub achieved_rps: f64,
    pub wall_seconds: f64,
    /// `overall` first, then one row per scenario that saw traffic.
    pub rows: Vec<ScenarioRow>,
}

impl LoadgenReport {
    /// The `overall` row (always present).
    pub fn overall(&self) -> &ScenarioRow {
        &self.rows[0]
    }

    /// Human-readable rendering for the CLI.
    pub fn render_text(&self) -> String {
        let mut out = format!(
            "loadgen: concurrency={} target={:.0}/s achieved={:.1}/s wall={:.2}s\n",
            self.concurrency, self.target_rps, self.achieved_rps, self.wall_seconds
        );
        for r in &self.rows {
            out.push_str(&format!(
                "  {}: n={} ok={} 429={} 503={} 504={} http_err={} transport_err={} \
                 p50={}us p95={}us p99={}us p999={}us\n",
                r.scenario,
                r.requests,
                r.ok,
                r.http_429,
                r.http_503,
                r.http_504,
                r.http_errors,
                r.transport_errors,
                r.p50_us,
                r.p95_us,
                r.p99_us,
                r.p999_us
            ));
        }
        out
    }

    /// Render as a JSON object (one entry of the report's `runs`).
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self.rows.iter().map(|r| format!("      {}", r.to_json())).collect();
        format!(
            "{{\"concurrency\": {}, \"target_rps\": {:.1}, \"achieved_rps\": {:.1}, \
             \"wall_seconds\": {:.3}, \"rows\": [\n{}\n    ]}}",
            self.concurrency,
            self.target_rps,
            self.achieved_rps,
            self.wall_seconds,
            rows.join(",\n")
        )
    }
}

/// Render a full `BENCH_loadgen.json` document from runs at several
/// concurrency levels.
pub fn render_report_json(cfg: &LoadgenConfig, runs: &[LoadgenReport], provenance: &str) -> String {
    let mut json = String::from("{\n  \"bench\": \"loadgen\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"rate_rps\": {:.1}, \"duration_s\": {:.1}, \"seed\": {}, \
         \"hotspot\": {:.2}, \"dims\": [{}, {}, {}], \
         \"mix\": {{\"cutout\": {}, \"tile\": {}, \"write\": {}, \"poll\": {}}}}},\n",
        cfg.rate,
        cfg.duration.as_secs_f64(),
        cfg.seed,
        cfg.hotspot,
        cfg.dims[0],
        cfg.dims[1],
        cfg.dims[2],
        cfg.mix.cutout,
        cfg.mix.tile,
        cfg.mix.write,
        cfg.mix.poll
    ));
    json.push_str(&format!("  \"provenance\": \"{provenance}\",\n"));
    json.push_str("  \"runs\": [\n");
    let entries: Vec<String> = runs.iter().map(|r| format!("    {}", r.to_json())).collect();
    json.push_str(&entries.join(",\n"));
    json.push_str("\n  ]\n}\n");
    json
}

/// Pick the arrival's scenario from the weighted mix (writes are
/// skipped when no annotation token is configured).
fn pick_scenario(cfg: &LoadgenConfig, rng: &mut Rng) -> Scenario {
    let weight = |s: Scenario| {
        if s == Scenario::AnnotationWrite && cfg.annotation_token.is_none() {
            0
        } else {
            cfg.mix.weight(s)
        }
    };
    let total: u64 = SCENARIOS.iter().map(|&s| weight(s) as u64).sum();
    if total == 0 {
        return Scenario::JobPoll;
    }
    let mut pick = rng.below(total);
    for &s in &SCENARIOS {
        let w = weight(s) as u64;
        if pick < w {
            return s;
        }
        pick -= w;
    }
    Scenario::JobPoll
}

/// A request box: the origin-corner hot region with probability
/// `hotspot`, a uniformly random in-bounds box otherwise.
fn pick_box(cfg: &LoadgenConfig, rng: &mut Rng, extent: [u64; 3]) -> ([u64; 3], [u64; 3]) {
    let ext = [
        extent[0].clamp(1, cfg.dims[0]),
        extent[1].clamp(1, cfg.dims[1]),
        extent[2].clamp(1, cfg.dims[2]),
    ];
    let mut lo = [0u64; 3];
    if !rng.chance(cfg.hotspot) {
        for a in 0..3 {
            lo[a] = rng.below(cfg.dims[a] - ext[a] + 1);
        }
    }
    (lo, [lo[0] + ext[0], lo[1] + ext[1], lo[2] + ext[2]])
}

/// Issue one arrival's request. Returns the raw transport outcome.
fn issue(cfg: &LoadgenConfig, scenario: Scenario, rng: &mut Rng) -> Result<(u16, Vec<u8>)> {
    let base = &cfg.base_url;
    // Loadgen measures the server's answers, so throttles are counted,
    // never retried; the deadline budget rides every request.
    let opts = RequestOpts { deadline_ms: cfg.deadline_ms, retry: None };
    let call = |method: &str, url: String, body: &[u8]| -> Result<(u16, Vec<u8>)> {
        let info = request_with(method, &url, body, &opts)?;
        Ok((info.status, info.body))
    };
    match scenario {
        Scenario::CutoutRead => {
            let (lo, hi) = pick_box(cfg, rng, cfg.read_extent);
            call(
                "GET",
                format!(
                    "{base}/{}/ocpk/0/{},{}/{},{}/{},{}/",
                    cfg.image_token, lo[0], hi[0], lo[1], hi[1], lo[2], hi[2]
                ),
                &[],
            )
        }
        Scenario::TileZoom => {
            // Tiles are 256² in x/y; pick an in-bounds tile coordinate
            // and a z slice, hot-corner-skewed like cutouts.
            let (lo, _) = pick_box(cfg, rng, [1, 1, 1]);
            call(
                "GET",
                format!(
                    "{base}/{}/tile/0/{}/{}_{}.gray",
                    cfg.image_token,
                    lo[2],
                    lo[1] / 256,
                    lo[0] / 256
                ),
                &[],
            )
        }
        Scenario::AnnotationWrite => {
            let token = cfg.annotation_token.as_deref().unwrap_or(&cfg.image_token);
            let (lo, hi) = pick_box(cfg, rng, [16, 16, 4]);
            let ext = [hi[0] - lo[0], hi[1] - lo[1], hi[2] - lo[2]];
            let mut vol = DenseVolume::<u32>::zeros(ext);
            vol.fill_box(
                crate::core::Box3::new([0, 0, 0], ext),
                1 + rng.below(1 << 20) as u32,
            );
            let body = ocpk::encode_volume(Dtype::U32, lo, &vol)?;
            call("PUT", format!("{base}/{token}/overwrite/0/"), &body)
        }
        Scenario::JobPoll => call("GET", format!("{base}/jobs/status/"), &[]),
    }
}

/// Run one open-loop load generation at `cfg.concurrency` workers.
///
/// Fails only on setup errors (zero rate/duration); per-request
/// failures are counted, not raised.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.rate <= 0.0 {
        return Err(Error::BadRequest("loadgen rate must be positive".into()));
    }
    let total = (cfg.rate * cfg.duration.as_secs_f64()).ceil() as usize;
    if total == 0 {
        return Err(Error::BadRequest("loadgen duration too short for one arrival".into()));
    }
    let interval = Duration::from_secs_f64(1.0 / cfg.rate);
    let stats: Vec<Stats> = (0..SCENARIOS.len()).map(|_| Stats::default()).collect();
    let overall = Stats::default();
    let next = AtomicUsize::new(0);
    let start = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..cfg.concurrency.max(1) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= total {
                    return;
                }
                // The open-loop schedule: arrival i is due at i/rate
                // seconds, regardless of how prior requests fared.
                let due = start + interval.mul_f64(i as f64);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                // Per-arrival RNG: reproducible independent of which
                // worker claims the arrival.
                let mut rng =
                    Rng::new(cfg.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let scenario = pick_scenario(cfg, &mut rng);
                let outcome = issue(cfg, scenario, &mut rng);
                // Latency from the *scheduled* start: queueing delay
                // behind saturated workers stays in the tail.
                let latency = Instant::now().saturating_duration_since(due);
                let idx = SCENARIOS.iter().position(|&s| s == scenario).unwrap_or(0);
                stats[idx].record(latency, &outcome);
                overall.record(latency, &outcome);
            });
        }
    });
    let wall = start.elapsed().as_secs_f64();
    let mut rows = vec![overall.row("overall")];
    for (i, &s) in SCENARIOS.iter().enumerate() {
        let row = stats[i].row(s.name());
        if row.requests > 0 {
            rows.push(row);
        }
    }
    Ok(LoadgenReport {
        concurrency: cfg.concurrency.max(1),
        target_rps: cfg.rate,
        achieved_rps: total as f64 / wall.max(1e-9),
        wall_seconds: wall,
        rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LoadgenConfig {
        LoadgenConfig::new("http://127.0.0.1:1", "img")
    }

    #[test]
    fn mix_honors_zero_weights_and_missing_annotation_token() {
        let mut c = cfg();
        c.mix = ScenarioMix { cutout: 0, tile: 0, write: 5, poll: 0 };
        // No annotation token: the only weighted scenario is disabled,
        // so the picker falls back to the poll scenario.
        let mut rng = Rng::new(7);
        for _ in 0..32 {
            assert_eq!(pick_scenario(&c, &mut rng), Scenario::JobPoll);
        }
        c.annotation_token = Some("ann".into());
        for _ in 0..32 {
            assert_eq!(pick_scenario(&c, &mut rng), Scenario::AnnotationWrite);
        }
    }

    #[test]
    fn hotspot_pins_boxes_to_the_origin_corner() {
        let mut c = cfg();
        c.hotspot = 1.0;
        let mut rng = Rng::new(3);
        for _ in 0..64 {
            let (lo, hi) = pick_box(&c, &mut rng, [64, 64, 8]);
            assert_eq!(lo, [0, 0, 0]);
            assert_eq!(hi, [64, 64, 8]);
        }
        // hotspot=0 spreads: at least one box away from the origin.
        c.hotspot = 0.0;
        let spread = (0..64).any(|_| pick_box(&c, &mut rng, [64, 64, 8]).0 != [0, 0, 0]);
        assert!(spread, "uniform boxes never left the origin");
    }

    #[test]
    fn boxes_stay_in_bounds_and_extents_clamp() {
        let mut c = cfg();
        c.dims = [100, 50, 10];
        let mut rng = Rng::new(11);
        for _ in 0..256 {
            let (lo, hi) = pick_box(&c, &mut rng, [64, 64, 64]);
            for a in 0..3 {
                assert!(lo[a] < hi[a]);
                assert!(hi[a] <= c.dims[a], "box {lo:?}..{hi:?} outside {:?}", c.dims);
            }
        }
    }

    #[test]
    fn report_json_is_well_formed() {
        let report = LoadgenReport {
            concurrency: 4,
            target_rps: 100.0,
            achieved_rps: 99.5,
            wall_seconds: 5.02,
            rows: vec![ScenarioRow {
                scenario: "overall".into(),
                requests: 500,
                ok: 498,
                http_429: 0,
                http_503: 2,
                http_504: 0,
                http_errors: 0,
                transport_errors: 0,
                mean_us: 1234.5,
                p50_us: 1023,
                p95_us: 4095,
                p99_us: 8191,
                p999_us: 16383,
            }],
        };
        let json = render_report_json(&cfg(), &[report], "unit test");
        assert!(json.contains("\"bench\": \"loadgen\""));
        assert!(json.contains("\"runs\": ["));
        assert!(json.contains("\"scenario\": \"overall\""));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn run_rejects_degenerate_configs() {
        let mut c = cfg();
        c.rate = 0.0;
        assert!(run(&c).is_err());
        let mut c = cfg();
        c.rate = 10.0;
        c.duration = Duration::ZERO;
        assert!(run(&c).is_err());
    }

    #[test]
    fn open_loop_counts_every_arrival_even_against_a_dead_server() {
        // Port 1 refuses connections: every request is a transport
        // error, but the open-loop schedule still issues all of them.
        let mut c = cfg();
        c.rate = 200.0;
        c.duration = Duration::from_millis(100);
        c.concurrency = 4;
        c.hotspot = 0.5;
        let report = run(&c).expect("run completes");
        let overall = report.overall();
        assert_eq!(overall.requests, 20);
        assert_eq!(overall.transport_errors, 20);
        assert_eq!(overall.ok, 0);
        // Scenario rows partition the overall count.
        let scenario_sum: u64 = report.rows[1..].iter().map(|r| r.requests).sum();
        assert_eq!(scenario_sum, overall.requests);
    }
}
