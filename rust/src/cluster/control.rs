//! The cluster control plane: node registry, lease-based failure
//! detection, and failover promotion (DESIGN.md §10).
//!
//! One [`ControlPlane`] per [`super::Cluster`] tracks every storage node
//! and every project's replica sets. Each [`ControlPlane::tick`]:
//!
//! 1. probes every registered node and records liveness;
//! 2. catches dead-marked followers back up (retained-chunk replay or
//!    full resync, see [`ReplicaSet::catch_up`]);
//! 3. probes each multi-member set's leader — a live leader renews its
//!    lease; a dead one whose lease has expired gets the most-caught-up
//!    follower promoted in its place.
//!
//! Ticks run either explicitly (the deterministic test harness calls
//! `tick()` by hand with `lease = ZERO`) or from a background monitor
//! thread holding only a weak reference, the same lifecycle idiom as the
//! WAL flusher.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::Duration;

use crate::metrics::Counter;
use crate::shard::NodeId;
use crate::storage::Engine;
use crate::{Error, Result};

use super::replica::{PromotionReport, ReplicaSet};

struct RegisteredNode {
    id: NodeId,
    name: String,
    role: &'static str,
    engine: Engine,
    alive: AtomicBool,
}

/// Liveness snapshot of one node.
#[derive(Clone, Debug)]
pub struct NodeHealth {
    pub id: NodeId,
    pub name: String,
    pub role: &'static str,
    pub alive: bool,
}

/// Node registry + failure detector + promoter for one cluster.
pub struct ControlPlane {
    nodes: Vec<RegisteredNode>,
    /// `(project token, set)` for every replicated shard in the cluster.
    sets: RwLock<Vec<(String, Arc<ReplicaSet>)>>,
    /// Failovers performed by this control plane (all projects).
    pub promotions: Counter,
    /// Ticks executed (probe rounds), for status/metrics.
    pub ticks: Counter,
    stop: AtomicBool,
    monitor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ControlPlane {
    /// Build the registry from the cluster's nodes.
    pub fn new(nodes: Vec<(NodeId, String, &'static str, Engine)>) -> Arc<Self> {
        Arc::new(ControlPlane {
            nodes: nodes
                .into_iter()
                .map(|(id, name, role, engine)| RegisteredNode {
                    id,
                    name,
                    role,
                    engine,
                    alive: AtomicBool::new(true),
                })
                .collect(),
            sets: RwLock::new(Vec::new()),
            promotions: Counter::default(),
            ticks: Counter::default(),
            stop: AtomicBool::new(false),
            monitor: Mutex::new(None),
        })
    }

    /// Track a project's replica sets (called at project creation).
    pub fn register_sets(&self, token: &str, sets: &[Arc<ReplicaSet>]) {
        let mut g = self.sets.write().unwrap();
        for s in sets {
            g.push((token.to_string(), Arc::clone(s)));
        }
    }

    /// Forget a project's replica sets (called when a project is
    /// dropped, so the monitor stops probing retired shards).
    pub fn unregister_sets(&self, token: &str) {
        self.sets.write().unwrap().retain(|(t, _)| t != token);
    }

    /// The replica sets registered for `token`, in shard order.
    pub fn sets_for(&self, token: &str) -> Vec<Arc<ReplicaSet>> {
        let mut out: Vec<Arc<ReplicaSet>> = self
            .sets
            .read()
            .unwrap()
            .iter()
            .filter(|(t, _)| t == token)
            .map(|(_, s)| Arc::clone(s))
            .collect();
        out.sort_by_key(|s| s.shard());
        out
    }

    /// Every registered set as `(token, set)` pairs.
    pub fn all_sets(&self) -> Vec<(String, Arc<ReplicaSet>)> {
        self.sets.read().unwrap().clone()
    }

    /// Manually promote one shard of one project (the
    /// `/cluster/failover/` handler). Fails when the project has no
    /// replicas or no live follower.
    pub fn failover(&self, token: &str, shard: usize) -> Result<PromotionReport> {
        let sets = self.sets_for(token);
        let set = sets
            .iter()
            .find(|s| s.shard() == shard)
            .ok_or_else(|| Error::NotFound(format!("no replica set for {token} shard {shard}")))?;
        let report = set.promote()?;
        self.promotions.inc();
        Ok(report)
    }

    /// One probe/repair/promote round. Returns the promotions performed.
    pub fn tick(&self) -> Vec<PromotionReport> {
        self.ticks.inc();
        for n in &self.nodes {
            let ok = n.engine.get("cluster/health", 0).is_ok();
            n.alive.store(ok, Ordering::Release);
        }
        let mut out = Vec::new();
        for (_, set) in self.all_sets() {
            set.catch_up();
            if set.num_members() < 2 {
                continue;
            }
            if set.probe_leader() {
                continue; // live leader renewed its lease
            }
            if !set.lease_expired() {
                continue; // dead-looking, but still within its grace period
            }
            if let Ok(report) = set.promote() {
                self.promotions.inc();
                out.push(report);
            }
        }
        out
    }

    /// Spawn the background monitor: `tick()` every `interval` until the
    /// cluster (the owning `Arc`) is dropped or `shutdown` is called.
    pub fn start_monitor(self: &Arc<Self>, interval: Duration) {
        let weak: Weak<ControlPlane> = Arc::downgrade(self);
        let handle = std::thread::Builder::new()
            .name("ocpd-cluster-monitor".into())
            .spawn(move || loop {
                std::thread::sleep(interval);
                let Some(cp) = weak.upgrade() else { break };
                if cp.stop.load(Ordering::Relaxed) {
                    break;
                }
                let _ = cp.tick();
            })
            .expect("spawn cluster monitor");
        *self.monitor.lock().unwrap() = Some(handle);
    }

    /// Stop the monitor thread (idempotent). Never joins from within the
    /// monitor itself — same self-join guard as the WAL flusher.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.monitor.lock().unwrap().take() {
            if std::thread::current().id() != h.thread().id() {
                let _ = h.join();
            }
        }
    }

    /// Per-node liveness, from the most recent tick (nodes start alive).
    pub fn node_health(&self) -> Vec<NodeHealth> {
        self.nodes
            .iter()
            .map(|n| NodeHealth {
                id: n.id,
                name: n.name.clone(),
                role: n.role,
                alive: n.alive.load(Ordering::Acquire),
            })
            .collect()
    }

    /// Human-readable cluster view — the `/cluster/status/` body.
    pub fn status_text(&self) -> String {
        let mut out = String::from("cluster:\n  nodes:\n");
        for n in self.node_health() {
            out.push_str(&format!(
                "    {}: id={} role={} alive={}\n",
                n.name, n.id, n.role, n.alive
            ));
        }
        let sets = self.all_sets();
        out.push_str(&format!(
            "  control: ticks={} promotions={} replica_sets={}\n",
            self.ticks.get(),
            self.promotions.get(),
            sets.len()
        ));
        let mut by_token: Vec<(String, Vec<Arc<ReplicaSet>>)> = Vec::new();
        for (token, set) in sets {
            match by_token.iter_mut().find(|(t, _)| *t == token) {
                Some((_, v)) => v.push(set),
                None => by_token.push((token, vec![set])),
            }
        }
        for (token, mut project_sets) in by_token {
            project_sets.sort_by_key(|s| s.shard());
            out.push_str(&format!("  project {token}:\n"));
            for set in project_sets {
                let st = set.status();
                let members: Vec<String> = st
                    .replicas
                    .iter()
                    .map(|r| {
                        format!(
                            "node{}:lsn={}{}{}",
                            r.node,
                            r.applied_lsn,
                            if r.is_leader { ":leader" } else { "" },
                            if r.alive { "" } else { ":dead" }
                        )
                    })
                    .collect();
                out.push_str(&format!(
                    "    shard {}: epoch={} leader=node{} lag={} failovers={} fenced={} \
                     ships={} ship_errors={} [{}]\n",
                    st.shard,
                    st.epoch,
                    st.leader,
                    st.max_lag(),
                    st.failovers,
                    st.fenced,
                    st.ships,
                    st.ship_errors,
                    members.join(", ")
                ));
            }
        }
        out
    }
}

impl Drop for ControlPlane {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::replica::ReplicationConfig;
    use crate::storage::{MemStore, SimulatedStore};

    fn faulty_nodes(n: usize) -> Vec<(NodeId, String, &'static str, Engine)> {
        (0..n)
            .map(|i| {
                let inner: Engine = Arc::new(MemStore::new());
                let e: Engine = Arc::new(SimulatedStore::instant(inner, i as u64));
                (i, format!("db{i}"), "database", e)
            })
            .collect()
    }

    fn replicated_set(nodes: &[(NodeId, String, &'static str, Engine)]) -> Arc<ReplicaSet> {
        let members: Vec<(NodeId, Engine)> =
            nodes.iter().map(|(id, _, _, e)| (*id, Arc::clone(e))).collect();
        let cfg = ReplicationConfig { lease: Duration::ZERO, ..ReplicationConfig::default() };
        ReplicaSet::new("p", 0, (0, u64::MAX), members, cfg).unwrap()
    }

    #[test]
    fn tick_promotes_past_expired_lease_and_tracks_health() {
        let nodes = faulty_nodes(3);
        let set = replicated_set(&nodes);
        let cp = ControlPlane::new(nodes.clone());
        cp.register_sets("p", &[Arc::clone(&set)]);
        set.apply(0, "p/t", &[(1, Some(b"v".to_vec()))]).unwrap();
        assert!(cp.tick().is_empty(), "healthy leader must not be demoted");

        nodes[0].3.fault_injector().unwrap().crash();
        let reports = cp.tick();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].from, 0);
        assert_eq!(cp.promotions.get(), 1);
        let health = cp.node_health();
        assert!(!health[0].alive);
        assert!(health[1].alive && health[2].alive);
        // Reads against the new epoch see the acked write.
        let e = set.epoch();
        assert_eq!(**set.get(e, "p/t", 1).unwrap().unwrap(), *b"v");
        // Status text names the new leader and the dead node.
        let txt = cp.status_text();
        assert!(txt.contains("db0: id=0 role=database alive=false"), "{txt}");
        assert!(txt.contains("epoch=1"), "{txt}");
    }

    #[test]
    fn tick_revives_followers_and_manual_failover_routes_by_token() {
        let nodes = faulty_nodes(2);
        let set = replicated_set(&nodes);
        let cp = ControlPlane::new(nodes.clone());
        cp.register_sets("p", &[Arc::clone(&set)]);
        set.apply(0, "p/t", &[(1, Some(b"a".to_vec()))]).unwrap();
        nodes[1].3.fault_injector().unwrap().crash();
        assert!(set.apply(0, "p/t", &[(2, Some(b"b".to_vec()))]).is_err());
        nodes[1].3.fault_injector().unwrap().revive();
        cp.tick();
        assert_eq!(set.status().max_lag(), 0, "tick must catch the follower up");

        assert!(cp.failover("nope", 0).is_err());
        assert!(cp.failover("p", 9).is_err());
        let r = cp.failover("p", 0).unwrap();
        assert_eq!(r.to, 1);
        assert_eq!(cp.sets_for("p").len(), 1);
    }

    #[test]
    fn monitor_thread_promotes_without_explicit_ticks() {
        let nodes = faulty_nodes(2);
        let set = replicated_set(&nodes);
        let cp = ControlPlane::new(nodes.clone());
        cp.register_sets("p", &[Arc::clone(&set)]);
        set.apply(0, "p/t", &[(7, Some(b"v".to_vec()))]).unwrap();
        cp.start_monitor(Duration::from_millis(5));
        nodes[0].3.fault_injector().unwrap().crash();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while set.epoch() == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        cp.shutdown();
        assert!(set.epoch() >= 1, "monitor should have promoted");
        let e = set.epoch();
        assert_eq!(**set.get(e, "p/t", 7).unwrap().unwrap(), *b"v");
    }
}
