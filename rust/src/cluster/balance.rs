//! Heat-driven shard splitting and live rebalancing (DESIGN.md §13).
//!
//! The balancer closes the loop that ROADMAP item 1 left open: the
//! per-project [`HeatTracker`] already ranks shards and computes the
//! cumulative-heat-median split key; this module *acts* on it. Each
//! [`Cluster::balance_tick`] inspects every sharded image project and,
//! when the hottest shard's decayed score exceeds the project mean by
//! the configured imbalance ratio, cuts it at the heat median (snapped
//! to a Morton-block boundary so no cuboid run is ever torn across
//! shards) and rehomes the hot half onto the least-loaded database node
//! through [`ShardedEngine`]'s dual-route move window — readers never
//! stall while the bytes travel.
//!
//! The same machinery backs the manual surface (`POST
//! /shards/split/{token}/{shard}/`, `ocpd shards --split TOKEN/SHARD`):
//! a manual split of a *cold* shard falls back to the block-snapped
//! range midpoint, since there is no heat median to cut at.
//!
//! Auto mode (`PUT /shards/auto/{on|off}/`) runs ticks on a background
//! thread holding only a `Weak<Cluster>`, mirroring the control plane's
//! failure monitor: dropping the cluster stops the thread.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::log_warn;
use crate::metrics::Counter;
use crate::obs::heat::snap_split_key;
use crate::shard::{NodeId, ShardMap};
use crate::storage::{Engine, StorageEngine};
use crate::{Error, Result};

use super::replica::{ReplicaSet, ReplicationConfig};
use super::sharded::{ShardMove, ShardedEngine, TopologyStatus};
use super::{Cluster, NodeRole};

/// Splitter policy knobs.
#[derive(Clone, Debug)]
pub struct BalanceConfig {
    /// Split when the hottest shard's score exceeds the project mean by
    /// this factor.
    pub imbalance_ratio: f64,
    /// Ignore shards cooler than this decayed score — a skewed but idle
    /// project is not worth moving bytes for.
    pub min_score: f64,
    /// Never grow a project past this many shards.
    pub max_shards: usize,
    /// Auto-mode tick cadence.
    pub interval: Duration,
    /// Keys copied per move-lock hold — the knob bounding how long a
    /// copy chunk can stall a dual-routed write.
    pub copy_chunk: usize,
}

impl Default for BalanceConfig {
    fn default() -> Self {
        BalanceConfig {
            imbalance_ratio: 2.0,
            min_score: 4096.0,
            max_shards: 64,
            interval: Duration::from_millis(500),
            copy_chunk: 256,
        }
    }
}

/// Balancer counters, exported as `ocpd_shard_*` metrics.
#[derive(Debug, Default)]
pub struct BalanceMetrics {
    /// Planner rounds run (manual or auto).
    pub ticks: Counter,
    /// Splits executed to completion.
    pub splits: Counter,
    /// Split candidates passed over (unsplittable shard or failed move).
    pub skipped: Counter,
}

/// What one executed split did — the `POST /shards/split/` response
/// body and the `ocpd shards` audit trail.
#[derive(Clone, Debug)]
pub struct SplitReport {
    pub token: String,
    /// The shard that was split (it keeps the lower half).
    pub shard: usize,
    /// The Morton key the range was cut at (block-snapped).
    pub cut: u64,
    /// Node now owning the upper half.
    pub target_node: NodeId,
    /// Keys copied through the move window.
    pub keys_moved: u64,
    /// Keys purged from the old owner after commit.
    pub keys_purged: u64,
    /// Map generation installed by the split.
    pub map_version: u64,
}

/// The cluster's splitter state: policy, counters, and the auto-mode
/// switch. One per cluster, embedded in [`Cluster`].
pub struct Balancer {
    pub(super) enabled: AtomicBool,
    pub(super) thread_started: AtomicBool,
    cfg: RwLock<BalanceConfig>,
    pub metrics: BalanceMetrics,
    /// Most recent split reports, oldest first (bounded).
    history: Mutex<Vec<SplitReport>>,
}

impl Balancer {
    pub(super) fn new() -> Self {
        Balancer {
            enabled: AtomicBool::new(false),
            thread_started: AtomicBool::new(false),
            cfg: RwLock::new(BalanceConfig::default()),
            metrics: BalanceMetrics::default(),
            history: Mutex::new(Vec::new()),
        }
    }

    pub fn config(&self) -> BalanceConfig {
        self.cfg.read().unwrap().clone()
    }

    pub fn set_config(&self, cfg: BalanceConfig) {
        *self.cfg.write().unwrap() = cfg;
    }

    /// The most recent split reports, oldest first.
    pub fn recent_splits(&self) -> Vec<SplitReport> {
        self.history.lock().unwrap().clone()
    }

    fn record(&self, report: SplitReport) {
        let mut h = self.history.lock().unwrap();
        h.push(report);
        let overflow = h.len().saturating_sub(32);
        if overflow > 0 {
            h.drain(..overflow);
        }
    }
}

impl Cluster {
    /// The sharded engine behind an image project.
    pub fn sharded_engine(&self, token: &str) -> Result<Arc<ShardedEngine>> {
        self.sharded
            .read()
            .unwrap()
            .get(token)
            .cloned()
            .ok_or_else(|| Error::NotFound(format!("'{token}' is not a sharded image project")))
    }

    /// Topology snapshots of every sharded project, by token (the
    /// `GET /shards/status/` surface).
    pub fn shard_status(&self) -> Vec<(String, TopologyStatus)> {
        let engines: Vec<(String, Arc<ShardedEngine>)> = {
            let guard = self.sharded.read().unwrap();
            guard.iter().map(|(k, e)| (k.clone(), Arc::clone(e))).collect()
        };
        let mut v: Vec<(String, TopologyStatus)> =
            engines.into_iter().map(|(k, e)| (k, e.topology_status())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Human-readable topology report (the `GET /shards/status/` route
    /// body and `ocpd shards`).
    pub fn shard_status_text(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let m = &self.balance.metrics;
        let _ = writeln!(
            s,
            "auto balance: {}  (ticks {}  splits {}  skipped {})",
            if self.auto_balance() { "on" } else { "off" },
            m.ticks.get(),
            m.splits.get(),
            m.skipped.get(),
        );
        for (token, st) in self.shard_status() {
            let moving = match st.moving {
                Some((lo, hi, copied)) => {
                    format!("  moving [{lo}, {hi}) ({copied} keys copied)")
                }
                None => String::new(),
            };
            let _ = writeln!(
                s,
                "project {token}: map v{}  {} shard(s){moving}",
                st.version,
                st.shards.len(),
            );
            for sh in &st.shards {
                let _ = writeln!(
                    s,
                    "  shard {:>3}  [{}, {})  node {}  epoch {}  x{}",
                    sh.shard, sh.lo, sh.hi, sh.node, sh.epoch, sh.replicas,
                );
            }
            let _ = writeln!(
                s,
                "  fence retries {}  map swaps {}  dual writes {}  keys moved {}",
                st.fence_retries, st.map_swaps, st.dual_writes, st.keys_moved,
            );
        }
        for r in self.balance.recent_splits() {
            let _ = writeln!(
                s,
                "split {}/{} at {} -> node {}  ({} moved, {} purged, v{})",
                r.token, r.shard, r.cut, r.target_node, r.keys_moved, r.keys_purged, r.map_version,
            );
        }
        s
    }

    /// Is the background splitter acting on heat evidence?
    pub fn auto_balance(&self) -> bool {
        self.balance.enabled.load(Ordering::Acquire)
    }

    /// Switch auto balancing on or off (`PUT /shards/auto/{on|off}/`).
    /// The first enable starts the background tick thread.
    pub fn set_auto_balance(self: &Arc<Self>, on: bool) -> bool {
        self.balance.enabled.store(on, Ordering::Release);
        if on {
            self.ensure_balance_thread();
        }
        on
    }

    fn ensure_balance_thread(self: &Arc<Self>) {
        if self.balance.thread_started.swap(true, Ordering::AcqRel) {
            return;
        }
        let weak = Arc::downgrade(self);
        let interval = self.balance.config().interval;
        let _ = std::thread::Builder::new().name("ocpd-balance".into()).spawn(move || loop {
            std::thread::sleep(interval);
            let Some(c) = weak.upgrade() else { return };
            if c.balance.enabled.load(Ordering::Acquire) {
                let _ = c.balance_tick();
            }
        });
    }

    /// One planner round over every sharded project: split the hottest
    /// shard of any project whose heat skew crosses the imbalance
    /// ratio. Returns the splits performed (usually zero or one).
    pub fn balance_tick(&self) -> Vec<SplitReport> {
        self.balance.metrics.ticks.inc();
        let cfg = self.balance.config();
        let tokens: Vec<String> = {
            let guard = self.sharded.read().unwrap();
            let mut t: Vec<String> = guard.keys().cloned().collect();
            t.sort();
            t
        };
        let mut out = Vec::new();
        for token in tokens {
            let Ok(eng) = self.sharded_engine(&token) else { continue };
            if eng.move_in_flight().is_some() {
                continue;
            }
            let Some(heat) = self.heat(&token) else { continue };
            let map = eng.map();
            if map.num_shards() >= cfg.max_shards {
                continue;
            }
            let snap = heat.snapshot();
            let Some(hot) = snap.shards.first() else { continue };
            if hot.score < cfg.min_score {
                continue;
            }
            let mean = snap.total_score / snap.shards.len().max(1) as f64;
            if mean > 0.0 && hot.score / mean < cfg.imbalance_ratio {
                continue;
            }
            let Some(cut) = heat.hot_split_key(hot.shard) else {
                // Hot but unsplittable (sub-block shard): nothing to do.
                self.balance.metrics.skipped.inc();
                continue;
            };
            let target = self.split_target_node(&token, &map);
            match self.execute_split(&token, &eng, hot.shard, cut, target) {
                Ok(r) => out.push(r),
                Err(e) => {
                    self.balance.metrics.skipped.inc();
                    log_warn!(
                        target: "balance",
                        "split failed project={token} shard={} cut={cut}: {e}",
                        hot.shard
                    );
                }
            }
        }
        out
    }

    /// Split one shard of one project (`POST
    /// /shards/split/{token}/{shard}/`). Cuts at the heat median when
    /// the shard is hot, else at the block-snapped range midpoint.
    pub fn split_shard(&self, token: &str, shard: usize) -> Result<SplitReport> {
        self.balance.metrics.ticks.inc();
        let eng = self.sharded_engine(token)?;
        let map = eng.map();
        if shard >= map.num_shards() {
            return Err(Error::NotFound(format!(
                "shard {shard} of '{token}' ({} shards)",
                map.num_shards()
            )));
        }
        let (lo, hi) = map.shard_range(shard);
        let heat = self.heat(token);
        // Cold fallback: cut at the range midpoint. The last shard's
        // range is open-ended (`hi == u64::MAX`); clamp it to the real
        // key space so the cut lands inside actual data.
        let data_hi = match &heat {
            Some(h) if hi == u64::MAX => h.total_keys().max(lo + 1),
            _ => hi,
        };
        let cut = heat
            .and_then(|h| h.hot_split_key(shard))
            .or_else(|| snap_split_key(lo + (data_hi - lo) / 2, lo, hi))
            .ok_or_else(|| {
                Error::BadRequest(format!("shard {shard} of '{token}' is too small to split"))
            })?;
        let target = self.split_target_node(token, &map);
        self.execute_split(token, &eng, shard, cut, target)
    }

    /// The database node that should receive a split's hot half: the
    /// one whose led shards carry the least decayed heat (idle nodes
    /// score zero and win immediately).
    fn split_target_node(&self, token: &str, map: &ShardMap) -> NodeId {
        let db = self.nodes_with_role(NodeRole::Database);
        let mut load: HashMap<NodeId, f64> = db.iter().map(|&n| (n, 0.0)).collect();
        if let Some(heat) = self.heat(token) {
            for sh in &heat.snapshot().shards {
                if let Some(&node) = map.nodes().get(sh.shard) {
                    if let Some(l) = load.get_mut(&node) {
                        *l += sh.score;
                    }
                }
            }
        }
        db.into_iter()
            .min_by(|a, b| load[a].partial_cmp(&load[b]).unwrap_or(std::cmp::Ordering::Equal))
            .unwrap_or(0)
    }

    /// A replica set for a freshly split-off shard: leader on `leader`,
    /// followers round-robin over the remaining database nodes, exactly
    /// as [`Cluster::create_image_project`] builds the initial sets.
    fn new_shard_set(
        &self,
        token: &str,
        shard: usize,
        range: (u64, u64),
        leader: NodeId,
    ) -> Result<Arc<ReplicaSet>> {
        let db = self.nodes_with_role(NodeRole::Database);
        let replicas = self.cfg.replicas.min(db.len()).max(1);
        let li = db.iter().position(|&n| n == leader).unwrap_or(0);
        let members: Vec<(NodeId, Engine)> = (0..replicas)
            .map(|j| {
                let node = db[(li + j) % db.len()];
                (node, Arc::clone(&self.nodes[node].engine))
            })
            .collect();
        let rcfg = ReplicationConfig {
            min_acks: self.cfg.min_acks,
            staleness_bound: self.cfg.staleness_bound,
            lease: self.cfg.lease,
            ..ReplicationConfig::default()
        };
        let set = ReplicaSet::new(token, shard, range, members, rcfg)?;
        if let Some(cache) = self.cache(token) {
            set.set_on_promote(Some(Arc::new(move |_epoch| cache.clear())));
        }
        Ok(set)
    }

    /// Execute one split end to end: settle pending writes, open the
    /// dual-route window, copy the hot half to its new owner, commit
    /// the new map, and rebind every living object (heat tracker,
    /// control plane, metrics) to the new generation.
    fn execute_split(
        &self,
        token: &str,
        eng: &Arc<ShardedEngine>,
        shard: usize,
        cut: u64,
        target: NodeId,
    ) -> Result<SplitReport> {
        // Settle pending state first — the WAL'd-project analogue of
        // flush-then-migrate; image shards just sync their engines.
        if self.wal(token).is_some() {
            self.flush_wal(token)?;
        }
        eng.sync()?;
        let map = eng.map();
        let new_map = Arc::new(map.split(shard, cut)?.assign(shard + 1, target)?);
        let upper = new_map.shard_range(shard + 1);
        let old_sets = eng.sets();
        let from = Arc::clone(&old_sets[shard]);
        let to = self.new_shard_set(token, shard + 1, upper, target)?;
        let mut sets = old_sets;
        sets.insert(shard + 1, Arc::clone(&to));
        eng.begin_move(ShardMove {
            range: upper,
            from,
            to,
            scope: token.to_string(),
            map: Arc::clone(&new_map),
            sets,
        })?;
        let moved = match eng.copy_moving(self.balance.config().copy_chunk) {
            Ok(n) => n,
            Err(e) => {
                let _ = eng.abort_move();
                return Err(e);
            }
        };
        let purged = eng.commit_move()?;
        // Rebind the living objects to the new generation.
        if let Some(heat) = self.heat(token) {
            heat.set_shards(Arc::clone(&new_map));
        }
        self.control.unregister_sets(token);
        self.control.register_sets(token, &eng.sets());
        if self.cfg.replicas > 1 {
            self.register_replication_metrics(token, &eng.sets());
        }
        let report = SplitReport {
            token: token.to_string(),
            shard,
            cut,
            target_node: target,
            keys_moved: moved,
            keys_purged: purged,
            map_version: new_map.version(),
        };
        self.balance.metrics.splits.inc();
        self.balance.record(report.clone());
        Ok(report)
    }
}
