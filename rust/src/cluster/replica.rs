//! Replica sets: leader + N followers per shard, shipping the WAL's
//! CRC32-framed records as the replication log (DESIGN.md §10).
//!
//! Every mutation round on a shard is framed exactly like a WAL
//! group-commit chunk ([`crate::wal::record::WalRecord`]): the leader
//! applies it, ships the framed chunk to each live follower (which
//! decodes and applies it whole, in LSN order), and acknowledges the
//! write only once `min_acks` followers have it. A bounded ring of
//! recent chunks lets a briefly-dead follower replay its way back;
//! anything older falls back to a key-range-scoped full resync.
//!
//! Failure handling is epoch-fenced: promotion bumps the set's epoch
//! under the same lock that serializes shipping, so a routed operation
//! carrying a stale epoch gets [`Error::Fenced`] instead of touching a
//! demoted leader — the same generation-counter protocol the cuboid
//! cache uses against stale inserts.

use std::collections::{BTreeMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::metrics::Counter;
use crate::obs::trace;
use crate::shard::NodeId;
use crate::storage::{Blob, Engine};
use crate::wal::record::{decode_chunk, WalRecord};
use crate::{Error, Result};

/// Durability and freshness knobs for one replica set.
#[derive(Clone, Debug)]
pub struct ReplicationConfig {
    /// Follower acknowledgements required before a write is acked
    /// (clamped to the follower count; the default `usize::MAX` means
    /// "every follower that is currently alive").
    pub min_acks: usize,
    /// Permit follower reads lagging the leader by at most this many
    /// records; `None` routes every read to the leader.
    pub staleness_bound: Option<u64>,
    /// Grace period after the last successful leader contact before the
    /// control plane may promote. `Duration::ZERO` promotes on the first
    /// failed probe — the deterministic-test setting.
    pub lease: Duration,
    /// Recent chunks retained for follower catch-up; beyond this the
    /// follower takes a full resync.
    pub retain_chunks: usize,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        ReplicationConfig {
            min_acks: usize::MAX,
            staleness_bound: None,
            lease: Duration::from_millis(500),
            retain_chunks: 64,
        }
    }
}

/// One copy of the shard: a node, its engine, and how far it has applied.
struct Replica {
    node: NodeId,
    engine: Engine,
    applied_lsn: AtomicU64,
    alive: AtomicBool,
}

/// Replication counters, shared with the metrics registry.
#[derive(Debug, Default)]
pub struct ReplicaMetrics {
    /// Chunks successfully applied on a follower.
    pub ships: Counter,
    /// Failed follower applies (the follower is marked dead).
    pub ship_errors: Counter,
    /// Leadership changes on this set.
    pub failovers: Counter,
    /// Operations refused with a stale epoch.
    pub fenced: Counter,
    /// Followers replayed back to currency from the retained ring.
    pub catch_ups: Counter,
    /// Followers rebuilt by full key-range resync.
    pub resyncs: Counter,
    /// Reads served by a follower within the staleness bound.
    pub follower_reads: Counter,
}

/// A framed mutation round kept for follower catch-up.
struct Retained {
    first_lsn: u64,
    last_lsn: u64,
    chunk: Vec<u8>,
}

/// What a promotion did — surfaced by `/cluster/status/` and the tests.
#[derive(Clone, Debug)]
pub struct PromotionReport {
    pub shard: usize,
    pub from: NodeId,
    pub to: NodeId,
    /// The epoch after the bump; readers holding anything older are fenced.
    pub epoch: u64,
    /// Records the old leader had that the new one does not (unacked
    /// writes that died with it).
    pub lost_lsns: u64,
}

/// Point-in-time view of one replica.
#[derive(Clone, Debug)]
pub struct ReplicaStatus {
    pub node: NodeId,
    pub applied_lsn: u64,
    pub alive: bool,
    pub is_leader: bool,
    /// Records behind the leader.
    pub lag: u64,
}

/// Point-in-time view of one replica set.
#[derive(Clone, Debug)]
pub struct ReplicaSetStatus {
    pub shard: usize,
    pub epoch: u64,
    pub leader: NodeId,
    pub next_lsn: u64,
    pub replicas: Vec<ReplicaStatus>,
    pub retained_chunks: usize,
    pub failovers: u64,
    pub fenced: u64,
    pub ships: u64,
    pub ship_errors: u64,
}

impl ReplicaSetStatus {
    /// Worst follower lag, in records.
    pub fn max_lag(&self) -> u64 {
        self.replicas.iter().map(|r| r.lag).max().unwrap_or(0)
    }
}

/// Borrowed view of one mutation round, in each of the three shapes the
/// storage trait produces — lets the solo fast path and the leader apply
/// run straight off the caller's slices with no intermediate copies.
enum MutRef<'a> {
    Puts(&'a [(u64, Vec<u8>)]),
    Deletes(&'a [u64]),
    Mixed(&'a [(u64, Option<Vec<u8>>)]),
}

impl MutRef<'_> {
    fn len(&self) -> usize {
        match self {
            MutRef::Puts(v) => v.len(),
            MutRef::Deletes(v) => v.len(),
            MutRef::Mixed(v) => v.len(),
        }
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Frame the round as CRC32 WAL records starting at `first_lsn` —
    /// the chunk shipped to followers and retained for catch-up.
    fn frame(&self, table: &str, first_lsn: u64) -> Vec<u8> {
        let mut chunk = Vec::new();
        let mut lsn = first_lsn;
        let mut push = |key: u64, value: Option<Vec<u8>>| {
            WalRecord { lsn, table: table.to_string(), key, value }.encode_into(&mut chunk);
            lsn += 1;
        };
        match self {
            MutRef::Puts(items) => {
                for (k, v) in *items {
                    push(*k, Some(v.clone()));
                }
            }
            MutRef::Deletes(keys) => {
                for &k in *keys {
                    push(k, None);
                }
            }
            MutRef::Mixed(muts) => {
                for (k, v) in *muts {
                    push(*k, v.clone());
                }
            }
        }
        chunk
    }

    /// Apply the round directly to one engine.
    fn apply_to(&self, engine: &Engine, table: &str) -> Result<()> {
        match self {
            MutRef::Puts(items) => engine.put_batch(table, items),
            MutRef::Deletes(keys) => engine.delete_batch(table, keys),
            MutRef::Mixed(muts) => ReplicaSet::apply_grouped(engine, table, muts),
        }
    }
}

/// Leader + followers for one shard of one project.
///
/// All mutation, shipping, catch-up, and promotion serialize on one
/// internal lock, so followers observe whole rounds in order and a
/// promotion can never interleave with a half-shipped write.
pub struct ReplicaSet {
    scope: String,
    /// Shard index in the owning map — shifts when a split renumbers the
    /// shards after it, hence atomic.
    shard: AtomicUsize,
    /// Key range `[lo, hi)` this shard owns (`hi == u64::MAX` open-ended)
    /// — bounds full resyncs so shared node engines don't bleed other
    /// shards' data across replicas. A split shrinks it; a merge extends
    /// it (the map is a living object, DESIGN.md §13).
    range: RwLock<(u64, u64)>,
    /// A retired set (its range moved elsewhere and it left the
    /// topology) fences every operation permanently.
    retired: AtomicBool,
    members: Vec<Replica>,
    leader: AtomicUsize,
    epoch: AtomicU64,
    next_lsn: AtomicU64,
    ship_lock: Mutex<()>,
    retained: Mutex<VecDeque<Retained>>,
    lease_expiry: Mutex<Instant>,
    cfg: ReplicationConfig,
    on_promote: RwLock<Option<Arc<dyn Fn(u64) + Send + Sync>>>,
    read_rr: AtomicUsize,
    pub metrics: Arc<ReplicaMetrics>,
}

impl ReplicaSet {
    /// Build a set whose leader is `members[0]`. `scope` is the project
    /// token (resyncs only touch `scope/...` tables); `range` the key
    /// span this shard owns.
    pub fn new(
        scope: &str,
        shard: usize,
        range: (u64, u64),
        members: Vec<(NodeId, Engine)>,
        cfg: ReplicationConfig,
    ) -> Result<Arc<Self>> {
        if members.is_empty() {
            return Err(Error::Cluster("replica set needs >= 1 member".into()));
        }
        let members = members
            .into_iter()
            .map(|(node, engine)| Replica {
                node,
                engine,
                applied_lsn: AtomicU64::new(0),
                alive: AtomicBool::new(true),
            })
            .collect();
        let lease = cfg.lease;
        Ok(Arc::new(ReplicaSet {
            scope: scope.to_string(),
            shard: AtomicUsize::new(shard),
            range: RwLock::new(range),
            retired: AtomicBool::new(false),
            members,
            leader: AtomicUsize::new(0),
            epoch: AtomicU64::new(0),
            next_lsn: AtomicU64::new(1),
            ship_lock: Mutex::new(()),
            retained: Mutex::new(VecDeque::new()),
            lease_expiry: Mutex::new(Instant::now() + lease),
            cfg,
            on_promote: RwLock::new(None),
            read_rr: AtomicUsize::new(0),
            metrics: Arc::new(ReplicaMetrics::default()),
        }))
    }

    /// An unreplicated (single-member) set — the seed topology. Framing
    /// and shipping are skipped entirely on the write path.
    pub fn solo(shard: usize, node: NodeId, engine: Engine) -> Arc<Self> {
        Self::new("", shard, (0, u64::MAX), vec![(node, engine)], ReplicationConfig::default())
            .expect("one member is always valid")
    }

    pub fn shard(&self) -> usize {
        self.shard.load(Ordering::Acquire)
    }

    /// Renumber the set after a split shifts shard indices.
    pub fn set_shard(&self, shard: usize) {
        self.shard.store(shard, Ordering::Release);
    }

    /// Project token this set replicates for ("" = everything).
    pub fn scope(&self) -> &str {
        &self.scope
    }

    /// The key range `[lo, hi)` this set currently owns.
    pub fn range(&self) -> (u64, u64) {
        *self.range.read().unwrap()
    }

    /// Rebound the owned range (split shrinks, merge extends). Bounds
    /// future resyncs and purges; routing is the shard map's business.
    pub fn set_range(&self, range: (u64, u64)) {
        *self.range.write().unwrap() = range;
    }

    /// Current shard-map epoch; bumped by every promotion.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Bump the epoch without changing leadership — fences every
    /// operation still holding the old view (a topology swap uses this
    /// to chase in-flight ops onto the new map). Runs the on-promote
    /// hook so dependent caches fence too. Returns the new epoch.
    pub fn bump_epoch(&self) -> u64 {
        let _g = self.ship_lock.lock().unwrap();
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        let hook = self.on_promote.read().unwrap().clone();
        if let Some(h) = hook {
            h(epoch);
        }
        epoch
    }

    /// Permanently fence the set: its range has moved to another owner
    /// and it left the topology. Idempotent.
    pub fn retire(&self) {
        self.retired.store(true, Ordering::Release);
        self.bump_epoch();
    }

    pub fn is_retired(&self) -> bool {
        self.retired.load(Ordering::Acquire)
    }

    pub fn num_members(&self) -> usize {
        self.members.len()
    }

    pub fn leader_node(&self) -> NodeId {
        self.members[self.leader_idx()].node
    }

    /// Run `hook(new_epoch)` after every promotion (the cluster fences
    /// the project's cuboid cache here).
    pub fn set_on_promote(&self, hook: Option<Arc<dyn Fn(u64) + Send + Sync>>) {
        *self.on_promote.write().unwrap() = hook;
    }

    fn leader_idx(&self) -> usize {
        self.leader.load(Ordering::Acquire)
    }

    /// Refuse the operation if `held` is not the current epoch, or the
    /// set is retired (then nothing is ever current again — `current`
    /// reports the sentinel `u64::MAX` so callers re-route instead of
    /// refreshing).
    fn fence(&self, held: u64) -> Result<()> {
        if self.is_retired() {
            self.metrics.fenced.inc();
            return Err(Error::Fenced { held, current: u64::MAX });
        }
        let current = self.epoch.load(Ordering::Acquire);
        if held != current {
            self.metrics.fenced.inc();
            return Err(Error::Fenced { held, current });
        }
        Ok(())
    }

    fn renew_lease(&self) {
        *self.lease_expiry.lock().unwrap() = Instant::now() + self.cfg.lease;
    }

    /// True once the leader's grace period has run out.
    pub fn lease_expired(&self) -> bool {
        Instant::now() >= *self.lease_expiry.lock().unwrap()
    }

    /// Cheap liveness check: any read the engine can answer.
    fn probe(engine: &Engine) -> bool {
        engine.get("cluster/health", 0).is_ok()
    }

    /// Probe the current leader; a successful probe renews its lease.
    pub fn probe_leader(&self) -> bool {
        let idx = self.leader_idx();
        let ok = Self::probe(&self.members[idx].engine);
        if ok {
            self.members[idx].alive.store(true, Ordering::Release);
            self.renew_lease();
        }
        ok
    }

    /// Replicate a batch of puts. Equivalent to [`ReplicaSet::apply`]
    /// with all-`Some` values, without the intermediate copies (this is
    /// the cutout write path's shape).
    pub fn put_batch(&self, held: u64, table: &str, items: &[(u64, Vec<u8>)]) -> Result<()> {
        self.mutate(held, table, MutRef::Puts(items))
    }

    /// Replicate a batch of deletes (absent keys are no-ops).
    pub fn delete_batch(&self, held: u64, table: &str, keys: &[u64]) -> Result<()> {
        self.mutate(held, table, MutRef::Deletes(keys))
    }

    /// Apply one mixed mutation round (`value: None` deletes).
    pub fn apply(&self, held: u64, table: &str, muts: &[(u64, Option<Vec<u8>>)]) -> Result<()> {
        self.mutate(held, table, MutRef::Mixed(muts))
    }

    /// The write path shared by every mutation shape: leader first, then
    /// ship the framed chunk to every live follower. An error means the
    /// round is *unacknowledged* — on a leader failure the followers
    /// never saw it (fully absent); on an under-replication failure it
    /// is applied but the caller must treat it as unacked.
    fn mutate(&self, held: u64, table: &str, muts: MutRef<'_>) -> Result<()> {
        if muts.is_empty() {
            return Ok(());
        }
        self.fence(held)?;
        if self.members.len() == 1 {
            // Solo fast path: no framing, no shipping — seed behavior.
            // Still serialized with epoch bumps: a topology swap bumps
            // the epoch under this lock, and a write that re-checked the
            // fence after losing the race here could otherwise land
            // unseen by a move's copier.
            let _g = self.ship_lock.lock().unwrap();
            self.fence(held)?;
            return muts.apply_to(&self.members[self.leader_idx()].engine, table);
        }
        let _g = self.ship_lock.lock().unwrap();
        // Promotion bumps the epoch under this same lock — check again.
        self.fence(held)?;
        let leader_idx = self.leader_idx();

        // Frame the round once: the same CRC32 frames the WAL commits.
        let first_lsn = self.next_lsn.load(Ordering::Relaxed);
        let last_lsn = first_lsn + muts.len() as u64 - 1;
        let chunk = muts.frame(table, first_lsn);

        // Leader applies first; if it is down the round dies here and no
        // follower ever sees it.
        let leader = &self.members[leader_idx];
        if let Err(e) = muts.apply_to(&leader.engine, table) {
            if matches!(e, Error::NodeDown(_)) {
                leader.alive.store(false, Ordering::Release);
            }
            return Err(e);
        }
        self.next_lsn.store(last_lsn + 1, Ordering::Relaxed);
        leader.applied_lsn.store(last_lsn, Ordering::Release);
        self.renew_lease();

        // Ship to followers, in member order; a failed apply marks the
        // follower dead until the control plane catches it back up.
        let mut sp = trace::span("cluster", "ship");
        let mut live = 0usize;
        let mut acks = 0usize;
        for (i, m) in self.members.iter().enumerate() {
            if i == leader_idx {
                continue;
            }
            if !m.alive.load(Ordering::Acquire) {
                continue;
            }
            live += 1;
            match Self::apply_chunk(&m.engine, &chunk) {
                Ok(applied) => {
                    m.applied_lsn.store(applied, Ordering::Release);
                    acks += 1;
                    self.metrics.ships.inc();
                }
                Err(_) => {
                    m.alive.store(false, Ordering::Release);
                    self.metrics.ship_errors.inc();
                }
            }
        }
        sp.tag("shard", self.shard().to_string());
        sp.tag("records", muts.len().to_string());
        sp.tag("acks", format!("{acks}/{live}"));
        drop(sp);
        self.retain(first_lsn, last_lsn, chunk);
        // Default `min_acks` (usize::MAX) means "every live follower";
        // an explicit value is a hard floor that dead followers do not
        // excuse — degraded durability surfaces as an error.
        let required = if self.cfg.min_acks == usize::MAX {
            live
        } else {
            self.cfg.min_acks.min(self.members.len() - 1)
        };
        if acks < required {
            return Err(Error::Cluster(format!(
                "shard {}: write under-replicated ({acks}/{required} follower acks)",
                self.shard()
            )));
        }
        Ok(())
    }

    /// Apply a round directly to one engine, grouping puts and deletes
    /// into the engine's batch calls.
    fn apply_grouped(engine: &Engine, table: &str, muts: &[(u64, Option<Vec<u8>>)]) -> Result<()> {
        let mut puts: Vec<(u64, Vec<u8>)> = Vec::new();
        let mut dels: Vec<u64> = Vec::new();
        for (key, value) in muts {
            match value {
                Some(v) => puts.push((*key, v.clone())),
                None => dels.push(*key),
            }
        }
        if !puts.is_empty() {
            engine.put_batch(table, &puts)?;
        }
        if !dels.is_empty() {
            engine.delete_batch(table, &dels)?;
        }
        Ok(())
    }

    /// Decode a framed chunk and apply it whole to a follower engine.
    /// Returns the highest LSN applied.
    fn apply_chunk(engine: &Engine, chunk: &[u8]) -> Result<u64> {
        let d = decode_chunk(chunk);
        if !d.clean {
            return Err(Error::Codec("torn replication chunk".into()));
        }
        let mut last = 0u64;
        let mut by_table: BTreeMap<String, Vec<(u64, Option<Vec<u8>>)>> = BTreeMap::new();
        for r in d.records {
            last = last.max(r.lsn);
            by_table.entry(r.table).or_default().push((r.key, r.value));
        }
        for (table, muts) in by_table {
            Self::apply_grouped(engine, &table, &muts)?;
        }
        Ok(last)
    }

    fn retain(&self, first_lsn: u64, last_lsn: u64, chunk: Vec<u8>) {
        let mut r = self.retained.lock().unwrap();
        r.push_back(Retained { first_lsn, last_lsn, chunk });
        while r.len() > self.cfg.retain_chunks.max(1) {
            r.pop_front();
        }
    }

    /// Pick the replica to serve a read: the leader unless a staleness
    /// bound admits followers, in which case round-robin over every
    /// in-bound live replica. Returns `(index, served_by_follower)`.
    fn read_replica(&self) -> (usize, bool) {
        let leader = self.leader_idx();
        let Some(bound) = self.cfg.staleness_bound else {
            return (leader, false);
        };
        if self.members.len() == 1 {
            return (leader, false);
        }
        let head = self.members[leader].applied_lsn.load(Ordering::Acquire);
        let candidates: Vec<usize> = self
            .members
            .iter()
            .enumerate()
            .filter(|(_, m)| {
                m.alive.load(Ordering::Acquire)
                    && head.saturating_sub(m.applied_lsn.load(Ordering::Acquire)) <= bound
            })
            .map(|(i, _)| i)
            .collect();
        if candidates.is_empty() {
            return (leader, false);
        }
        let pick = candidates[self.read_rr.fetch_add(1, Ordering::Relaxed) % candidates.len()];
        (pick, pick != leader)
    }

    fn reader(&self) -> &Engine {
        let (idx, follower) = self.read_replica();
        if follower {
            self.metrics.follower_reads.inc();
        }
        &self.members[idx].engine
    }

    pub fn get(&self, held: u64, table: &str, key: u64) -> Result<Option<Blob>> {
        self.fence(held)?;
        self.reader().get(table, key)
    }

    pub fn get_batch(&self, held: u64, table: &str, keys: &[u64]) -> Result<Vec<Option<Blob>>> {
        self.fence(held)?;
        self.reader().get_batch(table, keys)
    }

    pub fn get_run(&self, held: u64, table: &str, start: u64, len: u64) -> Result<Vec<(u64, Blob)>> {
        self.fence(held)?;
        self.reader().get_run(table, start, len)
    }

    pub fn keys(&self, held: u64, table: &str) -> Result<Vec<u64>> {
        self.fence(held)?;
        self.reader().keys(table)
    }

    pub fn tables(&self, held: u64) -> Result<Vec<String>> {
        self.fence(held)?;
        self.reader().tables()
    }

    /// Batched read pinned to the leader copy regardless of the
    /// staleness bound — the move copier must see the authoritative
    /// head, or a lagging follower's value could overwrite a fresher
    /// dual-written one on the new owner.
    pub fn get_batch_leader(&self, held: u64, table: &str, keys: &[u64]) -> Result<Vec<Option<Blob>>> {
        self.fence(held)?;
        self.members[self.leader_idx()].engine.get_batch(table, keys)
    }

    /// Key listing pinned to the leader copy (see
    /// [`ReplicaSet::get_batch_leader`]).
    pub fn keys_leader(&self, held: u64, table: &str) -> Result<Vec<u64>> {
        self.fence(held)?;
        self.members[self.leader_idx()].engine.keys(table)
    }

    /// Table listing pinned to the leader copy (see
    /// [`ReplicaSet::get_batch_leader`]).
    pub fn tables_leader(&self, held: u64) -> Result<Vec<String>> {
        self.fence(held)?;
        self.members[self.leader_idx()].engine.tables()
    }

    /// Engines of every member, in member order — the move machinery
    /// checks these against the old owner's members so a purge never
    /// deletes from an engine the new set also lives on.
    pub fn engines(&self) -> Vec<Engine> {
        self.members.iter().map(|m| Arc::clone(&m.engine)).collect()
    }

    /// Delete every key in `[lo, hi)` (`hi == u64::MAX` open-ended) of
    /// the in-scope tables from every member engine not in `exclude` —
    /// the retire step after the range moved to another owner. `scope`
    /// bounds the table set the same way resync's scope does ("" =
    /// every table). Bypasses fencing: a retired set must still purge.
    pub fn purge_range(&self, scope: &str, lo: u64, hi: u64, exclude: &[Engine]) -> Result<u64> {
        let in_range = |k: u64| k >= lo && (k < hi || hi == u64::MAX);
        let prefix = format!("{scope}/");
        let mut purged = 0u64;
        for m in &self.members {
            if exclude.iter().any(|e| Arc::ptr_eq(e, &m.engine)) {
                continue;
            }
            for table in m.engine.tables()? {
                if !scope.is_empty() && !table.starts_with(&prefix) {
                    continue;
                }
                let dead: Vec<u64> =
                    m.engine.keys(&table)?.into_iter().filter(|&k| in_range(k)).collect();
                if !dead.is_empty() {
                    purged += dead.len() as u64;
                    m.engine.delete_batch(&table, &dead)?;
                }
            }
        }
        Ok(purged)
    }

    pub fn sync(&self) -> Result<()> {
        let idx = self.leader_idx();
        self.members[idx].engine.sync()
    }

    /// Promote the most-caught-up live follower to leader, bumping the
    /// epoch so operations routed with the old shard-map view are fenced.
    /// The old leader is marked dead; if it comes back it rejoins as a
    /// follower via catch-up (divergent unacked writes are resynced away).
    pub fn promote(&self) -> Result<PromotionReport> {
        let _g = self.ship_lock.lock().unwrap();
        let old = self.leader_idx();
        let mut best: Option<usize> = None;
        for (i, m) in self.members.iter().enumerate() {
            if i == old {
                continue;
            }
            if !Self::probe(&m.engine) {
                m.alive.store(false, Ordering::Release);
                continue;
            }
            // A probe-ok member is a candidate, but a dead-marked one is
            // NOT flipped alive here — it may have a replication gap that
            // only `catch_up` can close. Only the member we actually
            // promote becomes authoritative (its copy defines the head).
            let lsn = m.applied_lsn.load(Ordering::Acquire);
            let better = match best {
                None => true,
                Some(b) => lsn > self.members[b].applied_lsn.load(Ordering::Acquire),
            };
            if better {
                best = Some(i);
            }
        }
        let Some(new) = best else {
            return Err(Error::Cluster(format!(
                "shard {}: no live follower to promote",
                self.shard()
            )));
        };
        let mut sp = trace::span("cluster", "promote");
        self.members[old].alive.store(false, Ordering::Release);
        self.members[new].alive.store(true, Ordering::Release);
        self.leader.store(new, Ordering::Release);
        let epoch = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
        let new_lsn = self.members[new].applied_lsn.load(Ordering::Acquire);
        let lost = self.next_lsn.load(Ordering::Relaxed).saturating_sub(1).saturating_sub(new_lsn);
        // The new leader's applied LSN is the head now; unacked rounds
        // beyond it are gone, so LSN assignment resumes right after it.
        self.next_lsn.store(new_lsn + 1, Ordering::Relaxed);
        self.metrics.failovers.inc();
        self.renew_lease();
        sp.tag("shard", self.shard().to_string());
        sp.tag("from_node", self.members[old].node.to_string());
        sp.tag("to_node", self.members[new].node.to_string());
        sp.tag("epoch", epoch.to_string());
        let hook = self.on_promote.read().unwrap().clone();
        if let Some(h) = hook {
            h(epoch);
        }
        Ok(PromotionReport {
            shard: self.shard(),
            from: self.members[old].node,
            to: self.members[new].node,
            epoch,
            lost_lsns: lost,
        })
    }

    /// Bring dead-marked followers whose nodes answer probes back into
    /// the set: replay retained chunks when they cover the gap, else a
    /// key-range-scoped full resync from the leader. Divergent followers
    /// (a demoted leader carrying unacked writes) are always resynced.
    pub fn catch_up(&self) {
        let leader_idx = self.leader_idx();
        let any_dead = self
            .members
            .iter()
            .enumerate()
            .any(|(i, m)| i != leader_idx && !m.alive.load(Ordering::Acquire));
        if !any_dead {
            return;
        }
        let _g = self.ship_lock.lock().unwrap();
        let mut sp = trace::span("cluster", "catch_up");
        let leader_idx = self.leader_idx();
        let head = self.members[leader_idx].applied_lsn.load(Ordering::Acquire);
        let mut recovered = 0usize;
        for (i, m) in self.members.iter().enumerate() {
            if i == leader_idx || m.alive.load(Ordering::Acquire) {
                continue;
            }
            if !Self::probe(&m.engine) {
                continue;
            }
            let from = m.applied_lsn.load(Ordering::Acquire);
            let diverged = from > head;
            let covered = {
                let r = self.retained.lock().unwrap();
                from >= head || r.front().is_some_and(|c| c.first_lsn <= from + 1)
            };
            let ok = if !diverged && covered {
                self.replay_retained(m, from)
            } else {
                self.resync(&self.members[leader_idx].engine, m).is_ok()
            };
            if ok {
                m.applied_lsn.store(head, Ordering::Release);
                m.alive.store(true, Ordering::Release);
                recovered += 1;
            }
        }
        sp.tag("shard", self.shard().to_string());
        sp.tag("recovered", recovered.to_string());
    }

    /// Replay retained chunks past `from` onto a follower.
    fn replay_retained(&self, m: &Replica, from: u64) -> bool {
        let r = self.retained.lock().unwrap();
        for c in r.iter() {
            if c.last_lsn <= from {
                continue;
            }
            if Self::apply_chunk(&m.engine, &c.chunk).is_err() {
                return false;
            }
        }
        self.metrics.catch_ups.inc();
        true
    }

    /// Rebuild a follower's copy of this shard from the leader: copy
    /// every in-range key of every in-scope table, delete in-range keys
    /// the leader no longer holds.
    fn resync(&self, leader: &Engine, m: &Replica) -> Result<()> {
        let mut sp = trace::span("cluster", "resync");
        sp.tag("shard", self.shard().to_string());
        sp.tag("node", m.node.to_string());
        let (lo, hi) = self.range();
        let in_range = |k: u64| k >= lo && (k < hi || hi == u64::MAX);
        let prefix = format!("{}/", self.scope);
        for table in leader.tables()? {
            if !self.scope.is_empty() && !table.starts_with(&prefix) {
                continue;
            }
            let keep: Vec<u64> = leader.keys(&table)?.into_iter().filter(|&k| in_range(k)).collect();
            let keep_set: HashSet<u64> = keep.iter().copied().collect();
            let stale: Vec<u64> = m
                .engine
                .keys(&table)
                .unwrap_or_default()
                .into_iter()
                .filter(|&k| in_range(k) && !keep_set.contains(&k))
                .collect();
            if !stale.is_empty() {
                m.engine.delete_batch(&table, &stale)?;
            }
            let mut batch: Vec<(u64, Vec<u8>)> = Vec::new();
            for k in keep {
                if let Some(v) = leader.get(&table, k)? {
                    batch.push((k, (*v).clone()));
                }
                if batch.len() >= 256 {
                    m.engine.put_batch(&table, &batch)?;
                    batch.clear();
                }
            }
            if !batch.is_empty() {
                m.engine.put_batch(&table, &batch)?;
            }
        }
        self.metrics.resyncs.inc();
        Ok(())
    }

    pub fn status(&self) -> ReplicaSetStatus {
        let leader_idx = self.leader_idx();
        let head = self.members[leader_idx].applied_lsn.load(Ordering::Acquire);
        let replicas = self
            .members
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let applied = m.applied_lsn.load(Ordering::Acquire);
                ReplicaStatus {
                    node: m.node,
                    applied_lsn: applied,
                    alive: m.alive.load(Ordering::Acquire),
                    is_leader: i == leader_idx,
                    lag: head.saturating_sub(applied),
                }
            })
            .collect();
        ReplicaSetStatus {
            shard: self.shard(),
            epoch: self.epoch(),
            leader: self.members[leader_idx].node,
            next_lsn: self.next_lsn.load(Ordering::Relaxed),
            replicas,
            retained_chunks: self.retained.lock().unwrap().len(),
            failovers: self.metrics.failovers.get(),
            fenced: self.metrics.fenced.get(),
            ships: self.metrics.ships.get(),
            ship_errors: self.metrics.ship_errors.get(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{MemStore, SimulatedStore};

    fn engines(n: usize) -> Vec<(NodeId, Engine)> {
        (0..n).map(|i| (i, Arc::new(MemStore::new()) as Engine)).collect()
    }

    fn faulty(n: usize, seed: u64) -> Vec<(NodeId, Engine)> {
        (0..n)
            .map(|i| {
                let inner: Engine = Arc::new(MemStore::new());
                (i, Arc::new(SimulatedStore::instant(inner, seed + i as u64)) as Engine)
            })
            .collect()
    }

    fn set(members: Vec<(NodeId, Engine)>) -> Arc<ReplicaSet> {
        ReplicaSet::new("p", 0, (0, u64::MAX), members, ReplicationConfig::default()).unwrap()
    }

    #[test]
    fn writes_replicate_to_all_followers() {
        let members = engines(3);
        let copies: Vec<Engine> = members.iter().map(|(_, e)| Arc::clone(e)).collect();
        let s = set(members);
        s.apply(0, "p/t", &[(1, Some(b"a".to_vec())), (2, Some(b"b".to_vec()))]).unwrap();
        s.apply(0, "p/t", &[(1, None)]).unwrap();
        for e in &copies {
            assert!(e.get("p/t", 1).unwrap().is_none());
            assert_eq!(**e.get("p/t", 2).unwrap().unwrap(), *b"b");
        }
        let st = s.status();
        assert_eq!(st.max_lag(), 0);
        assert_eq!(st.ships, 4); // 2 rounds x 2 followers
    }

    #[test]
    fn stale_epoch_is_fenced_and_reports_current() {
        let s = set(engines(2));
        s.apply(0, "p/t", &[(1, Some(b"v".to_vec()))]).unwrap();
        let r = s.promote().unwrap();
        assert_eq!(r.epoch, 1);
        match s.get(0, "p/t", 1) {
            Err(Error::Fenced { held: 0, current: 1 }) => {}
            other => panic!("expected fence, got {other:?}"),
        }
        assert_eq!(**s.get(1, "p/t", 1).unwrap().unwrap(), *b"v");
        assert!(s.metrics.fenced.get() >= 1);
    }

    #[test]
    fn promotion_picks_most_caught_up_follower() {
        let members = faulty(3, 9);
        let injectors: Vec<Engine> = members.iter().map(|(_, e)| Arc::clone(e)).collect();
        let s = set(members);
        s.apply(0, "p/t", &[(1, Some(b"a".to_vec()))]).unwrap();
        // Kill follower 2, write again: only follower 1 keeps up.
        injectors[2].fault_injector().unwrap().crash();
        let _ = s.apply(0, "p/t", &[(2, Some(b"b".to_vec()))]);
        injectors[2].fault_injector().unwrap().revive();
        // Now kill the leader; promotion must pick node 1, not node 2.
        injectors[0].fault_injector().unwrap().crash();
        let r = s.promote().unwrap();
        assert_eq!(r.from, 0);
        assert_eq!(r.to, 1);
        assert_eq!(**s.get(r.epoch, "p/t", 2).unwrap().unwrap(), *b"b");
    }

    #[test]
    fn dead_follower_catches_up_from_retained_ring() {
        let members = faulty(2, 3);
        let injectors: Vec<Engine> = members.iter().map(|(_, e)| Arc::clone(e)).collect();
        let s = set(members);
        s.apply(0, "p/t", &[(1, Some(b"a".to_vec()))]).unwrap();
        injectors[1].fault_injector().unwrap().crash();
        // Follower down: the write applies on the leader but is unacked.
        assert!(s.apply(0, "p/t", &[(2, Some(b"b".to_vec()))]).is_err());
        assert!(!s.status().replicas[1].alive);
        injectors[1].fault_injector().unwrap().revive();
        s.catch_up();
        let st = s.status();
        assert!(st.replicas[1].alive);
        assert_eq!(st.max_lag(), 0);
        assert!(s.metrics.catch_ups.get() >= 1);
        // And the follower really holds the missed round.
        assert_eq!(**injectors[1].get("p/t", 2).unwrap().unwrap(), *b"b");
    }

    #[test]
    fn follower_past_retention_takes_full_resync() {
        let members = faulty(2, 5);
        let injectors: Vec<Engine> = members.iter().map(|(_, e)| Arc::clone(e)).collect();
        let cfg = ReplicationConfig { retain_chunks: 2, ..ReplicationConfig::default() };
        let s = ReplicaSet::new("p", 0, (0, u64::MAX), members, cfg).unwrap();
        s.apply(0, "p/t", &[(1, Some(b"a".to_vec()))]).unwrap();
        injectors[1].fault_injector().unwrap().crash();
        for k in 2..10u64 {
            let _ = s.apply(0, "p/t", &[(k, Some(vec![k as u8]))]);
        }
        injectors[1].fault_injector().unwrap().revive();
        s.catch_up();
        assert!(s.metrics.resyncs.get() >= 1);
        for k in 1..10u64 {
            assert!(injectors[1].get("p/t", k).unwrap().is_some(), "key {k} missing after resync");
        }
    }

    #[test]
    fn resync_stays_inside_shard_range_and_scope() {
        let members = engines(2);
        let leader = Arc::clone(&members[0].1);
        let follower = Arc::clone(&members[1].1);
        // Out-of-range and out-of-scope data on both nodes (other shards /
        // projects sharing the engines) must survive resync untouched.
        leader.put("p/t", 500, b"other-shard").unwrap();
        follower.put("q/t", 5, b"other-project").unwrap();
        follower.put("p/t", 7, b"stale").unwrap();
        let cfg = ReplicationConfig { retain_chunks: 1, ..ReplicationConfig::default() };
        let s = ReplicaSet::new("p", 0, (0, 100), members, cfg).unwrap();
        s.apply(0, "p/t", &[(3, Some(b"live".to_vec()))]).unwrap();
        // Force the resync path: mark the follower dead and overrun the ring.
        s.members[1].alive.store(false, Ordering::Release);
        s.members[1].applied_lsn.store(0, Ordering::Release);
        let _ = s.apply(0, "p/t", &[(4, Some(b"x".to_vec()))]);
        let _ = s.apply(0, "p/t", &[(5, Some(b"y".to_vec()))]);
        s.catch_up();
        assert_eq!(**follower.get("p/t", 3).unwrap().unwrap(), *b"live");
        assert!(follower.get("p/t", 7).unwrap().is_none(), "stale in-range key must go");
        assert_eq!(**follower.get("q/t", 5).unwrap().unwrap(), *b"other-project");
        assert!(follower.get("p/t", 500).unwrap().is_none(), "out-of-range key must not copy");
        assert_eq!(**leader.get("p/t", 500).unwrap().unwrap(), *b"other-shard");
    }

    #[test]
    fn no_live_follower_means_no_promotion() {
        let members = faulty(2, 1);
        let injectors: Vec<Engine> = members.iter().map(|(_, e)| Arc::clone(e)).collect();
        let s = set(members);
        injectors[1].fault_injector().unwrap().crash();
        assert!(s.promote().is_err());
        let solo = ReplicaSet::solo(0, 0, Arc::new(MemStore::new()));
        assert!(solo.promote().is_err());
    }
}
