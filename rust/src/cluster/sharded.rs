//! [`ShardedEngine`]: application-level sharding as a storage engine.
//!
//! Routes every operation by Morton key to the owning shard's
//! [`ReplicaSet`]. Contiguous-run reads split at shard boundaries
//! ([`ShardMap::route_run`]) so each node still serves its fragment as
//! one streaming I/O — and multi-shard reads (`get_run`, `get_batch`)
//! issue their per-shard requests *concurrently* on scoped threads, so a
//! single cutout fans out across the node set the way the paper's
//! requests fan out across disk arrays (§4.1).
//!
//! The engine holds a *view* of each shard's epoch. Routed operations
//! carry it; when a failover bumps a shard's epoch the set answers
//! [`Error::Fenced`], and the engine refreshes its view and retries the
//! operation once against the new leader — callers above (`CuboidStore`,
//! the write engine) never see the fence.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::metrics::Counter;
use crate::shard::ShardMap;
use crate::storage::{Blob, Engine, IoStats, StorageEngine};
use crate::util::pool::scoped_map;
use crate::{Error, Result};

use super::replica::ReplicaSet;

/// Routes keys across per-shard replica sets by Morton partition.
pub struct ShardedEngine {
    map: ShardMap,
    /// One set per shard, in shard order.
    sets: Vec<Arc<ReplicaSet>>,
    /// This engine's view of each shard's epoch (refreshed on fence).
    epochs: Vec<AtomicU64>,
    /// Operations that were fenced by a failover and transparently
    /// re-routed to the new leader.
    pub fence_retries: Counter,
    stats: IoStats,
}

impl ShardedEngine {
    /// The seed topology: one unreplicated copy per shard. `engines` is
    /// indexed by `NodeId` (the cluster's full node list; only nodes
    /// named in the map are used).
    pub fn new(map: ShardMap, engines: Vec<Engine>) -> Self {
        let sets = map
            .nodes()
            .iter()
            .enumerate()
            .map(|(shard, &node)| ReplicaSet::solo(shard, node, Arc::clone(&engines[node])))
            .collect();
        Self::from_sets(map, sets).expect("solo sets match the map by construction")
    }

    /// A replicated topology: one [`ReplicaSet`] per shard, in shard
    /// order (`map.nodes()[i]` names shard `i`'s initial leader).
    pub fn replicated(map: ShardMap, sets: Vec<Arc<ReplicaSet>>) -> Result<Self> {
        if sets.len() != map.num_shards() {
            return Err(Error::Cluster(format!(
                "{} shards need {} replica sets, got {}",
                map.num_shards(),
                map.num_shards(),
                sets.len()
            )));
        }
        Self::from_sets(map, sets)
    }

    fn from_sets(map: ShardMap, sets: Vec<Arc<ReplicaSet>>) -> Result<Self> {
        let epochs = sets.iter().map(|s| AtomicU64::new(s.epoch())).collect();
        Ok(ShardedEngine {
            map,
            sets,
            epochs,
            fence_retries: Counter::default(),
            stats: IoStats::default(),
        })
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    /// The per-shard replica sets, in shard order.
    pub fn sets(&self) -> &[Arc<ReplicaSet>] {
        &self.sets
    }

    /// Run `f(set, epoch)` against one shard with this engine's epoch
    /// view; on an epoch fence (a failover happened since the view was
    /// taken) refresh the view and retry once against the new leader.
    fn with_set<T>(&self, shard: usize, f: impl Fn(&ReplicaSet, u64) -> Result<T>) -> Result<T> {
        let set = &self.sets[shard];
        let held = self.epochs[shard].load(Ordering::Acquire);
        match f(set, held) {
            Err(Error::Fenced { current, .. }) => {
                self.fence_retries.inc();
                self.epochs[shard].store(current, Ordering::Release);
                f(set, current)
            }
            r => r,
        }
    }

    /// Group keys by owning shard, preserving arrival order within each
    /// group; items carry their original index for reassembly.
    fn by_shard<T: Copy>(
        &self,
        keys: impl Iterator<Item = (T, u64)>,
    ) -> Vec<(usize, Vec<(T, u64)>)> {
        let mut per_shard: Vec<(usize, Vec<(T, u64)>)> = Vec::new();
        for (tag, k) in keys {
            let shard = self.map.shard_for(k);
            match per_shard.iter_mut().find(|(s, _)| *s == shard) {
                Some((_, v)) => v.push((tag, k)),
                None => per_shard.push((shard, vec![(tag, k)])),
            }
        }
        per_shard
    }
}

impl StorageEngine for ShardedEngine {
    fn name(&self) -> &str {
        "sharded"
    }

    fn get(&self, table: &str, key: u64) -> Result<Option<Blob>> {
        let shard = self.map.shard_for(key);
        let v = self.with_set(shard, |set, e| set.get(e, table, key))?;
        if let Some(v) = &v {
            self.stats.record_read(v.len());
        } else {
            self.stats.record_miss();
        }
        Ok(v)
    }

    fn put(&self, table: &str, key: u64, value: &[u8]) -> Result<()> {
        self.stats.record_write(value.len());
        let shard = self.map.shard_for(key);
        let item = [(key, value.to_vec())];
        self.with_set(shard, |set, e| set.put_batch(e, table, &item))
    }

    fn delete(&self, table: &str, key: u64) -> Result<()> {
        let shard = self.map.shard_for(key);
        self.with_set(shard, |set, e| set.delete_batch(e, table, &[key]))
    }

    fn delete_batch(&self, table: &str, keys: &[u64]) -> Result<()> {
        // Group by shard, one batched delete per shard, issued
        // concurrently when several shards are involved (mirrors
        // `get_batch`).
        let per_shard = self.by_shard(keys.iter().map(|&k| ((), k)));
        let n = per_shard.len();
        let results = scoped_map(n, n, |p| {
            let (shard, items) = &per_shard[p];
            let mut sp = crate::obs::trace::span("shard", "delete_batch");
            sp.tag("shard", shard.to_string());
            sp.tag("keys", items.len().to_string());
            let ks: Vec<u64> = items.iter().map(|(_, k)| *k).collect();
            self.with_set(*shard, |set, e| set.delete_batch(e, table, &ks))
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    fn get_batch(&self, table: &str, keys: &[u64]) -> Result<Vec<Option<Blob>>> {
        // Group by shard, one batched request per shard — issued
        // concurrently when several shards are involved — then
        // reassemble in request order.
        let mut out = vec![None; keys.len()];
        let per_shard = self.by_shard(keys.iter().copied().enumerate());
        let n = per_shard.len();
        let fetched = scoped_map(n, n, |p| {
            let (shard, items) = &per_shard[p];
            let mut sp = crate::obs::trace::span("shard", "get_batch");
            sp.tag("shard", shard.to_string());
            sp.tag("keys", items.len().to_string());
            let ks: Vec<u64> = items.iter().map(|(_, k)| *k).collect();
            self.with_set(*shard, |set, e| set.get_batch(e, table, &ks))
        });
        for ((_, items), vs) in per_shard.iter().zip(fetched) {
            for ((i, _), v) in items.iter().zip(vs?) {
                out[*i] = v;
            }
        }
        Ok(out)
    }

    fn put_batch(&self, table: &str, items: &[(u64, Vec<u8>)]) -> Result<()> {
        let mut per_shard: Vec<(usize, Vec<(u64, Vec<u8>)>)> = Vec::new();
        for (k, v) in items {
            self.stats.record_write(v.len());
            let shard = self.map.shard_for(*k);
            match per_shard.iter_mut().find(|(s, _)| *s == shard) {
                Some((_, batch)) => batch.push((*k, v.clone())),
                None => per_shard.push((shard, vec![(*k, v.clone())])),
            }
        }
        for (shard, batch) in per_shard {
            let mut sp = crate::obs::trace::span("shard", "put_batch");
            sp.tag("shard", shard.to_string());
            sp.tag("keys", batch.len().to_string());
            self.with_set(shard, |set, e| set.put_batch(e, table, &batch))?;
        }
        Ok(())
    }

    fn get_run(&self, table: &str, start: u64, len: u64) -> Result<Vec<(u64, Blob)>> {
        self.stats.record_run_read();
        // A run that straddles shard boundaries reads each shard's
        // fragment concurrently; per-shard sub-runs are disjoint and
        // ascending, so concatenation preserves key order.
        let parts = self.map.route_run(start, len);
        let n = parts.len();
        let fetched = scoped_map(n, n, |p| {
            let (_, lo, l) = parts[p];
            let shard = self.map.shard_for(lo);
            let mut sp = crate::obs::trace::span("shard", "get_run");
            sp.tag("shard", shard.to_string());
            sp.tag("len", l.to_string());
            self.with_set(shard, |set, e| set.get_run(e, table, lo, l))
        });
        let mut out = Vec::new();
        for part in fetched {
            out.extend(part?);
        }
        Ok(out)
    }

    fn keys(&self, table: &str) -> Result<Vec<u64>> {
        // Shards own disjoint ascending key ranges, so per-shard keys
        // (filtered to the shard's own range — replica sets of different
        // shards may share node engines) concatenate already sorted.
        let mut all = Vec::new();
        for (shard, _) in self.sets.iter().enumerate() {
            let ks = self.with_set(shard, |set, e| set.keys(e, table))?;
            all.extend(ks.into_iter().filter(|&k| self.map.shard_for(k) == shard));
        }
        Ok(all)
    }

    fn tables(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for (shard, _) in self.sets.iter().enumerate() {
            names.extend(self.with_set(shard, |set, e| set.tables(e))?);
        }
        names.sort();
        names.dedup();
        Ok(names)
    }

    fn stats(&self) -> &IoStats {
        &self.stats
    }

    fn sync(&self) -> Result<()> {
        for set in &self.sets {
            set.sync()?;
        }
        Ok(())
    }

    fn shard_map(&self) -> Option<&ShardMap> {
        Some(&self.map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::replica::ReplicationConfig;
    use crate::shard::NodeId;
    use crate::storage::MemStore;
    use std::sync::Arc;

    fn sharded(n: usize, total: u64) -> (ShardedEngine, Vec<Arc<MemStore>>) {
        let mems: Vec<Arc<MemStore>> = (0..n).map(|_| Arc::new(MemStore::new())).collect();
        let engines: Vec<Engine> = mems.iter().map(|m| Arc::clone(m) as Engine).collect();
        let map = ShardMap::even(total, (0..n).collect()).unwrap();
        (ShardedEngine::new(map, engines), mems)
    }

    /// `n` shards over `n` nodes, every shard replicated on all nodes
    /// (leader = its map node, followers = the rest, round-robin).
    fn replicated(n: usize, total: u64, replicas: usize) -> (ShardedEngine, Vec<Engine>) {
        let engines: Vec<Engine> = (0..n).map(|_| Arc::new(MemStore::new()) as Engine).collect();
        let map = ShardMap::even(total, (0..n).collect()).unwrap();
        let sets = (0..n)
            .map(|shard| {
                let members: Vec<(NodeId, Engine)> = (0..replicas.min(n))
                    .map(|j| {
                        let node = (shard + j) % n;
                        (node, Arc::clone(&engines[node]))
                    })
                    .collect();
                ReplicaSet::new(
                    "t",
                    shard,
                    map.shard_range(shard),
                    members,
                    ReplicationConfig::default(),
                )
                .unwrap()
            })
            .collect();
        (ShardedEngine::replicated(map, sets).unwrap(), engines)
    }

    #[test]
    fn conformance() {
        let (s, _) = sharded(3, 1 << 20);
        crate::storage::tests::conformance(&s);
    }

    #[test]
    fn replicated_conformance() {
        // The full engine contract holds with every shard on 2 copies.
        let (s, _) = replicated(3, 1 << 20, 2);
        crate::storage::tests::conformance(&s);
    }

    #[test]
    fn keys_distribute_across_nodes() {
        let (s, mems) = sharded(4, 1024);
        for k in 0..1024u64 {
            s.put("t", k, &k.to_le_bytes()).unwrap();
        }
        for (i, m) in mems.iter().enumerate() {
            let n = m.stored_values();
            assert_eq!(n, 256, "node {i} has {n}");
        }
        // Round trip through routing.
        for k in (0..1024u64).step_by(97) {
            assert_eq!(**s.get("t", k).unwrap().unwrap(), k.to_le_bytes());
        }
    }

    #[test]
    fn run_read_spans_shards() {
        let (s, _) = sharded(2, 100); // split at 50
        let items: Vec<(u64, Vec<u8>)> = (45..55).map(|k| (k, vec![k as u8])).collect();
        s.put_batch("t", &items).unwrap();
        let run = s.get_run("t", 45, 10).unwrap();
        assert_eq!(run.len(), 10);
        assert_eq!(run.first().unwrap().0, 45);
        assert_eq!(run.last().unwrap().0, 54);
    }

    #[test]
    fn batch_get_preserves_request_order() {
        let (s, _) = sharded(3, 300);
        for k in 0..300u64 {
            s.put("t", k, &[k as u8]).unwrap();
        }
        let keys = vec![250u64, 10, 150, 11, 299];
        let got = s.get_batch("t", &keys).unwrap();
        for (k, v) in keys.iter().zip(got) {
            assert_eq!(*v.unwrap(), vec![*k as u8]);
        }
    }

    #[test]
    fn keys_stay_deduped_when_replicas_share_nodes() {
        // 2 shards x 2 replicas over 2 nodes: every node engine holds
        // both shards' data; keys() must report each key exactly once.
        let (s, _) = replicated(2, 100, 2);
        let items: Vec<(u64, Vec<u8>)> = (0..100).map(|k| (k, vec![k as u8])).collect();
        s.put_batch("t/a", &items).unwrap();
        assert_eq!(s.keys("t/a").unwrap(), (0..100).collect::<Vec<u64>>());
        let run = s.get_run("t/a", 0, 100).unwrap();
        assert_eq!(run.len(), 100);
    }

    #[test]
    fn fenced_ops_retry_transparently_after_failover() {
        let (s, _) = replicated(2, 100, 2);
        s.put("t/a", 10, b"before").unwrap();
        // Fail shard 0 over; the engine's epoch view is now stale.
        let report = s.sets()[0].promote().unwrap();
        assert_eq!(report.epoch, 1);
        // The next routed ops fence internally, refresh, and succeed.
        assert_eq!(**s.get("t/a", 10).unwrap().unwrap(), *b"before");
        s.put("t/a", 11, b"after").unwrap();
        assert_eq!(**s.get("t/a", 11).unwrap().unwrap(), *b"after");
        assert!(s.fence_retries.get() >= 1, "retry counter should have moved");
        // Shard 1 was untouched: no fence on its path.
        s.put("t/a", 60, b"s1").unwrap();
        assert_eq!(**s.get("t/a", 60).unwrap().unwrap(), *b"s1");
    }
}
