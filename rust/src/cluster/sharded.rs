//! [`ShardedEngine`]: application-level sharding as a storage engine.
//!
//! Routes every operation by Morton key to the owning shard's
//! [`ReplicaSet`]. Contiguous-run reads split at shard boundaries
//! ([`ShardMap::route_run`]) so each node still serves its fragment as
//! one streaming I/O — and multi-shard reads (`get_run`, `get_batch`)
//! issue their per-shard requests *concurrently* on scoped threads, so a
//! single cutout fans out across the node set the way the paper's
//! requests fan out across disk arrays (§4.1).
//!
//! The shard map is a *living object* (DESIGN.md §13). The engine holds
//! an immutable [`Topology`] snapshot — map + replica sets + its view of
//! each set's epoch — behind one swap pointer. Routed operations clone
//! the snapshot, so an in-flight batched read can never observe a torn
//! map; a split or live move builds the next generation and swaps it in
//! whole. Fencing closes the gap: when a failover (or a topology swap)
//! bumps a set's epoch, the set answers [`Error::Fenced`], and the
//! engine re-reads the current topology and retries — callers above
//! (`CuboidStore`, the write engine) never see the fence.
//!
//! A live move runs through a **dual-route window** ([`ShardMove`]):
//! while the moving range is copied to its new owner, writes apply to
//! both owners (old first — the old set stays authoritative) and reads
//! prefer the new owner with fallback to the old, so the move never
//! stalls readers. The copy is chunked under the window's lock, which
//! serializes each copy chunk against dual writes — a chunk can never
//! overwrite a newer dual-written value.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::metrics::Counter;
use crate::shard::{NodeId, ShardMap};
use crate::storage::{Blob, Engine, IoStats, StorageEngine};
use crate::util::pool::scoped_map;
use crate::{Error, Result};

use super::replica::ReplicaSet;

/// Everything a live move needs, built by the planner up front: the
/// range changing owner, the sets on each side of the window, and the
/// topology to install when the copy commits.
pub struct ShardMove {
    /// Keys being rehomed, `[lo, hi)` (`hi == u64::MAX` open-ended).
    pub range: (u64, u64),
    /// The set the range is leaving. Stays in the new topology when the
    /// move is a split (it keeps the other half); retired when it is not.
    pub from: Arc<ReplicaSet>,
    /// The set receiving the copy and the window's dual writes.
    pub to: Arc<ReplicaSet>,
    /// Project scope: only tables named `{scope}/...` are copied and
    /// purged. Empty copies everything (engines dedicated to one
    /// project).
    pub scope: String,
    /// The map to install at commit (a newer version than the current).
    pub map: Arc<ShardMap>,
    /// One set per shard of `map`, in shard order.
    pub sets: Vec<Arc<ReplicaSet>>,
}

/// An open dual-route window.
struct MoveState {
    mv: ShardMove,
    /// Serializes copy chunks against dual writes: each chunk reads the
    /// old owner and writes the new one under this lock, so it can never
    /// overwrite a newer value a dual write put there.
    lock: Mutex<()>,
    /// Keys copied so far (the `/shards/status/` progress gauge).
    copied: AtomicU64,
}

/// One immutable generation of the sharding: map, sets, and this
/// engine's view of each set's epoch. Swapped whole; ops run against
/// the snapshot they loaded.
struct Topology {
    map: Arc<ShardMap>,
    sets: Vec<Arc<ReplicaSet>>,
    epochs: Vec<AtomicU64>,
    moving: Option<Arc<MoveState>>,
}

impl Topology {
    fn snapshot(map: Arc<ShardMap>, sets: Vec<Arc<ReplicaSet>>, moving: Option<Arc<MoveState>>) -> Arc<Self> {
        let epochs = sets.iter().map(|s| AtomicU64::new(s.epoch())).collect();
        Arc::new(Topology { map, sets, epochs, moving })
    }

    fn refresh_epochs(&self) {
        for (e, s) in self.epochs.iter().zip(&self.sets) {
            e.store(s.epoch(), Ordering::Release);
        }
    }

    /// Is `key` inside the open move window?
    fn in_window(&self, key: u64) -> bool {
        match &self.moving {
            Some(ms) => {
                let (lo, hi) = ms.mv.range;
                key >= lo && (key < hi || hi == u64::MAX)
            }
            None => false,
        }
    }
}

/// One shard's row of `GET /shards/status/`.
#[derive(Clone, Debug)]
pub struct ShardInfo {
    pub shard: usize,
    pub lo: u64,
    pub hi: u64,
    pub node: NodeId,
    pub epoch: u64,
    pub replicas: usize,
}

/// Point-in-time view of the sharding topology.
#[derive(Clone, Debug)]
pub struct TopologyStatus {
    /// Map generation ([`ShardMap::version`]).
    pub version: u64,
    pub shards: Vec<ShardInfo>,
    /// The open move window, if any: `(lo, hi, keys_copied)`.
    pub moving: Option<(u64, u64, u64)>,
    pub fence_retries: u64,
    pub map_swaps: u64,
    pub dual_writes: u64,
    pub keys_moved: u64,
}

/// Routes keys across per-shard replica sets by Morton partition.
pub struct ShardedEngine {
    topo: RwLock<Arc<Topology>>,
    /// Operations that were fenced (failover or topology swap) and
    /// transparently re-routed.
    pub fence_retries: Counter,
    /// Topology generations installed ([`ShardedEngine::commit_move`]).
    pub map_swaps: Counter,
    /// Write rounds mirrored to a move's new owner during the window.
    pub dual_writes: Counter,
    /// Keys shipped to new owners by committed moves.
    pub keys_moved: Counter,
    /// Run after every topology swap with the new map version — the
    /// cluster fences the project's cuboid cache here, mirroring the
    /// replica sets' on-promote hook.
    on_map_change: RwLock<Option<Arc<dyn Fn(u64) + Send + Sync>>>,
    stats: IoStats,
}

impl ShardedEngine {
    /// The seed topology: one unreplicated copy per shard. `engines` is
    /// indexed by `NodeId` (the cluster's full node list; only nodes
    /// named in the map are used).
    pub fn new(map: ShardMap, engines: Vec<Engine>) -> Self {
        let sets = map
            .nodes()
            .iter()
            .enumerate()
            .map(|(shard, &node)| {
                let set = ReplicaSet::solo(shard, node, Arc::clone(&engines[node]));
                set.set_range(map.shard_range(shard));
                set
            })
            .collect();
        Self::from_sets(map, sets).expect("solo sets match the map by construction")
    }

    /// A replicated topology: one [`ReplicaSet`] per shard, in shard
    /// order (`map.nodes()[i]` names shard `i`'s initial leader).
    pub fn replicated(map: ShardMap, sets: Vec<Arc<ReplicaSet>>) -> Result<Self> {
        if sets.len() != map.num_shards() {
            return Err(Error::Cluster(format!(
                "{} shards need {} replica sets, got {}",
                map.num_shards(),
                map.num_shards(),
                sets.len()
            )));
        }
        Self::from_sets(map, sets)
    }

    fn from_sets(map: ShardMap, sets: Vec<Arc<ReplicaSet>>) -> Result<Self> {
        Ok(ShardedEngine {
            topo: RwLock::new(Topology::snapshot(Arc::new(map), sets, None)),
            fence_retries: Counter::default(),
            map_swaps: Counter::default(),
            dual_writes: Counter::default(),
            keys_moved: Counter::default(),
            on_map_change: RwLock::new(None),
            stats: IoStats::default(),
        })
    }

    fn topo(&self) -> Arc<Topology> {
        Arc::clone(&self.topo.read().unwrap())
    }

    /// The current map generation (a consistent snapshot; the next swap
    /// does not mutate it).
    pub fn map(&self) -> Arc<ShardMap> {
        Arc::clone(&self.topo().map)
    }

    /// The per-shard replica sets of the current generation, in shard
    /// order.
    pub fn sets(&self) -> Vec<Arc<ReplicaSet>> {
        self.topo().sets.clone()
    }

    /// Run `hook(map_version)` after every topology swap.
    pub fn set_on_map_change(&self, hook: Option<Arc<dyn Fn(u64) + Send + Sync>>) {
        *self.on_map_change.write().unwrap() = hook;
    }

    /// Run `f` against a topology snapshot; on an epoch fence that the
    /// per-shard retry could not absorb (a topology swap retired or
    /// re-routed the shard), re-read the current topology and run the
    /// whole operation again.
    fn run_op<T>(&self, f: impl Fn(&Topology) -> Result<T>) -> Result<T> {
        let mut tries = 0;
        loop {
            let topo = self.topo();
            match f(&topo) {
                Err(Error::Fenced { .. }) if tries < 3 => {
                    tries += 1;
                    self.fence_retries.inc();
                    topo.refresh_epochs();
                }
                r => return r,
            }
        }
    }

    /// Run `f(set, epoch)` against one shard of `topo`. Fences propagate
    /// to [`ShardedEngine::run_op`] — a fence can mean a promotion *or*
    /// a move window opening, and only re-reading the topology handles
    /// both (an in-place retry would run an op that routed before the
    /// window straight past the dual-write path).
    fn call<T>(
        &self,
        topo: &Topology,
        shard: usize,
        f: impl Fn(&ReplicaSet, u64) -> Result<T>,
    ) -> Result<T> {
        let set = &topo.sets[shard];
        let held = topo.epochs[shard].load(Ordering::Acquire);
        f(set, held)
    }

    /// Mirror a write round into the move window's new owner, serialized
    /// with the copier. The old owner was already written — it stays
    /// authoritative until commit — so a hit on the new owner always
    /// equals the old owner's current value.
    fn dual_write(
        &self,
        topo: &Topology,
        table: &str,
        muts: &[(u64, Option<Vec<u8>>)],
    ) -> Result<()> {
        let Some(ms) = &topo.moving else { return Ok(()) };
        let moving: Vec<(u64, Option<Vec<u8>>)> = muts
            .iter()
            .filter(|(k, _)| topo.in_window(*k))
            .cloned()
            .collect();
        if moving.is_empty() {
            return Ok(());
        }
        let _g = ms.lock.lock().unwrap();
        self.dual_writes.inc();
        ms.mv.to.apply(ms.mv.to.epoch(), table, &moving)
    }

    /// Group keys by owning shard, preserving arrival order within each
    /// group; items carry their original index for reassembly.
    fn by_shard<T: Copy>(
        map: &ShardMap,
        keys: impl Iterator<Item = (T, u64)>,
    ) -> Vec<(usize, Vec<(T, u64)>)> {
        let mut per_shard: Vec<(usize, Vec<(T, u64)>)> = Vec::new();
        for (tag, k) in keys {
            let shard = map.shard_for(k);
            match per_shard.iter_mut().find(|(s, _)| *s == shard) {
                Some((_, v)) => v.push((tag, k)),
                None => per_shard.push((shard, vec![(tag, k)])),
            }
        }
        per_shard
    }

    // ------------------------------------------------------------------
    // Live moves (split / merge / rebalance)
    // ------------------------------------------------------------------

    /// Open the dual-route window for `mv`. From here until
    /// [`ShardedEngine::commit_move`], writes into `mv.range` land on
    /// both owners and reads prefer the new one. In-flight operations
    /// that routed before the window are fenced by an epoch bump on the
    /// old owner, so none of their writes can slip past the copier.
    pub fn begin_move(&self, mv: ShardMove) -> Result<()> {
        let (lo, hi) = mv.range;
        if lo >= hi {
            return Err(Error::Cluster(format!("move: empty range [{lo}, {hi})")));
        }
        {
            let mut guard = self.topo.write().unwrap();
            let cur = Arc::clone(&guard);
            if cur.moving.is_some() {
                return Err(Error::Cluster("a shard move is already in flight".into()));
            }
            let shard = cur.map.shard_for(lo);
            let (slo, shi) = cur.map.shard_range(shard);
            if lo < slo || hi > shi {
                return Err(Error::Cluster(format!(
                    "move: range [{lo}, {hi}) is not within one current shard ([{slo}, {shi}))"
                )));
            }
            if !Arc::ptr_eq(&cur.sets[shard], &mv.from) {
                return Err(Error::Cluster("move: `from` is not the range's current owner".into()));
            }
            if mv.map.version() <= cur.map.version() {
                return Err(Error::Cluster(format!(
                    "move: target map version {} is not newer than current {}",
                    mv.map.version(),
                    cur.map.version()
                )));
            }
            if mv.sets.len() != mv.map.num_shards() {
                return Err(Error::Cluster(format!(
                    "move: target map has {} shards but {} sets were supplied",
                    mv.map.num_shards(),
                    mv.sets.len()
                )));
            }
            let from = Arc::clone(&mv.from);
            let ms = Arc::new(MoveState {
                mv,
                lock: Mutex::new(()),
                copied: AtomicU64::new(0),
            });
            *guard = Topology::snapshot(Arc::clone(&cur.map), cur.sets.clone(), Some(ms));
            drop(guard);
            // Fence writers that routed before the window opened: their
            // retry re-reads the topology and dual-routes.
            from.bump_epoch();
        }
        Ok(())
    }

    /// The open move window's range, if any.
    pub fn move_in_flight(&self) -> Option<(u64, u64)> {
        self.topo().moving.as_ref().map(|ms| ms.mv.range)
    }

    /// Copy the moving range to its new owner in chunks of `chunk`
    /// keys. Each chunk reads the old owner's *leader* and writes the
    /// new set under the window lock, so dual writes interleave between
    /// chunks (bounded reader/writer stall) but never lose to a chunk.
    pub fn copy_moving(&self, chunk: usize) -> Result<u64> {
        let topo = self.topo();
        let Some(ms) = &topo.moving else {
            return Err(Error::Cluster("no shard move in flight".into()));
        };
        let (lo, hi) = ms.mv.range;
        let in_range = |k: u64| k >= lo && (k < hi || hi == u64::MAX);
        let from = &ms.mv.from;
        let to = &ms.mv.to;
        let prefix = format!("{}/", ms.mv.scope);
        let mut moved = 0u64;
        let mut sp = crate::obs::trace::span("shard", "move_copy");
        for table in from.tables_leader(from.epoch())? {
            if !ms.mv.scope.is_empty() && !table.starts_with(&prefix) {
                continue;
            }
            let keys: Vec<u64> = from
                .keys_leader(from.epoch(), &table)?
                .into_iter()
                .filter(|&k| in_range(k))
                .collect();
            for ck in keys.chunks(chunk.max(1)) {
                let _g = ms.lock.lock().unwrap();
                let vals = from.get_batch_leader(from.epoch(), &table, ck)?;
                let items: Vec<(u64, Vec<u8>)> = ck
                    .iter()
                    .zip(vals)
                    .filter_map(|(&k, v)| v.map(|v| (k, (*v).clone())))
                    .collect();
                if !items.is_empty() {
                    to.put_batch(to.epoch(), &table, &items)?;
                    moved += items.len() as u64;
                    ms.copied.fetch_add(items.len() as u64, Ordering::Relaxed);
                }
            }
        }
        sp.tag("range", format!("[{lo}, {hi})"));
        sp.tag("keys", moved.to_string());
        Ok(moved)
    }

    /// Close the window: install the move's topology, fence stragglers,
    /// retire the old owner if it left the topology, and purge the moved
    /// range from it. Returns the keys purged from the old owner.
    pub fn commit_move(&self) -> Result<u64> {
        let ms = {
            let mut guard = self.topo.write().unwrap();
            let cur = Arc::clone(&guard);
            let Some(ms) = cur.moving.clone() else {
                return Err(Error::Cluster("no shard move in flight".into()));
            };
            // Align every set's identity with the new map before it
            // serves: shard indices shift by one past a split point.
            for (i, set) in ms.mv.sets.iter().enumerate() {
                set.set_shard(i);
                set.set_range(ms.mv.map.shard_range(i));
            }
            *guard = Topology::snapshot(
                Arc::clone(&ms.mv.map),
                ms.mv.sets.clone(),
                None,
            );
            ms
        };
        self.map_swaps.inc();
        self.keys_moved.add(ms.copied.load(Ordering::Relaxed));
        // Stragglers holding window-era views dual-wrote the new owner,
        // so nothing is lost; the bump just hurries them onto the new
        // topology. A set that left the topology is retired outright —
        // it fences everything from now on.
        ms.mv.from.bump_epoch();
        let stays = ms.mv.sets.iter().any(|s| Arc::ptr_eq(s, &ms.mv.from));
        if !stays {
            ms.mv.from.retire();
        }
        // Drop the moved keys from the old owner — but never from an
        // engine the new set also lives on (shared nodes keep the data
        // as legitimate members of the new set).
        let purged =
            ms.mv.from.purge_range(&ms.mv.scope, ms.mv.range.0, ms.mv.range.1, &ms.mv.to.engines())?;
        let hook = self.on_map_change.read().unwrap().clone();
        if let Some(h) = hook {
            h(ms.mv.map.version());
        }
        let mut sp = crate::obs::trace::span("shard", "move_commit");
        sp.tag("version", ms.mv.map.version().to_string());
        sp.tag("purged", purged.to_string());
        Ok(purged)
    }

    /// Abandon an open window without installing its topology (the
    /// planner's error path). Data already copied stays on the target —
    /// it is value-identical — but routing never changes.
    pub fn abort_move(&self) -> Result<()> {
        let mut guard = self.topo.write().unwrap();
        let cur = Arc::clone(&guard);
        if cur.moving.is_none() {
            return Err(Error::Cluster("no shard move in flight".into()));
        }
        *guard = Topology::snapshot(Arc::clone(&cur.map), cur.sets.clone(), None);
        Ok(())
    }

    /// Point-in-time topology view (the `GET /shards/status/` surface).
    pub fn topology_status(&self) -> TopologyStatus {
        let topo = self.topo();
        let shards = (0..topo.map.num_shards())
            .map(|s| {
                let (lo, hi) = topo.map.shard_range(s);
                ShardInfo {
                    shard: s,
                    lo,
                    hi,
                    node: topo.sets[s].leader_node(),
                    epoch: topo.sets[s].epoch(),
                    replicas: topo.sets[s].num_members(),
                }
            })
            .collect();
        TopologyStatus {
            version: topo.map.version(),
            shards,
            moving: topo.moving.as_ref().map(|ms| {
                (ms.mv.range.0, ms.mv.range.1, ms.copied.load(Ordering::Relaxed))
            }),
            fence_retries: self.fence_retries.get(),
            map_swaps: self.map_swaps.get(),
            dual_writes: self.dual_writes.get(),
            keys_moved: self.keys_moved.get(),
        }
    }
}

impl StorageEngine for ShardedEngine {
    fn name(&self) -> &str {
        "sharded"
    }

    fn get(&self, table: &str, key: u64) -> Result<Option<Blob>> {
        let v = self.run_op(|topo| {
            // Dual-route window: prefer the new owner, fall back to the
            // old — a hit on the new owner always equals the old one's
            // current value.
            if topo.in_window(key) {
                if let Some(ms) = &topo.moving {
                    if let Some(v) = ms.mv.to.get(ms.mv.to.epoch(), table, key)? {
                        return Ok(Some(v));
                    }
                }
            }
            let shard = topo.map.shard_for(key);
            self.call(topo, shard, |set, e| set.get(e, table, key))
        })?;
        if let Some(v) = &v {
            self.stats.record_read(v.len());
        } else {
            self.stats.record_miss();
        }
        Ok(v)
    }

    fn put(&self, table: &str, key: u64, value: &[u8]) -> Result<()> {
        self.stats.record_write(value.len());
        self.run_op(|topo| {
            let shard = topo.map.shard_for(key);
            let item = [(key, value.to_vec())];
            self.call(topo, shard, |set, e| set.put_batch(e, table, &item))?;
            self.dual_write(topo, table, &[(key, Some(value.to_vec()))])
        })
    }

    fn delete(&self, table: &str, key: u64) -> Result<()> {
        self.run_op(|topo| {
            let shard = topo.map.shard_for(key);
            self.call(topo, shard, |set, e| set.delete_batch(e, table, &[key]))?;
            self.dual_write(topo, table, &[(key, None)])
        })
    }

    fn delete_batch(&self, table: &str, keys: &[u64]) -> Result<()> {
        // Group by shard, one batched delete per shard, issued
        // concurrently when several shards are involved (mirrors
        // `get_batch`).
        self.run_op(|topo| {
            let per_shard = Self::by_shard(&topo.map, keys.iter().map(|&k| ((), k)));
            let n = per_shard.len();
            let results = scoped_map(n, n, |p| {
                let (shard, items) = &per_shard[p];
                let mut sp = crate::obs::trace::span("shard", "delete_batch");
                sp.tag("shard", shard.to_string());
                sp.tag("keys", items.len().to_string());
                let ks: Vec<u64> = items.iter().map(|(_, k)| *k).collect();
                self.call(topo, *shard, |set, e| set.delete_batch(e, table, &ks))
            });
            for r in results {
                r?;
            }
            let muts: Vec<(u64, Option<Vec<u8>>)> =
                keys.iter().map(|&k| (k, None)).collect();
            self.dual_write(topo, table, &muts)
        })
    }

    fn get_batch(&self, table: &str, keys: &[u64]) -> Result<Vec<Option<Blob>>> {
        // Group by shard, one batched request per shard — issued
        // concurrently when several shards are involved — then
        // reassemble in request order.
        self.run_op(|topo| {
            let mut out = vec![None; keys.len()];
            let per_shard = Self::by_shard(&topo.map, keys.iter().copied().enumerate());
            let n = per_shard.len();
            let fetched = scoped_map(n, n, |p| {
                let (shard, items) = &per_shard[p];
                let mut sp = crate::obs::trace::span("shard", "get_batch");
                sp.tag("shard", shard.to_string());
                sp.tag("keys", items.len().to_string());
                let ks: Vec<u64> = items.iter().map(|(_, k)| *k).collect();
                self.call(topo, *shard, |set, e| set.get_batch(e, table, &ks))
            });
            for ((_, items), vs) in per_shard.iter().zip(fetched) {
                for ((i, _), v) in items.iter().zip(vs?) {
                    out[*i] = v;
                }
            }
            // Dual-route window: overlay the new owner's values for
            // moving keys (prefer new, fall back to the old result).
            if let Some(ms) = &topo.moving {
                let moving: Vec<(usize, u64)> = keys
                    .iter()
                    .enumerate()
                    .filter(|(_, &k)| topo.in_window(k))
                    .map(|(i, &k)| (i, k))
                    .collect();
                if !moving.is_empty() {
                    let ks: Vec<u64> = moving.iter().map(|(_, k)| *k).collect();
                    let vs = ms.mv.to.get_batch(ms.mv.to.epoch(), table, &ks)?;
                    for ((i, _), v) in moving.iter().zip(vs) {
                        if v.is_some() {
                            out[*i] = v;
                        }
                    }
                }
            }
            Ok(out)
        })
    }

    fn put_batch(&self, table: &str, items: &[(u64, Vec<u8>)]) -> Result<()> {
        for (_, v) in items {
            self.stats.record_write(v.len());
        }
        self.run_op(|topo| {
            let mut per_shard: Vec<(usize, Vec<(u64, Vec<u8>)>)> = Vec::new();
            for (k, v) in items {
                let shard = topo.map.shard_for(*k);
                match per_shard.iter_mut().find(|(s, _)| *s == shard) {
                    Some((_, batch)) => batch.push((*k, v.clone())),
                    None => per_shard.push((shard, vec![(*k, v.clone())])),
                }
            }
            for (shard, batch) in per_shard {
                let mut sp = crate::obs::trace::span("shard", "put_batch");
                sp.tag("shard", shard.to_string());
                sp.tag("keys", batch.len().to_string());
                self.call(topo, shard, |set, e| set.put_batch(e, table, &batch))?;
            }
            let muts: Vec<(u64, Option<Vec<u8>>)> =
                items.iter().map(|(k, v)| (*k, Some(v.clone()))).collect();
            self.dual_write(topo, table, &muts)
        })
    }

    fn get_run(&self, table: &str, start: u64, len: u64) -> Result<Vec<(u64, Blob)>> {
        self.stats.record_run_read();
        // A run that straddles shard boundaries reads each shard's
        // fragment concurrently; per-shard sub-runs are disjoint and
        // ascending, so concatenation preserves key order.
        self.run_op(|topo| {
            let parts = topo.map.route_run(start, len);
            let n = parts.len();
            let fetched = scoped_map(n, n, |p| {
                let (_, lo, l) = parts[p];
                let shard = topo.map.shard_for(lo);
                let mut sp = crate::obs::trace::span("shard", "get_run");
                sp.tag("shard", shard.to_string());
                sp.tag("len", l.to_string());
                self.call(topo, shard, |set, e| set.get_run(e, table, lo, l))
            });
            let mut out = Vec::new();
            for part in fetched {
                out.extend(part?);
            }
            // Dual-route window: overlay the new owner's fragment of the
            // run, preferring its values where both owners hold a key.
            if let Some(ms) = &topo.moving {
                let (mlo, mhi) = ms.mv.range;
                let end = start.saturating_add(len);
                let olo = start.max(mlo);
                let ohi = end.min(mhi);
                if olo < ohi {
                    let fresh = ms.mv.to.get_run(ms.mv.to.epoch(), table, olo, ohi - olo)?;
                    if !fresh.is_empty() {
                        let mut merged: std::collections::BTreeMap<u64, Blob> =
                            out.into_iter().collect();
                        merged.extend(fresh);
                        out = merged.into_iter().collect();
                    } else {
                        return Ok(out);
                    }
                }
            }
            Ok(out)
        })
    }

    fn keys(&self, table: &str) -> Result<Vec<u64>> {
        // Shards own disjoint ascending key ranges, so per-shard keys
        // (filtered to the shard's own range — replica sets of different
        // shards may share node engines) concatenate already sorted.
        self.run_op(|topo| {
            let mut all = Vec::new();
            for shard in 0..topo.sets.len() {
                let ks = self.call(topo, shard, |set, e| set.keys(e, table))?;
                all.extend(ks.into_iter().filter(|&k| topo.map.shard_for(k) == shard));
            }
            Ok(all)
        })
    }

    fn tables(&self) -> Result<Vec<String>> {
        self.run_op(|topo| {
            let mut names = Vec::new();
            for shard in 0..topo.sets.len() {
                names.extend(self.call(topo, shard, |set, e| set.tables(e))?);
            }
            names.sort();
            names.dedup();
            Ok(names)
        })
    }

    fn stats(&self) -> &IoStats {
        &self.stats
    }

    fn sync(&self) -> Result<()> {
        for set in &self.topo().sets {
            set.sync()?;
        }
        Ok(())
    }

    fn shard_map(&self) -> Option<Arc<ShardMap>> {
        Some(self.map())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::replica::ReplicationConfig;
    use crate::shard::NodeId;
    use crate::storage::MemStore;
    use std::sync::Arc;

    fn sharded(n: usize, total: u64) -> (ShardedEngine, Vec<Arc<MemStore>>) {
        let mems: Vec<Arc<MemStore>> = (0..n).map(|_| Arc::new(MemStore::new())).collect();
        let engines: Vec<Engine> = mems.iter().map(|m| Arc::clone(m) as Engine).collect();
        let map = ShardMap::even(total, (0..n).collect()).unwrap();
        (ShardedEngine::new(map, engines), mems)
    }

    /// `n` shards over `n` nodes, every shard replicated on all nodes
    /// (leader = its map node, followers = the rest, round-robin).
    fn replicated(n: usize, total: u64, replicas: usize) -> (ShardedEngine, Vec<Engine>) {
        let engines: Vec<Engine> = (0..n).map(|_| Arc::new(MemStore::new()) as Engine).collect();
        let map = ShardMap::even(total, (0..n).collect()).unwrap();
        let sets = (0..n)
            .map(|shard| {
                let members: Vec<(NodeId, Engine)> = (0..replicas.min(n))
                    .map(|j| {
                        let node = (shard + j) % n;
                        (node, Arc::clone(&engines[node]))
                    })
                    .collect();
                ReplicaSet::new(
                    "t",
                    shard,
                    map.shard_range(shard),
                    members,
                    ReplicationConfig::default(),
                )
                .unwrap()
            })
            .collect();
        (ShardedEngine::replicated(map, sets).unwrap(), engines)
    }

    /// A split-and-move of shard `shard` cut at `at`, upper half to a
    /// brand-new engine; returns the target engine.
    fn split_move(s: &ShardedEngine, shard: usize, at: u64) -> Arc<MemStore> {
        let target = Arc::new(MemStore::new());
        let map = s.map();
        let new_map = map.split(shard, at).unwrap();
        let new_node = new_map.nodes().iter().copied().max().unwrap_or(0) + 1;
        let new_map = new_map.assign(shard + 1, new_node).unwrap();
        let from = Arc::clone(&s.sets()[shard]);
        let to = ReplicaSet::solo(shard + 1, new_node, Arc::clone(&target) as Engine);
        to.set_range(new_map.shard_range(shard + 1));
        let mut sets = s.sets();
        sets.insert(shard + 1, Arc::clone(&to));
        s.begin_move(ShardMove {
            range: new_map.shard_range(shard + 1),
            from,
            to,
            scope: String::new(),
            map: Arc::new(new_map),
            sets,
        })
        .unwrap();
        s.copy_moving(64).unwrap();
        s.commit_move().unwrap();
        target
    }

    #[test]
    fn conformance() {
        let (s, _) = sharded(3, 1 << 20);
        crate::storage::tests::conformance(&s);
    }

    #[test]
    fn replicated_conformance() {
        // The full engine contract holds with every shard on 2 copies.
        let (s, _) = replicated(3, 1 << 20, 2);
        crate::storage::tests::conformance(&s);
    }

    #[test]
    fn keys_distribute_across_nodes() {
        let (s, mems) = sharded(4, 1024);
        for k in 0..1024u64 {
            s.put("t", k, &k.to_le_bytes()).unwrap();
        }
        for (i, m) in mems.iter().enumerate() {
            let n = m.stored_values();
            assert_eq!(n, 256, "node {i} has {n}");
        }
        // Round trip through routing.
        for k in (0..1024u64).step_by(97) {
            assert_eq!(**s.get("t", k).unwrap().unwrap(), k.to_le_bytes());
        }
    }

    #[test]
    fn run_read_spans_shards() {
        let (s, _) = sharded(2, 100); // split at 50
        let items: Vec<(u64, Vec<u8>)> = (45..55).map(|k| (k, vec![k as u8])).collect();
        s.put_batch("t", &items).unwrap();
        let run = s.get_run("t", 45, 10).unwrap();
        assert_eq!(run.len(), 10);
        assert_eq!(run.first().unwrap().0, 45);
        assert_eq!(run.last().unwrap().0, 54);
    }

    #[test]
    fn batch_get_preserves_request_order() {
        let (s, _) = sharded(3, 300);
        for k in 0..300u64 {
            s.put("t", k, &[k as u8]).unwrap();
        }
        let keys = vec![250u64, 10, 150, 11, 299];
        let got = s.get_batch("t", &keys).unwrap();
        for (k, v) in keys.iter().zip(got) {
            assert_eq!(*v.unwrap(), vec![*k as u8]);
        }
    }

    #[test]
    fn keys_stay_deduped_when_replicas_share_nodes() {
        // 2 shards x 2 replicas over 2 nodes: every node engine holds
        // both shards' data; keys() must report each key exactly once.
        let (s, _) = replicated(2, 100, 2);
        let items: Vec<(u64, Vec<u8>)> = (0..100).map(|k| (k, vec![k as u8])).collect();
        s.put_batch("t/a", &items).unwrap();
        assert_eq!(s.keys("t/a").unwrap(), (0..100).collect::<Vec<u64>>());
        let run = s.get_run("t/a", 0, 100).unwrap();
        assert_eq!(run.len(), 100);
    }

    #[test]
    fn fenced_ops_retry_transparently_after_failover() {
        let (s, _) = replicated(2, 100, 2);
        s.put("t/a", 10, b"before").unwrap();
        // Fail shard 0 over; the engine's epoch view is now stale.
        let report = s.sets()[0].promote().unwrap();
        assert_eq!(report.epoch, 1);
        // The next routed ops fence internally, refresh, and succeed.
        assert_eq!(**s.get("t/a", 10).unwrap().unwrap(), *b"before");
        s.put("t/a", 11, b"after").unwrap();
        assert_eq!(**s.get("t/a", 11).unwrap().unwrap(), *b"after");
        assert!(s.fence_retries.get() >= 1, "retry counter should have moved");
        // Shard 1 was untouched: no fence on its path.
        s.put("t/a", 60, b"s1").unwrap();
        assert_eq!(**s.get("t/a", 60).unwrap().unwrap(), *b"s1");
    }

    #[test]
    fn split_move_rehomes_the_upper_half() {
        let (s, mems) = sharded(2, 128); // shards [0,64), [64,128)
        for k in 0..128u64 {
            s.put("t", k, &k.to_le_bytes()).unwrap();
        }
        let target = split_move(&s, 1, 96);
        // New topology: 3 shards, the hot tail on the new node.
        let map = s.map();
        assert_eq!(map.num_shards(), 3);
        assert_eq!(map.version(), 2);
        assert_eq!(map.shard_range(2), (96, u64::MAX));
        // Every key still reads back through the engine.
        for k in 0..128u64 {
            assert_eq!(**s.get("t", k).unwrap().unwrap(), k.to_le_bytes(), "key {k}");
        }
        // The moved half lives on the target, and only there.
        assert_eq!(target.keys("t").unwrap(), (96..128).collect::<Vec<u64>>());
        assert_eq!(mems[1].keys("t").unwrap(), (64..96).collect::<Vec<u64>>());
        assert_eq!(s.keys("t").unwrap(), (0..128).collect::<Vec<u64>>());
        // Writes route to the new owner now.
        s.put("t", 100, b"fresh").unwrap();
        assert_eq!(**target.get("t", 100).unwrap().unwrap(), *b"fresh");
        assert_eq!(mems[1].get("t", 100).unwrap(), None);
    }

    #[test]
    fn dual_route_window_serves_both_sides() {
        let (s, mems) = sharded(1, 128);
        for k in 0..128u64 {
            s.put("t", k, b"old").unwrap();
        }
        // Open the window but do NOT copy yet: reads of the moving half
        // must fall back to the old owner.
        let target = Arc::new(MemStore::new());
        let map = s.map();
        let new_map = map.split(0, 64).unwrap().assign(1, 1).unwrap();
        let from = Arc::clone(&s.sets()[0]);
        let to = ReplicaSet::solo(1, 1, Arc::clone(&target) as Engine);
        to.set_range(new_map.shard_range(1));
        let sets = vec![Arc::clone(&from), Arc::clone(&to)];
        s.begin_move(ShardMove {
            range: (64, u64::MAX),
            from,
            to,
            scope: String::new(),
            map: Arc::new(new_map),
            sets,
        })
        .unwrap();
        assert_eq!(s.move_in_flight(), Some((64, u64::MAX)));
        assert_eq!(**s.get("t", 100).unwrap().unwrap(), *b"old");
        // A write during the window lands on BOTH owners.
        s.put("t", 100, b"both").unwrap();
        assert_eq!(**mems[0].get("t", 100).unwrap().unwrap(), *b"both");
        assert_eq!(**target.get("t", 100).unwrap().unwrap(), *b"both");
        // Reads prefer the new owner (which only has the dual write).
        assert_eq!(**s.get("t", 100).unwrap().unwrap(), *b"both");
        assert_eq!(**s.get("t", 80).unwrap().unwrap(), *b"old", "fallback to old owner");
        // Deletes dual-route too.
        s.delete("t", 101).unwrap();
        assert_eq!(s.get("t", 101).unwrap(), None);
        // Run reads across the boundary merge both owners.
        let run = s.get_run("t", 60, 50).unwrap();
        assert_eq!(run.len(), 49, "key 101 deleted");
        // Copy + commit: everything converges on the new owner.
        s.copy_moving(16).unwrap();
        s.commit_move().unwrap();
        assert_eq!(**s.get("t", 100).unwrap().unwrap(), *b"both");
        assert_eq!(s.get("t", 101).unwrap(), None);
        assert_eq!(mems[0].keys("t").unwrap().last().copied(), Some(63));
        assert!(s.dual_writes.get() >= 2);
        assert_eq!(s.map_swaps.get(), 1);
    }

    #[test]
    fn begin_move_rejects_bad_plans() {
        let (s, _) = sharded(2, 128);
        let map = s.map();
        let from = Arc::clone(&s.sets()[0]);
        let to = ReplicaSet::solo(2, 2, Arc::new(MemStore::new()) as Engine);
        let plan = |range, map: Arc<ShardMap>, sets| ShardMove {
            range,
            from: Arc::clone(&from),
            to: Arc::clone(&to),
            scope: String::new(),
            map,
            sets,
        };
        // Empty range.
        let m2 = Arc::new(map.split(0, 32).unwrap());
        let sets3 = {
            let mut v = s.sets();
            v.insert(1, Arc::clone(&to));
            v
        };
        assert!(s.begin_move(plan((32, 32), Arc::clone(&m2), sets3.clone())).is_err());
        // Range straddling a shard boundary.
        assert!(s.begin_move(plan((32, 100), Arc::clone(&m2), sets3.clone())).is_err());
        // Stale map version.
        assert!(s.begin_move(plan((32, 64), Arc::new((*map).clone()), sets3.clone())).is_err());
        // Set count mismatch.
        assert!(s.begin_move(plan((32, 64), Arc::clone(&m2), s.sets())).is_err());
        // A valid plan is accepted exactly once while in flight.
        assert!(s.begin_move(plan((32, 64), Arc::clone(&m2), sets3.clone())).is_ok());
        assert!(s.begin_move(plan((32, 64), m2, sets3)).is_err(), "window already open");
        s.abort_move().unwrap();
        assert_eq!(s.move_in_flight(), None);
    }

    #[test]
    fn merge_move_returns_a_shard_home() {
        let (s, mems) = sharded(2, 128); // shards [0,64) on 0, [64,128) on 1
        for k in 0..128u64 {
            s.put("t", k, &k.to_le_bytes()).unwrap();
        }
        // Move shard 1's range back onto node 0's set, then merge.
        let map = s.map();
        let from = Arc::clone(&s.sets()[1]);
        let to = Arc::clone(&s.sets()[0]);
        to.set_range((0, u64::MAX));
        let merged = Arc::new(map.merge(0, 1).unwrap());
        s.begin_move(ShardMove {
            range: (64, u64::MAX),
            from: Arc::clone(&from),
            to: Arc::clone(&to),
            scope: String::new(),
            map: Arc::clone(&merged),
            sets: vec![to],
        })
        .unwrap();
        s.copy_moving(32).unwrap();
        s.commit_move().unwrap();
        assert_eq!(s.map().num_shards(), 1);
        assert!(from.is_retired());
        // All 128 keys on node 0; node 1 purged.
        assert_eq!(mems[0].keys("t").unwrap().len(), 128);
        assert!(mems[1].keys("t").unwrap().is_empty());
        for k in (0..128u64).step_by(17) {
            assert_eq!(**s.get("t", k).unwrap().unwrap(), k.to_le_bytes());
        }
        // A straggler write that would have routed to the retired set
        // re-routes transparently.
        s.put("t", 100, b"rerouted").unwrap();
        assert_eq!(**mems[0].get("t", 100).unwrap().unwrap(), *b"rerouted");
    }
}
