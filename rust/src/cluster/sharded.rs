//! [`ShardedEngine`]: application-level sharding as a storage engine.
//!
//! Wraps the full node engine list plus a [`ShardMap`]; every operation
//! routes by Morton key to the owning node. Contiguous-run reads split at
//! shard boundaries ([`ShardMap::route_run`]) so each node still serves
//! its fragment as one streaming I/O — and multi-node reads (`get_run`,
//! `get_batch`) issue their per-node requests *concurrently* on scoped
//! threads, so a single cutout fans out across the node set the way the
//! paper's requests fan out across disk arrays (§4.1).

use crate::shard::ShardMap;
use crate::storage::{Blob, Engine, IoStats, StorageEngine};
use crate::util::pool::scoped_map;
use crate::Result;

/// Routes keys across per-node engines by Morton partition.
pub struct ShardedEngine {
    map: ShardMap,
    /// Indexed by NodeId (the cluster's full node list; only nodes named
    /// in the map are used).
    engines: Vec<Engine>,
    stats: IoStats,
}

impl ShardedEngine {
    pub fn new(map: ShardMap, engines: Vec<Engine>) -> Self {
        ShardedEngine { map, engines, stats: IoStats::default() }
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }
}

impl StorageEngine for ShardedEngine {
    fn name(&self) -> &str {
        "sharded"
    }

    fn get(&self, table: &str, key: u64) -> Result<Option<Blob>> {
        let v = self.engines[self.map.node_for(key)].get(table, key)?;
        if let Some(v) = &v {
            self.stats.record_read(v.len());
        } else {
            self.stats.record_miss();
        }
        Ok(v)
    }

    fn put(&self, table: &str, key: u64, value: &[u8]) -> Result<()> {
        self.stats.record_write(value.len());
        self.engines[self.map.node_for(key)].put(table, key, value)
    }

    fn delete(&self, table: &str, key: u64) -> Result<()> {
        self.engines[self.map.node_for(key)].delete(table, key)
    }

    fn delete_batch(&self, table: &str, keys: &[u64]) -> Result<()> {
        // Group by node, one batched delete per node, issued concurrently
        // when several nodes are involved (mirrors `get_batch`).
        let mut per_node: Vec<(usize, Vec<u64>)> = Vec::new();
        for &k in keys {
            let node = self.map.node_for(k);
            match per_node.iter_mut().find(|(n, _)| *n == node) {
                Some((_, v)) => v.push(k),
                None => per_node.push((node, vec![k])),
            }
        }
        let n = per_node.len();
        let results = scoped_map(n, n, |p| {
            let (node, ks) = &per_node[p];
            self.engines[*node].delete_batch(table, ks)
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    fn get_batch(&self, table: &str, keys: &[u64]) -> Result<Vec<Option<Blob>>> {
        // Group by node, one batched request per node — issued
        // concurrently when several nodes are involved — then reassemble
        // in request order.
        let mut out = vec![None; keys.len()];
        let mut per_node: Vec<(usize, Vec<(usize, u64)>)> = Vec::new();
        for (i, &k) in keys.iter().enumerate() {
            let node = self.map.node_for(k);
            match per_node.iter_mut().find(|(n, _)| *n == node) {
                Some((_, v)) => v.push((i, k)),
                None => per_node.push((node, vec![(i, k)])),
            }
        }
        let n = per_node.len();
        let fetched = scoped_map(n, n, |p| {
            let (node, items) = &per_node[p];
            let mut sp = crate::obs::trace::span("shard", "get_batch");
            sp.tag("node", node.to_string());
            sp.tag("keys", items.len().to_string());
            let ks: Vec<u64> = items.iter().map(|(_, k)| *k).collect();
            self.engines[*node].get_batch(table, &ks)
        });
        for ((_, items), vs) in per_node.iter().zip(fetched) {
            for ((i, _), v) in items.iter().zip(vs?) {
                out[*i] = v;
            }
        }
        Ok(out)
    }

    fn put_batch(&self, table: &str, items: &[(u64, Vec<u8>)]) -> Result<()> {
        let mut per_node: Vec<(usize, Vec<(u64, Vec<u8>)>)> = Vec::new();
        for (k, v) in items {
            self.stats.record_write(v.len());
            let node = self.map.node_for(*k);
            match per_node.iter_mut().find(|(n, _)| *n == node) {
                Some((_, batch)) => batch.push((*k, v.clone())),
                None => per_node.push((node, vec![(*k, v.clone())])),
            }
        }
        for (node, batch) in per_node {
            let mut sp = crate::obs::trace::span("shard", "put_batch");
            sp.tag("node", node.to_string());
            sp.tag("keys", batch.len().to_string());
            self.engines[node].put_batch(table, &batch)?;
        }
        Ok(())
    }

    fn get_run(&self, table: &str, start: u64, len: u64) -> Result<Vec<(u64, Blob)>> {
        self.stats.record_run_read();
        // A run that straddles shard boundaries reads each node's
        // fragment concurrently; per-shard sub-runs are disjoint and
        // ascending, so concatenation preserves key order.
        let parts = self.map.route_run(start, len);
        let n = parts.len();
        let fetched = scoped_map(n, n, |p| {
            let (node, lo, l) = parts[p];
            let mut sp = crate::obs::trace::span("shard", "get_run");
            sp.tag("node", node.to_string());
            sp.tag("len", l.to_string());
            self.engines[node].get_run(table, lo, l)
        });
        let mut out = Vec::new();
        for part in fetched {
            out.extend(part?);
        }
        Ok(out)
    }

    fn keys(&self, table: &str) -> Result<Vec<u64>> {
        let mut all = Vec::new();
        // Each node holds a disjoint key range; collect and sort.
        let mut seen = std::collections::HashSet::new();
        for &node in self.map.nodes() {
            if seen.insert(node) {
                all.extend(self.engines[node].keys(table)?);
            }
        }
        all.sort_unstable();
        all
            .windows(2)
            .all(|w| w[0] < w[1])
            .then_some(())
            .ok_or_else(|| crate::Error::Storage("duplicate keys across shards".into()))?;
        Ok(all)
    }

    fn tables(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for &node in self.map.nodes() {
            if seen.insert(node) {
                names.extend(self.engines[node].tables()?);
            }
        }
        names.sort();
        names.dedup();
        Ok(names)
    }

    fn stats(&self) -> &IoStats {
        &self.stats
    }

    fn sync(&self) -> Result<()> {
        for e in &self.engines {
            e.sync()?;
        }
        Ok(())
    }

    fn shard_map(&self) -> Option<&ShardMap> {
        Some(&self.map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use std::sync::Arc;

    fn sharded(n: usize, total: u64) -> (ShardedEngine, Vec<Arc<MemStore>>) {
        let mems: Vec<Arc<MemStore>> = (0..n).map(|_| Arc::new(MemStore::new())).collect();
        let engines: Vec<Engine> = mems.iter().map(|m| Arc::clone(m) as Engine).collect();
        let map = ShardMap::even(total, (0..n).collect()).unwrap();
        (ShardedEngine::new(map, engines), mems)
    }

    #[test]
    fn conformance() {
        let (s, _) = sharded(3, 1 << 20);
        crate::storage::tests::conformance(&s);
    }

    #[test]
    fn keys_distribute_across_nodes() {
        let (s, mems) = sharded(4, 1024);
        for k in 0..1024u64 {
            s.put("t", k, &k.to_le_bytes()).unwrap();
        }
        for (i, m) in mems.iter().enumerate() {
            let n = m.stored_values();
            assert_eq!(n, 256, "node {i} has {n}");
        }
        // Round trip through routing.
        for k in (0..1024u64).step_by(97) {
            assert_eq!(**s.get("t", k).unwrap().unwrap(), k.to_le_bytes());
        }
    }

    #[test]
    fn run_read_spans_shards() {
        let (s, _) = sharded(2, 100); // split at 50
        let items: Vec<(u64, Vec<u8>)> = (45..55).map(|k| (k, vec![k as u8])).collect();
        s.put_batch("t", &items).unwrap();
        let run = s.get_run("t", 45, 10).unwrap();
        assert_eq!(run.len(), 10);
        assert_eq!(run.first().unwrap().0, 45);
        assert_eq!(run.last().unwrap().0, 54);
    }

    #[test]
    fn batch_get_preserves_request_order() {
        let (s, _) = sharded(3, 300);
        for k in 0..300u64 {
            s.put("t", k, &[k as u8]).unwrap();
        }
        let keys = vec![250u64, 10, 150, 11, 299];
        let got = s.get_batch("t", &keys).unwrap();
        for (k, v) in keys.iter().zip(got) {
            assert_eq!(*v.unwrap(), vec![*k as u8]);
        }
    }
}
