//! The OCP Data Cluster: heterogeneous node roles, workload placement,
//! application-level sharding, and project migration (§4.1, Figure 7).
//!
//! * **Database nodes** store image and annotation cuboids for cutout —
//!   read-optimized (RAID-6 arrays in the paper).
//! * **SSD I/O nodes** absorb the random small writes of parallel vision
//!   pipelines; projects migrate off them ("dump and restore") once no
//!   longer actively written.
//! * **Application servers** do all request parsing/assembly; here the
//!   [`crate::web`] front end plays that role over this struct.
//!
//! Placement policy ("Data Distribution"): concurrent workloads land on
//! distinct nodes — cutout reads on database nodes, annotation writes on
//! SSD nodes. Image cuboids shard across database nodes by partitioning
//! the Morton curve; sharding is application-level via [`ShardedEngine`].

mod sharded;

pub use sharded::ShardedEngine;

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::annotation::AnnotationDb;
use crate::chunkstore::CuboidStore;
use crate::core::{Dataset, Project};
use crate::cutout::CutoutService;
use crate::shard::{NodeId, ShardMap};
use crate::storage::{migrate, DeviceProfile, Engine, MemStore, SimulatedStore};
use crate::{Error, Result};

/// What a node is for (§4.1 "Architecture").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeRole {
    /// Cutout storage: capacity + sequential read I/O.
    Database,
    /// Random-write absorber for vision pipelines.
    Ssd,
    /// Tile stacks and ingest staging.
    FileServer,
}

/// One cluster node: a role and a storage engine.
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub role: NodeRole,
    pub engine: Engine,
}

/// A project's runtime handle: where its pieces live.
enum ProjectHandle {
    Image(Arc<CutoutService>),
    Annotation(Arc<AnnotationDb>),
}

/// The cluster: nodes + datasets + projects + placement.
pub struct Cluster {
    nodes: Vec<Node>,
    datasets: RwLock<HashMap<String, Arc<Dataset>>>,
    projects: RwLock<HashMap<String, ProjectHandle>>,
    /// Round-robin cursor for SSD placement.
    next_ssd: std::sync::atomic::AtomicUsize,
}

impl Cluster {
    /// A cluster whose nodes are plain in-memory engines (unit tests,
    /// "in cache" bench configurations).
    pub fn in_memory(n_database: usize, n_ssd: usize) -> Arc<Cluster> {
        let mut nodes = Vec::new();
        for i in 0..n_database.max(1) {
            nodes.push(Node {
                id: nodes.len(),
                name: format!("db{i}"),
                role: NodeRole::Database,
                engine: Arc::new(MemStore::new()),
            });
        }
        for i in 0..n_ssd {
            nodes.push(Node {
                id: nodes.len(),
                name: format!("ssd{i}"),
                role: NodeRole::Ssd,
                engine: Arc::new(MemStore::new()),
            });
        }
        Arc::new(Cluster {
            nodes,
            datasets: RwLock::new(HashMap::new()),
            projects: RwLock::new(HashMap::new()),
            next_ssd: std::sync::atomic::AtomicUsize::new(0),
        })
    }

    /// A durable cluster: every node is a [`crate::storage::FileStore`]
    /// rooted under `dir/<node-name>/` — the file-server / persistence
    /// analogue of §4.1. Reopening the same directory restores all
    /// cuboids, metadata and indexes (projects must be re-registered;
    /// configuration is code, as in the paper's dataset/project tables).
    pub fn persistent(
        dir: impl AsRef<std::path::Path>,
        n_database: usize,
        n_ssd: usize,
    ) -> crate::Result<Arc<Cluster>> {
        let dir = dir.as_ref();
        let mut nodes = Vec::new();
        for i in 0..n_database.max(1) {
            nodes.push(Node {
                id: nodes.len(),
                name: format!("db{i}"),
                role: NodeRole::Database,
                engine: Arc::new(crate::storage::FileStore::open(dir.join(format!("db{i}")))?)
                    as Engine,
            });
        }
        for i in 0..n_ssd {
            nodes.push(Node {
                id: nodes.len(),
                name: format!("ssd{i}"),
                role: NodeRole::Ssd,
                engine: Arc::new(crate::storage::FileStore::open(dir.join(format!("ssd{i}")))?)
                    as Engine,
            });
        }
        Ok(Arc::new(Cluster {
            nodes,
            datasets: RwLock::new(HashMap::new()),
            projects: RwLock::new(HashMap::new()),
            next_ssd: std::sync::atomic::AtomicUsize::new(0),
        }))
    }

    /// A cluster with simulated device economics: database nodes behind
    /// the RAID-6 HDD profile, SSD nodes behind the Vertex4 profile
    /// (DESIGN.md §1). `time_scale` shrinks all charged latencies.
    pub fn simulated(n_database: usize, n_ssd: usize, time_scale: f64) -> Arc<Cluster> {
        let mut nodes = Vec::new();
        for i in 0..n_database.max(1) {
            nodes.push(Node {
                id: nodes.len(),
                name: format!("db{i}"),
                role: NodeRole::Database,
                engine: Arc::new(SimulatedStore::new(
                    Arc::new(MemStore::new()),
                    DeviceProfile::hdd_array(),
                    time_scale,
                )) as Engine,
            });
        }
        for i in 0..n_ssd {
            nodes.push(Node {
                id: nodes.len(),
                name: format!("ssd{i}"),
                role: NodeRole::Ssd,
                engine: Arc::new(SimulatedStore::new(
                    Arc::new(MemStore::new()),
                    DeviceProfile::ssd_raid0(),
                    time_scale,
                )) as Engine,
            });
        }
        Arc::new(Cluster {
            nodes,
            datasets: RwLock::new(HashMap::new()),
            projects: RwLock::new(HashMap::new()),
            next_ssd: std::sync::atomic::AtomicUsize::new(0),
        })
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    fn nodes_with_role(&self, role: NodeRole) -> Vec<NodeId> {
        self.nodes.iter().filter(|n| n.role == role).map(|n| n.id).collect()
    }

    // ------------------------------------------------------------------
    // Datasets
    // ------------------------------------------------------------------

    pub fn register_dataset(&self, ds: Dataset) -> Arc<Dataset> {
        let ds = Arc::new(ds);
        self.datasets.write().unwrap().insert(ds.name.clone(), Arc::clone(&ds));
        ds
    }

    pub fn dataset(&self, name: &str) -> Result<Arc<Dataset>> {
        self.datasets
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::NotFound(format!("dataset '{name}'")))
    }

    // ------------------------------------------------------------------
    // Projects and placement
    // ------------------------------------------------------------------

    /// Create an image project, sharding cuboids across ALL database
    /// nodes by Morton partition (§4.1: only the largest datasets are
    /// sharded for capacity; a single DB node degenerates to no
    /// sharding).
    pub fn create_image_project(&self, project: Project) -> Result<Arc<CutoutService>> {
        let ds = self.dataset(&project.dataset)?;
        let db_nodes = self.nodes_with_role(NodeRole::Database);
        // Partition the Morton space of the *finest* level's grid.
        let g = ds.level(0)?.grid();
        let total_keys = (g[0].max(g[1]).max(g[2]).next_power_of_two()).pow(3);
        let map = ShardMap::even(total_keys, db_nodes.clone())?;
        let engines: Vec<Engine> =
            self.nodes.iter().map(|n| Arc::clone(&n.engine)).collect();
        let engine: Engine = Arc::new(ShardedEngine::new(map, engines));
        let store = Arc::new(CuboidStore::new(ds, Arc::new(project.clone()), engine));
        let svc = Arc::new(CutoutService::new(store));
        self.projects
            .write()
            .unwrap()
            .insert(project.token.clone(), ProjectHandle::Image(Arc::clone(&svc)));
        Ok(svc)
    }

    /// Create an annotation project. `hot` projects (actively written by
    /// vision pipelines) are placed on an SSD node; cold ones directly on
    /// a database node (§4.1 placement policy).
    pub fn create_annotation_project(
        &self,
        project: Project,
        hot: bool,
    ) -> Result<Arc<AnnotationDb>> {
        let ds = self.dataset(&project.dataset)?;
        let ssd = self.nodes_with_role(NodeRole::Ssd);
        let node = if hot && !ssd.is_empty() {
            let i = self.next_ssd.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            ssd[i % ssd.len()]
        } else {
            let dbs = self.nodes_with_role(NodeRole::Database);
            dbs[0]
        };
        let engine = Arc::clone(&self.nodes[node].engine);
        let store =
            Arc::new(CuboidStore::new(ds, Arc::new(project.clone()), Arc::clone(&engine)));
        let db = Arc::new(AnnotationDb::new(store, engine)?);
        self.projects
            .write()
            .unwrap()
            .insert(project.token.clone(), ProjectHandle::Annotation(Arc::clone(&db)));
        Ok(db)
    }

    pub fn image(&self, token: &str) -> Result<Arc<CutoutService>> {
        match self.projects.read().unwrap().get(token) {
            Some(ProjectHandle::Image(svc)) => Ok(Arc::clone(svc)),
            Some(_) => Err(Error::BadRequest(format!("'{token}' is not an image project"))),
            None => Err(Error::NotFound(format!("project '{token}'"))),
        }
    }

    pub fn annotation(&self, token: &str) -> Result<Arc<AnnotationDb>> {
        match self.projects.read().unwrap().get(token) {
            Some(ProjectHandle::Annotation(db)) => Ok(Arc::clone(db)),
            Some(_) => {
                Err(Error::BadRequest(format!("'{token}' is not an annotation project")))
            }
            None => Err(Error::NotFound(format!("project '{token}'"))),
        }
    }

    pub fn tokens(&self) -> Vec<String> {
        let mut t: Vec<String> = self.projects.read().unwrap().keys().cloned().collect();
        t.sort();
        t
    }

    /// Migrate an annotation project from its current node to the first
    /// database node — the paper's administrative dump/restore performed
    /// "when we build the annotation resolution hierarchy" (§4.1).
    /// Returns the rebound handle and the number of values moved.
    pub fn migrate_annotation_project(&self, token: &str) -> Result<(Arc<AnnotationDb>, u64)> {
        let db = self.annotation(token)?;
        let project = Arc::clone(&db.project);
        let ds = self.dataset(&project.dataset)?;
        let src_engine = Arc::clone(db.cutout.store().engine());
        let dst_node = self.nodes_with_role(NodeRole::Database)[0];
        let dst_engine = Arc::clone(&self.nodes[dst_node].engine);
        // Dump and restore every table belonging to this project.
        let mut moved = 0;
        for table in src_engine.tables()? {
            if table.starts_with(&format!("{}/", project.token)) {
                moved += migrate(src_engine.as_ref(), dst_engine.as_ref(), Some(&table))?;
            }
        }
        let store = Arc::new(CuboidStore::new(ds, project, Arc::clone(&dst_engine)));
        let new_db = Arc::new(AnnotationDb::new(store, dst_engine)?);
        self.projects
            .write()
            .unwrap()
            .insert(token.to_string(), ProjectHandle::Annotation(Arc::clone(&new_db)));
        Ok((new_db, moved))
    }

    /// Per-node I/O snapshots (the `ocpd info` CLI and benches).
    pub fn node_stats(&self) -> Vec<(String, crate::storage::IoSnapshot)> {
        self.nodes
            .iter()
            .map(|n| (n.name.clone(), n.engine.stats().snapshot()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::annotation::RamonObject;
    use crate::array::DenseVolume;
    use crate::core::{Box3, DatasetBuilder, WriteDiscipline};

    fn cluster() -> Arc<Cluster> {
        let c = Cluster::in_memory(2, 1);
        c.register_dataset(DatasetBuilder::new("ds", [256, 256, 32]).levels(2).build());
        c
    }

    #[test]
    fn image_project_sharded_across_db_nodes() {
        let c = cluster();
        let svc = c.create_image_project(Project::image("img", "ds")).unwrap();
        let whole = Box3::new([0, 0, 0], [256, 256, 32]);
        let mut v = DenseVolume::<u8>::zeros(whole.extent());
        v.fill_box(whole, 7);
        svc.write(0, 0, 0, whole, &v).unwrap();
        assert_eq!(svc.read::<u8>(0, 0, 0, whole).unwrap(), v);
        // Both database nodes hold data; the SSD node holds none.
        let stats = c.node_stats();
        assert!(stats[0].1.write_bytes > 0, "db0 idle");
        assert!(stats[1].1.write_bytes > 0, "db1 idle");
        assert_eq!(stats[2].1.write_bytes, 0, "ssd should be idle");
    }

    #[test]
    fn hot_annotation_lands_on_ssd() {
        let c = cluster();
        let db = c
            .create_annotation_project(Project::annotation("ann", "ds"), true)
            .unwrap();
        let bx = Box3::new([0, 0, 0], [16, 16, 4]);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), 5);
        db.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
        let stats = c.node_stats();
        assert!(stats[2].1.write_bytes > 0, "ssd idle");
        assert_eq!(stats[0].1.write_bytes + stats[1].1.write_bytes, 0, "db wrote");
    }

    #[test]
    fn cold_annotation_lands_on_db() {
        let c = cluster();
        let db = c
            .create_annotation_project(Project::annotation("cold", "ds"), false)
            .unwrap();
        db.put_object(RamonObject::new(0, crate::annotation::RamonType::Seed)).unwrap();
        let stats = c.node_stats();
        assert!(stats[0].1.write_bytes > 0);
        assert_eq!(stats[2].1.write_bytes, 0);
    }

    #[test]
    fn migration_moves_project_and_preserves_data() {
        let c = cluster();
        let db = c
            .create_annotation_project(Project::annotation("ann", "ds"), true)
            .unwrap();
        let bx = Box3::new([3, 5, 1], [40, 44, 9]);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), 9);
        db.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
        let id = db.put_object(RamonObject::synapse(9, 0.8, Default::default())).unwrap();
        assert_eq!(id, 9);

        let (new_db, moved) = c.migrate_annotation_project("ann").unwrap();
        assert!(moved >= 2, "expected cuboids + index + metadata moved, got {moved}");
        // All reads work against the database node now.
        assert_eq!(new_db.voxel_list(0, 9).unwrap().len() as u64, bx.volume());
        assert_eq!(new_db.get_object(9).unwrap().confidence, 0.8);
        // Handle rebound in the registry.
        let again = c.annotation("ann").unwrap();
        assert_eq!(again.voxel_list(0, 9).unwrap().len() as u64, bx.volume());
    }

    #[test]
    fn persistent_cluster_survives_reopen() {
        let dir = std::env::temp_dir().join(format!("ocpd-cluster-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ds = || DatasetBuilder::new("ds", [128, 128, 16]).levels(1).build();
        let bx = Box3::new([3, 5, 1], [40, 44, 9]);
        {
            let c = Cluster::persistent(&dir, 1, 1).unwrap();
            c.register_dataset(ds());
            let img = c.create_image_project(Project::image("img", "ds")).unwrap();
            let anno =
                c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
            let mut v = DenseVolume::<u8>::zeros(bx.extent());
            v.fill_box(Box3::new([0, 0, 0], bx.extent()), 9);
            img.write(0, 0, 0, bx, &v).unwrap();
            let mut a = DenseVolume::<u32>::zeros(bx.extent());
            a.fill_box(Box3::new([0, 0, 0], bx.extent()), 5);
            anno.write_volume(0, bx, &a, WriteDiscipline::Overwrite).unwrap();
            anno.put_object(RamonObject::synapse(5, 0.7, Default::default())).unwrap();
        }
        {
            let c = Cluster::persistent(&dir, 1, 1).unwrap();
            c.register_dataset(ds());
            let img = c.create_image_project(Project::image("img", "ds")).unwrap();
            let anno =
                c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
            assert_eq!(img.read::<u8>(0, 0, 0, bx).unwrap().count_eq(9), bx.volume());
            assert_eq!(anno.voxel_list(0, 5).unwrap().len() as u64, bx.volume());
            assert_eq!(anno.get_object(5).unwrap().confidence, 0.7);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_tokens_error() {
        let c = cluster();
        assert!(c.image("nope").is_err());
        assert!(c.annotation("nope").is_err());
        c.create_image_project(Project::image("img", "ds")).unwrap();
        assert!(c.annotation("img").is_err(), "type mismatch must error");
    }

    #[test]
    fn dataset_registry() {
        let c = cluster();
        assert!(c.dataset("ds").is_ok());
        assert!(c.dataset("missing").is_err());
        assert!(c.create_image_project(Project::image("x", "missing")).is_err());
    }
}
