//! The OCP Data Cluster: heterogeneous node roles, workload placement,
//! application-level sharding, and project migration (§4.1, Figure 7).
//!
//! * **Database nodes** store image and annotation cuboids for cutout —
//!   read-optimized (RAID-6 arrays in the paper).
//! * **SSD I/O nodes** absorb the random small writes of parallel vision
//!   pipelines; projects migrate off them ("dump and restore") once no
//!   longer actively written.
//! * **Application servers** do all request parsing/assembly; here the
//!   [`crate::web`] front end plays that role over this struct.
//!
//! Placement policy ("Data Distribution"): concurrent workloads land on
//! distinct nodes — cutout reads on database nodes, annotation writes on
//! SSD nodes. Image cuboids shard across database nodes by partitioning
//! the Morton curve; sharding is application-level via [`ShardedEngine`].
//!
//! Hot annotation projects write through the SSD **write-absorber**
//! ([`crate::wal`]): every mutation is group-committed to a segmented
//! log on an SSD node, reads merge the log's overlay over the database
//! node, and a background flusher drains sealed segments into the
//! database node in Morton order. This replaces the seed's one-shot
//! "dump and restore" migration with a continuous pipeline; an explicit
//! [`Cluster::migrate_annotation_project`] is now just "flush the log
//! and drop it".
//!
//! Every project also gets a sharded LRU **cuboid cache**
//! ([`crate::chunkstore::CuboidCache`]) in front of its engine. The
//! cluster owns the caches (surfaced at `GET /cache/status/` and `ocpd
//! cache`), and wires the WAL flusher's apply hook to them so draining
//! a log into a database node invalidates any cached cuboids for the
//! drained keys — read-your-writes holds end to end.
//!
//! Writes run through the **parallel write engine**
//! ([`crate::cutout::WriteConfig`]): RMW elision for fully covered
//! cuboids, batched pre-reads, and shard-aligned scatter commits. The
//! cluster surfaces it at `GET /write/status/` and retunes every
//! project's fan-out width via `PUT /write/workers/{n}/` / `ocpd
//! write --workers N`.
//!
//! When built with [`ClusterConfig::replicas`] > 1, every image shard
//! becomes a **replica set** ([`replica::ReplicaSet`]): the leader's
//! mutation rounds are framed as CRC32 WAL chunks and shipped to
//! followers, and a small **control plane** ([`control::ControlPlane`])
//! probes nodes, renews leader leases, and promotes the most-caught-up
//! follower when a leader dies — bumping the shard's epoch so stale
//! readers are fenced (DESIGN.md §10). The surface is
//! `GET /cluster/status/` / `ocpd cluster`.

pub mod balance;
pub mod control;
pub mod replica;
mod sharded;

pub use balance::{BalanceConfig, Balancer, SplitReport};
pub use control::{ControlPlane, NodeHealth};
pub use replica::{
    PromotionReport, ReplicaSet, ReplicaSetStatus, ReplicaStatus, ReplicationConfig,
};
pub use sharded::{ShardInfo, ShardMove, ShardedEngine, TopologyStatus};

use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::time::Duration;

use crate::annotation::AnnotationDb;
use crate::chunkstore::{CacheConfig, CacheStatus, CuboidCache, CuboidStore};
use crate::core::{Dataset, Project};
use crate::cutout::{CutoutService, WriteConfig, WriteStatus};
use crate::jobs::JobManager;
use crate::obs::account::{Accountant, LedgerSnapshot};
use crate::obs::heat::{HeatSnapshot, HeatTracker};
use crate::obs::registry::{MetricsRegistry, Sample};
use crate::qos::QosEnforcer;
use crate::shard::{NodeId, ShardMap};
use crate::storage::{migrate, DeviceProfile, Engine, FaultInjector, MemStore, SimulatedStore};
use crate::wal::{Wal, WalConfig, WalEngine, WalStatus};
use crate::{Error, Result};

/// What a node is for (§4.1 "Architecture").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeRole {
    /// Cutout storage: capacity + sequential read I/O.
    Database,
    /// Random-write absorber for vision pipelines.
    Ssd,
    /// Tile stacks and ingest staging.
    FileServer,
}

/// One cluster node: a role and a storage engine.
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub role: NodeRole,
    pub engine: Engine,
}

/// A project's runtime handle: where its pieces live.
#[derive(Clone)]
enum ProjectHandle {
    Image(Arc<CutoutService>),
    Annotation(Arc<AnnotationDb>),
}

/// The cluster: nodes + datasets + projects + placement.
pub struct Cluster {
    nodes: Vec<Node>,
    datasets: RwLock<HashMap<String, Arc<Dataset>>>,
    projects: RwLock<HashMap<String, ProjectHandle>>,
    /// Write-ahead logs of hot projects, by token.
    wals: RwLock<HashMap<String, Arc<Wal>>>,
    /// Cuboid caches, by project token (the `/cache/status` surface).
    caches: RwLock<HashMap<String, Arc<CuboidCache>>>,
    /// Workload heat maps, by project token (the `/heat/status/`
    /// surface, DESIGN.md §11). The tracker is shared with the
    /// project's [`CuboidStore`]; a migrate rebinds the store but keeps
    /// the same tracker, so heat history survives the move.
    heats: RwLock<HashMap<String, Arc<HeatTracker>>>,
    /// Per-project tenant ledgers (the `/account/status/` surface).
    accountant: Arc<Accountant>,
    /// Multi-tenant QoS enforcement: admission token buckets, fair
    /// worker-pool gates, and preemption (the `/qos/...` surface,
    /// DESIGN.md §12). Off by default; shared by the dispatcher, the
    /// cutout/write engines, and the job workers.
    qos: Arc<QosEnforcer>,
    /// Configuration applied to every project's cache.
    cache_cfg: CacheConfig,
    /// The batch compute engine (the `/jobs/...` surface). Checkpoint
    /// journals live on the first database node, so a persistent
    /// cluster's jobs resume across restarts. `Arc`'d so the metrics
    /// registry's jobs collector can hold it past `&self`.
    jobs: Arc<JobManager>,
    /// The unified metrics registry behind `GET /metrics/`: every
    /// project, the jobs engine, and (when a server attaches) the HTTP
    /// transport register collectors here.
    registry: Arc<MetricsRegistry>,
    /// Node registry, leases, and failover promotion (the
    /// `/cluster/status/` surface). Present even for unreplicated
    /// clusters — it then just reports node health.
    control: Arc<ControlPlane>,
    /// Sharded engines of image projects, by token — the handles the
    /// shard splitter ([`balance`], DESIGN.md §13) drives moves through.
    sharded: RwLock<HashMap<String, Arc<ShardedEngine>>>,
    /// Split planner state: policy knobs, counters, auto-mode switch
    /// (the `/shards/...` surface).
    balance: Balancer,
    /// The topology knobs this cluster was built with.
    cfg: ClusterConfig,
}

/// Topology and replication knobs for [`Cluster::with_config`].
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Database (cutout) nodes; clamped to at least 1.
    pub n_database: usize,
    /// SSD write-absorber nodes.
    pub n_ssd: usize,
    /// Copies per image shard (1 = the seed's unreplicated layout).
    pub replicas: usize,
    /// Follower acks required per write ([`ReplicationConfig::min_acks`]).
    pub min_acks: usize,
    /// Follower-read staleness bound, records
    /// ([`ReplicationConfig::staleness_bound`]).
    pub staleness_bound: Option<u64>,
    /// Leader lease ([`ReplicationConfig::lease`]); `Duration::ZERO`
    /// promotes on the first failed probe.
    pub lease: Duration,
    /// Run the background failure-detector thread.
    pub monitor: bool,
    /// Probe cadence of the monitor thread.
    pub monitor_interval: Duration,
    /// Wrap every node in a zero-latency [`SimulatedStore`] with
    /// deterministic fault hooks seeded from `seed + node_id` — the
    /// fault-injection test harness configuration.
    pub fault_seed: Option<u64>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_database: 2,
            n_ssd: 1,
            replicas: 1,
            min_acks: usize::MAX,
            staleness_bound: None,
            lease: Duration::from_millis(500),
            monitor: false,
            monitor_interval: Duration::from_millis(50),
            fault_seed: None,
        }
    }
}

/// Stable FNV-1a hash for SSD placement: a hot project's log node is
/// *derived* from its token, not remembered, so reopening a persistent
/// cluster finds each project's segments on the same SSD node it wrote
/// them to.
fn placement_hash(s: &str) -> u64 {
    crate::util::fnv1a(&[s.as_bytes()])
}

impl Cluster {
    /// A cluster whose nodes are plain in-memory engines (unit tests,
    /// "in cache" bench configurations).
    pub fn in_memory(n_database: usize, n_ssd: usize) -> Arc<Cluster> {
        Self::with_config(ClusterConfig { n_database, n_ssd, ..ClusterConfig::default() })
    }

    /// An in-memory cluster with explicit topology/replication knobs —
    /// the entry point of the failover test harness.
    pub fn with_config(cfg: ClusterConfig) -> Arc<Cluster> {
        let mut nodes: Vec<Node> = Vec::new();
        let add = |nodes: &mut Vec<Node>, name: String, role: NodeRole| {
            let id = nodes.len();
            let mem: Engine = Arc::new(MemStore::new());
            let engine: Engine = match cfg.fault_seed {
                Some(seed) => Arc::new(SimulatedStore::instant(mem, seed + id as u64)),
                None => mem,
            };
            nodes.push(Node { id, name, role, engine });
        };
        for i in 0..cfg.n_database.max(1) {
            add(&mut nodes, format!("db{i}"), NodeRole::Database);
        }
        for i in 0..cfg.n_ssd {
            add(&mut nodes, format!("ssd{i}"), NodeRole::Ssd);
        }
        Self::assemble(nodes, cfg)
    }

    /// Shared tail of every constructor: jobs engine, metrics registry,
    /// and the control plane (started when the config asks for the
    /// monitor thread).
    fn assemble(nodes: Vec<Node>, cfg: ClusterConfig) -> Arc<Cluster> {
        let jobs = Arc::new(JobManager::new(Arc::clone(&nodes[0].engine)));
        let registry = Self::new_registry(&jobs);
        let accountant = Arc::new(Accountant::new());
        jobs.set_accountant(Arc::clone(&accountant));
        let qos = Arc::new(QosEnforcer::new());
        jobs.set_qos(Arc::clone(&qos));
        let control = ControlPlane::new(
            nodes
                .iter()
                .map(|n| (n.id, n.name.clone(), Self::role_name(n.role), Arc::clone(&n.engine)))
                .collect(),
        );
        if cfg.monitor {
            control.start_monitor(cfg.monitor_interval);
        }
        let cluster = Arc::new(Cluster {
            nodes,
            datasets: RwLock::new(HashMap::new()),
            projects: RwLock::new(HashMap::new()),
            wals: RwLock::new(HashMap::new()),
            caches: RwLock::new(HashMap::new()),
            heats: RwLock::new(HashMap::new()),
            accountant,
            qos,
            cache_cfg: CacheConfig::default(),
            jobs,
            registry,
            control,
            sharded: RwLock::new(HashMap::new()),
            balance: Balancer::new(),
            cfg,
        });
        Self::register_account_metrics(&cluster);
        // The QoS collector (`ocpd_qos_*`) captures the enforcer
        // directly — it holds no cluster reference, so no Weak dance.
        let qos = Arc::clone(&cluster.qos);
        cluster.registry.register("qos", move |out| qos.collect(out));
        Self::register_balance_metrics(&cluster);
        cluster
    }

    /// Register the tenant-accounting collector (`ocpd_account_*`,
    /// labeled by project). Captures a `Weak` — the registry lives
    /// inside the cluster, so a strong capture would leak the cluster.
    fn register_account_metrics(cluster: &Arc<Cluster>) {
        let weak = Arc::downgrade(cluster);
        cluster.registry.register("account", move |out| {
            let Some(cluster) = weak.upgrade() else { return };
            for (token, s) in cluster.accountant.snapshot() {
                for (name, help, v) in [
                    ("ocpd_account_requests_total", "Requests attributed to the project.", s.requests),
                    ("ocpd_account_bytes_in_total", "Request body bytes received.", s.bytes_in),
                    ("ocpd_account_bytes_out_total", "Response body bytes sent.", s.bytes_out),
                    (
                        "ocpd_account_read_worker_us_total",
                        "Busy microseconds in the cutout read pool.",
                        s.read_worker_us,
                    ),
                    (
                        "ocpd_account_write_worker_us_total",
                        "Busy microseconds in the write pool.",
                        s.write_worker_us,
                    ),
                    (
                        "ocpd_account_job_worker_us_total",
                        "Busy microseconds executing job blocks.",
                        s.job_worker_us,
                    ),
                ] {
                    out.push(Sample::counter(name, help, v).label("project", token.clone()));
                }
                let cache_bytes = cluster
                    .caches
                    .read()
                    .unwrap()
                    .get(&token)
                    .map_or(0, |c| c.status().bytes);
                out.push(
                    Sample::gauge(
                        "ocpd_account_cache_bytes",
                        "Cuboid-cache bytes currently held by the project.",
                        cache_bytes,
                    )
                    .label("project", token),
                );
            }
        });
    }

    fn role_name(role: NodeRole) -> &'static str {
        match role {
            NodeRole::Database => "database",
            NodeRole::Ssd => "ssd",
            NodeRole::FileServer => "file",
        }
    }

    /// A durable cluster: every node is a [`crate::storage::FileStore`]
    /// rooted under `dir/<node-name>/` — the file-server / persistence
    /// analogue of §4.1. Reopening the same directory restores all
    /// cuboids, metadata and indexes (projects must be re-registered;
    /// configuration is code, as in the paper's dataset/project tables).
    pub fn persistent(
        dir: impl AsRef<std::path::Path>,
        n_database: usize,
        n_ssd: usize,
    ) -> crate::Result<Arc<Cluster>> {
        let dir = dir.as_ref();
        let mut nodes = Vec::new();
        for i in 0..n_database.max(1) {
            nodes.push(Node {
                id: nodes.len(),
                name: format!("db{i}"),
                role: NodeRole::Database,
                engine: Arc::new(crate::storage::FileStore::open(dir.join(format!("db{i}")))?)
                    as Engine,
            });
        }
        for i in 0..n_ssd {
            nodes.push(Node {
                id: nodes.len(),
                name: format!("ssd{i}"),
                role: NodeRole::Ssd,
                engine: Arc::new(crate::storage::FileStore::open(dir.join(format!("ssd{i}")))?)
                    as Engine,
            });
        }
        Ok(Self::assemble(
            nodes,
            ClusterConfig { n_database, n_ssd, ..ClusterConfig::default() },
        ))
    }

    /// A cluster with simulated device economics: database nodes behind
    /// the RAID-6 HDD profile, SSD nodes behind the Vertex4 profile
    /// (DESIGN.md §1). `time_scale` shrinks all charged latencies.
    pub fn simulated(n_database: usize, n_ssd: usize, time_scale: f64) -> Arc<Cluster> {
        let mut nodes = Vec::new();
        for i in 0..n_database.max(1) {
            nodes.push(Node {
                id: nodes.len(),
                name: format!("db{i}"),
                role: NodeRole::Database,
                engine: Arc::new(SimulatedStore::new(
                    Arc::new(MemStore::new()),
                    DeviceProfile::hdd_array(),
                    time_scale,
                )) as Engine,
            });
        }
        for i in 0..n_ssd {
            nodes.push(Node {
                id: nodes.len(),
                name: format!("ssd{i}"),
                role: NodeRole::Ssd,
                engine: Arc::new(SimulatedStore::new(
                    Arc::new(MemStore::new()),
                    DeviceProfile::ssd_raid0(),
                    time_scale,
                )) as Engine,
            });
        }
        Self::assemble(nodes, ClusterConfig { n_database, n_ssd, ..ClusterConfig::default() })
    }

    /// Build the cluster's metrics registry with the jobs collector
    /// pre-registered (projects and the HTTP transport register theirs
    /// when they come up).
    fn new_registry(jobs: &Arc<JobManager>) -> Arc<MetricsRegistry> {
        let registry = Arc::new(MetricsRegistry::new());
        let jm = Arc::clone(jobs);
        registry.register("jobs", move |out| {
            for h in jm.handles() {
                let id = h.id.to_string();
                let name = h.name().to_string();
                let m = &h.metrics;
                out.push(
                    Sample::gauge(
                        "ocpd_job_blocks_per_sec_milli",
                        "Fresh-block throughput, milli-blocks per second.",
                        m.blocks_per_sec_milli.get(),
                    )
                    .label("job", id.clone())
                    .label("name", name.clone()),
                );
                out.push(
                    Sample::counter(
                        "ocpd_job_retries_total",
                        "Block attempts retried after an error.",
                        m.retries.get(),
                    )
                    .label("job", id.clone())
                    .label("name", name.clone()),
                );
                out.push(
                    Sample::histogram(
                        "ocpd_job_block_latency_us",
                        "Wall latency per completed block, microseconds.",
                        m.block_latency.snapshot(),
                    )
                    .label("job", id)
                    .label("name", name),
                );
            }
        });
        registry
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    fn nodes_with_role(&self, role: NodeRole) -> Vec<NodeId> {
        self.nodes.iter().filter(|n| n.role == role).map(|n| n.id).collect()
    }

    // ------------------------------------------------------------------
    // Datasets
    // ------------------------------------------------------------------

    /// A token must be unclaimed and must not shadow a reserved
    /// top-level route name ([`crate::web::RESERVED`]: `/info/`,
    /// `/http/...`, `/wal/...`, `/cache/...`, `/jobs/...`,
    /// `/write/...`). Re-creating an existing hot token would be worse
    /// than confusing: two [`Wal`]s over one chunk table would
    /// overwrite each other's durable frames. Callers pass the held
    /// write guard so check and insert are one atomic step.
    fn check_token_free(
        projects: &HashMap<String, ProjectHandle>,
        token: &str,
    ) -> Result<()> {
        if crate::web::RESERVED.contains(&token) {
            return Err(Error::BadRequest(format!(
                "'{token}' is a reserved name and cannot be a project token"
            )));
        }
        if projects.contains_key(token) {
            return Err(Error::BadRequest(format!("project '{token}' already exists")));
        }
        Ok(())
    }

    pub fn register_dataset(&self, ds: Dataset) -> Arc<Dataset> {
        let ds = Arc::new(ds);
        self.datasets.write().unwrap().insert(ds.name.clone(), Arc::clone(&ds));
        ds
    }

    pub fn dataset(&self, name: &str) -> Result<Arc<Dataset>> {
        self.datasets
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::NotFound(format!("dataset '{name}'")))
    }

    // ------------------------------------------------------------------
    // Projects and placement
    // ------------------------------------------------------------------

    /// Create an image project, sharding cuboids across ALL database
    /// nodes by Morton partition (§4.1: only the largest datasets are
    /// sharded for capacity; a single DB node degenerates to no
    /// sharding).
    pub fn create_image_project(&self, project: Project) -> Result<Arc<CutoutService>> {
        // Hold the registry lock across check-and-insert so concurrent
        // creates of one token cannot both pass the check.
        let mut projects = self.projects.write().unwrap();
        Self::check_token_free(&projects, &project.token)?;
        let ds = self.dataset(&project.dataset)?;
        let db_nodes = self.nodes_with_role(NodeRole::Database);
        // Partition the Morton space of the *finest* level's grid.
        let g = ds.level(0)?.grid();
        let total_keys = (g[0].max(g[1]).max(g[2]).next_power_of_two()).pow(3);
        let map = ShardMap::even(total_keys, db_nodes.clone())?;
        let heat = Arc::new(HeatTracker::new(total_keys, Arc::new(map.clone())));
        let cache = Arc::new(CuboidCache::new(self.cache_cfg));
        let replicas = self.cfg.replicas.min(db_nodes.len());
        let sharded: Arc<ShardedEngine> = if replicas > 1 {
            // Replica sets: shard i's leader is its map node; followers
            // are the next `replicas - 1` database nodes, round-robin.
            let rcfg = ReplicationConfig {
                min_acks: self.cfg.min_acks,
                staleness_bound: self.cfg.staleness_bound,
                lease: self.cfg.lease,
                ..ReplicationConfig::default()
            };
            let mut sets = Vec::with_capacity(map.num_shards());
            for (shard, &leader) in map.nodes().iter().enumerate() {
                let li = db_nodes.iter().position(|&n| n == leader).unwrap_or(0);
                let members: Vec<(NodeId, Engine)> = (0..replicas)
                    .map(|j| {
                        let node = db_nodes[(li + j) % db_nodes.len()];
                        (node, Arc::clone(&self.nodes[node].engine))
                    })
                    .collect();
                let set = ReplicaSet::new(
                    &project.token,
                    shard,
                    map.shard_range(shard),
                    members,
                    rcfg.clone(),
                )?;
                // A promotion may strand cuboids cached under the old
                // leader's view; clear rather than chase them.
                let hook_cache = Arc::clone(&cache);
                set.set_on_promote(Some(Arc::new(move |_epoch| hook_cache.clear())));
                sets.push(set);
            }
            self.control.register_sets(&project.token, &sets);
            self.register_replication_metrics(&project.token, &sets);
            Arc::new(ShardedEngine::replicated(map, sets)?)
        } else {
            let engines: Vec<Engine> =
                self.nodes.iter().map(|n| Arc::clone(&n.engine)).collect();
            Arc::new(ShardedEngine::new(map, engines))
        };
        // A shard split strands cuboids cached under the old routing;
        // clear on every map swap, like the promotion hook above.
        let hook_cache = Arc::clone(&cache);
        sharded.set_on_map_change(Some(Arc::new(move |_version| hook_cache.clear())));
        self.register_shard_metrics(&project.token, &sharded);
        self.sharded.write().unwrap().insert(project.token.clone(), Arc::clone(&sharded));
        let engine: Engine = sharded;
        let store = Arc::new(
            CuboidStore::new(ds, Arc::new(project.clone()), engine)
                .with_cache(Arc::clone(&cache)),
        );
        store.set_heat(Arc::clone(&heat));
        let svc = Arc::new(CutoutService::new(store));
        svc.set_ledger(self.accountant.ledger(&project.token));
        svc.set_qos(Arc::clone(&self.qos));
        self.register_project_metrics(
            &project.token,
            ProjectHandle::Image(Arc::clone(&svc)),
            Arc::clone(&cache),
            None,
        );
        self.register_heat_metrics(&project.token, &heat);
        self.heats.write().unwrap().insert(project.token.clone(), heat);
        self.caches.write().unwrap().insert(project.token.clone(), cache);
        projects.insert(project.token.clone(), ProjectHandle::Image(Arc::clone(&svc)));
        Ok(svc)
    }

    /// Create an annotation project. `hot` projects (actively written by
    /// vision pipelines) write through the SSD write-absorber: mutations
    /// group-commit to a [`Wal`] segmented over an SSD node and drain in
    /// the background into a database node, while reads merge the log's
    /// overlay over the database node (§4.1 placement policy, done
    /// continuously). Cold projects live directly on a database node.
    pub fn create_annotation_project(
        &self,
        project: Project,
        hot: bool,
    ) -> Result<Arc<AnnotationDb>> {
        // Hold the registry lock across check-and-insert: two racing
        // creates of one hot token would otherwise open two `Wal`s over
        // the same chunk table and corrupt each other's frames.
        let mut projects = self.projects.write().unwrap();
        Self::check_token_free(&projects, &project.token)?;
        let ds = self.dataset(&project.dataset)?;
        let ssd = self.nodes_with_role(NodeRole::Ssd);
        let dbs = self.nodes_with_role(NodeRole::Database);
        let dest = Arc::clone(&self.nodes[dbs[0]].engine);
        let (engine, wal): (Engine, Option<Arc<Wal>>) = if hot && !ssd.is_empty() {
            let i = placement_hash(&project.token) as usize % ssd.len();
            let log = Arc::clone(&self.nodes[ssd[i]].engine);
            let wal = Wal::open(&project.token, log, dest, WalConfig::default())?;
            // Mirror the durable log onto other SSD nodes so a dead log
            // node doesn't take unflushed frames with it.
            for j in 1..self.cfg.replicas.min(ssd.len()) {
                let node = ssd[(i + j) % ssd.len()];
                wal.add_follower(Arc::clone(&self.nodes[node].engine))?;
            }
            self.wals.write().unwrap().insert(project.token.clone(), Arc::clone(&wal));
            (Arc::new(WalEngine::new(Arc::clone(&wal))) as Engine, Some(wal))
        } else {
            (dest, None)
        };
        let cache = Arc::new(CuboidCache::new(self.cache_cfg));
        // Annotation projects live on one node, but the heat map still
        // buckets their Morton space so a future splitter has evidence.
        let g = ds.level(0)?.grid();
        let total_keys = (g[0].max(g[1]).max(g[2]).next_power_of_two()).pow(3);
        let heat =
            Arc::new(HeatTracker::new(total_keys, Arc::new(ShardMap::single(dbs[0]))));
        if let Some(wal) = &wal {
            // Flush-side invalidation: when the flusher drains a record
            // into the database node, any cached cuboid for that key is
            // dropped before the overlay entry disappears. The drain also
            // counts as write traffic on the key's heat bucket (zero
            // bytes: the payload was already charged at append time).
            let hook_cache = Arc::clone(&cache);
            let hook_heat = Arc::clone(&heat);
            let hook: Arc<dyn Fn(&str, u64) + Send + Sync> =
                Arc::new(move |table: &str, key: u64| {
                    hook_cache.invalidate(table, key);
                    hook_heat.record_write(key, 0);
                });
            wal.set_on_apply(Some(hook));
        }
        let store = Arc::new(
            CuboidStore::new(ds, Arc::new(project.clone()), Arc::clone(&engine))
                .with_cache(Arc::clone(&cache)),
        );
        store.set_heat(Arc::clone(&heat));
        let db = Arc::new(AnnotationDb::new_with_wal(store, engine, wal.clone())?);
        db.cutout.set_ledger(self.accountant.ledger(&project.token));
        db.cutout.set_qos(Arc::clone(&self.qos));
        self.register_project_metrics(
            &project.token,
            ProjectHandle::Annotation(Arc::clone(&db)),
            Arc::clone(&cache),
            wal,
        );
        self.register_heat_metrics(&project.token, &heat);
        self.heats.write().unwrap().insert(project.token.clone(), heat);
        self.caches.write().unwrap().insert(project.token.clone(), cache);
        projects.insert(project.token.clone(), ProjectHandle::Annotation(Arc::clone(&db)));
        Ok(db)
    }

    pub fn image(&self, token: &str) -> Result<Arc<CutoutService>> {
        match self.projects.read().unwrap().get(token) {
            Some(ProjectHandle::Image(svc)) => Ok(Arc::clone(svc)),
            Some(_) => Err(Error::BadRequest(format!("'{token}' is not an image project"))),
            None => Err(Error::NotFound(format!("project '{token}'"))),
        }
    }

    pub fn annotation(&self, token: &str) -> Result<Arc<AnnotationDb>> {
        match self.projects.read().unwrap().get(token) {
            Some(ProjectHandle::Annotation(db)) => Ok(Arc::clone(db)),
            Some(_) => {
                Err(Error::BadRequest(format!("'{token}' is not an annotation project")))
            }
            None => Err(Error::NotFound(format!("project '{token}'"))),
        }
    }

    pub fn tokens(&self) -> Vec<String> {
        let mut t: Vec<String> = self.projects.read().unwrap().keys().cloned().collect();
        t.sort();
        t
    }

    /// Demote an annotation project to cold storage. For a WAL'd (hot)
    /// project this is "flush the log, drop it, rebind on the database
    /// node" — the continuous-pipeline version of the paper's
    /// administrative dump/restore performed "when we build the
    /// annotation resolution hierarchy" (§4.1). For a project without a
    /// log it falls back to the legacy table copy. Returns the rebound
    /// handle and the number of records/values moved.
    pub fn migrate_annotation_project(&self, token: &str) -> Result<(Arc<AnnotationDb>, u64)> {
        let db = self.annotation(token)?;
        let project = Arc::clone(&db.project);
        let ds = self.dataset(&project.dataset)?;
        let dst_node = self.nodes_with_role(NodeRole::Database)[0];
        let dst_engine = Arc::clone(&self.nodes[dst_node].engine);
        let wal = self.wals.read().unwrap().get(token).cloned();
        let moved = if let Some(wal) = wal {
            // Drain everything the log absorbed into the database node,
            // then retire it. The registry entry is removed only after
            // the flush succeeds — a failed flush must leave the log
            // reachable (and still draining) rather than orphaned.
            let mut moved = wal.flush_now()?;
            // Retire first (stale handles now get errors instead of
            // appending into a log nothing will drain), then sweep any
            // straggler appends that raced the retirement.
            wal.shutdown();
            moved += wal.flush_now()?;
            self.wals.write().unwrap().remove(token);
            moved
        } else {
            // Legacy dump-and-restore of every table of the project.
            let src_engine = Arc::clone(db.cutout.store().engine());
            let mut moved = 0;
            for table in src_engine.tables()? {
                if table.starts_with(&format!("{}/", project.token)) {
                    moved += migrate(src_engine.as_ref(), dst_engine.as_ref(), Some(&table))?;
                }
            }
            moved
        };
        // Rebind with a cleared cache: entries cached through the WAL'd
        // view are value-identical post-flush, but clearing makes the
        // rebind trivially stale-free.
        let cache = self.caches.read().unwrap().get(token).cloned();
        let mut store = CuboidStore::new(ds, project, Arc::clone(&dst_engine));
        if let Some(cache) = &cache {
            cache.clear();
            store = store.with_cache(Arc::clone(cache));
        }
        let store = Arc::new(store);
        // The heat map and ledger survive the move: access history is a
        // property of the data, not of which node currently holds it.
        if let Some(heat) = self.heats.read().unwrap().get(token) {
            store.set_heat(Arc::clone(heat));
        }
        let new_db = Arc::new(AnnotationDb::new(store, dst_engine)?);
        if let Some(ledger) = self.accountant.get(token) {
            new_db.cutout.set_ledger(ledger);
        }
        new_db.cutout.set_qos(Arc::clone(&self.qos));
        // Rebind the project's metrics collector too: the old one holds
        // the retired service (and its WAL), which would freeze on the
        // exposition.
        if let Some(cache) = cache {
            self.register_project_metrics(
                token,
                ProjectHandle::Annotation(Arc::clone(&new_db)),
                cache,
                None,
            );
        }
        self.projects
            .write()
            .unwrap()
            .insert(token.to_string(), ProjectHandle::Annotation(Arc::clone(&new_db)));
        Ok((new_db, moved))
    }

    // ------------------------------------------------------------------
    // Write-ahead logs
    // ------------------------------------------------------------------

    /// The write-ahead log of a hot project, if it has one.
    pub fn wal(&self, token: &str) -> Option<Arc<Wal>> {
        self.wals.read().unwrap().get(token).cloned()
    }

    /// Status of every project log, by token (the `/wal/status` route).
    pub fn wal_status(&self) -> Result<Vec<WalStatus>> {
        let wals: Vec<Arc<Wal>> = {
            let guard = self.wals.read().unwrap();
            let mut v: Vec<(String, Arc<Wal>)> =
                guard.iter().map(|(k, w)| (k.clone(), Arc::clone(w))).collect();
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v.into_iter().map(|(_, w)| w).collect()
        };
        wals.iter().map(|w| w.status()).collect()
    }

    /// Force one project's log down to its database node. Returns records
    /// applied.
    pub fn flush_wal(&self, token: &str) -> Result<u64> {
        match self.wal(token) {
            Some(w) => w.flush_now(),
            None => Err(Error::NotFound(format!("project '{token}' has no write log"))),
        }
    }

    /// Flush every project log (the `/wal/flush` route). Returns total
    /// records applied.
    pub fn flush_all_wals(&self) -> Result<u64> {
        let wals: Vec<Arc<Wal>> =
            self.wals.read().unwrap().values().map(Arc::clone).collect();
        let mut total = 0;
        for w in wals {
            total += w.flush_now()?;
        }
        Ok(total)
    }

    // ------------------------------------------------------------------
    // Replication control plane
    // ------------------------------------------------------------------

    /// The control plane: node health, replica-set registry, leases,
    /// and failover promotion.
    pub fn control(&self) -> &Arc<ControlPlane> {
        &self.control
    }

    /// Human-readable cluster health (the `GET /cluster/status/` route
    /// and `ocpd cluster`).
    pub fn cluster_status(&self) -> String {
        self.control.status_text()
    }

    /// Force a leader promotion on one project shard (`POST
    /// /cluster/failover/{token}/{shard}/`).
    pub fn failover(&self, token: &str, shard: usize) -> Result<PromotionReport> {
        self.control.failover(token, shard)
    }

    /// Deterministic fault hooks of one node, when the cluster was
    /// built with [`ClusterConfig::fault_seed`] — the kill-a-replica
    /// test harness.
    pub fn fault(&self, node: NodeId) -> Option<&FaultInjector> {
        self.nodes.get(node)?.engine.fault_injector()
    }

    // ------------------------------------------------------------------
    // Batch compute jobs
    // ------------------------------------------------------------------

    /// The batch compute engine: submit, inspect, and cancel jobs
    /// (`POST /jobs/{type}`, `GET /jobs/status/`, `POST
    /// /jobs/cancel/{id}`, `ocpd jobs`).
    pub fn jobs(&self) -> &JobManager {
        &self.jobs
    }

    // ------------------------------------------------------------------
    // Unified metrics
    // ------------------------------------------------------------------

    /// The unified metrics registry (the `GET /metrics/` surface).
    pub fn registry(&self) -> &Arc<MetricsRegistry> {
        &self.registry
    }

    /// Register (or re-register, after a migration rebinds the handle)
    /// one project's collector: read/write-engine, cache, and — for hot
    /// annotation projects — WAL metrics, all labeled with the token.
    fn register_project_metrics(
        &self,
        token: &str,
        handle: ProjectHandle,
        cache: Arc<CuboidCache>,
        wal: Option<Arc<Wal>>,
    ) {
        let project = token.to_string();
        self.registry.register(format!("project/{token}"), move |out| {
            let p = || ("project", project.clone());
            let svc = Cluster::cutout_service(&handle);
            let r = &svc.metrics;
            let pair = p();
            out.push(
                Sample::counter(
                    "ocpd_read_sequential_total",
                    "Cutout reads served on the caller's thread.",
                    r.sequential_reads.get(),
                )
                .label(pair.0, pair.1),
            );
            let pair = p();
            out.push(
                Sample::counter(
                    "ocpd_read_parallel_total",
                    "Cutout reads scattered across the worker pool.",
                    r.parallel_reads.get(),
                )
                .label(pair.0, pair.1),
            );
            let pair = p();
            out.push(
                Sample::histogram(
                    "ocpd_read_fanout_width",
                    "Batches per parallel cutout read.",
                    r.fanout_width.snapshot(),
                )
                .label(pair.0, pair.1),
            );
            let w = &svc.write_metrics;
            for (name, help, v) in [
                (
                    "ocpd_write_sequential_total",
                    "Writes merged and committed on the caller's thread.",
                    w.sequential_writes.get(),
                ),
                (
                    "ocpd_write_parallel_total",
                    "Writes scattered across the worker pool.",
                    w.parallel_writes.get(),
                ),
                (
                    "ocpd_write_elided_reads_total",
                    "Cuboid pre-reads elided by full coverage.",
                    w.elided_reads.get(),
                ),
                (
                    "ocpd_write_rmw_reads_total",
                    "Cuboid read-modify-write pre-reads paid.",
                    w.rmw_reads.get(),
                ),
            ] {
                let pair = p();
                out.push(Sample::counter(name, help, v).label(pair.0, pair.1));
            }
            let pair = p();
            out.push(
                Sample::histogram(
                    "ocpd_write_merge_latency_us",
                    "Per-batch in-memory merge latency, microseconds.",
                    w.merge_latency.snapshot(),
                )
                .label(pair.0, pair.1),
            );
            let c = &cache.metrics;
            for (name, help, v) in [
                ("ocpd_cache_hits_total", "Cuboid-cache hits.", c.hits.get()),
                ("ocpd_cache_misses_total", "Cuboid-cache misses.", c.misses.get()),
                ("ocpd_cache_inserts_total", "Cuboid-cache inserts.", c.inserts.get()),
                ("ocpd_cache_evictions_total", "Cuboid-cache LRU evictions.", c.evictions.get()),
                (
                    "ocpd_cache_invalidations_total",
                    "Cuboid-cache invalidations (WAL flush hook).",
                    c.invalidations.get(),
                ),
            ] {
                let pair = p();
                out.push(Sample::counter(name, help, v).label(pair.0, pair.1));
            }
            if let Some(wal) = &wal {
                let m = &wal.metrics;
                for (name, help, v) in [
                    (
                        "ocpd_wal_appended_records_total",
                        "WAL records appended.",
                        m.appended_records.get(),
                    ),
                    (
                        "ocpd_wal_appended_bytes_total",
                        "WAL framed bytes appended.",
                        m.appended_bytes.get(),
                    ),
                    (
                        "ocpd_wal_commit_batches_total",
                        "WAL group commits.",
                        m.commit_batches.get(),
                    ),
                    (
                        "ocpd_wal_commit_records_total",
                        "Records carried by group commits.",
                        m.commit_records.get(),
                    ),
                    (
                        "ocpd_wal_segments_sealed_total",
                        "WAL segments sealed.",
                        m.segments_sealed.get(),
                    ),
                    (
                        "ocpd_wal_flushed_records_total",
                        "WAL records drained to the database node.",
                        m.flushed_records.get(),
                    ),
                    (
                        "ocpd_wal_flushed_segments_total",
                        "WAL segments drained.",
                        m.flushed_segments.get(),
                    ),
                    (
                        "ocpd_wal_truncated_chunks_total",
                        "Torn WAL frames dropped.",
                        m.truncated_chunks.get(),
                    ),
                    (
                        "ocpd_wal_shipped_chunks_total",
                        "WAL chunks mirrored to follower logs.",
                        m.shipped_chunks.get(),
                    ),
                    (
                        "ocpd_wal_ship_errors_total",
                        "Failed WAL chunk ships (follower marked lagging).",
                        m.ship_errors.get(),
                    ),
                ] {
                    let pair = p();
                    out.push(Sample::counter(name, help, v).label(pair.0, pair.1));
                }
                let pair = p();
                out.push(
                    Sample::gauge(
                        "ocpd_wal_depth_records",
                        "Unflushed records currently in the log.",
                        m.depth.get(),
                    )
                    .label(pair.0, pair.1),
                );
                let pair = p();
                out.push(
                    Sample::gauge(
                        "ocpd_wal_depth_bytes",
                        "Unflushed framed bytes currently in the log.",
                        m.depth_bytes.get(),
                    )
                    .label(pair.0, pair.1),
                );
            }
        });
    }

    /// Register one replicated project's replica-set collector: epoch,
    /// lag, failover, and ship counters per shard.
    fn register_replication_metrics(&self, token: &str, sets: &[Arc<ReplicaSet>]) {
        let project = token.to_string();
        let sets: Vec<Arc<ReplicaSet>> = sets.to_vec();
        self.registry.register(format!("replication/{token}"), move |out| {
            for set in &sets {
                let st = set.status();
                let shard = st.shard.to_string();
                let labeled = |s: Sample| {
                    s.label("project", project.clone()).label("shard", shard.clone())
                };
                out.push(labeled(Sample::gauge(
                    "ocpd_replication_epoch",
                    "Current epoch of the shard's replica set.",
                    st.epoch,
                )));
                out.push(labeled(Sample::gauge(
                    "ocpd_replication_lag_records",
                    "Leader-to-slowest-replica lag, records.",
                    st.max_lag(),
                )));
                out.push(labeled(Sample::counter(
                    "ocpd_failovers_total",
                    "Leader promotions on this shard.",
                    st.failovers,
                )));
                out.push(labeled(Sample::counter(
                    "ocpd_replication_ships_total",
                    "Replication chunks shipped to followers.",
                    st.ships,
                )));
                out.push(labeled(Sample::counter(
                    "ocpd_replication_ship_errors_total",
                    "Failed follower ships (follower marked dead).",
                    st.ship_errors,
                )));
            }
        });
    }

    /// Register one image project's sharding collector: shard count,
    /// map generation, move/fence/dual-write counters (`ocpd_shard_*`).
    fn register_shard_metrics(&self, token: &str, eng: &Arc<ShardedEngine>) {
        let project = token.to_string();
        let eng = Arc::clone(eng);
        self.registry.register(format!("shards/{token}"), move |out| {
            let st = eng.topology_status();
            let labeled = |s: Sample| s.label("project", project.clone());
            out.push(labeled(Sample::gauge(
                "ocpd_shard_count",
                "Shards in the project's current map.",
                st.shards.len() as u64,
            )));
            out.push(labeled(Sample::gauge(
                "ocpd_shard_map_version",
                "Generation of the project's shard map.",
                st.version,
            )));
            out.push(labeled(Sample::gauge(
                "ocpd_shard_move_in_flight",
                "1 while a shard move's dual-route window is open.",
                u64::from(st.moving.is_some()),
            )));
            out.push(labeled(Sample::counter(
                "ocpd_shard_fence_retries_total",
                "Operations fenced by a topology swap and re-routed.",
                st.fence_retries,
            )));
            out.push(labeled(Sample::counter(
                "ocpd_shard_map_swaps_total",
                "Shard-map generations installed by splits/merges.",
                st.map_swaps,
            )));
            out.push(labeled(Sample::counter(
                "ocpd_shard_dual_writes_total",
                "Write rounds mirrored to a move's new owner.",
                st.dual_writes,
            )));
            out.push(labeled(Sample::counter(
                "ocpd_shard_keys_moved_total",
                "Keys rehomed by committed shard moves.",
                st.keys_moved,
            )));
        });
    }

    /// Register the global split-planner collector (`ocpd_balance_*`).
    fn register_balance_metrics(cluster: &Arc<Cluster>) {
        let weak = Arc::downgrade(cluster);
        cluster.registry.register("balance", move |out| {
            let Some(c) = weak.upgrade() else { return };
            let m = &c.balance.metrics;
            out.push(Sample::gauge(
                "ocpd_balance_auto",
                "1 while heat-driven auto splitting is enabled.",
                u64::from(c.auto_balance()),
            ));
            out.push(Sample::counter(
                "ocpd_balance_ticks_total",
                "Split-planner rounds run.",
                m.ticks.get(),
            ));
            out.push(Sample::counter(
                "ocpd_balance_splits_total",
                "Shard splits executed to completion.",
                m.splits.get(),
            ));
            out.push(Sample::counter(
                "ocpd_balance_skipped_total",
                "Split candidates passed over (unsplittable or failed).",
                m.skipped.get(),
            ));
        });
    }

    // ------------------------------------------------------------------
    // Cuboid caches
    // ------------------------------------------------------------------

    /// One project's cuboid cache, if it has one.
    pub fn cache(&self, token: &str) -> Option<Arc<CuboidCache>> {
        self.caches.read().unwrap().get(token).cloned()
    }

    /// Status of every project's cuboid cache, by token (the
    /// `/cache/status` route).
    pub fn cache_status(&self) -> Vec<(String, CacheStatus)> {
        let mut v: Vec<(String, CacheStatus)> = self
            .caches
            .read()
            .unwrap()
            .iter()
            .map(|(k, c)| (k.clone(), c.status()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    // ------------------------------------------------------------------
    // Write engine
    // ------------------------------------------------------------------

    /// One project's cutout service, whatever its type — the shared
    /// write-engine handle behind the `/write/...` surface.
    fn cutout_service(handle: &ProjectHandle) -> &CutoutService {
        match handle {
            ProjectHandle::Image(svc) => svc,
            ProjectHandle::Annotation(db) => &db.cutout,
        }
    }

    /// Status of every project's write engine, by token (the
    /// `GET /write/status/` route): configuration plus fan-out, elided
    /// vs RMW pre-read, and merge-latency counters.
    pub fn write_status(&self) -> Vec<(String, WriteStatus)> {
        let mut v: Vec<(String, WriteStatus)> = self
            .projects
            .read()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), Self::cutout_service(h).write_status()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Retune every project's write fan-out width — the live workers
    /// knob (`PUT /write/workers/{n}/`, `ocpd write --workers N`).
    /// Returns the number of projects updated.
    pub fn set_write_workers(&self, workers: usize) -> usize {
        let projects = self.projects.read().unwrap();
        for h in projects.values() {
            let svc = Self::cutout_service(h);
            let cfg = svc.write_config();
            svc.set_write_config(WriteConfig { workers: workers.max(1), ..cfg });
        }
        projects.len()
    }

    // ------------------------------------------------------------------
    // Workload telemetry: heat maps and tenant accounting
    // ------------------------------------------------------------------

    /// One project's heat tracker, if the project exists.
    pub fn heat(&self, token: &str) -> Option<Arc<HeatTracker>> {
        self.heats.read().unwrap().get(token).cloned()
    }

    /// Folded heat snapshots of every project, by token (the
    /// `GET /heat/status/` route and `ocpd heat`).
    pub fn heat_status(&self) -> Vec<(String, HeatSnapshot)> {
        let heats: Vec<(String, Arc<HeatTracker>)> = {
            let guard = self.heats.read().unwrap();
            guard.iter().map(|(k, h)| (k.clone(), Arc::clone(h))).collect()
        };
        let mut v: Vec<(String, HeatSnapshot)> =
            heats.into_iter().map(|(k, h)| (k, h.snapshot())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// The per-tenant accountant (admission-side request recording).
    pub fn accountant(&self) -> &Arc<Accountant> {
        &self.accountant
    }

    /// The QoS enforcer: admission token buckets, fair pool gates, and
    /// preemption (the `/qos/...` surface and `ocpd qos`).
    pub fn qos(&self) -> &Arc<QosEnforcer> {
        &self.qos
    }

    /// Ledger snapshots of every project, by token (the
    /// `GET /account/status/` route).
    pub fn account_status(&self) -> Vec<(String, LedgerSnapshot)> {
        self.accountant.snapshot()
    }

    /// Whether a project with this token exists (the admission-side
    /// guard that keeps unknown tokens from minting ledgers).
    pub fn has_project(&self, token: &str) -> bool {
        self.projects.read().unwrap().contains_key(token)
    }

    /// Register one project's heat collector: per-shard decayed scores
    /// plus the project total, all rounded to integral byte-equivalents.
    fn register_heat_metrics(&self, token: &str, heat: &Arc<HeatTracker>) {
        let project = token.to_string();
        let heat = Arc::clone(heat);
        self.registry.register(format!("heat/{token}"), move |out| {
            let snap = heat.snapshot();
            for sh in &snap.shards {
                let shard = sh.shard.to_string();
                let labeled = |s: Sample| {
                    s.label("project", project.clone()).label("shard", shard.clone())
                };
                for (name, help, v) in [
                    (
                        "ocpd_heat_shard_score",
                        "Decayed shard heat score, byte-equivalents.",
                        sh.score,
                    ),
                    (
                        "ocpd_heat_shard_read_bytes",
                        "Decayed read bytes attributed to the shard.",
                        sh.read_bytes,
                    ),
                    (
                        "ocpd_heat_shard_write_bytes",
                        "Decayed write bytes attributed to the shard.",
                        sh.write_bytes,
                    ),
                    (
                        "ocpd_heat_shard_ops",
                        "Decayed read+write ops attributed to the shard.",
                        sh.read_ops + sh.write_ops,
                    ),
                ] {
                    out.push(labeled(Sample::gauge(name, help, v.round() as u64)));
                }
            }
            out.push(
                Sample::gauge(
                    "ocpd_heat_total_score",
                    "Decayed whole-project heat score, byte-equivalents.",
                    snap.total_score.round() as u64,
                )
                .label("project", project.clone()),
            );
        });
    }

    /// Remove a project and every resource keyed by its token: WAL
    /// (flushed and retired first), cache, heat map, ledger, and all
    /// metrics collectors. A dropped project must vanish from
    /// `/metrics/` — stale collectors would freeze the exposition on
    /// retired handles.
    pub fn drop_project(&self, token: &str) -> Result<()> {
        // Take the write lock for check-and-remove so a racing create
        // of the same token can't interleave.
        let handle = self.projects.write().unwrap().remove(token);
        if handle.is_none() {
            return Err(Error::NotFound(format!("project '{token}'")));
        }
        if let Some(wal) = self.wals.write().unwrap().remove(token) {
            // Drain before retiring so nothing durable is stranded in
            // the log; a straggler append racing the shutdown gets an
            // error from the retired WAL rather than silent loss.
            wal.flush_now()?;
            wal.shutdown();
            wal.flush_now()?;
        }
        self.caches.write().unwrap().remove(token);
        self.heats.write().unwrap().remove(token);
        self.sharded.write().unwrap().remove(token);
        self.accountant.remove(token);
        self.qos.retire_tenant(token);
        self.control.unregister_sets(token);
        self.registry.unregister(&format!("project/{token}"));
        self.registry.unregister(&format!("replication/{token}"));
        self.registry.unregister(&format!("heat/{token}"));
        self.registry.unregister(&format!("shards/{token}"));
        Ok(())
    }

    /// Per-node I/O snapshots (the `ocpd info` CLI and benches).
    pub fn node_stats(&self) -> Vec<(String, crate::storage::IoSnapshot)> {
        self.nodes
            .iter()
            .map(|n| (n.name.clone(), n.engine.stats().snapshot()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::annotation::RamonObject;
    use crate::array::DenseVolume;
    use crate::core::{Box3, DatasetBuilder, WriteDiscipline};

    fn cluster() -> Arc<Cluster> {
        let c = Cluster::in_memory(2, 1);
        c.register_dataset(DatasetBuilder::new("ds", [256, 256, 32]).levels(2).build());
        c
    }

    #[test]
    fn image_project_sharded_across_db_nodes() {
        let c = cluster();
        let svc = c.create_image_project(Project::image("img", "ds")).unwrap();
        let whole = Box3::new([0, 0, 0], [256, 256, 32]);
        let mut v = DenseVolume::<u8>::zeros(whole.extent());
        v.fill_box(whole, 7);
        svc.write(0, 0, 0, whole, &v).unwrap();
        assert_eq!(svc.read::<u8>(0, 0, 0, whole).unwrap(), v);
        // Both database nodes hold data; the SSD node holds none.
        let stats = c.node_stats();
        assert!(stats[0].1.write_bytes > 0, "db0 idle");
        assert!(stats[1].1.write_bytes > 0, "db1 idle");
        assert_eq!(stats[2].1.write_bytes, 0, "ssd should be idle");
    }

    #[test]
    fn hot_annotation_lands_on_ssd() {
        let c = cluster();
        let db = c
            .create_annotation_project(Project::annotation("ann", "ds"), true)
            .unwrap();
        let bx = Box3::new([0, 0, 0], [16, 16, 4]);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), 5);
        db.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
        let stats = c.node_stats();
        assert!(stats[2].1.write_bytes > 0, "ssd idle");
        assert_eq!(stats[0].1.write_bytes + stats[1].1.write_bytes, 0, "db wrote");
    }

    #[test]
    fn cold_annotation_lands_on_db() {
        let c = cluster();
        let db = c
            .create_annotation_project(Project::annotation("cold", "ds"), false)
            .unwrap();
        db.put_object(RamonObject::new(0, crate::annotation::RamonType::Seed)).unwrap();
        let stats = c.node_stats();
        assert!(stats[0].1.write_bytes > 0);
        assert_eq!(stats[2].1.write_bytes, 0);
    }

    #[test]
    fn migration_moves_project_and_preserves_data() {
        let c = cluster();
        let db = c
            .create_annotation_project(Project::annotation("ann", "ds"), true)
            .unwrap();
        let bx = Box3::new([3, 5, 1], [40, 44, 9]);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), 9);
        db.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
        let id = db.put_object(RamonObject::synapse(9, 0.8, Default::default())).unwrap();
        assert_eq!(id, 9);

        let (new_db, moved) = c.migrate_annotation_project("ann").unwrap();
        assert!(moved >= 2, "expected cuboids + index + metadata moved, got {moved}");
        // All reads work against the database node now.
        assert_eq!(new_db.voxel_list(0, 9).unwrap().len() as u64, bx.volume());
        assert_eq!(new_db.get_object(9).unwrap().confidence, 0.8);
        // Handle rebound in the registry.
        let again = c.annotation("ann").unwrap();
        assert_eq!(again.voxel_list(0, 9).unwrap().len() as u64, bx.volume());
    }

    #[test]
    fn persistent_cluster_survives_reopen() {
        let dir = std::env::temp_dir().join(format!("ocpd-cluster-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ds = || DatasetBuilder::new("ds", [128, 128, 16]).levels(1).build();
        let bx = Box3::new([3, 5, 1], [40, 44, 9]);
        {
            let c = Cluster::persistent(&dir, 1, 1).unwrap();
            c.register_dataset(ds());
            let img = c.create_image_project(Project::image("img", "ds")).unwrap();
            let anno =
                c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
            let mut v = DenseVolume::<u8>::zeros(bx.extent());
            v.fill_box(Box3::new([0, 0, 0], bx.extent()), 9);
            img.write(0, 0, 0, bx, &v).unwrap();
            let mut a = DenseVolume::<u32>::zeros(bx.extent());
            a.fill_box(Box3::new([0, 0, 0], bx.extent()), 5);
            anno.write_volume(0, bx, &a, WriteDiscipline::Overwrite).unwrap();
            anno.put_object(RamonObject::synapse(5, 0.7, Default::default())).unwrap();
        }
        {
            let c = Cluster::persistent(&dir, 1, 1).unwrap();
            c.register_dataset(ds());
            let img = c.create_image_project(Project::image("img", "ds")).unwrap();
            let anno =
                c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
            assert_eq!(img.read::<u8>(0, 0, 0, bx).unwrap().count_eq(9), bx.volume());
            assert_eq!(anno.voxel_list(0, 5).unwrap().len() as u64, bx.volume());
            assert_eq!(anno.get_object(5).unwrap().confidence, 0.7);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hot_project_write_absorber_flushes_to_db() {
        let c = cluster();
        let db =
            c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
        assert!(c.wal("ann").is_some(), "hot project must have a log");
        let bx = Box3::new([0, 0, 0], [32, 32, 8]);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), 3);
        db.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();

        // Absorbed: log depth > 0, reads correct, database nodes idle.
        let st = c.wal_status().unwrap();
        assert_eq!(st.len(), 1);
        assert!(st[0].depth_records > 0);
        assert!(st[0].commit_batches > 0);
        assert_eq!(db.voxel_list(0, 3).unwrap().len() as u64, bx.volume());
        let before = c.node_stats();
        assert_eq!(before[0].1.write_bytes + before[1].1.write_bytes, 0, "db written early");

        // Flush through the cluster; data lands on db0, answers unchanged.
        let moved = c.flush_wal("ann").unwrap();
        assert!(moved >= 2, "expected cuboids + index records, got {moved}");
        let after = c.node_stats();
        assert!(after[0].1.write_bytes > 0, "flush must write the database node");
        assert_eq!(db.voxel_list(0, 3).unwrap().len() as u64, bx.volume());
        assert_eq!(c.wal_status().unwrap()[0].depth_records, 0);
        assert_eq!(c.flush_all_wals().unwrap(), 0, "nothing left to flush");
        assert!(c.flush_wal("nope").is_err());
        assert!(c.wal("img").is_none());
    }

    #[test]
    fn unknown_tokens_error() {
        let c = cluster();
        assert!(c.image("nope").is_err());
        assert!(c.annotation("nope").is_err());
        c.create_image_project(Project::image("img", "ds")).unwrap();
        assert!(c.annotation("img").is_err(), "type mismatch must error");
    }

    #[test]
    fn duplicate_and_reserved_tokens_rejected() {
        let c = cluster();
        c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
        // A second registration of the same token would open a second
        // Wal over the same chunk table — refuse it.
        assert!(c.create_annotation_project(Project::annotation("ann", "ds"), true).is_err());
        assert!(c.create_image_project(Project::image("ann", "ds")).is_err());
        // Reserved route names can never be project tokens.
        assert!(c.create_image_project(Project::image("info", "ds")).is_err());
        assert!(c.create_annotation_project(Project::annotation("wal", "ds"), false).is_err());
        assert!(c.create_image_project(Project::image("cache", "ds")).is_err());
        assert!(c.create_image_project(Project::image("jobs", "ds")).is_err());
        assert!(c.create_image_project(Project::image("write", "ds")).is_err());
        assert!(c.create_image_project(Project::image("http", "ds")).is_err());
        // The gate and the router share one list — every reserved route
        // name is covered, automatically.
        for token in crate::web::RESERVED {
            assert!(c.create_image_project(Project::image(token, "ds")).is_err());
        }
    }

    #[test]
    fn write_engine_status_and_cluster_wide_retune() {
        let c = cluster();
        c.create_image_project(Project::image("img", "ds")).unwrap();
        c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
        // Both project types surface a write engine, sorted by token.
        let st = c.write_status();
        assert_eq!(st.len(), 2);
        assert_eq!(st[0].0, "ann");
        assert_eq!(st[1].0, "img");
        // Retune applies to image and annotation services alike.
        assert_eq!(c.set_write_workers(3), 2);
        for (_, s) in c.write_status() {
            assert_eq!(s.workers, 3);
        }
        // A cuboid-aligned ingest write records its elided reads.
        let svc = c.image("img").unwrap();
        let bx = Box3::new([0, 0, 0], [256, 256, 32]);
        let mut v = DenseVolume::<u8>::zeros(bx.extent());
        v.fill_box(bx, 9);
        svc.write(0, 0, 0, bx, &v).unwrap();
        let st = c.write_status();
        assert!(st[1].1.elided_reads > 0, "aligned write must elide");
        assert_eq!(st[1].1.rmw_reads, 0);
    }

    #[test]
    fn cluster_runs_a_propagate_job() {
        use crate::jobs::{JobConfig, JobState, PropagateJob};
        let c = cluster();
        let db = c
            .create_annotation_project(Project::annotation("ann", "ds"), false)
            .unwrap();
        let bx = Box3::new([32, 32, 4], [96, 96, 12]);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), 42);
        db.write_volume(0, bx, &v, WriteDiscipline::Overwrite).unwrap();
        let spec = Arc::new(PropagateJob::annotation(Arc::clone(&db)));
        let h = c.jobs().submit(spec, JobConfig::with_workers(2)).unwrap();
        assert_eq!(h.wait(), JobState::Completed);
        // Level 1 holds the half-scale object.
        let out = db.cutout.read::<u32>(1, 0, 0, Box3::new([16, 16, 4], [48, 48, 12])).unwrap();
        assert_eq!(out.count_eq(42), 32 * 32 * 8);
        // The job is visible on the cluster's status surface.
        let st = c.jobs().statuses();
        assert_eq!(st.len(), 1);
        assert!(st[0].name.starts_with("propagate/ann"));
    }

    #[test]
    fn every_project_gets_a_cache_and_status_reports_it() {
        let c = cluster();
        c.create_image_project(Project::image("img", "ds")).unwrap();
        c.create_annotation_project(Project::annotation("ann", "ds"), true).unwrap();
        assert!(c.cache("img").is_some());
        assert!(c.cache("ann").is_some());
        assert!(c.cache("nope").is_none());
        // Warm the image cache and see counters move.
        let svc = c.image("img").unwrap();
        let bx = Box3::new([0, 0, 0], [256, 256, 32]);
        let mut v = DenseVolume::<u8>::zeros(bx.extent());
        v.fill_box(bx, 7);
        svc.write(0, 0, 0, bx, &v).unwrap();
        let _ = svc.read::<u8>(0, 0, 0, bx).unwrap();
        let _ = svc.read::<u8>(0, 0, 0, bx).unwrap();
        let st = c.cache_status();
        assert_eq!(st.len(), 2);
        assert_eq!(st[0].0, "ann");
        assert_eq!(st[1].0, "img");
        assert!(st[1].1.hits > 0, "second read must hit the cache");
        assert!(st[1].1.bytes > 0);
    }

    #[test]
    fn wal_flush_invalidates_cached_cuboids() {
        // Write → read (cache warm from the overlay) → flush → read:
        // the flush hook drops the cached entries, and the refetch from
        // the database node returns the same (fresh) data — no stale
        // hits, and invalidations are observable.
        let c = cluster();
        let db = c
            .create_annotation_project(Project::annotation("ann", "ds"), true)
            .unwrap();
        let bx = Box3::new([0, 0, 0], [64, 64, 16]);
        let mut v = DenseVolume::<u32>::zeros(bx.extent());
        v.fill_box(Box3::new([0, 0, 0], bx.extent()), 5);
        db.write_volume(0, bx, &v, crate::core::WriteDiscipline::Overwrite).unwrap();
        assert_eq!(db.cutout.read::<u32>(0, 0, 0, bx).unwrap(), v);
        let cache = c.cache("ann").unwrap();
        let before = cache.status();
        assert!(before.entries > 0, "overlay read must populate the cache");
        c.flush_wal("ann").unwrap();
        let after = cache.status();
        assert!(
            after.invalidations > before.invalidations,
            "flush must invalidate drained keys"
        );
        assert_eq!(db.cutout.read::<u32>(0, 0, 0, bx).unwrap(), v, "post-flush read fresh");
    }

    #[test]
    fn replicated_cluster_promotes_past_dead_leader() {
        let c = Cluster::with_config(ClusterConfig {
            n_database: 3,
            n_ssd: 1,
            replicas: 2,
            lease: Duration::ZERO,
            fault_seed: Some(7),
            ..ClusterConfig::default()
        });
        c.register_dataset(DatasetBuilder::new("ds", [256, 256, 32]).levels(2).build());
        let svc = c.create_image_project(Project::image("img", "ds")).unwrap();
        let whole = Box3::new([0, 0, 0], [256, 256, 32]);
        let mut v = DenseVolume::<u8>::zeros(whole.extent());
        v.fill_box(whole, 7);
        svc.write(0, 0, 0, whole, &v).unwrap();
        // Kill shard 0's leader; one control-plane tick promotes.
        let sets = c.control().sets_for("img");
        assert!(sets.iter().all(|s| s.num_members() == 2), "every shard replicated");
        let victim = sets[0].leader_node();
        c.fault(victim).unwrap().crash();
        let promoted = c.control().tick();
        assert!(promoted.iter().any(|r| r.from == victim), "dead leader not promoted away");
        assert_ne!(sets[0].leader_node(), victim);
        // Every acked write still reads back, through the new leader.
        assert_eq!(svc.read::<u8>(0, 0, 0, whole).unwrap(), v);
        // The status surface names the project; bad failover targets error.
        let status = c.cluster_status();
        assert!(status.contains("project img"), "{status}");
        assert!(c.failover("nope", 0).is_err());
        assert!(c.fault(victim).is_some());
    }

    #[test]
    fn dataset_registry() {
        let c = cluster();
        assert!(c.dataset("ds").is_ok());
        assert!(c.dataset("missing").is_err());
        assert!(c.create_image_project(Project::image("x", "missing")).is_err());
    }
}
