//! CATMAID-style tile service (§3.3).
//!
//! The paper stores a redundant 2-d tile stack for the image plane and
//! dynamically builds orthogonal-plane tiles from the cutout service. It
//! proposes — as future work — replacing stored tiles entirely with
//! cutout-backed tiles plus caching and cuboid-rounded prefetch; this
//! module implements that proposal:
//!
//! * tiles are cut from the cutout service on demand,
//! * an LRU cache holds recent tiles,
//! * a miss rounds the request up to the covering cuboids and
//!   materializes *all* tiles in that region ("round the request up to
//!   the next cuboid and materialize and cache all the nearby tiles").
//!
//! Tile keys follow the paper's restructured layout `r/z/y_x` (one
//! directory per viewing plane, §3.3).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::array::Plane;
use crate::cutout::CutoutService;
use crate::metrics::Counter;
use crate::Result;

/// Tile coordinates in the stored layout `r/z/y_x`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileKey {
    pub res: u32,
    pub z: u64,
    pub y: u64,
    pub x: u64,
}

impl TileKey {
    /// The paper's restructured path: `r/z/y_x.png` — one directory per
    /// viewing plane (§3.3).
    pub fn path(&self) -> String {
        format!("{}/{}/{}_{}.gray", self.res, self.z, self.y, self.x)
    }

    /// Parse the legacy CATMAID layout `z/y_x_r.png` (§3.3 describes
    /// rewriting these URLs).
    pub fn from_legacy(path: &str) -> Option<TileKey> {
        let mut parts = path.trim_end_matches(".png").split('/');
        let z = parts.next()?.parse().ok()?;
        let rest = parts.next()?;
        let mut seg = rest.split('_');
        let y = seg.next()?.parse().ok()?;
        let x = seg.next()?.parse().ok()?;
        let res = seg.next()?.parse().ok()?;
        Some(TileKey { res, z, y, x })
    }
}

/// Cutout-backed tile server with LRU cache and cuboid prefetch.
pub struct TileService {
    svc: std::sync::Arc<CutoutService>,
    tile_size: u64,
    cache: Mutex<LruCache>,
    pub hits: Counter,
    pub misses: Counter,
}

struct LruCache {
    cap: usize,
    // key -> (stamp, tile); tiles are Arc-shared so a cache hit answers
    // a request without copying the 64 KiB payload.
    map: HashMap<TileKey, (u64, Arc<Vec<u8>>)>,
    clock: u64,
}

impl LruCache {
    fn get(&mut self, k: &TileKey) -> Option<Arc<Vec<u8>>> {
        self.clock += 1;
        let clock = self.clock;
        self.map.get_mut(k).map(|(stamp, v)| {
            *stamp = clock;
            Arc::clone(v)
        })
    }

    fn put(&mut self, k: TileKey, v: Arc<Vec<u8>>) {
        self.clock += 1;
        if self.map.len() >= self.cap && !self.map.contains_key(&k) {
            // Evict the oldest entry.
            if let Some((&old, _)) =
                self.map.iter().min_by_key(|(_, (stamp, _))| *stamp)
            {
                self.map.remove(&old);
            }
        }
        self.map.insert(k, (self.clock, v));
    }
}

impl TileService {
    pub fn new(svc: std::sync::Arc<CutoutService>, tile_size: u64, cache_tiles: usize) -> Self {
        TileService {
            svc,
            tile_size,
            cache: Mutex::new(LruCache { cap: cache_tiles.max(1), map: HashMap::new(), clock: 0 }),
            hits: Counter::default(),
            misses: Counter::default(),
        }
    }

    pub fn tile_size(&self) -> u64 {
        self.tile_size
    }

    /// Fetch one XY tile (row-major u8 grayscale, `tile_size^2` bytes,
    /// zero-padded at volume edges). On a cache miss the covering
    /// cuboid-aligned region is materialized and all its tiles cached.
    pub fn get_tile(&self, key: TileKey) -> Result<Vec<u8>> {
        Ok((*self.get_tile_shared(key)?).clone())
    }

    /// [`get_tile`](Self::get_tile) without the copy: the returned
    /// `Arc` shares the cache's buffer, so the web tier can put a
    /// cached tile on the wire zero-copy.
    pub fn get_tile_shared(&self, key: TileKey) -> Result<Arc<Vec<u8>>> {
        if let Some(t) = self.cache.lock().unwrap().get(&key) {
            self.hits.inc();
            // A tile-cache hit never reaches the cuboid store, so feed
            // the heat map here — heat must see the access either way
            // (DESIGN.md §11). Attribute it to the covering cuboid.
            if let Some(heat) = self.svc.store().heat() {
                if let Ok(cshape) = self.svc.store().cuboid_shape(key.res) {
                    let code = crate::morton::encode3(
                        key.x * self.tile_size / cshape[0].max(1),
                        key.y * self.tile_size / cshape[1].max(1),
                        key.z / cshape[2].max(1),
                    );
                    heat.record_read(code, t.len() as u64);
                }
            }
            return Ok(t);
        }
        self.misses.inc();
        self.prefetch_region(key)?;
        Ok(self
            .cache
            .lock()
            .unwrap()
            .get(&key)
            .expect("prefetch populated requested tile"))
    }

    /// Materialize every tile overlapping the cuboid-aligned region
    /// around `key`, caching each (the §3.3 future-work prefetcher).
    fn prefetch_region(&self, key: TileKey) -> Result<()> {
        let ts = self.tile_size;
        let level = self.svc.store().dataset.level(key.res)?.clone();
        let cshape = level.cuboid;
        let dims = level.dims;

        // Requested tile box, rounded out to cuboids, clipped to volume.
        let tile_lo = [key.x * ts, key.y * ts, key.z];
        let want = crate::core::Box3::new(
            tile_lo,
            [
                (tile_lo[0] + ts).min(dims[0].max(tile_lo[0] + 1)),
                (tile_lo[1] + ts).min(dims[1].max(tile_lo[1] + 1)),
                key.z + 1,
            ],
        );
        let rounded = want.align_outward(cshape).intersect(&level.bounds());

        // One cutout for the whole rounded slab.
        let region = if rounded.is_empty() { want.intersect(&level.bounds()) } else { rounded };
        let vol = if region.is_empty() {
            None
        } else {
            Some((region, self.svc.read::<u8>(key.res, 0, 0, region)?))
        };

        // Slice every covered tile out of the slab.
        let t_lo = [region.lo[0] / ts, region.lo[1] / ts];
        let t_hi = [region.hi[0].div_ceil(ts), region.hi[1].div_ceil(ts)];
        let mut cache = self.cache.lock().unwrap();
        let mut requested: Option<Arc<Vec<u8>>> = None;
        for ty in t_lo[1]..t_hi[1].max(t_lo[1] + 1) {
            for tx in t_lo[0]..t_hi[0].max(t_lo[0] + 1) {
                let k = TileKey { res: key.res, z: key.z, y: ty, x: tx };
                let mut tile = vec![0u8; (ts * ts) as usize];
                if let Some((region, vol)) = &vol {
                    for py in 0..ts {
                        let gy = ty * ts + py;
                        if gy < region.lo[1] || gy >= region.hi[1] {
                            continue;
                        }
                        for px in 0..ts {
                            let gx = tx * ts + px;
                            if gx < region.lo[0] || gx >= region.hi[0] {
                                continue;
                            }
                            tile[(px + py * ts) as usize] = vol.get([
                                gx - region.lo[0],
                                gy - region.lo[1],
                                key.z - region.lo[2],
                            ]);
                        }
                    }
                }
                let tile = Arc::new(tile);
                if k == key {
                    requested = Some(Arc::clone(&tile));
                }
                cache.put(k, tile);
            }
        }
        // Ensure the requested tile survives its own prefetch: when the
        // cache capacity is smaller than a prefetch block, later inserts
        // can evict it — re-insert the real content rather than let the
        // caller see zeros. Outside volume bounds it is genuinely zero.
        if !cache.map.contains_key(&key) {
            cache.put(
                key,
                requested.unwrap_or_else(|| Arc::new(vec![0u8; (ts * ts) as usize])),
            );
        }
        Ok(())
    }

    /// Orthogonal-plane tile (XZ or YZ) built dynamically from the cutout
    /// service — never cached in the paper's design either (most viewing
    /// happens in the image plane).
    pub fn get_ortho_tile(&self, res: u32, plane: Plane, u0: u64, v0: u64) -> Result<Vec<u8>> {
        let ts = self.tile_size;
        let level = self.svc.store().dataset.level(res)?.clone();
        let (we, he) = match plane {
            Plane::Xy(_) => (level.dims[0], level.dims[1]),
            Plane::Xz(_) => (level.dims[0], level.dims[2]),
            Plane::Yz(_) => (level.dims[1], level.dims[2]),
        };
        let lo = [(u0 * ts).min(we), (v0 * ts).min(he)];
        let hi = [((u0 + 1) * ts).min(we), ((v0 + 1) * ts).min(he)];
        let mut tile = vec![0u8; (ts * ts) as usize];
        if lo[0] < hi[0] && lo[1] < hi[1] {
            let (w, _h, data) = self.svc.read_plane::<u8>(res, 0, 0, plane, lo, hi)?;
            for py in 0..hi[1] - lo[1] {
                for px in 0..hi[0] - lo[0] {
                    tile[(px + py * ts) as usize] = data[(px + py * w) as usize];
                }
            }
        }
        Ok(tile)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunkstore::CuboidStore;
    use crate::core::{Box3, DatasetBuilder, Project};
    use crate::storage::MemStore;
    use std::sync::Arc;

    fn service() -> Arc<CutoutService> {
        let ds = Arc::new(DatasetBuilder::new("t", [256, 256, 32]).levels(1).build());
        let pr = Arc::new(Project::image("img", "t"));
        let svc = Arc::new(CutoutService::new(Arc::new(CuboidStore::new(
            ds,
            pr,
            Arc::new(MemStore::new()),
        ))));
        // Position-hash image.
        let whole = Box3::new([0, 0, 0], [256, 256, 32]);
        let mut v = crate::array::DenseVolume::<u8>::zeros(whole.extent());
        for z in 0..32u64 {
            for y in 0..256u64 {
                for x in 0..256u64 {
                    v.set([x, y, z], ((x * 7 + y * 13 + z * 31) % 251) as u8);
                }
            }
        }
        svc.write(0, 0, 0, whole, &v).unwrap();
        svc
    }

    #[test]
    fn tile_content_matches_volume() {
        let ts = TileService::new(service(), 64, 128);
        let tile = ts.get_tile(TileKey { res: 0, z: 3, y: 1, x: 2 }).unwrap();
        // Global (x=128..192, y=64..128) at z=3.
        for py in 0..64u64 {
            for px in 0..64u64 {
                let expect = (((128 + px) * 7 + (64 + py) * 13 + 3 * 31) % 251) as u8;
                assert_eq!(tile[(px + py * 64) as usize], expect, "at ({px},{py})");
            }
        }
    }

    #[test]
    fn prefetch_warms_neighbours() {
        let ts = TileService::new(service(), 64, 128);
        ts.get_tile(TileKey { res: 0, z: 0, y: 0, x: 0 }).unwrap();
        assert_eq!(ts.misses.get(), 1);
        // Neighbour within the same cuboid span is already cached.
        ts.get_tile(TileKey { res: 0, z: 0, y: 1, x: 1 }).unwrap();
        assert_eq!(ts.hits.get(), 1);
        assert_eq!(ts.misses.get(), 1);
    }

    #[test]
    fn edge_tiles_zero_padded() {
        let ts = TileService::new(service(), 100, 64);
        // Tile starting at x=200: valid to 256, padded beyond.
        let tile = ts.get_tile(TileKey { res: 0, z: 0, y: 0, x: 2 }).unwrap();
        assert_eq!(tile.len(), 100 * 100);
        let expect = ((200 * 7) % 251) as u8;
        assert_eq!(tile[0], expect);
        assert_eq!(tile[99], 0, "beyond volume must be zero");
    }

    #[test]
    fn miss_materializes_every_tile_in_the_covering_cuboid_region() {
        // The prefetch contract: one miss rounds the request up to the
        // covering cuboids ([128,128,16] at this dataset's level 0) and
        // caches ALL tiles of that region — here 64-px tiles over a
        // 128x128 cuboid footprint, i.e. the full 2x2 tile block.
        let ts = TileService::new(service(), 64, 128);
        ts.get_tile(TileKey { res: 0, z: 3, y: 1, x: 0 }).unwrap();
        assert_eq!(ts.misses.get(), 1);
        {
            let cache = ts.cache.lock().unwrap();
            for ty in 0..2u64 {
                for tx in 0..2u64 {
                    let k = TileKey { res: 0, z: 3, y: ty, x: tx };
                    assert!(cache.map.contains_key(&k), "tile {k:?} not prefetched");
                }
            }
            // Nothing outside the covering region (other z-sections or
            // the neighbouring cuboid column) was speculatively built.
            assert!(!cache.map.contains_key(&TileKey { res: 0, z: 4, y: 0, x: 0 }));
            assert!(!cache.map.contains_key(&TileKey { res: 0, z: 3, y: 0, x: 2 }));
        }
        // Every tile of the region is now a hit, with no further misses.
        for ty in 0..2u64 {
            for tx in 0..2u64 {
                ts.get_tile(TileKey { res: 0, z: 3, y: ty, x: tx }).unwrap();
            }
        }
        assert_eq!(ts.misses.get(), 1, "prefetched tiles must not miss");
        assert_eq!(ts.hits.get(), 4);
    }

    #[test]
    fn lru_evicts() {
        let ts = TileService::new(service(), 64, 2);
        for x in 0..4 {
            ts.get_tile(TileKey { res: 0, z: 0, y: 0, x }).unwrap();
        }
        let cache_len = ts.cache.lock().unwrap().map.len();
        assert!(cache_len <= 2);
    }

    #[test]
    fn lru_eviction_respects_capacity_and_recency() {
        // Capacity is respected across many prefetch-heavy misses (each
        // miss inserts a 2x2 tile block, more than the per-put slack)...
        let ts = TileService::new(service(), 64, 6);
        for z in 0..8u64 {
            for x in 0..4u64 {
                ts.get_tile(TileKey { res: 0, z, y: 0, x }).unwrap();
            }
            assert!(
                ts.cache.lock().unwrap().map.len() <= 6,
                "capacity exceeded at z={z}"
            );
        }
        // ...and the most-recently-used tile survives a miss that
        // prefetches (and therefore evicts) a whole 4-tile block.
        let hot = TileKey { res: 0, z: 7, y: 0, x: 3 };
        ts.get_tile(hot).unwrap(); // touch: newest stamp
        let hits_before = ts.hits.get();
        ts.get_tile(TileKey { res: 0, z: 0, y: 1, x: 0 }).unwrap();
        ts.get_tile(hot).unwrap();
        assert!(ts.hits.get() >= hits_before + 1, "hot tile must survive eviction");
        assert!(ts.cache.lock().unwrap().map.len() <= 6);
    }

    #[test]
    fn tiny_cache_still_returns_real_tile_content() {
        // Capacity 1: the prefetch block evicts everything, including
        // the requested tile mid-prefetch; get_tile must still answer
        // with real data, not the zero placeholder.
        let ts = TileService::new(service(), 64, 1);
        let tile = ts.get_tile(TileKey { res: 0, z: 3, y: 1, x: 2 }).unwrap();
        let expect = ((128 * 7 + 64 * 13 + 3 * 31) % 251) as u8;
        assert_eq!(tile[0], expect, "evicted-during-prefetch tile must keep its data");
    }

    #[test]
    fn ortho_tiles_match() {
        let ts = TileService::new(service(), 32, 16);
        let tile = ts.get_ortho_tile(0, Plane::Xz(5), 0, 0).unwrap();
        // (x=0..32, z=0..32 clipped to 32); row py = z.
        let expect = ((3 * 7 + 5 * 13 + 2 * 31) % 251) as u8;
        assert_eq!(tile[3 + 2 * 32], expect);
    }

    #[test]
    fn legacy_path_parse_and_new_layout() {
        let k = TileKey::from_legacy("12/34_56_2.png").unwrap();
        assert_eq!(k, TileKey { res: 2, z: 12, y: 34, x: 56 });
        assert_eq!(k.path(), "2/12/34_56.gray");
        assert!(TileKey::from_legacy("garbage").is_none());
    }
}
