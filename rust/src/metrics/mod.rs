//! Lightweight metrics: counters, latency histograms, and throughput
//! reporting used by the web server, the vision pipeline, and every
//! bench harness.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A settable level (WAL log depth, flush lag, queue lengths) — unlike
/// [`Counter`] it can go down.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Saturating increment — the mirror of [`Gauge::sub`], so a gauge
    /// pinned at the top of its range clamps instead of wrapping.
    pub fn add(&self, n: u64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_add(n))
        });
    }

    /// Saturating decrement.
    pub fn sub(&self, n: u64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(n))
        });
    }

    /// Raise the gauge to `v` if it is below it — a high-water mark
    /// (peak in-flight requests, largest streamed chunk).
    pub fn record_max(&self, v: u64) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| (cur < v).then_some(v));
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed histogram (2x buckets). Records either latencies
/// ([`Histogram::record`], microseconds) or plain values
/// ([`Histogram::record_value`] — e.g. the cutout engine's fan-out
/// width); the bucketing is the same.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..32).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        (64 - us.max(1).leading_zeros() as usize - 1).min(31)
    }

    pub fn record(&self, d: Duration) {
        self.record_value(d.as_micros() as u64);
    }

    /// Record a dimensionless value (fan-out widths, batch sizes); shares
    /// the log-bucket layout with latency recording.
    pub fn record_value(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate percentile (upper edge of the bucket containing it).
    ///
    /// Bucket `i` holds values in `[2^i, 2^(i+1) - 1]` (bucket 0 holds
    /// `{0, 1}`), so the true upper edge is `2^(i+1) - 1` — and `1` for
    /// bucket 0, not the `2` an off-by-one shift would report.
    pub fn percentile_us(&self, p: f64) -> u64 {
        self.snapshot().percentile(p)
    }

    /// One consistent pass over the buckets: count, sum, and the full
    /// bucket array loaded once, so status handlers derive count, mean,
    /// and any percentile from a single view instead of racing four
    /// separate atomic loads.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = [0u64; 32];
        for (b, a) in buckets.iter_mut().zip(&self.buckets) {
            *b = a.load(Ordering::Relaxed);
        }
        // Derive the count from the buckets themselves so count and
        // bucket sums agree even mid-record; `sum` stays best-effort.
        HistogramSnapshot {
            count: buckets.iter().sum(),
            sum: self.sum_us.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Point-in-time view of a [`Histogram`]: the bucket array plus the
/// totals, captured in one pass.
#[derive(Clone, Copy, Debug)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; 32],
}

impl HistogramSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper edge of the bucket containing percentile `p` (see
    /// [`Histogram::percentile_us`] for the edge semantics).
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return if i == 0 { 1 } else { (1u64 << (i + 1)) - 1 };
            }
        }
        u64::MAX
    }

    /// Upper edge of log-bucket `i` — the `le` bound Prometheus
    /// exposition uses for the cumulative bucket series.
    pub fn bucket_edge(i: usize) -> u64 {
        if i == 0 {
            1
        } else {
            (1u64 << (i + 1)) - 1
        }
    }
}

/// Wall-clock throughput helper for benches: bytes (or items) over a
/// timed region.
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// MB/s for `bytes` moved since construction.
    pub fn mbps(&self, bytes: u64) -> f64 {
        bytes as f64 / 1e6 / self.elapsed().as_secs_f64().max(1e-9)
    }

    /// Items/s for `n` items since construction.
    pub fn per_sec(&self, n: u64) -> f64 {
        n as f64 / self.elapsed().as_secs_f64().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(9);
        assert_eq!(c.get(), 10);
    }

    #[test]
    fn gauge_moves_both_ways() {
        let g = Gauge::default();
        g.set(10);
        g.add(5);
        g.sub(3);
        assert_eq!(g.get(), 12);
        g.sub(100); // saturates at zero
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn gauge_saturates_at_both_ends() {
        let g = Gauge::default();
        g.set(u64::MAX);
        g.add(1); // saturates at the top instead of wrapping to 0
        assert_eq!(g.get(), u64::MAX);
        g.sub(1);
        assert_eq!(g.get(), u64::MAX - 1);
        g.add(5); // round-trips back to the boundary
        assert_eq!(g.get(), u64::MAX);
    }

    #[test]
    fn gauge_high_water_mark() {
        let g = Gauge::default();
        g.record_max(7);
        g.record_max(3); // below the mark: no change
        assert_eq!(g.get(), 7);
        g.record_max(20);
        assert_eq!(g.get(), 20);
    }

    #[test]
    fn histogram_buckets() {
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(1024), 10);
        let h = Histogram::new();
        for us in [10u64, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_us() - 2777.5).abs() < 1.0);
        assert!(h.percentile_us(50.0) <= 256);
        assert!(h.percentile_us(100.0) >= 8192);
    }

    #[test]
    fn percentile_reports_true_upper_edge() {
        let h = Histogram::new();
        h.record_value(0);
        h.record_value(1);
        // Both land in bucket 0, whose upper edge is 1 — not the 2 the
        // old off-by-one shift reported.
        assert_eq!(h.percentile_us(50.0), 1);
        assert_eq!(h.percentile_us(100.0), 1);
        h.record_value(100); // bucket 6: [64, 127]
        assert_eq!(h.percentile_us(100.0), 127);
    }

    #[test]
    fn snapshot_one_pass_view() {
        let h = Histogram::new();
        for us in [10u64, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert!((s.mean() - 2777.5).abs() < 1.0);
        assert_eq!(s.percentile(50.0), h.percentile_us(50.0));
        assert_eq!(s.percentile(99.0), h.percentile_us(99.0));
        assert_eq!(HistogramSnapshot::bucket_edge(0), 1);
        assert_eq!(HistogramSnapshot::bucket_edge(3), 15);
    }

    #[test]
    fn prop_percentile_matches_sorted_reference() {
        use crate::util::prop::property;
        // percentile(p) must report the upper edge of the bucket
        // holding the ceil(p% · n)-th smallest recorded value — checked
        // against a sorted reference over random value sets.
        property("percentile_vs_sorted_reference", 200, |g| {
            let n = 1 + g.usize_below(256);
            let values = g.vec_u64(n, 1 << 24);
            let h = Histogram::new();
            for &v in &values {
                h.record_value(v);
            }
            let mut sorted = values;
            sorted.sort_unstable();
            for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
                let target = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
                let reference = sorted[target.min(n) - 1];
                let expected =
                    HistogramSnapshot::bucket_edge(Histogram::bucket_of(reference.max(1)));
                assert_eq!(
                    h.percentile_us(p),
                    expected,
                    "p={p} n={n} reference={reference}"
                );
            }
        });
    }

    #[test]
    fn stopwatch_rates() {
        let sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(10));
        let mbps = sw.mbps(10_000_000);
        assert!(mbps > 1.0 && mbps < 1100.0, "{mbps}");
        assert!(sw.per_sec(100) > 10.0);
    }
}
